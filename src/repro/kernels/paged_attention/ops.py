"""Jitted wrapper for the paged decode attention kernel.

``paged_decode_attention_op`` takes the full FlowKV pool and a layer index,
slices that layer's contiguous page plane, and runs the kernel. On TPU the
call compiles to a Mosaic kernel; on this CPU container ``interpret=True``
executes the same kernel body for correctness (tests sweep shapes/dtypes
against ``ref.py``).

The batched zero-gather decode step (``models/transformer.decode_step_paged``)
calls the unjitted kernel directly inside its own jit — one compiled artifact
covers the whole layer stack plus the fused KV append.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.paged_attention import paged_decode_attention


@functools.partial(jax.jit, static_argnames=("block_size", "interpret",
                                             "return_stats"))
def paged_decode_attention_op(q: jax.Array, pool: jax.Array, layer,
                              block_tables: jax.Array, lengths: jax.Array,
                              *, block_size: int, interpret: bool = True,
                              return_stats: bool = False):
    """q (B,H,hd); pool (nb, L, 2, payload) FlowKV layout; layer scalar."""
    pages = jax.lax.dynamic_index_in_dim(pool, layer, axis=1, keepdims=False)
    return paged_decode_attention(q, pages, block_tables, lengths,
                                  block_size=block_size, interpret=interpret,
                                  return_stats=return_stats)
