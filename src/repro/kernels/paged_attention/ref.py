"""Pure-jnp oracle for the FlowKV-layout paged decode attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_decode_attention_ref(q: jax.Array, pages: jax.Array,
                               block_tables: jax.Array, lengths: jax.Array,
                               block_size: int) -> jax.Array:
    """Reference paged decode attention.

    q:            (B, H, hd)        — one query token per sequence
    pages:        (nb, 2, payload)  — ONE layer's slice of the FlowKV pool,
                                      payload = block_size * KV * hd
    block_tables: (B, maxb) int32   — physical block ids per sequence
    lengths:      (B,) int32        — valid tokens per sequence
    returns:      (B, H, hd)
    """
    b, h, hd = q.shape
    maxb = block_tables.shape[1]
    payload = pages.shape[-1]
    kv = payload // (block_size * hd)
    g = h // kv

    # gather pages -> dense (B, maxb*bs, KV, hd)
    gathered = jnp.take(pages, block_tables.reshape(-1), axis=0)
    gathered = gathered.reshape(b, maxb, 2, block_size, kv, hd)
    k = gathered[:, :, 0].reshape(b, maxb * block_size, kv, hd)
    v = gathered[:, :, 1].reshape(b, maxb * block_size, kv, hd)

    qg = q.reshape(b, kv, g, hd)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd)
    t = maxb * block_size
    valid = jnp.arange(t)[None, :] < lengths[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, jnp.finfo(jnp.float32).min)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", w.astype(v.dtype), v)
    return out.reshape(b, h, hd)


def paged_decode_attention_stats_ref(q: jax.Array, pages: jax.Array,
                                     block_tables: jax.Array, lengths: jax.Array,
                                     block_size: int):
    """Oracle for ``return_stats=True``: (out, m, l) with fp32 softmax state.

    ``m`` is the running max score, ``l`` the normalizer, per (B, KV, G) —
    the same quantities the kernel keeps in VMEM scratch.
    """
    b, h, hd = q.shape
    payload = pages.shape[-1]
    kv = payload // (block_size * hd)
    g = h // kv
    maxb = block_tables.shape[1]

    gathered = jnp.take(pages, block_tables.reshape(-1), axis=0)
    gathered = gathered.reshape(b, maxb, 2, block_size, kv, hd)
    k = gathered[:, :, 0].reshape(b, maxb * block_size, kv, hd)
    v = gathered[:, :, 1].reshape(b, maxb * block_size, kv, hd)

    qg = q.reshape(b, kv, g, hd)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd)
    t = maxb * block_size
    valid = jnp.arange(t)[None, :] < lengths[:, None]
    neg = jnp.finfo(jnp.float32).min
    scores = jnp.where(valid[:, None, None, :], scores, neg)
    m = jnp.max(scores, axis=-1)
    p = jnp.where(valid[:, None, None, :], jnp.exp(scores - m[..., None]), 0.0)
    l = p.sum(axis=-1)
    # masked weights (not a raw softmax) so a fully-masked row (length 0)
    # yields out = 0, matching the kernel's init state
    out = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    out = out / jnp.maximum(l, 1e-30)[..., None]
    m = jnp.where(lengths[:, None, None] > 0, m, neg)
    return out.reshape(b, h, hd).astype(q.dtype), m, l
