"""Pallas TPU kernel: decode attention over FlowKV block-major pages.

This is the paper's "targeted optimizations ... for the PagedAttention
kernel" (§3.3) adapted to TPU: the pool layout is block-major
``(nb, L, 2, payload)`` (Eq. 5), so the kernel for one layer receives the
contiguous slice ``pages = pool[:, layer]`` of shape ``(nb, 2, payload)``
and *one DMA per page* stages a block's K AND V for this layer into VMEM —
no per-(layer, k/v) descriptors, mirroring the transfer-path win.

Grid: ``(B, max_blocks)`` — the page dim iterates sequentially (TPU minor
grid dim), maintaining an online-softmax accumulator in VMEM scratch per
sequence. Page indirection uses scalar-prefetched block tables in the
BlockSpec index_map, so the pipeline prefetches page ``i+1`` while page
``i`` is being processed (the TPU analogue of overlapping transfer kernels
with compute).

Tiling: payload = block_size * KV * hd. With the default 32-token blocks and
128-wide head_dim every MXU operand is lane-aligned (hd multiple of 128 for
most archs; 64/160/256 variants still vector-friendly).

``return_stats=True`` additionally emits the per-(kv-head, group) online
softmax state ``(m, l)`` so callers can merge EXTRA keys exactly — the
zero-gather decode step uses this to fold in the in-flight token (whose K/V
is not in the pool yet) without densifying any cached page.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float(jnp.finfo(jnp.float32).min)


def _kernel(block_tables_ref, lengths_ref,     # scalar prefetch
            q_ref, pages_ref,                  # VMEM inputs
            *refs,                             # VMEM outputs + scratch
            block_size: int, num_kv: int, head_dim: int, return_stats: bool):
    if return_stats:
        o_ref, m_out_ref, l_out_ref, m_ref, l_ref, acc_ref = refs
    else:
        o_ref, m_ref, l_ref, acc_ref = refs
    b = pl.program_id(0)
    i = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]
    start = i * block_size

    @pl.when(start < length)
    def _process():
        q = q_ref[0]                                   # (H, hd)
        h = q.shape[0]
        g = h // num_kv
        page = pages_ref[0]                            # (2, payload)
        k = page[0].reshape(block_size, num_kv, head_dim)
        v = page[1].reshape(block_size, num_kv, head_dim)
        qg = q.reshape(num_kv, g, head_dim)
        s = jax.lax.dot_general(
            qg.astype(jnp.float32), k.astype(jnp.float32),
            (((2,), (2,)), ((0,), (1,))),
        )                                              # (KV, G, bs)
        s = s / jnp.sqrt(jnp.asarray(head_dim, jnp.float32))
        pos = start + jax.lax.broadcasted_iota(jnp.int32, (1, 1, block_size), 2)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_ref[...]                            # (KV, G)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(pos < length, p, 0.0)
        scale = jnp.exp(m_prev - m_new)
        l_new = l_prev * scale + p.sum(axis=-1)
        pv = jax.lax.dot_general(
            p, v.astype(jnp.float32),
            (((2,), (0,)), ((0,), (1,))),
        )                                              # (KV, G, hd)
        acc_ref[...] = acc_ref[...] * scale[..., None] + pv
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(i == nb - 1)
    def _finalize():
        h = q_ref.shape[1]
        denom = jnp.maximum(l_ref[...], 1e-30)[..., None]
        out = (acc_ref[...] / denom).reshape(h, head_dim)
        o_ref[0] = out.astype(o_ref.dtype)
        if return_stats:
            m_out_ref[0] = m_ref[...]
            l_out_ref[0] = l_ref[...]


def paged_decode_attention(q: jax.Array, pages: jax.Array,
                           block_tables: jax.Array, lengths: jax.Array,
                           *, block_size: int, interpret: bool = True,
                           return_stats: bool = False):
    """q (B,H,hd); pages (nb,2,payload); block_tables (B,maxb); lengths (B,).

    Returns ``out (B,H,hd)``; with ``return_stats=True`` returns
    ``(out, m, l)`` where ``m``/``l`` are the fp32 online-softmax max and
    normalizer per (B, KV, G) — ``out * l`` recovers the unnormalized
    accumulator for exact merging with additional keys.
    """
    b, h, hd = q.shape
    maxb = block_tables.shape[1]
    payload = pages.shape[-1]
    num_kv = payload // (block_size * hd)
    g = h // num_kv

    out_specs = [pl.BlockSpec((1, h, hd), lambda bb, i, bt, ln: (bb, 0, 0))]
    out_shapes = [jax.ShapeDtypeStruct((b, h, hd), q.dtype)]
    if return_stats:
        out_specs += [pl.BlockSpec((1, num_kv, g),
                                   lambda bb, i, bt, ln: (bb, 0, 0))] * 2
        out_shapes += [jax.ShapeDtypeStruct((b, num_kv, g), jnp.float32)] * 2

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, maxb),
        in_specs=[
            pl.BlockSpec((1, h, hd), lambda bb, i, bt, ln: (bb, 0, 0)),
            pl.BlockSpec((1, 2, payload),
                         lambda bb, i, bt, ln: (bt[bb, i], 0, 0)),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((num_kv, g), jnp.float32),
            pltpu.VMEM((num_kv, g), jnp.float32),
            pltpu.VMEM((num_kv, g, hd), jnp.float32),
        ],
    )
    kernel = functools.partial(_kernel, block_size=block_size,
                               num_kv=num_kv, head_dim=hd,
                               return_stats=return_stats)
    outs = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        interpret=interpret,
    )(block_tables, lengths, q, pages)
    if return_stats:
        return outs[0], outs[1], outs[2]
    return outs[0]
