from repro.kernels.paged_attention.ops import paged_decode_attention_op
from repro.kernels.paged_attention.paged_attention import paged_decode_attention
from repro.kernels.paged_attention.ref import (paged_decode_attention_ref,
                                               paged_decode_attention_stats_ref)

__all__ = ["paged_decode_attention", "paged_decode_attention_op",
           "paged_decode_attention_ref", "paged_decode_attention_stats_ref"]
