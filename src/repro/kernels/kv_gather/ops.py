"""Jitted wrapper for the KV gather kernel + the scatter inverse."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.kv_gather.kv_gather import kv_gather


@functools.partial(jax.jit, static_argnames=("interpret",))
def kv_gather_op(pool: jax.Array, block_ids: jax.Array, *,
                 interpret: bool = True) -> jax.Array:
    return kv_gather(pool, block_ids.astype(jnp.int32), interpret=interpret)


@jax.jit
def kv_scatter_op(pool: jax.Array, block_ids: jax.Array,
                  staging: jax.Array) -> jax.Array:
    """Receiver side: place staged pages into local blocks."""
    return pool.at[block_ids.astype(jnp.int32)].set(staging.astype(pool.dtype))
