"""Jitted wrappers for the KV gather / scatter / fused-transfer kernels."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.kv_gather.kv_gather import kv_gather
from repro.kernels.kv_gather.kv_scatter import kv_scatter
from repro.kernels.kv_gather.kv_transfer import kv_transfer


def _resolve(interpret: Optional[bool]) -> bool:
    # interpret everywhere except real TPU backends (compiled Mosaic there)
    return jax.default_backend() != "tpu" if interpret is None else interpret


@functools.partial(jax.jit, static_argnames=("interpret",))
def kv_gather_op(pool: jax.Array, block_ids: jax.Array, *,
                 interpret: Optional[bool] = None) -> jax.Array:
    return kv_gather(pool, block_ids.astype(jnp.int32),
                     interpret=_resolve(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def kv_scatter_op(pool: jax.Array, block_ids: jax.Array, staging: jax.Array, *,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Receiver side: place staged pages into local blocks (one dispatch)."""
    return kv_scatter(pool, block_ids.astype(jnp.int32), staging,
                      interpret=_resolve(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def kv_transfer_op(src_pool: jax.Array, dst_pool: jax.Array,
                   src_pages: jax.Array, dst_pages: jax.Array, *,
                   interpret: Optional[bool] = None) -> jax.Array:
    """One fused descriptor-table dispatch (see ``kv_transfer``)."""
    return kv_transfer(src_pool, dst_pool, src_pages.astype(jnp.int32),
                       dst_pages.astype(jnp.int32), interpret=_resolve(interpret))
