from repro.kernels.kv_gather.kv_gather import kv_gather
from repro.kernels.kv_gather.ops import kv_gather_op, kv_scatter_op
from repro.kernels.kv_gather.ref import kv_gather_ref

__all__ = ["kv_gather", "kv_gather_op", "kv_scatter_op", "kv_gather_ref"]
