from repro.kernels.kv_gather.kv_append import (append_slot_ids,
                                               kv_append_tokens, stage_tokens)
from repro.kernels.kv_gather.kv_gather import kv_gather
from repro.kernels.kv_gather.kv_scatter import kv_scatter
from repro.kernels.kv_gather.kv_transfer import kv_transfer
from repro.kernels.kv_gather.ops import kv_gather_op, kv_scatter_op, kv_transfer_op
from repro.kernels.kv_gather.ref import (kv_append_ref, kv_gather_ref,
                                         kv_scatter_ref, kv_transfer_ref)

__all__ = [
    "kv_gather", "kv_scatter", "kv_transfer", "kv_append_tokens",
    "append_slot_ids", "stage_tokens",
    "kv_gather_op", "kv_scatter_op", "kv_transfer_op",
    "kv_gather_ref", "kv_scatter_ref", "kv_transfer_ref", "kv_append_ref",
]
