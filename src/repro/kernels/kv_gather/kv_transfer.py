"""Pallas TPU kernel: fused descriptor-table KV transfer (gather–scatter).

This is THE transfer data plane. A :class:`~repro.core.transfer.TransferPlan`
lowers to a *descriptor table* — int32 arrays of flattened source/destination
page ids — and the whole plan executes as ONE kernel dispatch, regardless of
schedule (layerwise / blockwise / flowkv). Schedules differ only in how many
*transport calls* the cost model prices, never in Python loop structure.

Both pools are viewed as flat page tables ``(num_pages, payload)`` where one
page is one (block, layer, k/v) slice — the finest unit any schedule moves.
The two page-id tables are scalar-prefetched so the grid's index maps can
compute each page DMA's source and destination before the body runs: the
compiled artifact *is* the descriptor table. The destination pool is aliased
to the output (donated under ``jax.jit``), so pages not named by the table
keep their previous contents and no second pool allocation is made.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(src_pages_ref, dst_pages_ref, src_ref, dst_ref, out_ref):
    # one grid step == one page DMA: HBM(src[src_pages[i]]) -> HBM(dst[dst_pages[i]])
    out_ref[...] = src_ref[...].astype(out_ref.dtype)


def kv_transfer(src_pool: jax.Array, dst_pool: jax.Array,
                src_pages: jax.Array, dst_pages: jax.Array, *,
                interpret: bool = True) -> jax.Array:
    """Execute one descriptor table in one dispatch.

    ``src_pool`` / ``dst_pool`` are paged KV pools in either layout — they are
    flattened to ``(num_pages, payload)`` page tables internally, so the same
    kernel serves FLOWKV (B, L, 2, H) and VLLM (L, 2, B, H) pools on either
    side. ``src_pages`` / ``dst_pages`` are equal-length int32 page-id tables.
    Returns the updated destination pool (dst is aliased to the output).
    """
    payload = src_pool.shape[-1]
    if dst_pool.shape[-1] != payload:
        raise ValueError(
            f"src/dst page payloads differ: {payload} vs {dst_pool.shape[-1]}")
    src_flat = src_pool.reshape(-1, payload)
    dst_flat = dst_pool.reshape(-1, payload)
    n = src_pages.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, payload), lambda i, sp, dp: (sp[i], 0)),
            pl.BlockSpec((1, payload), lambda i, sp, dp: (dp[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, payload), lambda i, sp, dp: (dp[i], 0)),
    )
    out_flat = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst_flat.shape, dst_flat.dtype),
        # operand indices include the two scalar-prefetch tables: dst_flat is
        # operand 3 and aliases output 0 (in-place pool update / donation).
        input_output_aliases={3: 0},
        interpret=interpret,
    )(src_pages.astype(jnp.int32), dst_pages.astype(jnp.int32),
      src_flat, dst_flat)
    return out_flat.reshape(dst_pool.shape)
