"""Fused batched token append: one descriptor-table dispatch per decode step.

The zero-gather decode step produces one new token's K/V per request per
layer — ``2 * L * B`` token-sized pages. Instead of ``B`` per-request pool
rewrites (the old ``PagedKVCache.append_token`` loop), the whole batch lands
in ONE ``kv_transfer`` dispatch by viewing the pool at *token-slot*
granularity: a FlowKV page ``(block, layer, k/v)`` is ``block_size`` slots of
``num_kv_heads * head_dim`` elements, so the flat slot table is
``(nb * L * 2 * block_size, KV*hd)`` and a token append is a descriptor row
``staging[i] -> slots[ids[i]]``.

Padded batch lanes must replicate a REAL lane (token/length/block-table row),
not carry zeros: duplicate descriptors then write identical bytes to
identical slots, which is order-independent, whereas a zero lane would aim
its write at block 0. The engine's bucketing does exactly that.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.kv_gather.kv_transfer import kv_transfer


def append_slot_ids(block_tables: jax.Array, positions: jax.Array,
                    num_layers: int, block_size: int) -> jax.Array:
    """Flat token-slot ids for one token per request, all layers and K/V.

    block_tables (B, W) int32; positions (B,) int32 absolute token index.
    Returns (B * L * 2,) int32, row-major over (request, layer, k/v) — the
    same order ``stage_tokens`` emits.
    """
    blk = jnp.take_along_axis(block_tables,
                              (positions // block_size)[:, None], axis=1)[:, 0]
    slot = positions % block_size
    layer = jnp.arange(num_layers, dtype=jnp.int32)[None, :, None]
    kv = jnp.arange(2, dtype=jnp.int32)[None, None, :]
    page = (blk[:, None, None].astype(jnp.int32) * num_layers + layer) * 2 + kv
    ids = page * block_size + slot[:, None, None].astype(jnp.int32)
    return ids.reshape(-1)


def stage_tokens(k_new: jax.Array, v_new: jax.Array) -> jax.Array:
    """k/v (L, B, KV, hd) -> staging (B * L * 2, KV*hd), descriptor order."""
    L, B = k_new.shape[0], k_new.shape[1]
    stage = jnp.stack([k_new, v_new], axis=2)          # (L, B, 2, KV, hd)
    return stage.transpose(1, 0, 2, 3, 4).reshape(B * L * 2, -1)


def kv_append_tokens(pool: jax.Array, block_tables: jax.Array,
                     positions: jax.Array, k_new: jax.Array, v_new: jax.Array,
                     *, block_size: int,
                     interpret: Optional[bool] = None) -> jax.Array:
    """Append the batch's new-token K/V to the pool in ONE fused dispatch.

    pool (nb, L, 2, payload) FlowKV layout; block_tables (B, W) int32;
    positions (B,) int32 — the slot each request's token occupies;
    k_new / v_new (L, B, KV, hd). Returns the updated pool (aliased/donated
    through ``kv_transfer``; untouched slots keep their contents).
    ``interpret=None`` resolves by backend (compiled Mosaic on TPU).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    nb, L, two, payload = pool.shape
    tok_payload = payload // block_size                # KV * hd
    staging = stage_tokens(k_new, v_new).astype(pool.dtype)
    ids = append_slot_ids(block_tables, positions, L, block_size)
    src = jnp.arange(staging.shape[0], dtype=jnp.int32)
    pool_view = pool.reshape(nb, L, 2, block_size, tok_payload)
    out = kv_transfer(staging, pool_view, src, ids, interpret=interpret)
    return out.reshape(pool.shape)
