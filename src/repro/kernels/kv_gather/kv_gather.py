"""Pallas TPU kernel: gather scattered FlowKV pages into a contiguous
staging buffer.

This is the transfer-path FALLBACK: when bidirectional segment alignment
finds no mergeable runs (hostile fragmentation), the sender stages the
request's pages into one contiguous buffer — one DMA per page — and ships
the buffer with a single descriptor. The kernel makes the cost model's
"per-call overhead x n_pages" term concrete: the grid has exactly one step
per page, and the scalar-prefetched block table drives the source index of
each page DMA, so the compiled artifact *is* the descriptor list.

Block-major pool layout (paper Eq. 5) means one grid step moves a block's
K+V for ALL layers — under the vLLM (L, 2, B, H) layout the same staging
would need L x 2 grid steps per block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, pool_ref, out_ref):
    # one grid step == one page DMA: HBM(pool[ids[i]]) -> HBM(out[i])
    out_ref[...] = pool_ref[...]


def kv_gather(pool: jax.Array, block_ids: jax.Array, *,
              interpret: bool = True) -> jax.Array:
    """pool (nb, L, 2, payload); block_ids (n,) int32 -> (n, L, 2, payload)."""
    nb, L, two, payload = pool.shape
    n = block_ids.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, L, two, payload), lambda i, ids: (ids[i], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, L, two, payload), lambda i, ids: (i, 0, 0, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, L, two, payload), pool.dtype),
        interpret=interpret,
    )(block_ids, pool)
