"""Pallas TPU kernel: scatter a contiguous staging buffer into FlowKV pages.

The receiver-side inverse of ``kv_gather``: after a staged transfer lands as
one contiguous buffer ``(n, L, 2, payload)``, each grid step DMAs one staged
block into its local pool slot, driven by the scalar-prefetched block table.
The pool is aliased to the output, so untouched blocks keep their contents
without a second pool allocation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, staging_ref, pool_ref, out_ref):
    # one grid step == one block DMA: HBM(staging[i]) -> HBM(pool[ids[i]])
    out_ref[...] = staging_ref[...].astype(out_ref.dtype)


def kv_scatter(pool: jax.Array, block_ids: jax.Array, staging: jax.Array, *,
               interpret: bool = True) -> jax.Array:
    """pool (nb, L, 2, payload); block_ids (n,) int32; staging (n, L, 2, payload)."""
    nb, L, two, payload = pool.shape
    n = block_ids.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, L, two, payload), lambda i, ids: (i, 0, 0, 0)),
            pl.BlockSpec((1, L, two, payload), lambda i, ids: (ids[i], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, L, two, payload), lambda i, ids: (ids[i], 0, 0, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        # operand indices include the scalar-prefetch table: pool is operand 2
        # and aliases output 0 (in-place pool update / donation).
        input_output_aliases={2: 0},
        interpret=interpret,
    )(block_ids.astype(jnp.int32), staging, pool)
