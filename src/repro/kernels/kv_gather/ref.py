"""Pure-jnp oracles for the KV staging/transfer kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kv_gather_ref(pool: jax.Array, block_ids: jax.Array) -> jax.Array:
    """pool (nb, L, 2, payload); block_ids (n,) -> staging (n, L, 2, payload)."""
    return jnp.take(pool, block_ids, axis=0)


def kv_scatter_ref(pool: jax.Array, block_ids: jax.Array,
                   staging: jax.Array) -> jax.Array:
    """Inverse of :func:`kv_gather_ref`: place staged blocks into the pool."""
    return pool.at[block_ids].set(staging.astype(pool.dtype))


def kv_transfer_ref(src_pool: jax.Array, dst_pool: jax.Array,
                    src_pages: jax.Array, dst_pages: jax.Array) -> jax.Array:
    """Descriptor-table oracle over flat (num_pages, payload) page views."""
    payload = src_pool.shape[-1]
    src_flat = src_pool.reshape(-1, payload)
    dst_flat = dst_pool.reshape(-1, payload)
    out = dst_flat.at[dst_pages].set(
        jnp.take(src_flat, src_pages, axis=0).astype(dst_flat.dtype))
    return out.reshape(dst_pool.shape)
