"""Pure-jnp oracle for the KV block-gather staging kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kv_gather_ref(pool: jax.Array, block_ids: jax.Array) -> jax.Array:
    """pool (nb, L, 2, payload); block_ids (n,) -> staging (n, L, 2, payload)."""
    return jnp.take(pool, block_ids, axis=0)
