"""Pure-jnp oracles for the KV staging/transfer kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kv_gather_ref(pool: jax.Array, block_ids: jax.Array) -> jax.Array:
    """pool (nb, L, 2, payload); block_ids (n,) -> staging (n, L, 2, payload)."""
    return jnp.take(pool, block_ids, axis=0)


def kv_scatter_ref(pool: jax.Array, block_ids: jax.Array,
                   staging: jax.Array) -> jax.Array:
    """Inverse of :func:`kv_gather_ref`: place staged blocks into the pool."""
    return pool.at[block_ids].set(staging.astype(pool.dtype))


def kv_transfer_ref(src_pool: jax.Array, dst_pool: jax.Array,
                    src_pages: jax.Array, dst_pages: jax.Array) -> jax.Array:
    """Descriptor-table oracle over flat (num_pages, payload) page views."""
    payload = src_pool.shape[-1]
    src_flat = src_pool.reshape(-1, payload)
    dst_flat = dst_pool.reshape(-1, payload)
    out = dst_flat.at[dst_pages].set(
        jnp.take(src_flat, src_pages, axis=0).astype(dst_flat.dtype))
    return out.reshape(dst_pool.shape)


def kv_append_ref(pool: jax.Array, block_tables: jax.Array,
                  positions: jax.Array, k_new: jax.Array, v_new: jax.Array,
                  block_size: int) -> jax.Array:
    """Batched token-append oracle: per-request slot writes, plain indexing.

    pool (nb, L, 2, payload); block_tables (B, W); positions (B,);
    k_new / v_new (L, B, KV, hd).
    """
    nb, L, two, payload = pool.shape
    tok = payload // block_size
    pv = pool.reshape(nb, L, 2, block_size, tok)
    B = int(positions.shape[0])
    for b in range(B):
        blk = int(block_tables[b, int(positions[b]) // block_size])
        slot = int(positions[b]) % block_size
        pv = pv.at[blk, :, 0, slot].set(
            k_new[:, b].reshape(L, tok).astype(pool.dtype))
        pv = pv.at[blk, :, 1, slot].set(
            v_new[:, b].reshape(L, tok).astype(pool.dtype))
    return pv.reshape(pool.shape)
