"""Pure-jnp oracle for the prefill flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_prefill_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                      *, causal: bool = True) -> jax.Array:
    """q (B,S,H,hd); k/v (B,S,KV,hd) -> (B,S,H,hd). Direct softmax attention."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None, None, None], scores, jnp.finfo(jnp.float32).min)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w.astype(v.dtype), v)
    return out.reshape(b, s, h, hd)
