"""Pure-jnp oracle for the prefill flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_prefill_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                      *, causal: bool = True, q_offset: int = 0) -> jax.Array:
    """q (B,S,H,hd); k/v (B,T,KV,hd) -> (B,S,H,hd). Direct softmax attention.

    ``q_offset`` (suffix mode): query row i sits at global position
    ``q_offset + i`` over keys 0..T — the prefix-reuse oracle.
    """
    b, s, h, hd = q.shape
    t = k.shape[1]
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd)
    if causal:
        qpos = q_offset + jnp.arange(s)[:, None]
        mask = jnp.arange(t)[None, :] <= qpos
        scores = jnp.where(mask[None, None, None], scores, jnp.finfo(jnp.float32).min)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w.astype(v.dtype), v)
    return out.reshape(b, s, h, hd)
