"""Pallas TPU kernel: causal flash attention for chunked prefill.

Why a kernel when ``models/flash.py`` already exists: the jnp flash path
carries its fp32 online-softmax accumulators through XLA while-loop state,
which round-trips them through HBM every kv-chunk iteration — the dry-run
roofline shows prefill cells memory-bound largely because of that. Here the
accumulators live in VMEM scratch for the whole kv sweep, so HBM traffic
drops to ~(Q + K + V + O) once, moving prefill back toward the compute
roofline (the §Perf "kernel-adjusted" rows).

Grid ``(B, KV, nq, nk)``: nk iterates minor (sequential) so scratch carries
the accumulator across kv chunks; causal skip via ``pl.when`` — kv chunks
entirely above the diagonal are never loaded (exact-causal FLOPs, the wedge
optimization for free).

Tiles: q (q_blk, G, hd), k/v (k_blk, hd) with q_blk/k_blk multiples of 128
in production; hd is the MXU lane dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float(jnp.finfo(jnp.float32).min)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, q_blk: int, k_blk: int, causal: bool, q_offset: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # q_offset > 0 = suffix mode (prefix-cache reuse): query row i sits at
    # global position q_offset + i while keys cover the whole [0, T) range,
    # so the causal frontier — and the chunk-skip test — shift by q_offset.
    q_start = qi * q_blk + q_offset
    k_start = ki * k_blk
    run = (k_start <= q_start + q_blk - 1) if causal else (ki >= 0)

    @pl.when(run)
    def _process():
        q = q_ref[0, 0].astype(jnp.float32)               # (q_blk, G, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (k_blk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        hd = q.shape[-1]
        s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())))  # (q_blk,G,k_blk)
        s = s / jnp.sqrt(jnp.asarray(hd, jnp.float32))
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]                               # (q_blk, G)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        if causal:
            p = jnp.where(kpos <= qpos, p, 0.0)
        scale = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_ref[...] = l_ref[...] * scale + p.sum(axis=-1)
        pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((), ())))  # (q_blk,G,hd)
        acc_ref[...] = acc_ref[...] * scale[..., None] + pv
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, q_blk: int = 128, k_blk: int = 128,
                  q_offset: int = 0, interpret: bool = True) -> jax.Array:
    """q (B,S,H,hd); k/v (B,T,KV,hd) -> (B,S,H,hd). S/T divisible by blocks.

    T == S with ``q_offset=0`` is ordinary causal prefill. T > S with
    ``q_offset = T - S`` is SUFFIX prefill (prefix-cache reuse): the first
    ``q_offset`` keys are a resident cached prefix and queries are the
    uncached tail — the kernel computes exactly rows ``q_offset..T`` of the
    full-sequence result, skipping the prefix rows' compute entirely.
    """
    b, s, h, hd = q.shape
    t = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    if causal:
        # keys may extend past the query horizon (tile padding): causality
        # masks them for every real row
        assert t >= s + q_offset, "keys must cover prefix (q_offset) + queries"
    else:
        assert t == s and q_offset == 0, "q_offset is causal-only"
    q_blk = min(q_blk, s)
    k_blk = min(k_blk, t)
    assert s % q_blk == 0 and t % k_blk == 0, "pad S/T to block multiples"
    nq, nk = s // q_blk, t // k_blk
    # layout: (B, KV, S, G, hd) for q/o; (B, KV, S, hd) for k/v
    qr = jnp.transpose(q.reshape(b, s, kvh, g, hd), (0, 2, 1, 3, 4))
    kr = jnp.transpose(k, (0, 2, 1, 3))
    vr = jnp.transpose(v, (0, 2, 1, 3))

    kernel = functools.partial(_kernel, q_blk=q_blk, k_blk=k_blk, causal=causal,
                               q_offset=q_offset)
    out = pl.pallas_call(
        kernel,
        grid=(b, kvh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, q_blk, g, hd), lambda bb, kk, qi, ki: (bb, kk, qi, 0, 0)),
            pl.BlockSpec((1, 1, k_blk, hd), lambda bb, kk, qi, ki: (bb, kk, ki, 0)),
            pl.BlockSpec((1, 1, k_blk, hd), lambda bb, kk, qi, ki: (bb, kk, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_blk, g, hd),
                               lambda bb, kk, qi, ki: (bb, kk, qi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, s, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk, g), jnp.float32),
            pltpu.VMEM((q_blk, g), jnp.float32),
            pltpu.VMEM((q_blk, g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return jnp.transpose(out, (0, 2, 1, 3, 4)).reshape(b, s, h, hd)
