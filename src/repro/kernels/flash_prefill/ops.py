"""Jitted wrapper for the prefill flash-attention kernel (pads S to tile
multiples, strips padding after)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_prefill.flash_prefill import flash_prefill


@functools.partial(jax.jit, static_argnames=("causal", "q_blk", "k_blk", "interpret"))
def flash_prefill_op(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     causal: bool = True, q_blk: int = 128, k_blk: int = 128,
                     interpret: bool = True) -> jax.Array:
    b, s, h, hd = q.shape
    blk = max(min(q_blk, s), min(k_blk, s))
    pad = (-s) % blk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    out = flash_prefill(q, k, v, causal=causal, q_blk=min(q_blk, q.shape[1]),
                        k_blk=min(k_blk, q.shape[1]), interpret=interpret)
    return out[:, :s]
