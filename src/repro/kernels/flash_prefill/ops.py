"""Jitted wrapper for the prefill flash-attention kernel (pads S to tile
multiples, strips padding after).

Suffix mode (prefix-cache reuse): pass keys/values covering prefix+suffix
and ``q_offset = T - S`` — queries are just the uncached suffix rows and the
kernel computes exactly rows ``T-S..T`` of the full-sequence result. Both
sides pad at the END; padded key rows sit beyond every real query's causal
frontier, so they never contribute.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_prefill.flash_prefill import flash_prefill


@functools.partial(jax.jit, static_argnames=("causal", "q_blk", "k_blk",
                                             "q_offset", "interpret"))
def flash_prefill_op(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     causal: bool = True, q_blk: int = 128, k_blk: int = 128,
                     q_offset: int = 0, interpret: bool = True) -> jax.Array:
    b, s, h, hd = q.shape
    t = k.shape[1]
    assert t == s + q_offset, "keys must cover prefix (q_offset) + queries"
    blk = max(min(q_blk, s), min(k_blk, t))
    pad_q = (-s) % blk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    # keys must reach at least the last PADDED query row's position
    # (q_offset + s + pad_q - 1) and land on a tile boundary
    pad_k = (-t) % blk
    while t + pad_k < q.shape[1] + q_offset:
        pad_k += blk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    # both sides were padded to multiples of `blk`, so tile with exactly
    # `blk` — re-deriving from the padded lengths could pick a tile that
    # does not divide them (e.g. C=64, S=8: k pads to 144, min(128,144)=128)
    out = flash_prefill(q, k, v, causal=causal, q_blk=blk, k_blk=blk,
                        q_offset=q_offset, interpret=interpret)
    return out[:, :s]
