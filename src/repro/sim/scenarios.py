"""Stress-scenario registry for the load-aware scheduling claim (paper
§3.3–§3.4, Tables 1–2 narrative).

The paper's second claim is that load-aware scheduling with flexible PD
allocation holds peak throughput across NORMAL, COMPUTATIONALLY IMBALANCED
and EXTREME-OVERLOAD traffic, on homogeneous and heterogeneous fleets.
Each :class:`Scenario` here pins one of those regimes as a deterministic
discrete-event simulation (fixed seeds, calibrated cost models — no wall
clock anywhere), and ``benchmarks/scenarios.py`` runs every scenario under
three routing policies (``load_aware`` / ``round_robin`` / ``static_pd``,
see ``sim.cluster_sim.ROUTING_POLICIES``) and gates the comparison in CI.

Goodput here is Mooncake's definition (arXiv:2407.00079): the fraction of
OFFERED requests that finish within the scenario's TTFT SLO. Early-rejected
requests count against goodput — the admission gate only wins if rejecting
some requests lets the rest meet the SLO, which is exactly the paper's
overload story.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs import get_config
from repro.core.scheduler.global_controller import AdmissionPolicy
from repro.faults import FaultInjector, FaultSpec
from repro.sim.cluster_sim import ClusterSim
from repro.sim.hardware import A100, H20, L20, HardwareProfile
from repro.sim.workload import (WorkloadSpec, generate,
                                generate_conversations, generate_mixture)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One reproducible stress regime: cluster shape + traffic + SLO."""

    name: str
    description: str
    num_prefill: int
    num_decode: int
    rps: float
    ttft_slo_s: float               # goodput gate: TTFT within this
    specs: Tuple[WorkloadSpec, ...]  # one -> generate; many -> mixture
    weights: Tuple[float, ...] = ()
    num_requests: int = 100
    hw_prefill: HardwareProfile = A100
    hw_decode: Optional[HardwareProfile] = None
    hw_nodes: Optional[Tuple[HardwareProfile, ...]] = None
    admission: Optional[AdmissionPolicy] = None   # load-aware policy only
    role_flip: bool = False                       # load-aware policy only
    same_host: bool = False
    t_max: float = 50_000.0
    seed: int = 0
    model: str = "llama31-8b"
    # chaos: FaultSpecs scheduled on the sim clock (a FRESH seeded injector
    # per build, so re-running a scenario re-fires identical faults) and the
    # staleness window for declaring a quiet node dead
    faults: Tuple[FaultSpec, ...] = ()
    heartbeat_timeout: float = 10.0
    # multi-turn chat (turns > 1): num_requests counts CONVERSATIONS, each
    # re-submitting its growing history every think_time_s; specs[0] shapes
    # the first turn (mean_input) and the per-turn output (mean_output)
    turns: int = 1
    think_time_s: float = 2.0
    user_turn_tokens: int = 128
    # pool shape: small pools + a host tier make the demote/promote plane
    # load-bearing instead of idle (tiered KV scenario)
    blocks_per_node: int = 8192
    host_tier_blocks: int = 0
    # mesh-parallel degrees, one per node index (empty = all TP=1). A TP=k
    # node runs the model sharded over k chips; cross-degree P->D transfers
    # price one fused dispatch per overlapping shard pair.
    tp_degrees: Tuple[int, ...] = ()

    def requests(self):
        if self.turns > 1:
            return generate_conversations(
                self.num_requests, self.turns, rps=self.rps,
                first_turn_tokens=self.specs[0].mean_input,
                user_turn_tokens=self.user_turn_tokens,
                output_tokens=self.specs[0].mean_output,
                think_time_s=self.think_time_s, seed=self.seed)
        if len(self.specs) == 1:
            spec = dataclasses.replace(self.specs[0],
                                       num_requests=self.num_requests)
            return generate(spec, rps=self.rps, seed=self.seed)
        return generate_mixture(list(self.specs), list(self.weights),
                                rps=self.rps, num_requests=self.num_requests,
                                seed=self.seed)

    def build(self, routing: str) -> ClusterSim:
        """A fresh simulator running this scenario under one routing policy.

        The admission gate and the role-flip response are part of what
        "load-aware" MEANS here, so they arm only on that policy — the
        baselines stay naive by construction (passive controller).
        """
        load_aware = routing == "load_aware"
        return ClusterSim(
            get_config(self.model), "flowkv",
            num_prefill=self.num_prefill, num_decode=self.num_decode,
            hw_prefill=self.hw_prefill, hw_decode=self.hw_decode,
            hw_nodes=self.hw_nodes, same_host=self.same_host,
            routing=routing,
            role_flip=self.role_flip and load_aware,
            admission=self.admission if load_aware else None,
            faults=FaultInjector(self.faults, seed=self.seed)
            if self.faults else None,
            heartbeat_timeout=self.heartbeat_timeout,
            blocks_per_node=self.blocks_per_node,
            host_tier_blocks=self.host_tier_blocks,
            tp_degrees={i: d for i, d in enumerate(self.tp_degrees)
                        if d > 1} or None,
        )

    def run(self, routing: str) -> Dict[str, float]:
        """Run under one policy; returns sim stats + goodput vs the SLO."""
        sim = self.build(routing)
        stats = sim.run(self.requests(), t_max=self.t_max)
        within_slo = sum(
            1 for r in sim.finished
            if r.ttft() is not None and r.ttft() <= self.ttft_slo_s)
        stats["goodput"] = within_slo / max(1, stats["offered"])
        stats["ttft_slo_s"] = self.ttft_slo_s
        return stats


# --------------------------------------------------------------------------
# the four regimes
# --------------------------------------------------------------------------
_IN_1K = WorkloadSpec("normal-1k", 1024, 256)
_PREFILL_HEAVY = WorkloadSpec("imbalance-prefill", 10240, 32)
_DECODE_HEAVY = WorkloadSpec("imbalance-decode", 512, 384)
_OVERLOAD = WorkloadSpec("overload-10k", 10240, 256)
_HET = WorkloadSpec("het-4k", 4096, 256)
_CHAT = WorkloadSpec("chat-turn", 1024, 128)

SCENARIOS: Dict[str, Scenario] = {
    # Balanced traffic on a balanced fleet: every policy should clear this;
    # load-aware must not LOSE anything when there is nothing to exploit.
    "normal": Scenario(
        name="normal",
        description="balanced 1k-ctx traffic, 2P2D A100 — sanity regime",
        num_prefill=2, num_decode=2, rps=1.0, ttft_slo_s=10.0,
        specs=(_IN_1K,), num_requests=100,
    ),
    # Computational imbalance: a prefill-heavy burst against a decode-heavy
    # 1P3D split. Load-aware flips idle decode nodes into prefill
    # (role_flip) and drains the burst; fixed-role baselines serialize it
    # through the single P node.
    "imbalance": Scenario(
        name="imbalance",
        description="prefill-heavy mixture on a decode-heavy 1P3D split — "
                    "flexible PD allocation is the win",
        num_prefill=1, num_decode=3, rps=1.5, ttft_slo_s=10.0,
        specs=(_PREFILL_HEAVY, _DECODE_HEAVY), weights=(0.8, 0.2),
        num_requests=120, role_flip=True,
    ),
    # Extreme overload: sustained arrivals far beyond 1P1D capacity. The
    # admission gate early-rejects what cannot meet the SLO anyway so the
    # admitted remainder still can; baselines queue everything and miss the
    # SLO across the board.
    "overload": Scenario(
        name="overload",
        description="10k-ctx traffic at ~4x 1P1D capacity — admission "
                    "control (early rejection) is the win",
        num_prefill=1, num_decode=1, rps=1.2, ttft_slo_s=10.0,
        specs=(_OVERLOAD,), num_requests=120,
        admission=AdmissionPolicy(ttft_slo_s=10.0, max_queue_depth=64,
                                  max_defer_cycles=6, reject_factor=1.5),
    ),
    # Heterogeneous fleet: compute-lean L20s prefill, bandwidth-rich H20s
    # decode, one A100 on each side. Capability normalization keeps the
    # weak cards from silently saturating and the strong cards from
    # starving; gate: everything finishes and NO node is starved.
    # Fault tolerance: moderate load on a 2P2D fleet with a prefill node
    # crashing mid-run, a flaky transfer link (failures + corruption caught
    # by checksums) and a degraded-bandwidth window. Gate
    # (benchmarks/fault_tolerance.py): goodput stays within a bounded
    # fraction of the fault-free A/B of this same scenario, every
    # non-cancelled request terminates, zero blocks leak.
    "failure": Scenario(
        name="failure",
        description="2P2D under node crash + flaky/degraded transfers — "
                    "token-exact recovery and bounded goodput loss",
        num_prefill=2, num_decode=2, rps=1.0, ttft_slo_s=30.0,
        specs=(_IN_1K,), num_requests=100,
        faults=(FaultSpec("node_crash", at=20.0, node_id=0),
                FaultSpec("transfer_fail", at=5.0, count=3),
                FaultSpec("transfer_corrupt", at=10.0, count=3),
                FaultSpec("degraded_bandwidth", at=15.0, duration=20.0,
                          factor=4.0)),
        heartbeat_timeout=2.0,
    ),
    # Multi-turn chat on deliberately small HBM pools: every turn re-submits
    # the growing conversation history, and between turns capacity pressure
    # demotes the cold history to the host-DRAM tier. The tiered store wins
    # by promoting it back (one fused dispatch) instead of recomputing;
    # benchmarks/tiered_kv.py A/Bs this same scenario tiered vs HBM-only.
    "multiturn": Scenario(
        name="multiturn",
        description="multi-turn conversations on small HBM pools — the "
                    "host-DRAM tier turns history recompute into promotion",
        num_prefill=1, num_decode=1, rps=0.5, ttft_slo_s=10.0,
        specs=(_CHAT,), num_requests=16, turns=4, think_time_s=4.0,
        blocks_per_node=384, host_tier_blocks=4096,
    ),
    "heterogeneous": Scenario(
        name="heterogeneous",
        description="mixed A100/L20 prefill + A100/H20 decode fleet — "
                    "capability-normalized scores are the win",
        num_prefill=2, num_decode=2, rps=1.2, ttft_slo_s=30.0,
        specs=(_HET,), num_requests=120,
        hw_nodes=(A100, L20, A100, H20),
    ),
    # Sharded heterogeneous fleet on a 70B-class model: a TP=4 prefill node
    # (4 chips, 4x aggregate FLOPs) feeds TP=1 decode nodes. Cross-degree
    # P->D transfers lower to tp_src + tp_dst - gcd = 4 fused dispatches per
    # request instead of per-shard fan-out; capability stamping scales the
    # prefill node's score by its degree so routing doesn't starve it.
    "sharded_heterogeneous": Scenario(
        name="sharded_heterogeneous",
        description="TP=4 70B-class prefill node feeding TP=1 decode nodes "
                    "— per-shard-pair fused transfer + degree-aware scores",
        num_prefill=1, num_decode=2, rps=0.6, ttft_slo_s=30.0,
        specs=(_HET,), num_requests=80,
        model="llama31-70b",
        tp_degrees=(4, 1, 1),
    ),
}


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError as e:
        raise ValueError(
            f"unknown scenario {name!r}; have {sorted(SCENARIOS)}") from e
