"""Minimal discrete-event core: a heap of (time, seq, callback)."""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, List, Tuple


class EventQueue:
    def __init__(self) -> None:
        self._heap: List[Tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        self.now = 0.0

    def push(self, when: float, fn: Callable[[], None]) -> None:
        if when < self.now:
            when = self.now
        heapq.heappush(self._heap, (when, next(self._seq), fn))

    def __bool__(self) -> bool:
        return bool(self._heap)

    def run_until(self, t_end: float = float("inf"), max_events: int = 10_000_000) -> None:
        n = 0
        while self._heap and n < max_events:
            when, _, fn = heapq.heappop(self._heap)
            if when > t_end:
                heapq.heappush(self._heap, (when, next(self._seq), fn))
                break
            self.now = when
            fn()
            n += 1
