"""Workload generation: Poisson arrivals + the paper's request mixes.

* Simulated data (Tables 1-2): fixed input length (1K/5K/10K), output 256.
* Real-world proxy (Fig. 4): LongBench summarization subtask length
  profiles — gov_report / multi_news / qmsum input-length distributions
  (means taken from the published dataset statistics) with summary-length
  outputs.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.serving.request import Request, SamplingParams


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    mean_input: int
    mean_output: int
    input_std: float = 0.0        # 0 => fixed length
    output_std: float = 0.0
    num_requests: int = 100


# paper §4.1: simulated sets
SIMULATED = {
    "1k": WorkloadSpec("sim-1k", 1024, 256),
    "5k": WorkloadSpec("sim-5k", 5120, 256),
    "10k": WorkloadSpec("sim-10k", 10240, 256),
}

# LongBench summarization subtasks (token-length profiles)
LONGBENCH = {
    "gov_report": WorkloadSpec("gov_report", 8734, 512, input_std=3000, output_std=120),
    "multi_news": WorkloadSpec("multi_news", 2113, 256, input_std=1200, output_std=80),
    "qmsum": WorkloadSpec("qmsum", 10614, 256, input_std=2500, output_std=60),
}


def generate(spec: WorkloadSpec, rps: float, seed: int = 0,
             vocab_size: int = 32000) -> List[Request]:
    """Poisson arrival process at `rps`; token ids are synthetic."""
    rng = np.random.RandomState(seed)
    gaps = rng.exponential(1.0 / max(rps, 1e-9), size=spec.num_requests)
    arrivals = np.cumsum(gaps)
    out: List[Request] = []
    for i in range(spec.num_requests):
        ilen = spec.mean_input if spec.input_std == 0 else max(
            16, int(rng.normal(spec.mean_input, spec.input_std)))
        olen = spec.mean_output if spec.output_std == 0 else max(
            8, int(rng.normal(spec.mean_output, spec.output_std)))
        # token ids only matter for prefix-cache hashing; randomize
        prompt = rng.randint(0, vocab_size, size=ilen).tolist()
        out.append(Request(
            prompt_tokens=prompt,
            sampling=SamplingParams(max_new_tokens=olen),
            arrival_time=float(arrivals[i]),
        ))
    return out


def generate_conversations(num_conversations: int, turns: int, rps: float,
                           *, first_turn_tokens: int = 1024,
                           user_turn_tokens: int = 128,
                           output_tokens: int = 128,
                           think_time_s: float = 2.0, seed: int = 0,
                           vocab_size: int = 32000) -> List[Request]:
    """Multi-turn chat traffic: turn ``k``'s prompt is turn ``k-1``'s prompt
    + its generated output + a fresh user message.

    This is the workload the tiered KV store exists for — every turn
    re-submits the whole conversation history, so the shared prefix GROWS
    per turn and stays valuable across the think-time gap (long enough for
    capacity pressure to demote it to host DRAM between turns).

    Conversation STARTS are a Poisson process at ``rps``; turns within a
    conversation are spaced ``think_time_s`` apart. The simulator emits
    token id 0 for every generated token, so histories append ``[0] *
    output_tokens`` — digest-exact with what the virtual decode produced.
    """
    rng = np.random.RandomState(seed)
    gaps = rng.exponential(1.0 / max(rps, 1e-9), size=num_conversations)
    starts = np.cumsum(gaps)
    out: List[Request] = []
    for c in range(num_conversations):
        history = rng.randint(0, vocab_size,
                              size=first_turn_tokens).tolist()
        t = float(starts[c])
        for _ in range(turns):
            out.append(Request(
                prompt_tokens=list(history),
                sampling=SamplingParams(max_new_tokens=output_tokens),
                arrival_time=t,
            ))
            history = (history + [0] * output_tokens +
                       rng.randint(0, vocab_size,
                                   size=user_turn_tokens).tolist())
            t += think_time_s
    out.sort(key=lambda r: r.arrival_time)
    return out


def generate_mixture(specs: Sequence[WorkloadSpec], weights: Sequence[float],
                     rps: float, num_requests: int, seed: int = 0,
                     vocab_size: int = 32000) -> List[Request]:
    """One Poisson arrival stream whose per-request shape is drawn from a
    weighted mix of specs — e.g. the computationally-imbalanced scenario
    mixes long-prompt/short-output (prefill-heavy) with short-prompt/
    long-output (decode-heavy) traffic in one stream.
    """
    if len(specs) != len(weights):
        raise ValueError("specs and weights must have the same length")
    rng = np.random.RandomState(seed)
    probs = np.asarray(weights, dtype=float)
    probs = probs / probs.sum()
    gaps = rng.exponential(1.0 / max(rps, 1e-9), size=num_requests)
    arrivals = np.cumsum(gaps)
    picks = rng.choice(len(specs), size=num_requests, p=probs)
    out: List[Request] = []
    for i in range(num_requests):
        spec = specs[picks[i]]
        ilen = spec.mean_input if spec.input_std == 0 else max(
            16, int(rng.normal(spec.mean_input, spec.input_std)))
        olen = spec.mean_output if spec.output_std == 0 else max(
            8, int(rng.normal(spec.mean_output, spec.output_std)))
        prompt = rng.randint(0, vocab_size, size=ilen).tolist()
        out.append(Request(
            prompt_tokens=prompt,
            sampling=SamplingParams(max_new_tokens=olen),
            arrival_time=float(arrivals[i]),
        ))
    return out
