"""Discrete-event PD-cluster simulator.

The control plane is REAL: each simulated node owns an actual
``HybridScheduler`` + ``BlockManager`` (segment or freelist allocator), and
the global controller is the actual ``GlobalController``. Only the data
plane is virtual — step durations come from the hardware cost models and
transfer latencies from the exact ``TransferPlanner`` call counts over the
Table-3-calibrated transport profiles. This is what lets the simulator
reproduce the paper's throughput tables while exercising the same scheduler
code the CPU-scale runtime runs.

``SystemKind`` encodes the paper's comparison set:

  flowkv        — segment allocator, aligned transfer, load-aware scheduling
  vllm_disagg   — freelist allocator, per-layer buffer-merge transfer,
                  fixed roles, least-loaded routing
  mooncake      — freelist, RDMA-profile transfer (no NIC-direct VRAM)
  distserve     — fixed roles, NO chunked prefill (one prefill at a time)
  vllm_colocated— single-instance P+D with chunked prefill interference
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core.scheduler.global_controller import (AdmissionPolicy,
                                                    GlobalController, ModelCost,
                                                    NodeHandle)
from repro.core.scheduler.hybrid_scheduler import HybridScheduler
from repro.core.block_manager import BlockManager, OutOfBlocksError
from repro.core.costmodel import (HOST_DRAM, MOONCAKE_RDMA, NCCL_ENI, IPC,
                                  VLLM_MERGE_ENI, VLLM_MERGE_INTRA,
                                  TransportProfile, layer_window_overlap,
                                  select_route)
from repro.core.layout import KVCacheSpec
from repro.core.transfer import TransferPlanner, get_backend
from repro.faults import as_injector
from repro.models.common import ModelConfig
from repro.serving.host_tier import TierManager
from repro.serving.request import Request, RequestState
from repro.sim.events import EventQueue
from repro.sim.hardware import A100, HardwareProfile

SYSTEMS = ("flowkv", "vllm_disagg", "mooncake", "distserve", "vllm_colocated")

# Routing policies for the scenario suite (benchmarks/scenarios.py):
#   load_aware  — the full FlowKV control plane: smoothed-score routing,
#                 regime actions (role switch / flip / scale) and, when an
#                 AdmissionPolicy is set, the overload admission gate.
#   round_robin — blind rotation over P and D nodes; controller PASSIVE
#                 (observes and classifies but never acts).
#   static_pd   — fixed role partition, round-robin P, least-instantaneous-
#                 queue D (the classic disaggregated baseline); controller
#                 PASSIVE.
# Constructing with routing=None keeps the legacy behavior: the system
# spec's load_aware bit picks between the controller path and static_pd
# routing with the controller left ACTIVE (exactly the pre-scenario code).
ROUTING_POLICIES = ("load_aware", "round_robin", "static_pd")


@dataclasses.dataclass
class SystemSpec:
    kind: str
    allocator: str
    schedule: str                      # transfer schedule
    chunked_prefill: bool
    load_aware: bool
    colocated: bool = False
    transfer_intra: Optional[TransportProfile] = None
    transfer_inter: Optional[TransportProfile] = None
    # fraction of transfer latency that BLOCKS the sender's compute stream
    # (paper §1/§3.3: per-block NCCL kernels contend with GEMMs; FlowKV's
    # single merged call all but removes this)
    transfer_blocking: float = 0.5


def system_spec(kind: str) -> SystemSpec:
    if kind == "flowkv":
        return SystemSpec(kind, "flowkv", "flowkv", True, True,
                          transfer_intra=IPC, transfer_inter=NCCL_ENI,
                          transfer_blocking=0.05)
    if kind == "vllm_disagg":
        return SystemSpec(kind, "freelist", "blockwise", True, False,
                          transfer_intra=VLLM_MERGE_INTRA,
                          transfer_inter=VLLM_MERGE_ENI)
    if kind == "mooncake":
        return SystemSpec(kind, "freelist", "blockwise", True, False,
                          transfer_intra=MOONCAKE_RDMA,
                          transfer_inter=MOONCAKE_RDMA,
                          transfer_blocking=0.3)
    if kind == "distserve":
        # modeled without continuous prefill batching (one prompt at a time) —
        # reproduces the paper's observed long-prompt saturation (Table 1/2)
        return SystemSpec(kind, "freelist", "blockwise", False, False,
                          transfer_intra=VLLM_MERGE_INTRA,
                          transfer_inter=VLLM_MERGE_ENI)
    if kind == "vllm_colocated":
        return SystemSpec(kind, "freelist", "blockwise", True, False,
                          colocated=True,
                          transfer_intra=IPC, transfer_inter=NCCL_ENI)
    raise ValueError(f"unknown system {kind!r}")


class SimNode:
    def __init__(self, node_id: int, role: str, hw: HardwareProfile,
                 spec: SystemSpec, kv_spec: KVCacheSpec, cost: ModelCost,
                 max_batch_tokens: int, chunked_prefill: Optional[bool] = None,
                 prefill_chunk_tokens: Optional[int] = None, tp: int = 1):
        self.node_id = node_id
        self.role = role
        self.hw = hw
        self.spec = spec
        self.kv_spec = kv_spec
        self.cost = cost
        # mesh-parallel degree of this node: tp chips execute the model
        # cooperatively, so per-token FLOPs and weight/KV bytes are split
        # tp-ways across the aggregate fleet FLOPs/bandwidth (the same
        # aggregate the controller's capability stamping uses). The SAME
        # attribute name the sharded transfer backend reads (duck-typed
        # against ShardedKVCache.tp), so cross-degree P->D plans price one
        # fused dispatch per overlapping shard pair.
        self.tp = tp
        # chunked_prefill override (None = the system spec's baseline bit);
        # SAME HybridScheduler knobs as the real NodeEngine, so chunk-size
        # semantics cannot drift between sim and engine (parity-tested).
        chunked = spec.chunked_prefill if chunked_prefill is None \
            else chunked_prefill
        self.chunked_prefill = chunked
        self.bm = BlockManager(kv_spec.num_blocks, kv_spec.block_size, spec.allocator)
        self.scheduler = HybridScheduler(
            node_id, self.bm,
            max_batch_tokens=max_batch_tokens if chunked else 1 << 30,
            chunked_prefill=chunked,
            prefill_chunk_tokens=prefill_chunk_tokens,
            # distserve-style: whole-prompt prefill, one prompt at a time
            # (no sarathi chunking) — reproduces the long-prompt saturation
            max_running=1 if (role == "prefill" and not chunked) else 64,
        )
        if spec.colocated:
            self.scheduler.set_priority("both")
        self.busy_until = 0.0
        self.planner = TransferPlanner(kv_spec)
        # scenario bookkeeping: work this node actually executed (a node with
        # both at 0 at the end of a run was STARVED by the routing policy)
        self.served_prefill = 0     # requests that ran a prefill chunk here
        self.served_decode = 0      # request-cycles decoded here
        self.prefill_tokens_computed = 0   # prompt tokens actually priced

    # -- cost model ----------------------------------------------------------
    def prefill_duration(self, num_tokens: int) -> float:
        return self.hw.prefill_time(
            num_tokens * self.cost.flops_per_token / self.tp)

    def decode_duration(self, batch: List[Request]) -> float:
        kv_bytes = sum(self.cost.kv_bytes_per_token * r.total_len for r in batch)
        return self.hw.decode_time(
            (self.cost.weight_bytes + kv_bytes) / self.tp)


class ClusterSim:
    def __init__(self, cfg: ModelConfig, kind: str, *, num_prefill: int = 1,
                 num_decode: int = 1, hw_prefill: HardwareProfile = A100,
                 hw_decode: Optional[HardwareProfile] = None,
                 hw_nodes: Optional[Sequence[HardwareProfile]] = None,
                 same_host: bool = True, blocks_per_node: int = 8192,
                 max_batch_tokens: int = 8192, tp: int = 1,
                 tp_degrees: Optional[Dict[int, int]] = None,
                 routing: Optional[str] = None,
                 role_flip: bool = False,
                 admission: Optional[AdmissionPolicy] = None,
                 prefix_reuse: Optional[bool] = None,
                 host_tier_blocks: int = 0,
                 chunked_prefill: Optional[bool] = None,
                 prefill_chunk_tokens: Optional[int] = None,
                 layer_window: int = 0,
                 faults=None,
                 heartbeat_timeout: float = 10.0):
        self.cfg = cfg
        self.spec = system_spec(kind)
        self.kind = kind
        self.same_host = same_host
        # Fault plane (mirrors PDCluster): a repro.faults.FaultInjector (or
        # spec list / capture-meta dict) schedules node crashes on the event
        # clock, verdicts transfer attempts, degrades bandwidth and
        # suppresses heartbeats. The sim's transfer faults are PRICED-only
        # (virtual data plane): a failed/corrupt attempt adds the retry
        # backoff to the wire latency — the same control-flow path the real
        # cluster takes, minus the actual bytes.
        self.faults = as_injector(faults)
        self.heartbeat_timeout = heartbeat_timeout
        self.transfer_max_retries = 3
        self.transfer_backoff_s = 0.05
        self._dead: set = set()      # killed nodes stop heartbeating/working
        self.fault_kills = 0
        self.transfer_retry_count = 0
        self.degraded_to_recompute = 0
        self.recoveries = 0
        # chunked_prefill / prefill_chunk_tokens override the system spec's
        # baseline bit per run (A/B: lockstep vs sarathi-chunked on the SAME
        # system); layer_window > 0 prices layerwise transfer/compute
        # overlap exactly like PDCluster._transfer_windowed does.
        self.chunked_override = chunked_prefill
        self.prefill_chunk_tokens = prefill_chunk_tokens
        self.layer_window = layer_window
        # Optional repro.obs.tracing.SpanRecorder (attach_tracer). The sim
        # emits the same span taxonomy as PDCluster on the SIMULATED clock
        # (start_cycle/end_cycle in sim seconds); wall stamps stay None —
        # the virtual data plane consumes no wall time worth attributing.
        self.tracer = None
        hw_decode = hw_decode or hw_prefill
        if routing is not None and routing not in ROUTING_POLICIES:
            raise ValueError(
                f"routing must be one of {ROUTING_POLICIES}, got {routing!r}")
        # legacy construction (routing=None): spec.load_aware picks the path
        # and the controller stays active, exactly as before the scenario
        # suite existed; explicit baselines get a passive controller.
        self.routing = routing or \
            ("load_aware" if self.spec.load_aware else "static_pd")
        passive = routing is not None and routing != "load_aware"
        n_attn = cfg.num_attention_layers() or cfg.num_layers
        self.kv_spec = KVCacheSpec(
            num_layers=n_attn, num_blocks=blocks_per_node,
            block_size=cfg.block_size, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, dtype=cfg.dtype)
        cost = ModelCost(
            flops_per_token=2.0 * cfg.active_params() / tp,
            kv_bytes_per_token=float(cfg.kv_bytes_per_token() or 1024) / tp,
            weight_bytes=2.0 * cfg.num_params() / tp,
        )
        self.cost = cost
        self.controller = GlobalController(cost, cfg.block_size,
                                           target="gpu",
                                           role_flip=role_flip,
                                           admission=admission,
                                           actions_enabled=not passive,
                                           layer_window=layer_window,
                                           num_layers=n_attn,
                                           heartbeat_timeout=heartbeat_timeout)
        # deferred admissions re-routed inside controller.step need their
        # target node's event loop poked (event-driven runtime)
        self.controller.on_admit = lambda req: self._poke(req.prefill_node)
        self.nodes: Dict[int, SimNode] = {}
        if self.spec.colocated:
            # same GPU budget as 1P1D: two colocated hybrid instances
            roles = [("prefill", hw_prefill)] * (num_prefill + num_decode)
        else:
            roles = [("prefill", hw_prefill)] * num_prefill + \
                    [("decode", hw_decode)] * num_decode
        if hw_nodes is not None:
            # heterogeneous fleet: per-node profile overrides (same length)
            if len(hw_nodes) != len(roles):
                raise ValueError(
                    f"hw_nodes has {len(hw_nodes)} profiles for {len(roles)} nodes")
            roles = [(role, hw_nodes[i]) for i, (role, _) in enumerate(roles)]
        # Prefix-reuse mirror of the real runtime (priced, virtual data
        # plane). Default: only the FlowKV system under load-aware routing
        # has a global prefix cache — baselines never claim hits.
        if prefix_reuse is None:
            prefix_reuse = self.spec.load_aware and self.routing == "load_aware"
        self.prefix_reuse = prefix_reuse
        # Host-DRAM tier mirror: the SAME TierManager class the real cluster
        # wires (kv=None = bookkeeping-only pools), so demote/promote
        # decisions and span sequences are identical by construction. The
        # priced legs are the promote latencies (HOST_DRAM profile) charged
        # where the real cluster pays the host->HBM copy.
        self.host_tier_blocks = host_tier_blocks
        self.tiers: Dict[int, TierManager] = {}
        # per-node mesh-parallel degrees (node_id -> tp). The legacy global
        # ``tp`` knob keeps dividing ModelCost uniformly; ``tp_degrees``
        # instead scales individual nodes (a TP=4 prefill node runs 4x the
        # aggregate FLOPs of a TP=1 decode node) and stamps the degree onto
        # the controller handle, so capability normalization, TTFT estimates
        # and the shard-pair transfer pricing all see the topology.
        self.tp_degrees: Dict[int, int] = dict(tp_degrees or {})
        for i, (role, hw) in enumerate(roles):
            node_tp = self.tp_degrees.get(i, 1)
            node = SimNode(i, role, hw, self.spec, self.kv_spec, cost,
                           max_batch_tokens, chunked_prefill=chunked_prefill,
                           prefill_chunk_tokens=prefill_chunk_tokens,
                           tp=node_tp)
            self.nodes[i] = node
            self.controller.register_node(NodeHandle(
                node_id=i, role=role, host_id=0 if same_host else i,
                hardware=hw, scheduler=node.scheduler,
                supports_prefix_reuse=prefix_reuse,
                tp_degree=node_tp))
            # same residency honesty as the real cluster: physical frees
            # drop the freed blocks' index entries
            node.bm.on_free = \
                (lambda blocks, nid=i:
                 self.controller.prefix_index.invalidate_blocks(nid, blocks))
            if prefix_reuse:
                node.scheduler.resolve_prefix = self._make_resolver(node)
                # host tier mirrors the real cluster's tp==1 restriction:
                # whole-payload page moves don't span sharded pools
                if host_tier_blocks > 0 and node_tp == 1:
                    self.tiers[i] = TierManager(
                        i, node.bm, self.controller.prefix_index,
                        self.kv_spec, host_tier_blocks, kv=None,
                        schedule=self.spec.schedule,
                        get_tracer=lambda: self.tracer,
                        get_clock=lambda: self.eq.now).attach()
        if self.spec.colocated:
            for node in self.nodes.values():
                node.scheduler.set_priority("both")
        self.eq = EventQueue()
        self.finished: List[Request] = []
        self.rejected: List[Request] = []
        self.offered = 0
        self._rr = 0   # round-robin cursor
        self.transfer_latencies: List[float] = []   # EXPOSED latencies
        self.transfer_calls: List[int] = []
        self.transfer_dispatches: List[int] = []
        self.transfer_hidden: List[float] = []      # wire time hidden by overlap
        self.prefix_hits = 0               # prefills that reused a prefix
        self.prefix_tokens_reused = 0      # prompt tokens never priced
        self.prefix_fetches = 0            # remote fetches executed
        self.prefix_fetch_dispatches: List[int] = []
        self._poll_scheduled: Dict[int, bool] = {i: False for i in self.nodes}
        self._recheck_scheduled = False   # admission-recheck event in flight

    def _make_resolver(self, node: SimNode):
        """Admission-time prefix resolution: same shared controller helper
        as PDCluster, so engine and sim semantics cannot drift."""
        nid, bm = node.node_id, node.bm
        return lambda req: self.controller.resolve_local_prefix(
            nid, req, bm.block_alive)

    # -- routing ------------------------------------------------------------------
    def _route(self, req: Request) -> None:
        self.offered += 1
        if self.routing == "load_aware":
            decision = self.controller.submit_request(req)
            self._collect_rejected()
            if not decision.admitted:
                if decision.verdict == "deferred":
                    # deferred re-evaluation runs in controller.step, which
                    # only fires from _complete — keep a recheck event alive
                    # so a deferral on an otherwise-idle cluster cannot
                    # strand the request with an empty event queue
                    self._schedule_admission_recheck()
                return
        elif self.routing == "round_robin":
            # blind rotation over both sides, no load signal at all
            pn = self.controller.prefill_nodes() or \
                [n for n in self.controller.nodes.values() if n.alive]
            dn = self.controller.decode_nodes() or pn
            p = pn[self._rr % len(pn)]
            d = dn[self._rr % len(dn)]
            self._rr += 1
            req.decode_node = d.node_id
            p.scheduler.enqueue_prefill(req)
        else:
            # static_pd: fixed roles, round-robin P, least-loaded D node
            pn = [n for n in self.controller.prefill_nodes()]
            p = pn[req.request_id % len(pn)]
            dn = self.controller.decode_nodes() or pn
            d = min(dn, key=lambda n: len(n.scheduler.decode.running))
            req.decode_node = d.node_id
            p.scheduler.enqueue_prefill(req)
        node_id = req.prefill_node
        self._poke(node_id)

    def _collect_rejected(self) -> None:
        for r in self.controller.take_rejected():
            r.finish_time = self.eq.now
            self.rejected.append(r)

    def _schedule_admission_recheck(self, period: float = 0.05) -> None:
        """Periodic controller tick while any request sits deferred."""
        if self._recheck_scheduled:
            return
        self._recheck_scheduled = True

        def recheck():
            self._recheck_scheduled = False
            self._heartbeat_all(self.eq.now)
            self.controller.step(self.eq.now)
            self._collect_rejected()
            if self.controller.deferred:
                self._schedule_admission_recheck(period)

        self.eq.push(self.eq.now + period, recheck)

    def _poke(self, node_id: int) -> None:
        """Schedule a scheduling-cycle poll for a node if idle."""
        if self._poll_scheduled.get(node_id):
            return
        self._poll_scheduled[node_id] = True
        node = self.nodes[node_id]
        self.eq.push(max(self.eq.now, node.busy_until), lambda: self._cycle(node_id))

    # -- fault plane --------------------------------------------------------------------
    def _heartbeat_all(self, now: float) -> None:
        """Refresh every HEALTHY node's heartbeat (idle != dead in the sim —
        failure is explicit), skipping killed and suppressed nodes so
        staleness detection can actually fire on them."""
        for nid, handle in self.controller.nodes.items():
            if handle.alive and nid not in self._dead and \
                    (self.faults is None or
                     not self.faults.heartbeat_suppressed(nid, now)):
                self.controller.heartbeat(nid, now)

    def kill_node(self, node_id: int) -> None:
        """Node death on the event clock: it stops heartbeating and working
        (no sentinel stamp — detection is pure staleness), its pool is
        released, and a failure-check event is scheduled past the heartbeat
        timeout so detection fires even on an otherwise-idle cluster."""
        self._dead.add(node_id)
        self.fault_kills += 1
        # the host tier dies with the node: detach the demotion hook BEFORE
        # the pool teardown (nowhere to demote to), then drop its entries
        self.nodes[node_id].bm.on_evict = None
        tm = self.tiers.get(node_id)
        if tm is not None:
            tm.clear()
        self.nodes[node_id].bm.release_all()
        self.eq.push(self.eq.now + self.heartbeat_timeout + 1e-6,
                     self._failure_check)

    def _failure_check(self) -> None:
        """Heartbeat the healthy fleet, then let the controller's staleness
        scan drain + reroute whatever went quiet."""
        self._heartbeat_all(self.eq.now)
        self.controller.step(self.eq.now)
        self._collect_rejected()

    def _finish_recovery(self, req: Request, node_id: int, now: float) -> None:
        """Close the failure→re-prefilled window (same semantics as
        PDCluster._finish_recovery, sim clock only)."""
        req.recovery_s += now - req.recovery_start
        req.recoveries += 1
        self.recoveries += 1
        if self.tracer is not None:
            self.tracer.emit(
                req.request_id, "recovery",
                start_cycle=req.recovery_start, end_cycle=now,
                node_id=node_id,
                attrs={"replayed_tokens": req.replayed_tokens,
                       "retries": req.retries})
        req.recovery_start = None
        req.recovery_start_wall = None

    # -- tier promotion (mirrors PDCluster._promote_pending, priced) -----------------
    def _promote_pending(self, node: SimNode) -> float:
        """Lift the head-of-line waiting request's LOCAL host-tier prefix
        back into the pool before this node schedules; returns the priced
        host->HBM latency (charged against this node's compute stream —
        where the real cluster pays the actual copy)."""
        tm = self.tiers.get(node.node_id)
        if tm is None or not node.scheduler.prefill.waiting:
            return 0.0
        req = node.scheduler.prefill.waiting[0]
        if node.bm.owns(req.request_id):
            return 0.0
        if req.prefix_src_node is not None and \
                req.prefix_src_node != node.node_id:
            return 0.0   # remote plan: promotion happens at the SOURCE node
        if tm.promote_match(req.prompt_tokens, trace_id=req.request_id,
                            profile=HOST_DRAM):
            return tm.last_promote_latency_s
        return 0.0

    # -- prefix fetch (mirrors PDCluster._fetch_prefix, priced) ----------------------
    def _fetch_pending_prefixes(self, node: SimNode) -> None:
        """Start the remote-prefix pull for this node's next admission.

        Head-of-line only, like the real cluster: queue-tail fetches could
        starve a large head request of free blocks. The request leaves the
        waiting queue for the fetch's (priced) latency — exactly ONE
        fused-dispatch plan per fetch, same descriptor tables as hardware —
        and re-enters it when the blocks land, so admission can only share
        a prefix that is actually resident."""
        if not node.scheduler.prefill.waiting:
            return
        req = node.scheduler.prefill.waiting[0]
        src_id = req.prefix_src_node
        if src_id is None or src_id == node.node_id or \
                node.bm.owns(req.request_id):
            return
        src = self.nodes.get(src_id)
        if src is None:
            req.clear_prefix_plan()
            return
        # Source-side promotion first (same ordering as the real cluster):
        # demote->promote changes physical ids, so the routed block list is
        # refreshed before validation. The host->HBM leg is a priced serial
        # prelude to the wire fetch.
        promote_s = 0.0
        src_tm = self.tiers.get(src_id)
        if src_tm is not None and \
                src_tm.promote_match(req.prompt_tokens,
                                     trace_id=req.request_id,
                                     profile=HOST_DRAM):
            promote_s = src_tm.last_promote_latency_s
            if not self.controller.refresh_prefix_plan(req):
                return   # nothing shareable survived promotion
        if not self.controller.validate_prefix_plan(req):
            return   # stale plan cleared by the shared validator
        hit = req.num_cached_prefix_tokens
        if not node.bm.can_allocate(hit):
            return   # destination pool full — retry next cycle
        dst_blocks = node.bm.allocate(req.request_id, hit)
        plan = src.planner.plan(self.spec.schedule,
                                req.prefix_block_ids, dst_blocks)
        profile = (self.spec.transfer_intra if self.same_host
                   else self.spec.transfer_inter)
        latency = plan.latency(profile) + promote_s
        self.prefix_fetches += 1
        self.prefix_fetch_dispatches.append(plan.num_dispatches)
        req.prefix_fetch_dispatches = plan.num_dispatches
        node.scheduler.prefill.waiting.remove(req)

        start = self.eq.now

        def arrive(req=req, dst_blocks=dst_blocks, hit=hit,
                   nid=node.node_id):
            if self.tracer is not None:
                self.tracer.emit(
                    req.request_id, "prefix_fetch",
                    start_cycle=start, end_cycle=self.eq.now, node_id=nid,
                    attrs={"src_node": src_id, "tokens": hit,
                           "dispatches": plan.num_dispatches,
                           "bytes": plan.total_bytes,
                           "est_latency_s": latency})
            dst = self.nodes[nid]
            if nid in self._dead or not self.controller.nodes[nid].alive:
                dst.bm.free(req.request_id)   # node died mid-fetch
                self.controller._stamp_failure(req, self.eq.now, nid,
                                               "node_died_mid_fetch")
                req.reset_for_retry()
                self.controller.retry_queue.append(req)
                return
            self.controller.record_prefix(nid, req.prompt_tokens[:hit],
                                          dst_blocks)
            req.prefix_src_node = nid
            req.prefix_block_ids = dst_blocks
            # the prefix is resident: back to the HEAD (this request's
            # admission was what the fetch was for)
            dst.scheduler.prefill.waiting.appendleft(req)
            self._poke(nid)

        self.eq.push(self.eq.now + latency, arrive)

    def _rehome_prefix(self, req: Request, node_id: int,
                       blocks: Sequence[int]) -> None:
        """Advertise a prompt's full-block prefix where its KV now lives
        (shared controller helper — sim and engine can never drift)."""
        if self.prefix_reuse:
            self.controller.rehome_prefix(req, node_id, blocks)

    # -- node cycle -----------------------------------------------------------------
    def _cycle(self, node_id: int) -> None:
        self._poll_scheduled[node_id] = False
        node = self.nodes[node_id]
        handle = self.controller.nodes[node_id]
        if not handle.alive or node_id in self._dead:
            return
        if self.faults is None or \
                not self.faults.heartbeat_suppressed(node_id, self.eq.now):
            self.controller.heartbeat(node_id, self.eq.now)
        promote_s = 0.0
        if self.prefix_reuse:
            promote_s = self._promote_pending(node)
            self._fetch_pending_prefixes(node)
        decision = node.scheduler.schedule()
        # a local promote is a serial host->HBM copy ahead of this cycle's
        # compute (the real engine blocks on the actual dispatch)
        duration = promote_s
        if decision.prefill_batch:
            tokens = decision.num_prefill_tokens
            duration += node.prefill_duration(tokens)
            for req in decision.prefill_batch:
                # first scheduled chunk = compute starts (the real engine
                # stamps this in run_prefill); queue_s / prefill_s and the
                # queue span depend on it
                if req.prefill_start is None:
                    req.prefill_start = self.eq.now
            node.scheduler.last_compute_util = 1.0
            node.served_prefill += len(decision.prefill_batch)
            # chunks are suffix-sized on a hit: the simulator prices exactly
            # the compute the real engine would run
            node.prefill_tokens_computed += tokens
        if decision.decode_batch:
            duration += node.decode_duration(decision.decode_batch)
            node.served_decode += len(decision.decode_batch)
            # same signal as NodeEngine.run_decode: the admitted batch's
            # progress fraction — identically 1.0 here because every
            # simulated decode request progresses each cycle.
            node.scheduler.last_bandwidth_util = 1.0
        if not decision.prefill_batch and not decision.decode_batch:
            node.scheduler.last_compute_util = 0.0
            node.scheduler.last_bandwidth_util = 0.0
            if promote_s:
                node.busy_until = max(node.busy_until,
                                      self.eq.now + promote_s)
            return   # idle: next arrival/transfer will poke us
        node.busy_until = self.eq.now + duration
        self.eq.push(node.busy_until,
                     lambda: self._complete(node_id, decision))

    def _complete(self, node_id: int, decision) -> None:
        if node_id in self._dead or not self.controller.nodes[node_id].alive:
            return   # killed mid-batch: the in-flight work is lost (the
            #          failure drain requeues its requests token-exactly)
        node = self.nodes[node_id]
        now = self.eq.now
        # prefill completions
        for req in list(decision.prefill_batch):
            chunk = decision.prefill_chunks.get(req.request_id, req.prompt_len)
            offset = node.scheduler.prefill_tokens_done(req)
            executed = min(chunk, req.prompt_len - offset)
            if self.tracer is not None and executed > 0:
                # same zero-width per-chunk span the real engine emits, so
                # sim and engine chunk sequences are directly comparable
                # (tests/test_chunked_prefill.py parity test)
                self.tracer.emit(
                    req.request_id, "prefill_chunk",
                    start_cycle=now, end_cycle=now, node_id=node_id,
                    attrs={"offset": offset, "tokens": executed,
                           "prompt_len": req.prompt_len,
                           "final": offset + executed == req.prompt_len})
            if node.scheduler.prefill_progressed(req, chunk):
                req.prefill_end = now
                # recovery re-prefill already HAS its tokens (kept across
                # reset_for_retry) — same final-append guard as the engine
                if not req.output_tokens:
                    req.output_tokens.append(0)   # first token (virtual)
                # the first token is EMITTED here, by prefill — TTFT must not
                # include the transfer (same fix as the real cluster)
                if req.first_token_time is None:
                    req.first_token_time = now
                if req.recovery_start is not None:
                    self._finish_recovery(req, node_id, now)
                if self.tracer is not None:
                    self.tracer.emit(
                        req.request_id, "queue",
                        start_cycle=req.arrival_time,
                        end_cycle=req.prefill_start, node_id=node_id,
                        attrs={"defers": req.admission_defers,
                               "retries": req.retries})
                    self.tracer.emit(
                        req.request_id, "prefill",
                        start_cycle=req.prefill_start, end_cycle=now,
                        node_id=node_id,
                        attrs={"prompt_len": req.prompt_len,
                               "cached_prefix_tokens":
                                   req.num_cached_prefix_tokens})
                if req.num_cached_prefix_tokens:
                    self.prefix_hits += 1
                    self.prefix_tokens_reused += req.num_cached_prefix_tokens
                if self.spec.colocated:
                    node.scheduler.bm  # same pool: no transfer
                    node.scheduler.enqueue_decode(req)
                    self._rehome_prefix(req, node_id,
                                        node.bm.get(req.request_id))
                else:
                    node.scheduler.mark_sending(req)
                    # the final chunk's compute is the window layer-wise
                    # transfer overlap hides behind (same stamp the real
                    # engine records in run_prefill)
                    req.last_prefill_chunk_tokens = chunk
                    self._start_transfer(req, now)
        # decode completions (one token per request per cycle)
        for req in list(decision.decode_batch):
            req.output_tokens.append(0)
            if req.first_token_time is None:
                req.first_token_time = now
            if req.num_output >= req.sampling.max_new_tokens:
                node.scheduler.decode_finished(req)
                req.finish_time = now
                if self.tracer is not None:
                    self.tracer.emit(
                        req.request_id, "decode",
                        start_cycle=req.transfer_end, end_cycle=now,
                        node_id=node_id,
                        attrs={"new_tokens": req.num_output})
                self.finished.append(req)
        # keep heartbeats fresh for all healthy nodes (failure injection is
        # explicit in this simulator; idle != dead)
        self._heartbeat_all(now)
        self.controller.step(now)
        self._collect_rejected()   # deferred admissions the gate gave up on
        self._poke(node_id)

    # -- transfer ----------------------------------------------------------------------
    def _pick_decode_node(self, exclude=()) -> Optional[int]:
        """Least-loaded live decode node (any live node as fallback)."""
        cands = [n for n in self.controller.nodes.values()
                 if n.alive and n.node_id not in self._dead
                 and n.node_id not in exclude]
        if not cands:
            return None
        decode = [n for n in cands if n.role == "decode"] or cands
        return min(decode,
                   key=lambda n: len(n.scheduler.decode.running)).node_id

    def _degrade_to_recompute(self, req: Request, src: SimNode, dst: SimNode,
                              now: float) -> None:
        """Retry-exhausted transfer (mirror of the real cluster): drop both
        sides' blocks and re-prefill token-exactly on the decode node."""
        if dst.bm.owns(req.request_id):
            dst.bm.free(req.request_id)
        src.scheduler.sending_done(req, free=True)
        self.degraded_to_recompute += 1
        alive = dst.node_id not in self._dead and \
            self.controller.nodes[dst.node_id].alive
        target = dst if alive else src
        self.controller._stamp_failure(req, now, target.node_id,
                                       "transfer_retries_exhausted")
        req.reset_for_retry()
        req.prefill_node = target.node_id
        req.decode_node = target.node_id
        target.scheduler.enqueue_prefill(req)
        self._poke(target.node_id)

    def _start_transfer(self, req: Request, now: float) -> None:
        src = self.nodes[req.prefill_node]
        dst_id = req.decode_node if req.decode_node is not None else req.prefill_node
        # failover re-target: the routed decode node may have died while
        # the request prefilled
        if dst_id in self._dead or not self.controller.nodes[dst_id].alive:
            nd = self._pick_decode_node(exclude={dst_id})
            dst_id = nd if nd is not None else req.prefill_node
            req.decode_node = dst_id
        dst = self.nodes[dst_id]
        if not src.bm.owns(req.request_id):
            return   # request was drained/requeued (failover) mid-transfer
        if src is dst:
            # Role-flexible node serving both stages (degenerate routing):
            # the cache is already in this pool — local handoff, no transfer
            # (mirrors PDCluster._transfer).
            req.transfer_start = req.transfer_end = now
            req.transfer_calls = req.transfer_dispatches = 0
            if self.tracer is not None:
                self.tracer.emit(
                    req.request_id, "transfer",
                    start_cycle=now, end_cycle=now, node_id=src.node_id,
                    attrs={"schedule": "local", "calls": 0, "dispatches": 0,
                           "bytes": 0, "est_latency_s": 0.0})
            src.scheduler.sending_done(req, free=False)
            dst.scheduler.enqueue_decode(req)
            self._rehome_prefix(req, dst.node_id, dst.bm.get(req.request_id))
            self._poke(dst.node_id)
            return
        # Same TransferBackend registry as the real runtime: the "sim"
        # backend plans/prices exactly but its data plane is a no-op.
        backend = get_backend("sim", schedule=self.spec.schedule)
        try:
            job = backend.plan(req, src, dst)
        except OutOfBlocksError:
            # D pool full: requeue transfer shortly (backpressure). Anything
            # else (bad schedule, double registration) must surface.
            self.eq.push(now + 0.01, lambda: self._start_transfer(req, self.eq.now))
            return
        backend.execute(job, src, dst)
        # transfer faults, PRICED: the virtual data plane cannot corrupt real
        # bytes, so fail and corrupt verdicts are identical here — each failed
        # attempt adds its exponential backoff to the wire latency, and
        # exhausting every retry degrades to recompute-on-the-decode-node
        # (the same control path PDCluster takes with real checksums).
        penalty = 0.0
        exhausted = False
        if self.faults is not None:
            for attempt in range(self.transfer_max_retries + 1):
                fault = self.faults.transfer_attempt(now)
                if fault is None:
                    break
                req.transfer_retries += 1
                self.transfer_retry_count += 1
                backoff = self.transfer_backoff_s * (2.0 ** attempt)
                penalty += backoff
                if self.tracer is not None:
                    self.tracer.emit(
                        req.request_id, "transfer_retry",
                        start_cycle=now, end_cycle=now + backoff,
                        node_id=src.node_id,
                        attrs={"attempt": attempt, "fault": fault,
                               "backoff_s": backoff})
            else:
                exhausted = True
        if exhausted:
            self._degrade_to_recompute(req, src, dst, now)
            return
        profile = (self.spec.transfer_intra if self.same_host
                   else self.spec.transfer_inter)
        bw = self.faults.bandwidth_factor(now) if self.faults is not None \
            else 1.0
        latency = backend.price(job, profile) * bw
        hidden = 0.0
        windows = 1
        if self.layer_window > 0 and job.plan is not None and \
                job.plan.num_layers > self.layer_window:
            # Layer-window overlap, priced EXACTLY like the real cluster
            # (PDCluster._transfer_windowed): per-window sub-plan latencies
            # through the shared pipeline recurrence; only the spill past
            # the producing prefill tail is exposed.
            subs = job.plan.split_layer_windows(self.layer_window)
            lats = [sub.latency(profile) * bw for sub in subs]
            ends = [sub.layer_span[1] for sub in subs]
            L = job.plan.num_layers
            prefill_s = src.prefill_duration(
                req.last_prefill_chunk_tokens or req.prompt_len)
            latency, hidden = layer_window_overlap(lats, ends, L, prefill_s)
            job.num_calls = sum(sub.num_calls for sub in subs)
            job.num_dispatches = sum(sub.num_dispatches for sub in subs)
            windows = len(subs)
            if self.tracer is not None:
                t0 = now - prefill_s
                finish = 0.0
                for sub, lat in zip(subs, lats):
                    lo, hi = sub.layer_span
                    start_rel = max(finish, prefill_s * hi / L)
                    finish = start_rel + lat
                    self.tracer.emit(
                        req.request_id, "transfer_layer_window",
                        start_cycle=t0 + start_rel, end_cycle=t0 + finish,
                        node_id=src.node_id,
                        attrs={"layer_lo": lo, "layer_hi": hi,
                               "bytes": sub.total_bytes,
                               "est_latency_s": lat,
                               "hidden": finish <= prefill_s})
        latency += penalty   # retry backoffs are exposed wire time
        req.transfer_start = now
        req.transfer_calls = job.num_calls
        req.transfer_dispatches = job.num_dispatches
        self.transfer_latencies.append(latency)
        self.transfer_calls.append(job.num_calls)
        self.transfer_dispatches.append(job.num_dispatches)
        self.transfer_hidden.append(hidden)
        # sender-side compute blocked for a schedule-dependent share of the
        # EXPOSED transfer (per-call kernel contention; hidden windows ran
        # concurrently with compute that already paid for them)
        src.busy_until = max(src.busy_until, now) + \
            self.spec.transfer_blocking * latency

        def arrive():
            if req.state is not RequestState.SENDING:
                # drained (src death) or cancelled while on the wire: the
                # dst-side registration is a partial arrival — drop it
                # instead of billing blocks to a request that left
                if dst.bm.owns(req.request_id):
                    dst.bm.free(req.request_id)
                return
            if dst.node_id in self._dead or \
                    not self.controller.nodes[dst.node_id].alive:
                # dst died while the KV was in flight: free both sides and
                # requeue — recovery re-prefills token-exactly elsewhere
                if dst.bm.owns(req.request_id):
                    dst.bm.free(req.request_id)
                src.scheduler.sending_done(req, free=True)
                self.controller._stamp_failure(req, self.eq.now, dst.node_id,
                                               "dst_died_in_flight")
                req.reset_for_retry()
                self.controller.retry_queue.append(req)
                self._failure_check()   # reroute now (heartbeats refreshed)
                return
            req.transfer_end = self.eq.now
            if self.tracer is not None:
                self.tracer.emit(
                    req.request_id, "transfer",
                    start_cycle=req.transfer_start, end_cycle=self.eq.now,
                    node_id=src.node_id,
                    attrs={"schedule": job.schedule, "calls": job.num_calls,
                           "dispatches": job.num_dispatches,
                           "bytes": job.num_bytes, "est_latency_s": latency,
                           "hidden_s": hidden, "windows": windows,
                           "dst_node": dst.node_id,
                           "src_tp": src.tp, "dst_tp": dst.tp})
            # KV now lives on the decode node; the sending_done free below
            # invalidates the prefill-side entry (same as the real cluster)
            self._rehome_prefix(req, dst.node_id, job.dst_blocks)
            src.scheduler.sending_done(req)
            dst.scheduler.enqueue_decode(req)
            self._poke(dst.node_id)

        self.eq.push(now + latency, arrive)

    # -- run ---------------------------------------------------------------------------
    def run(self, requests: List[Request], t_max: float = 10_000.0) -> Dict[str, float]:
        if self.faults is not None:
            # rewind the injector (same instance re-runs identically) and put
            # its scheduled faults on the event clock: crashes kill at their
            # time; heartbeat-loss windows get a staleness check past the
            # timeout so detection fires even on an idle cluster.
            self.faults.reset()
            for spec in self.faults.crash_specs():
                self.eq.push(spec.at,
                             (lambda nid: lambda: self.kill_node(nid))(
                                 spec.node_id))
            for spec in self.faults.heartbeat_loss_specs():
                self.eq.push(spec.at + self.heartbeat_timeout + 1e-6,
                             self._failure_check)
        for req in requests:
            self.eq.push(req.arrival_time, (lambda r: (lambda: self._route(r)))(req))
        self.eq.run_until(t_max)
        total_tokens = sum(r.num_output for r in self.finished)
        span = max((r.finish_time for r in self.finished), default=1.0)
        e2e = [r.e2e() for r in self.finished if r.e2e() is not None]
        tpot = [t for t in (r.tpot() for r in self.finished) if t is not None]
        ttfts = sorted(t for t in (r.ttft() for r in self.finished)
                       if t is not None)
        p95 = ttfts[max(0, -(-len(ttfts) * 95 // 100) - 1)] if ttfts else 0.0
        starved = [n.node_id for n in self.nodes.values()
                   if n.served_prefill + n.served_decode == 0]
        return {
            "system": self.kind,
            "routing": self.routing,
            "offered": self.offered,
            "rejected": len(self.rejected),
            # prefix-reuse plane (priced identically to the real engine:
            # hits shrink the prefill chunks the duration model sees)
            "prefill_tokens_computed": sum(
                n.prefill_tokens_computed for n in self.nodes.values()),
            "prefix_hits": self.prefix_hits,
            "prefix_tokens_reused": self.prefix_tokens_reused,
            "prefix_fetches": self.prefix_fetches,
            "mean_prefix_fetch_dispatches": (
                sum(self.prefix_fetch_dispatches) / len(self.prefix_fetch_dispatches)
                if self.prefix_fetch_dispatches else 0.0),
            "p95_ttft_s": p95,
            "starved_nodes": len(starved),
            "finished": len(self.finished),
            "throughput_tok_s": total_tokens / span if span else 0.0,
            "mean_e2e_s": sum(e2e) / len(e2e) if e2e else 0.0,
            "mean_tpot_s": sum(tpot) / len(tpot) if tpot else 0.0,
            "mean_transfer_s": (sum(self.transfer_latencies) / len(self.transfer_latencies)
                                if self.transfer_latencies else 0.0),
            "mean_transfer_calls": (sum(self.transfer_calls) / len(self.transfer_calls)
                                    if self.transfer_calls else 0.0),
            "mean_transfer_dispatches": (
                sum(self.transfer_dispatches) / len(self.transfer_dispatches)
                if self.transfer_dispatches else 0.0),
            # layer-window overlap: wire time hidden behind prefill compute;
            # mean_transfer_s above is the EXPOSED remainder
            "transfer_hidden_s": sum(self.transfer_hidden),
            "transfer_hidden_frac": (
                sum(self.transfer_hidden)
                / (sum(self.transfer_hidden) + sum(self.transfer_latencies))
                if self.transfer_hidden and
                (sum(self.transfer_hidden) + sum(self.transfer_latencies)) > 0
                else 0.0),
            "events": len(self.controller.events),
            # mesh-parallel topology (same keys as PDCluster.stats)
            "sharded_nodes": sum(1 for n in self.nodes.values() if n.tp > 1),
            "max_tp_degree": max(
                (n.tp for n in self.nodes.values()), default=1),
            # tier plane (same keys as PDCluster.stats)
            "tier_demoted_blocks": sum(
                t.demoted_blocks for t in self.tiers.values()),
            "tier_promoted_blocks": sum(
                t.promoted_blocks for t in self.tiers.values()),
            "tier_host_resident": sum(
                t.host.num_resident for t in self.tiers.values()),
            "cached_reused": sum(
                n.bm.cached_reused for n in self.nodes.values()),
            "cached_evicted": sum(
                n.bm.cached_evicted for n in self.nodes.values()),
            # fault plane (same keys as PDCluster.stats)
            "fault_kills": self.fault_kills,
            "transfer_retries": self.transfer_retry_count,
            "degraded_to_recompute": self.degraded_to_recompute,
            "recoveries": self.recoveries,
            "leaked_blocks": float(self.audit_blocks()),
        }

    # -- leak auditing ------------------------------------------------------------------
    def live_request_ids(self) -> set:
        """Cluster-wide live set (see PDCluster.live_request_ids): a SENDING
        request's dst registration lives on the destination bm while the
        request queues on the source."""
        live = set()
        for node in self.nodes.values():
            s = node.scheduler
            for sub in (s.prefill, s.decode):
                for q in (sub.waiting, sub.running, sub.swapped, sub.sending):
                    live.update(r.request_id for r in q)
        live.update(r.request_id for r in self.controller.retry_queue)
        live.update(r.request_id for r in self.controller.deferred)
        return live

    def audit_blocks(self) -> int:
        """Count leaked block tables fleet-wide (0 on a healthy run)."""
        live = self.live_request_ids()
        leaked = 0
        for node in self.nodes.values():
            node.bm.check_invariants()
            tm = self.tiers.get(node.node_id)
            if tm is not None and node.node_id not in self._dead:
                tm.check_invariants()
            leaked += sum(1 for rid in node.bm._table if rid not in live)
        return leaked

    def assert_no_leaks(self) -> None:
        """Hard audit (tests / chaos gate): raise on any leaked table."""
        live = self.live_request_ids()
        for node in self.nodes.values():
            node.bm.assert_no_leaks(live)
