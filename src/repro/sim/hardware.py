"""Hardware profiles for the cluster simulator and the controller's cost
estimates.

GPU profiles cover the paper's measurement fleet (A100 homogeneous, L20/H20
heterogeneous); TPU v5e is the port target and uses the system constants
(197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).

Step-time estimation follows the standard roofline split: prefill is
compute-bound (FLOPs / peak), decode is memory-bound (weight + KV bytes /
HBM bandwidth), each with a floor from kernel-dispatch overhead.
"""
from __future__ import annotations

import dataclasses

from repro.core.costmodel import (NCCL_ENI, IPC, TPU_DCN, TPU_ICI,
                                  TransportProfile, predicted_ttft_s)


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str
    peak_flops: float           # bf16 FLOP/s per device
    hbm_bandwidth: float        # bytes/s per device
    hbm_bytes: int              # capacity per device
    intra_host: TransportProfile
    inter_host: TransportProfile
    mfu_prefill: float = 0.55   # achievable fraction of peak in prefill
    mbu_decode: float = 0.60    # achievable fraction of HBM bw in decode
    step_overhead_s: float = 4e-3

    # -- step-time estimates --------------------------------------------------
    def prefill_time(self, flops: float) -> float:
        # one formula with the controller's routing/admission TTFT estimate
        return predicted_ttft_s(0.0, flops,
                                self.peak_flops * self.mfu_prefill,
                                self.step_overhead_s)

    def decode_time(self, bytes_moved: float) -> float:
        return self.step_overhead_s + bytes_moved / (self.hbm_bandwidth * self.mbu_decode)


A100 = HardwareProfile(
    name="A100-80G",
    peak_flops=312e12, hbm_bandwidth=2.0e12, hbm_bytes=80 << 30,
    intra_host=IPC, inter_host=NCCL_ENI,
)
L20 = HardwareProfile(  # compute-lean, bandwidth-lean (48 GB) — paper's P-friendly card
    name="L20-48G",
    peak_flops=119e12, hbm_bandwidth=0.864e12, hbm_bytes=48 << 30,
    intra_host=IPC, inter_host=NCCL_ENI,
)
H20 = HardwareProfile(  # compute-lean but bandwidth/memory-rich — paper's D-friendly card
    name="H20-96G",
    peak_flops=148e12, hbm_bandwidth=4.0e12, hbm_bytes=96 << 30,
    intra_host=IPC, inter_host=NCCL_ENI,
)
TPU_V5E = HardwareProfile(
    name="TPUv5e",
    peak_flops=197e12, hbm_bandwidth=819e9, hbm_bytes=16 << 30,
    intra_host=TPU_ICI, inter_host=TPU_DCN,
)

PROFILES = {p.name: p for p in (A100, L20, H20, TPU_V5E)}
ALIASES = {"a100": A100, "l20": L20, "h20": H20, "tpuv5e": TPU_V5E, "v5e": TPU_V5E}


def get_hardware(name: str) -> HardwareProfile:
    key = name.lower()
    if key in ALIASES:
        return ALIASES[key]
    if name in PROFILES:
        return PROFILES[name]
    raise ValueError(f"unknown hardware {name!r}; have {sorted(ALIASES)}")
