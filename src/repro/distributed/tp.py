"""Single-controller tensor/expert-parallel execution of the serving paths.

A ``NodeEngine`` with ``tp_degree > 1`` runs every layer's attention and FFN
as ``tp`` independent shard computations over parameter slices chosen by the
SAME logical-axis rule walk production meshes use (``spec_for`` over
``transformer.param_axes`` with an :class:`~repro.distributed.sharding.
AbstractMesh` whose ``model`` axis has size ``tp``):

* attention — wq/wk/wv column-sliced on (kv_)heads, per-shard
  :func:`~repro.models.attention.self_attention_heads` /
  :func:`~repro.models.attention.decode_paged_attention_heads`;
* dense MLP — w_gate/w_up column-sliced on the mlp dim;
* MoE — router columns + expert slices (expert parallelism; dense-dispatch
  combine only).

Bit-identity with the single-device engine is by construction, not by
tolerance: every sliced computation is per-output-column (or per-kv-head /
per-expert) independent, so the concatenation of shard outputs reproduces
the full-width intermediate exactly, and every COMBINE contraction
(``out_project``'s reduce over heads, ``w_down``'s reduce over the mlp dim,
the MoE combine's reduce over experts) runs ONCE over the concatenated
operands — never as per-shard partial sums, whose float addition order
would differ from the unsharded einsum. On a real mesh the concatenations
are the all-gathers the logical-axis rules imply; here they are
``jnp.concatenate`` on one controller, which keeps the data path testable
on 1-CPU hosts (``make_local_mesh`` cannot build a model>1 mesh there).

Embedding and unembedding stay replicated: the rule table maps ``vocab`` to
the model axis, but a vocab-sharded gather/projection needs masked
all-reduce plumbing that buys nothing for the serving data path reproduced
here.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import DEFAULT_RULES, AbstractMesh, spec_for
from repro.models import attention as A
from repro.models import mlp as M
from repro.models import moe as MOE
from repro.models import transformer as TF
from repro.models.common import ModelConfig, embed, rms_norm, unembed

Params = Dict[str, Any]

TP_FAMILIES = ("dense", "moe")


def ep_degree(cfg: ModelConfig, tp: int) -> int:
    """Expert-parallel degree implied by a tp degree: MoE configs run their
    experts over the same model axis, everything else has no expert axis."""
    return tp if cfg.family == "moe" else 1


def validate_tp(cfg: ModelConfig, tp: int) -> None:
    """Reject configs the sharded data path cannot run exactly."""
    if tp <= 1:
        return
    if cfg.family not in TP_FAMILIES:
        raise ValueError(f"tensor parallelism supports families {TP_FAMILIES}, "
                         f"got {cfg.family!r}")
    if cfg.num_heads % tp or cfg.num_kv_heads % tp:
        raise ValueError(f"heads ({cfg.num_heads}/{cfg.num_kv_heads}) must "
                         f"divide tp={tp}")
    if cfg.family == "moe":
        if cfg.num_experts % tp:
            raise ValueError(f"experts ({cfg.num_experts}) must divide tp={tp}")
        if cfg.moe_dispatch != "dense" or (cfg.top_k == 1 and
                                           cfg.moe_sparse_dispatch):
            raise ValueError("expert-parallel serving supports dense dispatch "
                             "only (capacity/sparse dispatch reorders tokens "
                             "per shard)")
    elif cfg.d_ff % tp:
        raise ValueError(f"d_ff ({cfg.d_ff}) must divide tp={tp}")


def shard_params(params: Params, cfg: ModelConfig, tp: int) -> List[Params]:
    """Slice a full parameter tree into ``tp`` shard trees.

    Which dim of each tensor is sliced is decided by ``spec_for`` over
    ``param_axes`` — the exact walk a production mesh's shardings use — so
    the emulation and a real ``model``-axis mesh partition identically.
    Replicated tensors are shared by reference, not copied.
    """
    validate_tp(cfg, tp)
    if tp == 1:
        return [params]
    mesh = AbstractMesh(model=tp)
    axes = dict(TF.param_axes(cfg))
    axes["embed"] = (None, None)        # replicated (see module docstring)
    if "unembed" in axes:
        axes["unembed"] = (None, None)
    flat, treedef = jax.tree.flatten(params)
    axes_flat = treedef.flatten_up_to(axes)
    shards: List[Params] = []
    for s in range(tp):
        leaves = []
        for x, ax in zip(flat, axes_flat):
            spec = spec_for(x.shape, ax, mesh, DEFAULT_RULES)
            dim = next((i for i, part in enumerate(spec) if part == "model"),
                       None)
            if dim is None:
                leaves.append(x)
            else:
                width = x.shape[dim] // tp
                leaves.append(jax.lax.slice_in_dim(
                    x, s * width, (s + 1) * width, axis=dim))
        shards.append(jax.tree.unflatten(treedef, leaves))
    return shards


# ---------------------------------------------------------------------------
# Shard-and-merge layer bodies
# ---------------------------------------------------------------------------
def _merged_out_project(lps: Sequence[Params], outs: Sequence[jax.Array]
                        ) -> jax.Array:
    """Concat shard head-outputs + shard wo slices, ONE combine einsum."""
    out = jnp.concatenate(list(outs), axis=2)
    wo = jnp.concatenate([lp["wo"] for lp in lps], axis=0)
    return A.out_project({"wo": wo}, out)


def _sharded_mlp(lps: Sequence[Params], x: jax.Array, cfg: ModelConfig
                 ) -> Tuple[jax.Array, jax.Array]:
    """Column-parallel SwiGLU: per-shard gate/up, one full-width down."""
    hidden = jnp.concatenate(
        [M._act(jnp.einsum("bsd,df->bsf", x, lp["w_gate"]), cfg.activation)
         * jnp.einsum("bsd,df->bsf", x, lp["w_up"]) for lp in lps], axis=-1)
    w_down = jnp.concatenate([lp["w_down"] for lp in lps], axis=0)
    return jnp.einsum("bsf,fd->bsd", hidden, w_down), jnp.zeros((), jnp.float32)


def _sharded_moe(moe_ps: Sequence[Params], x: jax.Array, cfg: ModelConfig
                 ) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel dense-dispatch MoE (mirrors ``moe.moe_ffn``).

    Router logits are per-expert-column independent, so shard columns concat
    to the full logits exactly; routing (softmax / top-k / normalize) then
    runs replicated on the full tensor, expert matmuls run per shard on the
    expert slices, and the token combine reduces ONCE over the concatenated
    (B, S, E, D) expert outputs.
    """
    x32 = x.astype(jnp.float32)
    logits = jnp.concatenate(
        [jnp.einsum("bsd,de->bse", x32, p["router"].astype(jnp.float32))
         for p in moe_ps], axis=-1)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_idx = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    combine = jnp.zeros_like(probs).at[
        jnp.arange(probs.shape[0])[:, None, None],
        jnp.arange(probs.shape[1])[None, :, None],
        top_idx,
    ].set(top_p)
    combine = combine.astype(x.dtype)
    expert_out = jnp.concatenate(
        [jnp.einsum("bsef,efd->bsed",
                    MOE._act(jnp.einsum("bsd,edf->bsef", x, p["w_gate"]),
                             cfg.activation)
                    * jnp.einsum("bsd,edf->bsef", x, p["w_up"]),
                    p["w_down"]) for p in moe_ps], axis=2)
    out = jnp.einsum("bsed,bse->bsd", expert_out, combine)
    density = combine.astype(jnp.float32).mean(axis=(0, 1))
    router_prob = probs.mean(axis=(0, 1))
    aux = cfg.num_experts * jnp.sum(density * router_prob)
    return out, aux


def _sharded_ffn(lps: Sequence[Params], x: jax.Array, cfg: ModelConfig
                 ) -> Tuple[jax.Array, jax.Array]:
    if cfg.family == "moe":
        moe_ps = [{k[len("moe_"):]: v for k, v in lp.items()
                   if k.startswith("moe_")} for lp in lps]
        return _sharded_moe(moe_ps, x, cfg)
    return _sharded_mlp(lps, x, cfg)


def _kv_head_slices(arr: jax.Array, tp: int, axis: int) -> List[jax.Array]:
    """Contiguous kv-head slices of a full-width cache tensor."""
    width = arr.shape[axis] // tp
    return [jax.lax.slice_in_dim(arr, s * width, (s + 1) * width, axis=axis)
            for s in range(tp)]


# ---------------------------------------------------------------------------
# Entry points (mirror transformer.prefill / prefill_suffix /
# decode_step_paged with sharded layer bodies)
# ---------------------------------------------------------------------------
def sharded_prefill(shards: Sequence[Params], cfg: ModelConfig,
                    tokens: jax.Array
                    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Sharded twin of ``transformer.prefill``; returns the FULL-width cache
    (k/v (L, B, S, KV, hd)) so callers slice per shard when writing pools."""
    x = embed(tokens, shards[0]["embed"], scale=cfg.embed_scale)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(carry, lps):
        h, aux = carry
        hn = rms_norm(h, lps[0]["norm_attn"], cfg.norm_eps)
        outs, ks, vs = [], [], []
        for lp in lps:
            o, (k, v) = A.self_attention_heads(lp, hn, cfg, positions,
                                               cfg.attn_window)
            outs.append(o), ks.append(k), vs.append(v)
        h = h + _merged_out_project(lps, outs)
        hn = rms_norm(h, lps[0]["norm_mlp"], cfg.norm_eps)
        ffn_out, aux_i = _sharded_ffn(lps, hn, cfg)
        return (h + ffn_out, aux + aux_i), (jnp.concatenate(ks, axis=2),
                                            jnp.concatenate(vs, axis=2))

    (x, _), (ks, vs) = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        tuple(sp["layers"] for sp in shards))
    x = rms_norm(x[:, -1:], shards[0]["final_norm"], cfg.norm_eps)
    logits = unembed(x, shards[0].get("unembed", shards[0]["embed"]))[:, 0]
    length = jnp.full((tokens.shape[0],), ks.shape[2], jnp.int32)
    return logits, {"k": ks, "v": vs, "length": length}


def sharded_prefill_suffix(shards: Sequence[Params], cfg: ModelConfig,
                           tokens: jax.Array, prefix_k: jax.Array,
                           prefix_v: jax.Array
                           ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Sharded twin of ``transformer.prefill_suffix`` (chunked prefill /
    prefix-cache hits). prefix_k/v are FULL-width (L, B, C, KV, hd)."""
    tp = len(shards)
    x = embed(tokens, shards[0]["embed"], scale=cfg.embed_scale)
    c = prefix_k.shape[2]
    positions = c + jnp.arange(x.shape[1])[None, :]

    def body(carry, inputs):
        h, aux = carry
        lps, pk, pv = inputs
        hn = rms_norm(h, lps[0]["norm_attn"], cfg.norm_eps)
        outs, ks, vs = [], [], []
        for lp, pk_s, pv_s in zip(lps, _kv_head_slices(pk, tp, 2),
                                  _kv_head_slices(pv, tp, 2)):
            o, (k, v) = A.suffix_attention_heads(lp, hn, cfg, positions,
                                                 pk_s, pv_s, cfg.attn_window)
            outs.append(o), ks.append(k), vs.append(v)
        h = h + _merged_out_project(lps, outs)
        hn = rms_norm(h, lps[0]["norm_mlp"], cfg.norm_eps)
        ffn_out, aux_i = _sharded_ffn(lps, hn, cfg)
        return (h + ffn_out, aux + aux_i), (jnp.concatenate(ks, axis=2),
                                            jnp.concatenate(vs, axis=2))

    (x, _), (ks, vs) = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (tuple(sp["layers"] for sp in shards), prefix_k, prefix_v))
    x = rms_norm(x[:, -1:], shards[0]["final_norm"], cfg.norm_eps)
    logits = unembed(x, shards[0].get("unembed", shards[0]["embed"]))[:, 0]
    length = jnp.full((tokens.shape[0],), c + ks.shape[2], jnp.int32)
    return logits, {"k": ks, "v": vs, "length": length}


def sharded_decode_step_paged(shards: Sequence[Params], cfg: ModelConfig,
                              token: jax.Array,
                              pools: Sequence[jax.Array],
                              block_tables: jax.Array, lengths: jax.Array,
                              *, interpret: Optional[bool] = None
                              ) -> Tuple[jax.Array, Tuple[jax.Array, ...]]:
    """Sharded twin of ``transformer.decode_step_paged``.

    ``pools[s]`` is shard s's FLOWKV pool (same blocks/layers, its kv-head
    slice of every payload). Each shard reads its own page plane through the
    paged kernel and appends its slice of the batch's new K/V with its own
    fused scatter — on a real mesh that is one dispatch per device, here
    ``tp`` calls inside one jitted step. The in-flight-token online-softmax
    merge runs ONCE on the concatenated kernel stats (the post-gather merge):
    its einsums are not bit-stable across kv-head extents, so a per-shard
    merge would drift from the single-device logits by an ulp.
    """
    from repro.kernels.kv_gather import kv_append_tokens
    from repro.kernels.paged_attention import paged_decode_attention

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    x = embed(token[:, None], shards[0]["embed"], scale=cfg.embed_scale)
    position = lengths
    num_layers = pools[0].shape[1]

    def body(h, inputs):
        lps, layer = inputs
        hn = rms_norm(h, lps[0]["norm_attn"], cfg.norm_eps)
        pos = jnp.broadcast_to(jnp.asarray(position), (hn.shape[0],))
        q1s, k1s, v1s, outs, ms, ls = [], [], [], [], [], []
        for lp, pool in zip(lps, pools):
            pages = jax.lax.dynamic_index_in_dim(pool, layer, axis=1,
                                                 keepdims=False)
            q, k_new, v_new = A.qkv_project(lp, hn, cfg, pos[:, None])
            q1s.append(q[:, 0]), k1s.append(k_new[:, 0]), v1s.append(v_new[:, 0])
            o, m, l = paged_decode_attention(
                q[:, 0], pages, block_tables, pos, block_size=cfg.block_size,
                interpret=interpret, return_stats=True)
            outs.append(o), ms.append(m), ls.append(l)
        kns, vns = k1s, v1s
        merged = A.merge_inflight_token(
            jnp.concatenate(q1s, axis=1), jnp.concatenate(k1s, axis=1),
            jnp.concatenate(v1s, axis=1), jnp.concatenate(outs, axis=1),
            jnp.concatenate(ms, axis=1), jnp.concatenate(ls, axis=1), hn.dtype)
        h = h + _merged_out_project(lps, [merged])
        hn = rms_norm(h, lps[0]["norm_mlp"], cfg.norm_eps)
        ffn_out, _ = _sharded_ffn(lps, hn, cfg)
        return h + ffn_out, (tuple(kns), tuple(vns))

    x, (ks, vs) = jax.lax.scan(
        body, x, (tuple(sp["layers"] for sp in shards),
                  jnp.arange(num_layers, dtype=jnp.int32)))
    new_pools = tuple(
        kv_append_tokens(pool, block_tables, position, ks[s], vs[s],
                         block_size=cfg.block_size, interpret=interpret)
        for s, pool in enumerate(pools))
    x = rms_norm(x, shards[0]["final_norm"], cfg.norm_eps)
    logits = unembed(x, shards[0].get("unembed", shards[0]["embed"]))[:, 0]
    return logits, new_pools
