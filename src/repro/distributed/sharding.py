"""Logical-axis -> mesh sharding rules with divisibility fallbacks.

Models annotate every parameter / cache / input dim with a *logical* axis
name; this module maps those onto the production mesh:

    batch    -> ("pod", "data")   (multi-pod) or ("data",)
    heads / kv_heads / mlp / experts / vocab / inner / lru -> "model"
    kv_seq   -> "model"           (decode caches; wins when kv_heads
                                   can't divide the model axis)
    everything else replicated

Assignment walks a tensor's dims in order; a mesh axis is used at most once
per tensor, and a candidate is skipped when the dim size isn't divisible by
the mesh-axis size (e.g. gemma's 8 query heads on a 16-way model axis fall
back to replication — see DESIGN.md and the llava hillclimb in
EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> ordered mesh-axis candidates. Each candidate is a tuple of
# mesh axes to use JOINTLY for that dim (e.g. batch over pod x data).
DEFAULT_RULES: Dict[str, Tuple[Tuple[str, ...], ...]] = {
    "batch": (("pod", "data"), ("data",)),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "mlp": (("model",),),
    "experts": (("model",),),
    "vocab": (("model",),),
    "inner": (("model",),),     # mamba2 d_inner channels
    "lru": (("model",),),       # griffin RG-LRU width
    "kv_seq": (("model",),),    # decode-cache length dim (fallback TP target)
    # "kv_pages" — a paged pool's block/page dim — is EXPLICITLY pinned to
    # replication: page ids are global names shared by every shard's block
    # manager and transfer descriptor table, so sharding the page dim would
    # silently split the address space the descriptor plane indexes into.
    # A paged pool shards only inside the payload (its kv-head slice; see
    # serving/kv_cache.ShardedKVCache), never across pages. Declared as an
    # empty candidate list (not just left out of the dict) so the intent
    # survives anyone extending the kv_seq fallback chain.
    "kv_pages": (),
    # replicated: embed, head_dim, seq, layers, groups, conv, state, lru_in
}

# Canonical logical axes of a FLOWKV paged pool (num_blocks, L, 2, payload).
# The page dim must use "kv_pages" (never "kv_seq": the decode-cache length
# fallback would shard page tables when num_blocks happens to divide the
# model axis — see tests/test_sharding.py::test_paged_pool_never_shards_pages).
PAGED_POOL_AXES: Tuple[Optional[str], ...] = ("kv_pages", "layers", None, None)


class AbstractMesh:
    """Mesh stand-in for planning shardings without physical devices.

    ``spec_for`` / ``tree_specs`` only consult ``axis_names`` and
    ``devices.shape``, so parameter-slicing decisions for a tp-degree that
    exceeds the local device count (the single-controller TP emulation in
    ``distributed/tp.py``, unit tests on 1-CPU hosts) can reuse the exact
    production rule walk. Not usable with ``NamedSharding``/``jax.jit``.
    """

    def __init__(self, **sizes: int):
        self.axis_names = tuple(sizes)
        self.devices = np.empty(tuple(sizes.values()), dtype=object)


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for(shape: Sequence[int], logical: Sequence[Optional[str]], mesh: Mesh,
             rules: Optional[Dict] = None) -> P:
    """Build a PartitionSpec for one tensor, with divisibility fallbacks."""
    rules = rules or DEFAULT_RULES
    sizes = mesh_axis_sizes(mesh)
    used: set = set()
    parts = []
    assert len(shape) == len(logical), (shape, logical)
    for dim, name in zip(shape, logical):
        assigned = None
        for cand in rules.get(name or "", ()):
            cand = tuple(ax for ax in cand if ax in sizes)
            if not cand or any(ax in used for ax in cand):
                continue
            total = math.prod(sizes[ax] for ax in cand)
            if dim % total != 0:
                continue
            assigned = cand
            used.update(cand)
            break
        if assigned is None:
            parts.append(None)
        elif len(assigned) == 1:
            parts.append(assigned[0])
        else:
            parts.append(assigned)
    # trim trailing Nones (canonical form)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_specs(shapes_tree: Any, axes_tree: Any, mesh: Mesh,
               rules: Optional[Dict] = None) -> Any:
    """Map spec_for over parallel (shapes, logical axes) pytrees.

    ``shapes_tree`` leaves: arrays or ShapeDtypeStructs. ``axes_tree``
    leaves: tuples of logical axis names (a tuple IS a pytree, so we walk
    the shapes tree and look the axes up by path).
    """
    flat, treedef = jax.tree.flatten(shapes_tree)
    axes_flat = treedef.flatten_up_to(axes_tree)
    specs = [spec_for(x.shape, ax, mesh, rules) for x, ax in zip(flat, axes_flat)]
    return jax.tree.unflatten(treedef, specs)


def tree_shardings(shapes_tree: Any, axes_tree: Any, mesh: Mesh,
                   rules: Optional[Dict] = None) -> Any:
    specs = tree_specs(shapes_tree, axes_tree, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def scalar_spec() -> P:
    return P()


def bytes_per_device(shapes_tree: Any, specs_tree: Any, mesh: Mesh) -> int:
    """Estimate per-device bytes for a (shapes, specs) pair."""
    sizes = mesh_axis_sizes(mesh)
    total = 0
    flat, treedef = jax.tree.flatten(shapes_tree)
    specs = treedef.flatten_up_to(specs_tree)
    for x, spec in zip(flat, specs):
        shard = 1
        for entry in spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            shard *= math.prod(sizes[a] for a in axes)
        total += int(np.prod(x.shape)) * x.dtype.itemsize // max(1, shard)
    return total
