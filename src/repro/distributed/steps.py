"""Jitted distributed step functions: train_step / prefill_step / decode_step
plus the FlowKV cross-pod KV-transfer program.

Every step is built as (fn, in_shardings, out_shardings) against a concrete
mesh, ready for ``jax.jit(...).lower(**specs).compile()`` — the multi-pod
dry-run path — or for real execution on the CPU-scale meshes in tests.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as SH
from repro.models.api import Model, input_specs
from repro.models.common import ModelConfig
from repro.training import optimizer as OPT


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------
def zero1_specs(params_shapes, p_spec, mesh: Mesh):
    """Extend TP param specs with data(-and-pod)-axis sharding for the
    optimizer state (ZeRO-1): the first unsharded dim divisible by the
    data-axis size additionally shards over ("data",) (+"pod" if present).

    Under SPMD this makes XLA reduce-scatter gradients into the optimizer
    shards and all-gather updated params once per step — exactly the ZeRO-1
    communication pattern.
    """
    sizes = SH.mesh_axis_sizes(mesh)
    dp_axes = tuple(a for a in ("pod", "data") if a in sizes)
    dp = 1
    for a in dp_axes:
        dp *= sizes[a]

    def extend(x, spec):
        parts = list(spec) + [None] * (len(x.shape) - len(spec))
        for i, (dim, cur) in enumerate(zip(x.shape, parts)):
            if cur is None and dim % dp == 0 and dim >= dp:
                parts[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
                break
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    return jax.tree.map(extend, params_shapes, p_spec,
                        is_leaf=lambda s: isinstance(s, P))


def make_train_step(model: Model, mesh: Mesh, params_shapes,
                    opt_cfg: Optional[OPT.AdamWConfig] = None,
                    compress_pod_grads: bool = False, zero1: bool = True):
    """Returns (train_step, state_spec).

    ``train_step(state, batch) -> (state, metrics)``. Compute params stay
    TP-sharded (logical rules); master/m/v are additionally ZeRO-1 sharded
    over the data(+pod) axes. The bf16 compute cast is constrained back to
    the TP spec so the ZeRO all-gather happens once per step, not per layer.

    ``compress_pod_grads``: int8-compress gradients (with error feedback)
    before the optimizer — the DCN gradient-compression path.
    """
    opt_cfg = opt_cfg or OPT.AdamWConfig()
    cfg = model.cfg
    axes = model.param_axes()
    p_spec = SH.tree_specs(params_shapes, axes, mesh)
    z_spec = zero1_specs(params_shapes, p_spec, mesh) if zero1 else p_spec
    p_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), p_spec,
                               is_leaf=lambda s: isinstance(s, P))

    def train_step(state, batch):
        def loss_with_compute_dtype(master):
            compute = jax.tree.map(
                lambda w, sh: jax.lax.with_sharding_constraint(w.astype(cfg.dtype), sh),
                master, p_shardings)
            return model.loss(compute, batch)

        loss, grads = jax.value_and_grad(loss_with_compute_dtype)(state["master"])
        if compress_pod_grads:
            q, scales, residual = OPT.compress_grads(grads, state["ef"])
            grads = OPT.decompress_grads(q, scales)
            new_state, metrics = OPT.apply_updates(
                {k: v for k, v in state.items() if k != "ef"}, grads, opt_cfg,
                compute_dtype=cfg.dtype)
            new_state["ef"] = residual
        else:
            new_state, metrics = OPT.apply_updates(state, grads, opt_cfg,
                                                   compute_dtype=cfg.dtype)
        metrics["loss"] = loss
        return new_state, metrics

    state_spec = {"params": p_spec, "master": z_spec, "m": z_spec,
                  "v": z_spec, "step": P()}
    if compress_pod_grads:
        state_spec["ef"] = z_spec
    return train_step, state_spec


def abstract_train_state(model: Model, with_ef: bool = False):
    """eval_shape the full train state without allocating."""
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    state = {
        "params": params,
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if with_ef:
        state["ef"] = jax.tree.map(f32, params)
    return state


# ---------------------------------------------------------------------------
# Serve: prefill / decode
# ---------------------------------------------------------------------------
def make_prefill_step(model: Model, mesh: Mesh):
    """prefill_step(params, batch) -> (logits, cache)."""
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def make_decode_step(model: Model, mesh: Mesh):
    """decode_step(params, token, cache) -> (logits, cache). Cache donated."""
    def decode_step(params, token, cache):
        return model.decode(params, token, cache)

    return decode_step


# ---------------------------------------------------------------------------
# FlowKV cross-pod KV transfer (the paper-representative collective program)
# ---------------------------------------------------------------------------
# The old make_kv_transfer_step ring-shift (ppermute over "pod") is gone: the
# serving data plane moves KV through descriptor-table plans
# (core/transfer.py — ShardedTransferEngine for mesh-parallel pools), which
# subsumes the whole-pool shift with per-page addressing. Only the
# shape/sharding specs below survive for the dry-run compile path.
def kv_transfer_specs(cfg: ModelConfig, mesh: Mesh, seq: int, batch: int):
    """ShapeDtypeStructs for the transfer program: the paged FlowKV pool.

    Pool shape (num_blocks, L, 2, payload): block-major (paper Eq. 5), block
    dim sharded (pod, data) so each pod/replica owns its page slab.
    """
    from repro.core.layout import KVCacheSpec

    n_attn = cfg.num_attention_layers()
    if n_attn == 0:   # ssm: transfer the state tensor instead
        spec = jax.ShapeDtypeStruct(
            (batch, cfg.num_layers, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            cfg.dtype)
        return spec, P("pod")
    kv_spec = KVCacheSpec(
        num_layers=n_attn,
        num_blocks=batch * -(-seq // cfg.block_size),
        block_size=cfg.block_size,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim,
        dtype=cfg.dtype,
    )
    spec = jax.ShapeDtypeStruct(kv_spec.shape, cfg.dtype)
    return spec, P("pod")
