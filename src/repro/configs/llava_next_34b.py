"""llava-next-34b [vlm] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000, anyres tiling (stub patch embeddings, 2880 tokens).
[hf:llava-hf/llava-v1.6-34b-hf; unverified]"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

ARCH_ID = "llava-next-34b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="vlm", num_layers=60, d_model=7168,
        num_heads=56, num_kv_heads=8, head_dim=128, d_ff=20480,
        vocab_size=64000, frontend="vision", frontend_tokens=2880,
        rope_theta=5000000.0, dtype=jnp.bfloat16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="vlm", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=128, frontend="vision", frontend_tokens=16, dtype=jnp.float32,
    )
