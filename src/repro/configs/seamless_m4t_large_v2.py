"""seamless-m4t-large-v2 [audio enc-dec] — 24L enc + 24L dec, d_model=1024
16H (MHA kv=16) d_ff=8192 vocab=256206. Frontend = stub frame embeddings.
[arXiv:2308.11596; hf]"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

ARCH_ID = "seamless-m4t-large-v2"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="encdec", num_layers=24, num_encoder_layers=24,
        d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64, d_ff=8192,
        cross_attention=True, frontend="audio", vocab_size=256206,
        dtype=jnp.bfloat16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="encdec", num_layers=2, num_encoder_layers=2,
        d_model=32, num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
        cross_attention=True, frontend="audio", vocab_size=128, dtype=jnp.float32,
    )
