"""stablelm-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352. [hf:stabilityai/stablelm-2-12b; hf]"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

ARCH_ID = "stablelm-12b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense", num_layers=40, d_model=5120,
        num_heads=32, num_kv_heads=8, head_dim=160, d_ff=13824,
        vocab_size=100352, dtype=jnp.bfloat16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=128, dtype=jnp.float32,
    )
