"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000, RG-LRU + local attn (window 2048), pattern 2 rec : 1 attn.
[arXiv:2402.19427; hf]"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

ARCH_ID = "recurrentgemma-2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="hybrid", num_layers=26, d_model=2560,
        num_heads=10, num_kv_heads=1, head_dim=256, d_ff=7680,
        activation="gelu", attn_window=2048, layer_pattern=("rec", "rec", "attn"),
        lru_width=2560, vocab_size=256000, embed_scale=True, dtype=jnp.bfloat16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="hybrid", num_layers=8, d_model=32,
        num_heads=2, num_kv_heads=1, head_dim=16, d_ff=64,
        activation="gelu", attn_window=8, layer_pattern=("rec", "rec", "attn"),
        lru_width=32, vocab_size=128, embed_scale=True, dtype=jnp.float32,
    )
