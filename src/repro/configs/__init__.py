"""Config registry: the 10 assigned architectures + the paper's own models.

``--arch <id>`` everywhere resolves through :func:`get_config`.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.common import ModelConfig

# arch id -> module name
_REGISTRY: Dict[str, str] = {
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "minitron-8b": "minitron_8b",
    "gemma-2b": "gemma_2b",
    "stablelm-12b": "stablelm_12b",
    "qwen3-1.7b": "qwen3_1_7b",
    "mamba2-370m": "mamba2_370m",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "llava-next-34b": "llava_next_34b",
    # the paper's own measurement models (Tables 1-3)
    "llama31-8b": "llama31_8b",
    "llama31-70b": "llama31_70b",
}

ASSIGNED_ARCHS: List[str] = list(_REGISTRY)[:10]
ALL_ARCHS: List[str] = list(_REGISTRY)

# The assigned input-shape set: shape name -> (kind, seq_len, global_batch).
SHAPES = {
    "train_4k": ("train", 4096, 256),
    "prefill_32k": ("prefill", 32768, 32),
    "decode_32k": ("decode", 32768, 128),
    "long_500k": ("decode", 524288, 1),
}


def _module(arch: str):
    if arch not in _REGISTRY:
        raise ValueError(f"unknown arch {arch!r}; have {sorted(_REGISTRY)}")
    return importlib.import_module(f"repro.configs.{_REGISTRY[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def shape_applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """Whether a dry-run cell applies to this arch (DESIGN.md §4)."""
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, ("skip: pure full-attention arch has no sub-quadratic "
                       "mechanism at 524k context (DESIGN.md §4)")
    return True, ""


def list_cells(archs=None):
    """All (arch, shape_name) dry-run cells with applicability flags."""
    out = []
    for arch in (archs or ASSIGNED_ARCHS):
        cfg = get_config(arch)
        for shape_name in SHAPES:
            ok, why = shape_applicable(cfg, shape_name)
            out.append({"arch": arch, "shape": shape_name, "applicable": ok, "why": why})
    return out
