"""llama31-70b — the paper's large measurement model (Table 2):
80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256."""
import jax.numpy as jnp
from repro.models.common import ModelConfig

ARCH_ID = "llama31-70b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense", num_layers=80, d_model=8192,
        num_heads=64, num_kv_heads=8, head_dim=128, d_ff=28672,
        vocab_size=128256, rope_theta=500000.0, dtype=jnp.bfloat16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=128, dtype=jnp.float32,
    )
