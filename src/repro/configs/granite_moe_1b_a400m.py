"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) expert_ff=512
vocab=49155, MoE 32 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

ARCH_ID = "granite-moe-1b-a400m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe", num_layers=24, d_model=1024,
        num_heads=16, num_kv_heads=8, head_dim=64, d_ff=0, moe_d_ff=512,
        num_experts=32, top_k=8, vocab_size=49155, dtype=jnp.bfloat16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=0, moe_d_ff=32,
        num_experts=4, top_k=2, vocab_size=128, dtype=jnp.float32,
    )
