"""gemma-2b [dense] — 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000,
GeGLU, head_dim=256, sqrt(d) embedding scale. [arXiv:2403.08295; hf]"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

ARCH_ID = "gemma-2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense", num_layers=18, d_model=2048,
        num_heads=8, num_kv_heads=1, head_dim=256, d_ff=16384,
        activation="gelu", vocab_size=256000, embed_scale=True,
        dtype=jnp.bfloat16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=1, head_dim=16, d_ff=128,
        activation="gelu", vocab_size=128, embed_scale=True, dtype=jnp.float32,
    )
