"""qwen3-1.7b [dense] — 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936, qk_norm. [hf:Qwen/Qwen3-1.7B; hf]"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

ARCH_ID = "qwen3-1.7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense", num_layers=28, d_model=2048,
        num_heads=16, num_kv_heads=8, head_dim=128, d_ff=6144,
        qk_norm=True, vocab_size=151936, rope_theta=1000000.0,
        dtype=jnp.bfloat16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        qk_norm=True, vocab_size=128, dtype=jnp.float32,
    )
