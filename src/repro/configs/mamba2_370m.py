"""mamba2-370m [ssm] — 48L d_model=1024 attn-free, ssm_state=128, SSD.
[arXiv:2405.21060; unverified]"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

ARCH_ID = "mamba2-370m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="ssm", num_layers=48, d_model=1024,
        vocab_size=50280, ssm_state=128, ssm_expand=2, ssm_head_dim=64,
        ssm_conv=4, ssm_chunk=256, dtype=jnp.bfloat16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="ssm", num_layers=2, d_model=64,
        vocab_size=128, ssm_state=16, ssm_expand=2, ssm_head_dim=16,
        ssm_conv=4, ssm_chunk=8, dtype=jnp.float32,
    )
