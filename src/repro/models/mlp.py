"""Gated MLPs (SwiGLU / GeGLU) and the dense transformer block glue."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig


def mlp_param_shapes(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, Tuple[int, ...]]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    return {"w_gate": (d, f), "w_up": (d, f), "w_down": (f, d)}


def mlp_param_axes() -> Dict[str, Tuple[Optional[str], ...]]:
    return {
        "w_gate": ("embed", "mlp"),
        "w_up": ("embed", "mlp"),
        "w_down": ("mlp", "embed"),
    }


def _act(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {kind!r}")


def gated_mlp(p: Dict[str, jax.Array], x: jax.Array, activation: str) -> jax.Array:
    """SwiGLU/GeGLU: down( act(x @ gate) * (x @ up) )."""
    gate = _act(jnp.einsum("bsd,df->bsf", x, p["w_gate"]), activation)
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    return jnp.einsum("bsf,fd->bsd", gate * up, p["w_down"])
