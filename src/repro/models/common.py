"""Shared model substrate: config schema, normed layers, RoPE, embeddings,
and logical-axis annotations used by the sharding layer.

Models are pure-functional JAX: ``init_*`` builds a params pytree of
``jnp`` arrays; a parallel *axes* pytree (same structure, tuples of logical
axis names) feeds ``distributed/sharding.py``, which maps logical axes onto
the production mesh with divisibility fallbacks.

Layer parameters are **stacked** along a leading ``layers`` axis and the
forward passes scan over them (``jax.lax.scan``) so the lowered HLO stays
compact even for 60-layer configs — essential for the 512-device dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    vocab_size: int
    # attention (0/None where attention-free)
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    qk_norm: bool = False
    rope_theta: float = 10000.0
    attn_window: int = 0             # 0 = full causal; >0 = local windowed
    # mlp
    d_ff: int = 0
    activation: str = "silu"         # silu (swiglu) | gelu (geglu)
    # moe
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden (granite: 512)
    # ssm (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 64
    # hybrid (recurrentgemma): repeating layer pattern, e.g. ("rec","rec","attn")
    layer_pattern: Tuple[str, ...] = ()
    lru_width: int = 0
    # encoder-decoder
    num_encoder_layers: int = 0
    cross_attention: bool = False
    # modality frontend stub
    frontend: str = "none"           # none | vision | audio
    frontend_tokens: int = 0         # patches / frames consumed per example
    # numerics / serving
    dtype: Any = jnp.bfloat16
    block_size: int = 32             # KV page size (tokens)
    tie_embeddings: bool = True
    embed_scale: bool = False        # gemma-style sqrt(d_model) input scale
    norm_eps: float = 1e-6
    # attention impl knobs (perf levers; defaults are the faithful baseline)
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    attn_wedge: bool = False         # exact-causal unrolled flash (see models/flash.py)
    flash_threshold: int = 1024      # use chunked flash above this seq length
    moe_sparse_dispatch: bool = False  # gather-based top-1 (serving-scale only)
    moe_dispatch: str = "dense"      # dense (paper-faithful baseline) | gshard
    moe_capacity_factor: float = 1.25
    remat: str = "none"              # none | full | dots — scan-body checkpointing
    tp_reduce_bf16: bool = False     # emit TP partial-sum reductions in bf16

    # -- derived -------------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(1, self.num_kv_heads)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid-with-bounded-window)."""
        return self.family == "ssm" or (self.family == "hybrid" and self.attn_window > 0)

    def kv_bytes_per_token(self) -> int:
        """KV-cache bytes per token (what the P->D transfer moves)."""
        itemsize = jnp.dtype(self.dtype).itemsize
        if self.family == "ssm":
            # SSD state is per-request, not per-token; report amortized 0.
            return 0
        n_attn = self.num_attention_layers()
        return 2 * n_attn * self.num_kv_heads * self.head_dim * itemsize

    def num_attention_layers(self) -> int:
        if self.family == "ssm":
            return 0
        if self.family == "hybrid" and self.layer_pattern:
            pat = self.layer_pattern
            full, rem = divmod(self.num_layers, len(pat))
            return full * sum(1 for t in pat if t == "attn") + sum(
                1 for t in pat[:rem] if t == "attn")
        return self.num_layers

    def num_params(self) -> int:
        """Analytic parameter count (for 6ND roofline math)."""
        d, v = self.d_model, self.vocab_size
        emb = v * d
        if self.family == "ssm":
            di, n, h = self.ssm_d_inner, self.ssm_state, self.ssm_heads
            per = d * (2 * di + 2 * n + h) + di * d + di + h  # in/x/B/C/dt proj + out
            return emb + self.num_layers * per
        attn = d * self.num_heads * self.head_dim + 2 * d * self.num_kv_heads * self.head_dim \
            + self.num_heads * self.head_dim * d
        if self.family == "moe":
            ff = self.num_experts * 3 * d * (self.moe_d_ff or self.d_ff) + d * self.num_experts
        else:
            ff = 3 * d * self.d_ff
        per = attn + ff
        n_layers = self.num_layers
        if self.family == "hybrid":
            rec = 3 * d * self.lru_width + 2 * self.lru_width  # coarse RG-LRU block
            n_attn = self.num_attention_layers()
            return emb + n_attn * per + (self.num_layers - n_attn) * (rec + 3 * d * self.d_ff)
        if self.family == "encdec":
            cross = d * self.num_heads * self.head_dim * 2 + 2 * d * self.num_kv_heads * self.head_dim
            return emb + self.num_encoder_layers * per + n_layers * (per + cross)
        return emb + n_layers * per

    def active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.family != "moe":
            return self.num_params()
        d = self.d_model
        dense_share = self.num_params() - self.num_layers * (
            self.num_experts * 3 * d * (self.moe_d_ff or self.d_ff))
        return dense_share + self.num_layers * self.top_k * 3 * d * (self.moe_d_ff or self.d_ff)


# ---------------------------------------------------------------------------
# Initializers (all take explicit keys; stacked over layers where noted)
# ---------------------------------------------------------------------------
def dense_init(key: jax.Array, shape: Tuple[int, ...], dtype, scale: Optional[float] = None) -> jax.Array:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, dtype=jnp.float32) * s).astype(dtype)


def stacked_dense_init(key: jax.Array, layers: int, shape: Tuple[int, ...], dtype,
                       scale: Optional[float] = None) -> jax.Array:
    return dense_init(key, (layers, *shape), dtype, scale)


# ---------------------------------------------------------------------------
# Normalization / positional encodings
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(dtype)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                      # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def embed(tokens: jax.Array, table: jax.Array, scale: bool = False) -> jax.Array:
    out = jnp.take(table, tokens, axis=0)
    if scale:  # gemma-style sqrt(d) embedding scale
        out = out * jnp.asarray(out.shape[-1] ** 0.5, out.dtype)
    return out


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.einsum("...d,vd->...v", x, table).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token NLL. logits (..., vocab) fp32; labels int (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


# ---------------------------------------------------------------------------
# Param-tree helpers
# ---------------------------------------------------------------------------
def maybe_remat(body, cfg: "ModelConfig"):
    """Wrap a scan body with activation checkpointing per cfg.remat.

    ``full`` recomputes the whole layer in backward (save only carries);
    ``dots`` saves matmul outputs (jax checkpoint_dots policy) — the usual
    sweet spot on TPU where recomputing attention is cheap but recomputing
    big GEMMs is not.
    """
    if cfg.remat == "full":
        return jax.checkpoint(body)
    if cfg.remat == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return body


def count_params(params: Dict[str, Any]) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def cast_params(params, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
                        params)
