"""Decoder-only transformer LM covering the dense, MoE and VLM/backbone
configs (granite-moe, llama4-scout, minitron, gemma, stablelm, qwen3,
llava-next, and the paper's llama-3.1 models).

Layer parameters are stacked on a leading ``layers`` axis; forward passes
``jax.lax.scan`` over them so the lowered HLO is one layer body regardless
of depth. Pre-norm residual blocks::

    x = x + Attn(RMSNorm(x));  x = x + FFN(RMSNorm(x))

Three entry points per model:
  * ``forward_train``  — full-sequence causal logits (training).
  * ``prefill``        — full-sequence forward that also returns the dense
                         KV cache (the tensors FlowKV ships P -> D).
  * ``decode_step``    — one token against a dense cache.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import mlp as M
from repro.models import moe as MOE
from repro.models.common import (ModelConfig, dense_init, embed, maybe_remat,
                                 rms_norm, softmax_cross_entropy, unembed)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    keys = jax.random.split(key, 16)
    L = cfg.num_layers
    d = cfg.d_model

    def stack(k, shape, scale=None):
        return dense_init(k, (L, *shape), cfg.dtype, scale)

    attn_shapes = A.attn_param_shapes(cfg)
    layer: Dict[str, jax.Array] = {
        name: stack(k, shape)
        for (name, shape), k in zip(attn_shapes.items(), jax.random.split(keys[0], len(attn_shapes)))
    }
    if cfg.qk_norm:
        layer["q_norm"] = jnp.zeros((L, cfg.head_dim), cfg.dtype)
        layer["k_norm"] = jnp.zeros((L, cfg.head_dim), cfg.dtype)
    layer["norm_attn"] = jnp.zeros((L, d), cfg.dtype)
    layer["norm_mlp"] = jnp.zeros((L, d), cfg.dtype)
    if cfg.family == "moe":
        moe_shapes = MOE.moe_param_shapes(cfg)
        for (name, shape), k in zip(moe_shapes.items(), jax.random.split(keys[1], len(moe_shapes))):
            layer[f"moe_{name}"] = stack(k, shape)
    else:
        mlp_shapes = M.mlp_param_shapes(cfg)
        for (name, shape), k in zip(mlp_shapes.items(), jax.random.split(keys[2], len(mlp_shapes))):
            layer[name] = stack(k, shape)

    params: Params = {
        "embed": dense_init(keys[3], (cfg.vocab_size, d), cfg.dtype, scale=0.02),
        "final_norm": jnp.zeros((d,), cfg.dtype),
        "layers": layer,
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(keys[4], (cfg.vocab_size, d), cfg.dtype, scale=0.02)
    return params


def param_axes(cfg: ModelConfig) -> Params:
    layer_axes: Dict[str, Tuple[Optional[str], ...]] = {
        name: ("layers", *ax) for name, ax in A.attn_param_axes(cfg).items()
    }
    layer_axes["norm_attn"] = ("layers", "embed")
    layer_axes["norm_mlp"] = ("layers", "embed")
    if cfg.family == "moe":
        for name, ax in MOE.moe_param_axes().items():
            layer_axes[f"moe_{name}"] = ("layers", *ax)
    else:
        for name, ax in M.mlp_param_axes().items():
            layer_axes[name] = ("layers", *ax)
    axes: Params = {
        "embed": ("vocab", "embed"),
        "final_norm": ("embed",),
        "layers": layer_axes,
    }
    if not cfg.tie_embeddings:
        axes["unembed"] = ("vocab", "embed")
    return axes


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------
def _ffn(lp: Params, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    if cfg.family == "moe":
        moe_p = {k[len("moe_"):]: v for k, v in lp.items() if k.startswith("moe_")}
        if cfg.top_k == 1 and cfg.moe_sparse_dispatch:
            return MOE.moe_ffn_topk_sparse(moe_p, x, cfg)
        if cfg.moe_dispatch == "gshard":
            return MOE.moe_ffn_gshard(moe_p, x, cfg, cfg.moe_capacity_factor)
        if cfg.moe_dispatch == "gshard_einsum":
            return MOE.moe_ffn_gshard_einsum(moe_p, x, cfg, cfg.moe_capacity_factor)
        return MOE.moe_ffn(moe_p, x, cfg)
    return M.gated_mlp(lp, x, cfg.activation), jnp.zeros((), jnp.float32)


def _layer_train(cfg: ModelConfig, x: jax.Array, lp: Params,
                 positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    h = rms_norm(x, lp["norm_attn"], cfg.norm_eps)
    attn_out, (k, v) = A.self_attention(lp, h, cfg, positions, cfg.attn_window)
    x = x + attn_out
    h = rms_norm(x, lp["norm_mlp"], cfg.norm_eps)
    ffn_out, aux = _ffn(lp, h, cfg)
    return x + ffn_out, aux, k, v


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------
def _input_embeds(params: Params, cfg: ModelConfig, tokens: jax.Array,
                  frontend_embeds: Optional[jax.Array]) -> jax.Array:
    x = embed(tokens, params["embed"], scale=cfg.embed_scale)
    if frontend_embeds is not None:
        # VLM/audio backbone: splice precomputed patch/frame embeddings in
        # front of the text embeddings (stub frontend per spec).
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
    return x


def forward_train(params: Params, cfg: ModelConfig, tokens: jax.Array,
                  frontend_embeds: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """tokens (B, S_text) -> (logits (B, S_total, V) fp32, aux_loss)."""
    x = _input_embeds(params, cfg, tokens, frontend_embeds)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(carry, lp):
        h, aux = carry
        h, aux_i, _, _ = _layer_train(cfg, h, lp, positions)
        return (h, aux + aux_i), None

    (x, aux), _ = jax.lax.scan(maybe_remat(body, cfg),
                               (x, jnp.zeros((), jnp.float32)), params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params.get("unembed", params["embed"]))
    return logits, aux


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    logits, aux = forward_train(params, cfg, batch["tokens"],
                                batch.get("frontend_embeds"))
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if logits.shape[1] != labels.shape[1]:       # frontend positions carry no labels
        n_front = logits.shape[1] - labels.shape[1]
        logits = logits[:, n_front:]
    loss = softmax_cross_entropy(logits[:, :-1], labels[:, 1:],
                                 None if mask is None else mask[:, 1:])
    return loss + 0.01 * aux


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array,
            frontend_embeds: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence forward; returns last-position logits + dense KV cache.

    Cache: k/v (L, B, S_total, KV, head_dim) — the tensors FlowKV pages and
    ships to the decode node.
    """
    x = _input_embeds(params, cfg, tokens, frontend_embeds)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(carry, lp):
        h, aux = carry
        h, aux_i, k, v = _layer_train(cfg, h, lp, positions)
        return (h, aux + aux_i), (k, v)

    (x, _), (ks, vs) = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["layers"])
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params.get("unembed", params["embed"]))[:, 0]
    length = jnp.full((tokens.shape[0],), ks.shape[2], jnp.int32)
    return logits, {"k": ks, "v": vs, "length": length}


def prefill_suffix(params: Params, cfg: ModelConfig, tokens: jax.Array,
                   prefix_k: jax.Array, prefix_v: jax.Array
                   ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Suffix-only prefill over a resident prefix (prefix-cache hit).

    tokens (B, S_suf) are the prompt tokens AFTER the cached prefix;
    prefix_k/v (L, B, C, KV, hd) are the prefix's cached K/V exactly as a
    cold :func:`prefill` would have produced them (read back from the paged
    pool). Computes rows C..C+S_suf of the full forward — attention per
    layer runs over [prefix KV ++ suffix KV] with the suffix positions
    offset by C — so last-position logits and the returned suffix cache are
    bit-identical to the cold path's, at ``S_suf/S_total`` of the compute.

    Returns (logits (B, V) fp32, cache with k/v covering ONLY the suffix).
    """
    x = _input_embeds(params, cfg, tokens, None)
    c = prefix_k.shape[2]
    positions = c + jnp.arange(x.shape[1])[None, :]

    def body(carry, inputs):
        h, aux = carry
        lp, pk, pv = inputs
        hn = rms_norm(h, lp["norm_attn"], cfg.norm_eps)
        attn_out, (k, v) = A.suffix_attention(lp, hn, cfg, positions, pk, pv,
                                              cfg.attn_window)
        h = h + attn_out
        hn = rms_norm(h, lp["norm_mlp"], cfg.norm_eps)
        ffn_out, aux_i = _ffn(lp, hn, cfg)
        return (h + ffn_out, aux + aux_i), (k, v)

    (x, _), (ks, vs) = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (params["layers"], prefix_k, prefix_v))
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params.get("unembed", params["embed"]))[:, 0]
    length = jnp.full((tokens.shape[0],), c + ks.shape[2], jnp.int32)
    return logits, {"k": ks, "v": vs, "length": length}


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> Dict[str, jax.Array]:
    dtype = dtype or cfg.dtype
    shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def cache_axes() -> Dict[str, Tuple[Optional[str], ...]]:
    return {
        "k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        "v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        "length": ("batch",),
    }


def decode_step(params: Params, cfg: ModelConfig, token: jax.Array,
                cache: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """token (B,) int32; cache k/v (L, B, T, KV, hd) + length (B,).

    Returns (logits (B, V) fp32, updated cache).
    """
    x = embed(token[:, None], params["embed"], scale=cfg.embed_scale)
    position = cache["length"]

    def body(carry, inputs):
        h = carry
        lp, ck, cv = inputs
        hn = rms_norm(h, lp["norm_attn"], cfg.norm_eps)
        attn_out, (ck, cv) = A.decode_self_attention(
            lp, hn, cfg, ck, cv, position, cfg.attn_window)
        h = h + attn_out
        hn = rms_norm(h, lp["norm_mlp"], cfg.norm_eps)
        ffn_out, _ = _ffn(lp, hn, cfg)
        return h + ffn_out, (ck, cv)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params.get("unembed", params["embed"]))[:, 0]
    new_cache = {"k": ks, "v": vs, "length": cache["length"] + 1}
    return logits, new_cache


def decode_step_paged(params: Params, cfg: ModelConfig, token: jax.Array,
                      pool: jax.Array, block_tables: jax.Array,
                      lengths: jax.Array, *, interpret: Optional[bool] = None
                      ) -> Tuple[jax.Array, jax.Array]:
    """One batched decode step directly on the FlowKV pool (zero-gather).

    token (B,) int32; pool (nb, L, 2, payload); block_tables (B, W) int32;
    lengths (B,) int32 = tokens already cached per request — the new token's
    write position. Returns (logits (B, V) fp32, updated pool).

    Unlike :func:`decode_step`, no dense (L, B, T, KV, hd) cache is ever
    built: every layer's attention reads pages in place through the Pallas
    paged kernel (the in-flight token is merged via the kernel's softmax
    state), and the batch's new K/V for ALL layers lands in one fused
    descriptor-table scatter after the layer stack. Under ``jax.jit`` with
    the pool donated this is one device dispatch per decode cycle,
    independent of batch size and context length.
    """
    from repro.kernels.kv_gather import kv_append_tokens

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    x = embed(token[:, None], params["embed"], scale=cfg.embed_scale)
    position = lengths
    L = pool.shape[1]

    def body(h, inputs):
        lp, layer = inputs
        hn = rms_norm(h, lp["norm_attn"], cfg.norm_eps)
        pages = jax.lax.dynamic_index_in_dim(pool, layer, axis=1, keepdims=False)
        attn_out, (k_new, v_new) = A.decode_paged_self_attention(
            lp, hn, cfg, pages, block_tables, position, interpret=interpret)
        h = h + attn_out
        hn = rms_norm(h, lp["norm_mlp"], cfg.norm_eps)
        ffn_out, _ = _ffn(lp, hn, cfg)
        return h + ffn_out, (k_new, v_new)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["layers"], jnp.arange(L, dtype=jnp.int32)))
    pool = kv_append_tokens(pool, block_tables, position, ks, vs,
                            block_size=cfg.block_size, interpret=interpret)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params.get("unembed", params["embed"]))[:, 0]
    return logits, pool


# ---------------------------------------------------------------------------
# Convenience
# ---------------------------------------------------------------------------
def greedy_generate(params: Params, cfg: ModelConfig, prompt: jax.Array,
                    max_new_tokens: int, max_len: Optional[int] = None) -> jax.Array:
    """Reference autoregressive generation (used by tests/examples)."""
    b, s = prompt.shape
    max_len = max_len or (s + max_new_tokens)
    logits, pre = prefill(params, cfg, prompt)
    cache = init_cache(cfg, b, max_len)
    cache["k"] = cache["k"].at[:, :, :s].set(pre["k"])
    cache["v"] = cache["v"].at[:, :, :s].set(pre["v"])
    cache["length"] = jnp.full((b,), s, jnp.int32)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    for _ in range(max_new_tokens - 1):
        logits, cache = decode_step(params, cfg, tok, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    return jnp.stack(out, axis=1)
