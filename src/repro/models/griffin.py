"""Griffin / RecurrentGemma (arXiv:2402.19427): RG-LRU recurrent blocks +
local (sliding-window) MQA attention in a repeating (rec, rec, attn) pattern.

RG-LRU gate math (c = 8):

    r_t = sigmoid(x_t W_a + b_a)          # recurrence gate
    i_t = sigmoid(x_t W_i + b_i)          # input gate
    log a_t = -c * softplus(-Lambda) * r_t
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill evaluates the linear recurrence with an associative scan
(O(log S) depth); decode is the O(1) update. Local-attention layers keep a
ring-buffer KV cache of ``attn_window`` slots — this is what bounds the
long_500k cache and makes the arch sub-quadratic.

The layer pattern is scanned by *group* (one (rec, rec, attn) triple per
scan step) with the non-multiple tail unrolled, so HLO depth stays O(1) in
layer count while preserving exact layer ordering.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import mlp as MLPM
from repro.models.common import (ModelConfig, dense_init, embed, maybe_remat,
                                 rms_norm, softmax_cross_entropy, unembed)

Params = Dict[str, Any]
_C = 8.0   # RG-LRU gate sharpness constant


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _rec_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    d, w = cfg.d_model, cfg.lru_width
    return {
        "norm": (d,), "w_gate": (d, w), "w_branch": (d, w), "conv": (4, w),
        "w_a": (w, w), "b_a": (w,), "w_i": (w, w), "b_i": (w,),
        "lam": (w,), "w_out": (w, d),
    }


def _rec_axes() -> Dict[str, Tuple[Optional[str], ...]]:
    return {
        "norm": ("embed",), "w_gate": ("embed", "lru"), "w_branch": ("embed", "lru"),
        "conv": ("conv", "lru"), "w_a": ("lru_in", "lru"), "b_a": ("lru",),
        "w_i": ("lru_in", "lru"), "b_i": ("lru",), "lam": ("lru",),
        "w_out": ("lru", "embed"),
    }


def _mlp_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    return {"norm": (cfg.d_model,), **MLPM.mlp_param_shapes(cfg)}


def _mlp_axes() -> Dict[str, Tuple[Optional[str], ...]]:
    return {"norm": ("embed",), **MLPM.mlp_param_axes()}


def _attn_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    return {"norm": (cfg.d_model,), **A.attn_param_shapes(cfg)}


def _attn_axes(cfg: ModelConfig) -> Dict[str, Tuple[Optional[str], ...]]:
    return {"norm": ("embed",), **A.attn_param_axes(cfg)}


def _init_block(key, shapes: Dict[str, Tuple[int, ...]], cfg, stack: int = 0) -> Params:
    out = {}
    keys = jax.random.split(key, len(shapes))
    for (name, shape), k in zip(shapes.items(), keys):
        full = (stack, *shape) if stack else shape
        if name == "lam":
            # init so that a = sigmoid(lam)^(c*r) lies in ~(0.9, 0.999)
            out[name] = jnp.broadcast_to(jnp.asarray(4.0, jnp.float32), full).astype(jnp.float32)
        elif name.startswith(("b_", "norm")):
            out[name] = jnp.zeros(full, cfg.dtype if not name.startswith("b_") else jnp.float32)
        else:
            out[name] = dense_init(k, full, cfg.dtype)
    return out


def num_groups_and_tail(cfg: ModelConfig) -> Tuple[int, int]:
    plen = len(cfg.layer_pattern)
    return cfg.num_layers // plen, cfg.num_layers % plen


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    assert cfg.layer_pattern == ("rec", "rec", "attn"), "griffin pattern fixed"
    G, tail = num_groups_and_tail(cfg)
    ks = jax.random.split(key, 12)
    group = {
        "rec0": _init_block(ks[0], _rec_shapes(cfg), cfg, stack=G),
        "mlp0": _init_block(ks[1], _mlp_shapes(cfg), cfg, stack=G),
        "rec1": _init_block(ks[2], _rec_shapes(cfg), cfg, stack=G),
        "mlp1": _init_block(ks[3], _mlp_shapes(cfg), cfg, stack=G),
        "attn": _init_block(ks[4], _attn_shapes(cfg), cfg, stack=G),
        "mlp2": _init_block(ks[5], _mlp_shapes(cfg), cfg, stack=G),
    }
    params: Params = {
        "embed": dense_init(ks[6], (cfg.vocab_size, cfg.d_model), cfg.dtype, 0.02),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
        "groups": group,
    }
    for t in range(tail):   # tail layers are always "rec" for 26 = 8*3 + 2
        params[f"tail_rec{t}"] = _init_block(ks[7 + 2 * t], _rec_shapes(cfg), cfg)
        params[f"tail_mlp{t}"] = _init_block(ks[8 + 2 * t], _mlp_shapes(cfg), cfg)
    return params


def param_axes(cfg: ModelConfig) -> Params:
    G, tail = num_groups_and_tail(cfg)

    def stack_axes(ax):
        return {k: ("groups", *v) for k, v in ax.items()}

    axes: Params = {
        "embed": ("vocab", "embed"),
        "final_norm": ("embed",),
        "groups": {
            "rec0": stack_axes(_rec_axes()), "mlp0": stack_axes(_mlp_axes()),
            "rec1": stack_axes(_rec_axes()), "mlp1": stack_axes(_mlp_axes()),
            "attn": stack_axes(_attn_axes(cfg)), "mlp2": stack_axes(_mlp_axes()),
        },
    }
    for t in range(tail):
        axes[f"tail_rec{t}"] = _rec_axes()
        axes[f"tail_mlp{t}"] = _mlp_axes()
    return axes


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------
def _rg_lru(bx: jax.Array, p: Params, h0: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """bx (B,S,W) -> (out (B,S,W), h_final (B,W)). Associative scan over S."""
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", bx, p["w_a"]).astype(jnp.float32)
                       + p["b_a"][None, None])
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", bx, p["w_i"]).astype(jnp.float32)
                       + p["b_i"][None, None])
    log_a = -_C * jax.nn.softplus(-p["lam"].astype(jnp.float32))[None, None] * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * bx.astype(jnp.float32)
    if bx.shape[1] == 1:   # decode fast path
        h0v = jnp.zeros_like(gated[:, 0]) if h0 is None else h0
        h = a[:, 0] * h0v + gated[:, 0]
        return h[:, None].astype(bx.dtype), h

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    a_scan, h_scan = jax.lax.associative_scan(combine, (a, gated), axis=1)
    if h0 is not None:
        h_scan = h_scan + a_scan * h0[:, None]
    return h_scan.astype(bx.dtype), h_scan[:, -1]


def _rec_block(cfg: ModelConfig, p: Params, x: jax.Array,
               conv_state=None, h0=None) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (out, new_conv_state, h_final)."""
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", xn, p["w_gate"]), approximate=True)
    bx = jnp.einsum("bsd,dw->bsw", xn, p["w_branch"])
    # causal depthwise conv (window 4), silu-free (griffin uses plain conv)
    cw = p["conv"].shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], cw - 1, bx.shape[-1]), bx.dtype)
    xx = jnp.concatenate([conv_state, bx], axis=1)
    bx = sum(xx[:, i:i + bx.shape[1]] * p["conv"][i][None, None] for i in range(cw))
    new_conv = xx[:, -(cw - 1):]
    lru_out, h_final = _rg_lru(bx, p, h0)
    out = jnp.einsum("bsw,wd->bsd", lru_out * gate, p["w_out"])
    return x + out, new_conv, h_final


def _mlp_block(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    return x + MLPM.gated_mlp({k: p[k] for k in ("w_gate", "w_up", "w_down")}, xn, "gelu")


def _attn_block_train(cfg: ModelConfig, p: Params, x: jax.Array,
                      positions: jax.Array) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    out, (k, v) = A.self_attention(p, xn, cfg, positions, window=cfg.attn_window)
    return x + out, (k, v)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------
def _scan_groups(cfg: ModelConfig, params: Params, x: jax.Array, positions,
                 collect_cache: bool):
    def body(h, gp):
        h, conv0, hf0 = _rec_block(cfg, gp["rec0"], h)
        h = _mlp_block(cfg, gp["mlp0"], h)
        h, conv1, hf1 = _rec_block(cfg, gp["rec1"], h)
        h = _mlp_block(cfg, gp["mlp1"], h)
        h, (k, v) = _attn_block_train(cfg, gp["attn"], h, positions)
        h = _mlp_block(cfg, gp["mlp2"], h)
        out = (conv0, hf0, conv1, hf1, k, v) if collect_cache else None
        return h, out

    fn = body if collect_cache else maybe_remat(body, cfg)
    return jax.lax.scan(fn, x, params["groups"])


def forward_train(params: Params, cfg: ModelConfig, tokens: jax.Array,
                  frontend_embeds=None) -> Tuple[jax.Array, jax.Array]:
    x = embed(tokens, params["embed"], cfg.embed_scale)
    positions = jnp.arange(x.shape[1])[None, :]
    x, _ = _scan_groups(cfg, params, x, positions, collect_cache=False)
    _, tail = num_groups_and_tail(cfg)
    for t in range(tail):
        x, _, _ = _rec_block(cfg, params[f"tail_rec{t}"], x)
        x = _mlp_block(cfg, params[f"tail_mlp{t}"], x)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(x, params["embed"]), jnp.zeros((), jnp.float32)


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    logits, _ = forward_train(params, cfg, batch["tokens"])
    mask = batch.get("loss_mask")
    return softmax_cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                                 None if mask is None else mask[:, 1:])


def _ring_from_prefill(k: jax.Array, window: int) -> jax.Array:
    """k (..., S, KV, hd) -> ring (..., W, KV, hd) with slot q%W = roped k[q]."""
    s = k.shape[-3]
    w = window
    ring = jnp.zeros((*k.shape[:-3], w, *k.shape[-2:]), k.dtype)
    if s >= w:
        tail = k[..., s - w:, :, :]
        slots = (jnp.arange(s - w, s)) % w
        ring = ring.at[..., slots, :, :].set(tail)
    else:
        ring = ring.at[..., :s, :, :].set(k)
    return ring


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array,
            frontend_embeds=None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x = embed(tokens, params["embed"], cfg.embed_scale)
    positions = jnp.arange(x.shape[1])[None, :]
    x, outs = _scan_groups(cfg, params, x, positions, collect_cache=True)
    conv0, hf0, conv1, hf1, ks, vs = outs
    cache: Dict[str, jax.Array] = {
        "g_conv0": conv0, "g_h0": hf0, "g_conv1": conv1, "g_h1": hf1,
        "g_k": _ring_from_prefill(ks, cfg.attn_window),
        "g_v": _ring_from_prefill(vs, cfg.attn_window),
    }
    _, tail = num_groups_and_tail(cfg)
    for t in range(tail):
        x, conv, hf = _rec_block(cfg, params[f"tail_rec{t}"], x)
        x = _mlp_block(cfg, params[f"tail_mlp{t}"], x)
        cache[f"t_conv{t}"] = conv
        cache[f"t_h{t}"] = hf
    cache["length"] = jnp.full((tokens.shape[0],), tokens.shape[1], jnp.int32)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return unembed(x, params["embed"])[:, 0], cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int = 0, dtype=None) -> Dict[str, jax.Array]:
    del max_len   # bounded by window / state size
    dtype = dtype or cfg.dtype
    G, tail = num_groups_and_tail(cfg)
    w, lru, cw = cfg.attn_window, cfg.lru_width, 4
    cache = {
        "g_conv0": jnp.zeros((G, batch, cw - 1, lru), dtype),
        "g_h0": jnp.zeros((G, batch, lru), jnp.float32),
        "g_conv1": jnp.zeros((G, batch, cw - 1, lru), dtype),
        "g_h1": jnp.zeros((G, batch, lru), jnp.float32),
        "g_k": jnp.zeros((G, batch, w, cfg.num_kv_heads, cfg.head_dim), dtype),
        "g_v": jnp.zeros((G, batch, w, cfg.num_kv_heads, cfg.head_dim), dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }
    for t in range(tail):
        cache[f"t_conv{t}"] = jnp.zeros((batch, cw - 1, lru), dtype)
        cache[f"t_h{t}"] = jnp.zeros((batch, lru), jnp.float32)
    return cache


def cache_axes(cfg: ModelConfig) -> Dict[str, Tuple[Optional[str], ...]]:
    _, tail = num_groups_and_tail(cfg)
    axes = {
        "g_conv0": ("groups", "batch", "conv", "lru"),
        "g_h0": ("groups", "batch", "lru"),
        "g_conv1": ("groups", "batch", "conv", "lru"),
        "g_h1": ("groups", "batch", "lru"),
        "g_k": ("groups", "batch", "kv_seq", "kv_heads", "head_dim"),
        "g_v": ("groups", "batch", "kv_seq", "kv_heads", "head_dim"),
        "length": ("batch",),
    }
    for t in range(tail):
        axes[f"t_conv{t}"] = ("batch", "conv", "lru")
        axes[f"t_h{t}"] = ("batch", "lru")
    return axes


def _ring_decode_attn(cfg: ModelConfig, p: Params, x: jax.Array,
                      ring_k: jax.Array, ring_v: jax.Array, pos: jax.Array
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token local attention over a ring buffer of W slots.

    x (B,1,D); ring_k/v (B,W,KV,hd); pos (B,) absolute position of the new
    token. Slot s holds absolute position q = pos - ((pos - s) mod W).
    """
    w = ring_k.shape[1]
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    q, k_new, v_new = A.qkv_project(p, xn, cfg, pos[:, None])
    slot = pos % w
    b_idx = jnp.arange(x.shape[0])
    ring_k = ring_k.at[b_idx, slot].set(k_new[:, 0])
    ring_v = ring_v.at[b_idx, slot].set(v_new[:, 0])
    s_idx = jnp.arange(w)[None, :]
    qpos = pos[:, None]
    slot_pos = qpos - jnp.mod(qpos - s_idx, w)
    valid = slot_pos >= 0
    mask = valid[:, None, None, None, :]
    out = A.attend(q, ring_k, ring_v, mask)
    return x + A.out_project(p, out), ring_k, ring_v


def decode_step(params: Params, cfg: ModelConfig, token: jax.Array,
                cache: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x = embed(token[:, None], params["embed"], cfg.embed_scale)
    pos = cache["length"]

    def body(h, inp):
        gp, c0, h0, c1, h1, rk, rv = inp
        h, c0, h0 = _rec_block(cfg, gp["rec0"], h, conv_state=c0, h0=h0)
        h = _mlp_block(cfg, gp["mlp0"], h)
        h, c1, h1 = _rec_block(cfg, gp["rec1"], h, conv_state=c1, h0=h1)
        h = _mlp_block(cfg, gp["mlp1"], h)
        h, rk, rv = _ring_decode_attn(cfg, gp["attn"], h, rk, rv, pos)
        h = _mlp_block(cfg, gp["mlp2"], h)
        return h, (c0, h0, c1, h1, rk, rv)

    x, (c0, h0, c1, h1, rk, rv) = jax.lax.scan(
        body, x, (params["groups"], cache["g_conv0"], cache["g_h0"],
                  cache["g_conv1"], cache["g_h1"], cache["g_k"], cache["g_v"]))
    new_cache = {"g_conv0": c0, "g_h0": h0, "g_conv1": c1, "g_h1": h1,
                 "g_k": rk, "g_v": rv}
    _, tail = num_groups_and_tail(cfg)
    for t in range(tail):
        x, conv, hf = _rec_block(cfg, params[f"tail_rec{t}"], x,
                                 conv_state=cache[f"t_conv{t}"], h0=cache[f"t_h{t}"])
        x = _mlp_block(cfg, params[f"tail_mlp{t}"], x)
        new_cache[f"t_conv{t}"] = conv
        new_cache[f"t_h{t}"] = hf
    new_cache["length"] = cache["length"] + 1
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(x, params["embed"])[:, 0], new_cache
