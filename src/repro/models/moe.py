"""Mixture-of-Experts FFN with top-k routing and expert parallelism.

Dispatch is dense (one-hot combine over the expert axis) — the standard
pjit-friendly formulation: experts live sharded on the ``model`` mesh axis
(granite: 32 experts / 16 shards; llama4: 16 / 16) and the einsum contraction
over the expert axis lowers to local expert compute + reduce over the
expert-parallel axis. An auxiliary load-balancing loss (Switch-style) is
returned for training.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig


def moe_param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    d = cfg.d_model
    e = cfg.num_experts
    f = cfg.moe_d_ff or cfg.d_ff
    return {
        "router": (d, e),
        "w_gate": (e, d, f),
        "w_up": (e, d, f),
        "w_down": (e, f, d),
    }


def moe_param_axes() -> Dict[str, Tuple[Optional[str], ...]]:
    return {
        "router": ("embed", "experts"),
        "w_gate": ("experts", "embed", "mlp"),
        "w_up": ("experts", "embed", "mlp"),
        "w_down": ("experts", "mlp", "embed"),
    }


def _act(x: jax.Array, kind: str) -> jax.Array:
    return jax.nn.silu(x) if kind == "silu" else jax.nn.gelu(x, approximate=True)


def moe_ffn(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                       # (B,S,E)
    k = cfg.top_k
    top_p, top_idx = jax.lax.top_k(probs, k)                      # (B,S,k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # combine weights as a dense (B,S,E) tensor: 0 off the top-k
    combine = jnp.zeros_like(probs).at[
        jnp.arange(probs.shape[0])[:, None, None],
        jnp.arange(probs.shape[1])[None, :, None],
        top_idx,
    ].set(top_p)

    combine = combine.astype(x.dtype)                             # (B,S,E)
    # expert compute — dense dispatch: every expert sees all tokens, result
    # weighted by `combine`. Lowered under pjit this becomes expert-parallel
    # local matmuls + a reduce over the expert axis shards.
    gate = _act(jnp.einsum("bsd,edf->bsef", x, p["w_gate"]), cfg.activation)
    up = jnp.einsum("bsd,edf->bsef", x, p["w_up"])
    expert_out = jnp.einsum("bsef,efd->bsed", gate * up, p["w_down"])
    out = jnp.einsum("bsed,bse->bsd", expert_out, combine)

    # Switch-style load-balance aux loss
    density = combine.astype(jnp.float32).mean(axis=(0, 1))       # actual load
    router_prob = probs.mean(axis=(0, 1))                         # router mass
    aux = cfg.num_experts * jnp.sum(density * router_prob)
    return out, aux


def moe_ffn_gshard(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig,
                   capacity_factor: float = 1.25) -> Tuple[jax.Array, jax.Array]:
    """Capacity-based gather/scatter dispatch (the §Perf optimized path).

    Dense dispatch computes ALL experts for every token (E/top_k x FLOP and
    HBM inflation). Here each expert processes at most
    ``C = ceil(S * top_k * capacity_factor / E)`` tokens per sequence:
    token slots are assigned by cumulative arrival order (Switch semantics;
    overflow tokens fall back to the residual path), tokens are GATHERED
    into per-expert buffers (B, E, C, D), and results SCATTER-ADD back —
    all data movement is linear in tokens, no (B,S,E,F) intermediates.

    Under pjit the E axis stays expert-parallel on the model mesh axis; the
    gather/scatter cross the replicated S dim so XLA inserts one
    all-reduce per layer instead of materializing dense expert outputs.
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cap = max(1, int(s * k * capacity_factor / e))

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                        # (B,S,E)
    top_p, top_idx = jax.lax.top_k(probs, k)                       # (B,S,k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # arrival-order slot within each expert's capacity buffer
    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.int32)           # (B,S,k,E)
    flat = onehot.reshape(b, s * k, e)
    pos_flat = jnp.cumsum(flat, axis=1) - 1                        # (B,S*k,E)
    pos = jnp.where(flat > 0, pos_flat, 0).max(-1).reshape(b, s, k)
    keep = pos < cap

    b_idx = jnp.arange(b)[:, None, None]
    # slot -> source token index / combine weight (B, E, C). Overflow tokens
    # are routed to out-of-range slot `cap` and dropped by the scatter.
    slot = jnp.where(keep, pos, cap)
    slot_tok = jnp.zeros((b, e, cap), jnp.int32).at[
        b_idx, top_idx, slot].set(
        jnp.broadcast_to(jnp.arange(s)[None, :, None], (b, s, k)),
        mode="drop")
    slot_w = jnp.zeros((b, e, cap), jnp.float32).at[
        b_idx, top_idx, slot].set(top_p, mode="drop")

    # gather tokens into per-expert buffers
    xin = jnp.take_along_axis(x, slot_tok.reshape(b, e * cap)[..., None],
                              axis=1).reshape(b, e, cap, d)
    gate = _act(jnp.einsum("becd,edf->becf", xin, p["w_gate"]), cfg.activation)
    up = jnp.einsum("becd,edf->becf", xin, p["w_up"])
    out_e = jnp.einsum("becf,efd->becd", gate * up, p["w_down"])   # (B,E,C,D)
    out_e = out_e * slot_w[..., None].astype(out_e.dtype)

    # scatter-add back to token order
    out = jnp.zeros((b, s, d), x.dtype).at[
        b_idx[..., 0], slot_tok.reshape(b, e * cap)].add(
        out_e.reshape(b, e * cap, d).astype(x.dtype))

    density = onehot.astype(jnp.float32).sum(2).mean((0, 1))       # (E,)
    aux = cfg.num_experts * jnp.sum(density / k * probs.mean((0, 1)))
    return out, aux


def moe_ffn_gshard_einsum(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig,
                          capacity_factor: float = 1.25
                          ) -> Tuple[jax.Array, jax.Array]:
    """Mesh-TF/GShard one-hot EINSUM dispatch (§Perf optimized path, v2).

    The gather/scatter variant above lowers poorly under SPMD (XLA expands
    the scatters into one-hot dots anyway — measured 8x FLOP blowup, see
    EXPERIMENTS.md §Perf iteration 1). This variant expresses dispatch as
    explicit einsums against a (B, S, E, C) one-hot — the exact formulation
    GShard/Switch ran on TPU:

        dispatch cost ~ B*S*E_loc*C*D  per chip (two einsums)
        expert cost   ~ B*E_loc*C*3*D*F
        no (B, S, E, F) dense intermediate

    Per-(token, expert) there is at most one top-k choice, so position and
    combine weight collapse to (B, S, E) tensors before the one-hot.
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cap = max(1, int(s * k * capacity_factor / e))

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                        # (B,S,E)
    top_p, top_idx = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    assigned = jax.nn.one_hot(top_idx, e, dtype=probs.dtype)       # (B,S,k,E)
    gate_se = jnp.einsum("bske,bsk->bse", assigned, top_p)         # combine w
    mask_se = assigned.sum(2)                                      # 0/1 (B,S,E)
    # arrival-order position of each token within its expert buffer
    pos = jnp.cumsum(mask_se, axis=1) * mask_se - 1.0              # (B,S,E)
    keep = (pos >= 0) & (pos < cap)
    pos_c = jax.nn.one_hot(jnp.where(keep, pos, cap).astype(jnp.int32),
                           cap, dtype=x.dtype)                     # (B,S,E,C)
    dispatch = pos_c * keep[..., None].astype(x.dtype)             # (B,S,E,C)
    combine = dispatch * gate_se[..., None].astype(x.dtype)

    xin = jnp.einsum("bsd,bsec->becd", x, dispatch)                # (B,E,C,D)
    gate = _act(jnp.einsum("becd,edf->becf", xin, p["w_gate"]), cfg.activation)
    up = jnp.einsum("becd,edf->becf", xin, p["w_up"])
    out_e = jnp.einsum("becf,efd->becd", gate * up, p["w_down"])
    out = jnp.einsum("becd,bsec->bsd", out_e, combine)

    density = mask_se.mean((0, 1))
    aux = cfg.num_experts * jnp.sum(density / k * probs.mean((0, 1)))
    return out, aux


def moe_ffn_topk_sparse(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig
                        ) -> Tuple[jax.Array, jax.Array]:
    """Gather-based dispatch used when top_k == 1 (llama4-style).

    For top-1 routing, dense dispatch wastes E× compute; gathering the single
    expert's weights per token is the cheaper lowering on small E.
    """
    assert cfg.top_k == 1
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    idx = jnp.argmax(probs, axis=-1)                              # (B,S)
    gate_w = jnp.take(p["w_gate"], idx, axis=0)                   # (B,S,D,F)
    up_w = jnp.take(p["w_up"], idx, axis=0)
    down_w = jnp.take(p["w_down"], idx, axis=0)
    gate = _act(jnp.einsum("bsd,bsdf->bsf", x, gate_w), cfg.activation)
    up = jnp.einsum("bsd,bsdf->bsf", x, up_w)
    out = jnp.einsum("bsf,bsfd->bsd", gate * up, down_w)
    top_p = jnp.take_along_axis(probs, idx[..., None], axis=-1).astype(x.dtype)
    out = out * top_p
    density = jax.nn.one_hot(idx, cfg.num_experts).mean(axis=(0, 1))
    aux = cfg.num_experts * jnp.sum(density * probs.mean(axis=(0, 1)))
    return out, aux
