"""Attention blocks: GQA/MQA with optional qk-norm, full-causal or
local-window masks, cross-attention, and dense-cache decode.

These are the *reference* (pure-jnp) paths used by training, the dry-run
step functions, and as oracles for the Pallas kernels in ``repro.kernels``.
Serving-time paged decode goes through ``kernels/paged_attention`` (FlowKV
block-major layout).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, apply_rope, rms_norm


# ---------------------------------------------------------------------------
# Parameter init — per layer (caller stacks over layers)
# ---------------------------------------------------------------------------
def attn_param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    shapes = {
        "wq": (d, h, hd),
        "wk": (d, kv, hd),
        "wv": (d, kv, hd),
        "wo": (h, hd, d),
    }
    if cfg.qk_norm:
        shapes["q_norm"] = (hd,)
        shapes["k_norm"] = (hd,)
    return shapes


def attn_param_axes(cfg: ModelConfig) -> Dict[str, Tuple[Optional[str], ...]]:
    axes = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qk_norm:
        axes["q_norm"] = ("head_dim",)
        axes["k_norm"] = ("head_dim",)
    return axes


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------
def qkv_project(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig,
                positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x (B, S, D) -> q (B, S, H, hd), k/v (B, S, KV, hd), with RoPE + qk-norm."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def out_project(p: Dict[str, jax.Array], attn: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", attn, p["wo"])


# ---------------------------------------------------------------------------
# Core attention math (GQA-aware)
# ---------------------------------------------------------------------------
def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q (B,S,H,hd), k (B,T,KV,hd) -> scores (B,KV,G,S,T) with H = KV*G."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, hd)
    return jnp.einsum("bskgd,btkd->bkgst", qg, k) / jnp.sqrt(hd).astype(q.dtype)


def _gqa_combine(weights: jax.Array, v: jax.Array) -> jax.Array:
    """weights (B,KV,G,S,T), v (B,T,KV,hd) -> (B,S,H,hd)."""
    b, kvh, g, s, t = weights.shape
    out = jnp.einsum("bkgst,btkd->bskgd", weights, v)
    return out.reshape(b, s, kvh * g, v.shape[-1])


def causal_mask(s: int, t: int, offset: int = 0, window: int = 0) -> jax.Array:
    """(s, t) boolean mask; query i (global pos offset+i) sees key j iff
    j <= offset+i and (window == 0 or j > offset+i-window)."""
    qpos = jnp.arange(s)[:, None] + offset
    kpos = jnp.arange(t)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m


def attend(q: jax.Array, k: jax.Array, v: jax.Array,
           mask: Optional[jax.Array]) -> jax.Array:
    """Full-precision softmax attention. mask broadcastable to (B,KV,G,S,T)."""
    scores = _gqa_scores(q, k).astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask, scores, jnp.finfo(jnp.float32).min)
    weights = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return _gqa_combine(weights, v)


# ---------------------------------------------------------------------------
# Layer-level entry points
# ---------------------------------------------------------------------------
def self_attention_heads(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig,
                         positions: jax.Array, window: int = 0
                         ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """:func:`self_attention` minus the output projection.

    Returns (heads (B,S,H,hd), (k, v)). Every step is per-kv-head
    independent, so a tensor-parallel shard can run this on its contiguous
    head slice of wq/wk/wv and the concatenated shard outputs equal the
    full-width result exactly (``distributed/tp.py``).
    """
    from repro.models.flash import flash_attention  # local import: avoid cycle

    q, k, v = qkv_project(p, x, cfg, positions)
    s = x.shape[1]
    if window > 0 or s > cfg.flash_threshold:
        out = flash_attention(q, k, v, causal=True, window=window,
                              q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
                              wedge=cfg.attn_wedge)
    else:
        mask = causal_mask(s, s, 0, window)[None, None, None]
        out = attend(q, k, v, mask)
    return out, (k, v)


def self_attention(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig,
                   positions: jax.Array, window: int = 0) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Training/prefill: full-sequence causal (or windowed) self-attention.

    Returns (output (B,S,D), (k, v)) — k/v returned for cache capture.
    Long sequences (or any windowed attention) route through the chunked
    flash path so (S, T) scores never materialize.
    """
    out, (k, v) = self_attention_heads(p, x, cfg, positions, window)
    return out_project(p, out), (k, v)


def suffix_attention(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig,
                     positions: jax.Array, prefix_k: jax.Array,
                     prefix_v: jax.Array, window: int = 0
                     ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Suffix-only prefill attention over a resident prefix (prefix-cache hit).

    x (B, S_suf, D) are the UNCACHED prompt tokens; prefix_k/v (B, C, KV, hd)
    are the matched prefix's cached K/V (already RoPE'd at positions 0..C);
    ``positions`` must be the suffix's global positions (C + arange(S_suf)).
    Computes exactly the rows C..C+S_suf of full-prompt attention — same
    flash/dense dispatch policy as :func:`self_attention` keyed on the TOTAL
    length, so warm and cold prefill take the same numeric path and outputs
    stay bit-identical. Returns (out (B,S_suf,D), (k, v)) with k/v covering
    ONLY the suffix (the caller writes just those tokens' pages).
    """
    from repro.models.flash import flash_attention  # local import: avoid cycle

    out, (k, v) = suffix_attention_heads(p, x, cfg, positions, prefix_k,
                                         prefix_v, window)
    return out_project(p, out), (k, v)


def suffix_attention_heads(p: Dict[str, jax.Array], x: jax.Array,
                           cfg: ModelConfig, positions: jax.Array,
                           prefix_k: jax.Array, prefix_v: jax.Array,
                           window: int = 0
                           ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """:func:`suffix_attention` minus the output projection (TP shard body)."""
    from repro.models.flash import flash_attention  # local import: avoid cycle

    q, k, v = qkv_project(p, x, cfg, positions)
    k_full = jnp.concatenate([prefix_k.astype(k.dtype), k], axis=1)
    v_full = jnp.concatenate([prefix_v.astype(v.dtype), v], axis=1)
    s, t = x.shape[1], k_full.shape[1]
    offset = prefix_k.shape[1]
    if window > 0 or t > cfg.flash_threshold:
        out = flash_attention(q, k_full, v_full, causal=True, window=window,
                              q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
                              q_offset=offset, wedge=cfg.attn_wedge)
    else:
        mask = causal_mask(s, t, offset, window)[None, None, None]
        out = attend(q, k_full, v_full, mask)
    return out, (k, v)


def decode_self_attention(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig,
                          cache_k: jax.Array, cache_v: jax.Array,
                          position: jax.Array, window: int = 0
                          ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Single-token decode against a dense cache.

    x (B, 1, D); cache_k/v (B, T, KV, hd) — position is the write index
    (B,) or scalar. Returns (out (B,1,D), updated cache).
    """
    pos = jnp.broadcast_to(jnp.asarray(position), (x.shape[0],))
    q, k_new, v_new = qkv_project(p, x, cfg, pos[:, None])
    # write the new token's K/V at `pos`
    b_idx = jnp.arange(x.shape[0])
    cache_k = cache_k.at[b_idx, pos].set(k_new[:, 0])
    cache_v = cache_v.at[b_idx, pos].set(v_new[:, 0])
    t = cache_k.shape[1]
    kpos = jnp.arange(t)[None, :]
    valid = kpos <= pos[:, None]
    if window > 0:
        valid &= kpos > (pos[:, None] - window)
    mask = valid[:, None, None, None, :]          # (B,1,1,1,T)
    out = attend(q, cache_k, cache_v, mask)
    return out_project(p, out), (cache_k, cache_v)


def decode_paged_self_attention(p: Dict[str, jax.Array], x: jax.Array,
                                cfg: ModelConfig, pages: jax.Array,
                                block_tables: jax.Array, position: jax.Array,
                                *, interpret: bool = True
                                ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Single-token decode directly against one layer's FlowKV page plane.

    x (B, 1, D); pages (nb, 2, payload) — ``pool[:, layer]``; block_tables
    (B, W) int32; position (B,) int32 = tokens already cached (the in-flight
    token's absolute index). The cached keys are read IN PLACE by the paged
    kernel; the in-flight token — whose K/V is not in the pool yet — is
    folded in exactly via the kernel's online-softmax state (m, l), so no
    dense (B, T) cache is ever materialized. Returns
    (out (B, 1, D), (k_new (B, KV, hd), v_new (B, KV, hd))); the caller
    appends the new K/V for the whole layer stack in one fused scatter.
    """
    out, kv = decode_paged_attention_heads(p, x, cfg, pages, block_tables,
                                           position, interpret=interpret)
    return out_project(p, out), kv


def decode_paged_attention_heads(p: Dict[str, jax.Array], x: jax.Array,
                                 cfg: ModelConfig, pages: jax.Array,
                                 block_tables: jax.Array, position: jax.Array,
                                 *, interpret: bool = True
                                 ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """:func:`decode_paged_self_attention` minus the output projection.

    The paged read, the online-softmax merge of the in-flight token, and the
    normalization are all per-kv-head independent, so a TP shard runs this
    against its own head-sliced page plane (``distributed/tp.py``).
    """
    from repro.kernels.paged_attention import paged_decode_attention

    pos = jnp.broadcast_to(jnp.asarray(position), (x.shape[0],))
    q, k_new, v_new = qkv_project(p, x, cfg, pos[:, None])
    q1, k1, v1 = q[:, 0], k_new[:, 0], v_new[:, 0]
    out_old, m_old, l_old = paged_decode_attention(
        q1, pages, block_tables, pos, block_size=cfg.block_size,
        interpret=interpret, return_stats=True)
    out = merge_inflight_token(q1, k1, v1, out_old, m_old, l_old, x.dtype)
    return out, (k1, v1)


def merge_inflight_token(q1: jax.Array, k1: jax.Array, v1: jax.Array,
                         out_old: jax.Array, m_old: jax.Array,
                         l_old: jax.Array, out_dtype) -> jax.Array:
    """Fold the in-flight token into paged-kernel output as one extra key.

    q1 (B,H,hd), k1/v1 (B,KV,hd); out_old (B,H,hd) + m_old/l_old (B,KV,G)
    are the kernel's online-softmax state. Exact online-softmax step;
    returns (B,1,H,hd). The TP emulation calls this ONCE on the full-width
    concat of per-shard kernel outputs: the einsum lowerings here are not
    bit-stable across kv-head extents, so merging at per-shard width would
    drift from the single-device result by an ulp (distributed/tp.py).
    """
    b, h, hd = q1.shape
    kvh = k1.shape[1]
    g = h // kvh
    qg = q1.reshape(b, kvh, g, hd).astype(jnp.float32)
    s_self = jnp.einsum("bkgd,bkd->bkg", qg, k1.astype(jnp.float32))
    s_self = s_self / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    m_new = jnp.maximum(m_old, s_self)
    alpha = jnp.exp(m_old - m_new)
    p_self = jnp.exp(s_self - m_new)
    l_new = l_old * alpha + p_self
    acc = (out_old.reshape(b, kvh, g, hd).astype(jnp.float32)
           * (l_old * alpha)[..., None]
           + p_self[..., None] * v1.astype(jnp.float32)[:, :, None, :])
    out = acc / jnp.maximum(l_new, 1e-30)[..., None]
    return out.reshape(b, 1, h, hd).astype(out_dtype)


# ---------------------------------------------------------------------------
# Cross-attention (encoder-decoder)
# ---------------------------------------------------------------------------
def cross_param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {"wq": (d, h, hd), "wk": (d, kv, hd), "wv": (d, kv, hd), "wo": (h, hd, d)}


def cross_attention(p: Dict[str, jax.Array], x: jax.Array, memory_kv: Tuple[jax.Array, jax.Array],
                    cfg: ModelConfig, memory_mask: Optional[jax.Array] = None) -> jax.Array:
    """x (B,S,D) attends over precomputed encoder K/V (B,T,KV,hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k, v = memory_kv
    mask = None if memory_mask is None else memory_mask[:, None, None, None, :]
    out = attend(q, k, v, mask)
    return out_project(p, out)


def encode_memory(p: Dict[str, jax.Array], memory: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Project encoder output once into cross-attn K/V (cached per request)."""
    k = jnp.einsum("btd,dhk->bthk", memory, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", memory, p["wv"])
    return k, v
