"""Uniform model API over the four families.

``get_model(cfg)`` returns a :class:`Model` whose members close over the
config; every consumer (training step, serving engine, dry-run) talks to
this protocol instead of family-specific modules:

    init(key) -> params
    param_axes() -> logical-axis pytree (same structure as params)
    loss(params, batch) -> scalar
    prefill(params, batch) -> (logits, cache)
    decode(params, token, cache) -> (logits, cache)
    init_cache(batch, max_len) -> cache pytree
    cache_axes() -> logical-axis pytree for the cache
    input_specs(shape_kind, seq, batch) -> (batch_pytree_of_ShapeDtypeStruct,
                                            logical-axis pytree)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec, griffin, mamba2, transformer
from repro.models.common import ModelConfig

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], Params]
    param_axes: Callable[[], Params]
    loss: Callable[[Params, Dict[str, jax.Array]], jax.Array]
    prefill: Callable[[Params, Dict[str, jax.Array]], Tuple[jax.Array, Dict[str, jax.Array]]]
    decode: Callable[[Params, jax.Array, Dict[str, jax.Array]], Tuple[jax.Array, Dict[str, jax.Array]]]
    init_cache: Callable[..., Dict[str, jax.Array]]
    cache_axes: Callable[[], Dict[str, Tuple[Optional[str], ...]]]
    # Zero-gather decode: (params, token (B,), pool, block_tables, lengths)
    # -> (logits, updated pool). Only the paged transformer families have one;
    # None means the engine must use the dense ``decode`` bridge.
    decode_paged: Optional[Callable[..., Tuple[jax.Array, jax.Array]]] = None
    # Suffix-only prefill for prefix-cache hits: (params, batch, prefix_k,
    # prefix_v) -> (logits, suffix-only cache), where prefix_k/v
    # (L, B, C, KV, hd) are the resident prefix's K/V. None means a hit
    # cannot skip compute on this family (state caches, windowed attention)
    # and the engine must run the full prefill.
    prefill_suffix: Optional[Callable[..., Tuple[jax.Array, Dict[str, Any]]]] = None


def get_model(cfg: ModelConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        return _transformer_model(cfg)
    if cfg.family == "ssm":
        return _simple_model(cfg, mamba2)
    if cfg.family == "hybrid":
        return _griffin_model(cfg)
    if cfg.family == "encdec":
        return _encdec_model(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")


def _transformer_model(cfg: ModelConfig) -> Model:
    def prefill_fn(params, batch):
        return transformer.prefill(params, cfg, batch["tokens"],
                                   batch.get("frontend_embeds"))

    return Model(
        cfg=cfg,
        init=lambda key: transformer.init_params(cfg, key),
        param_axes=lambda: transformer.param_axes(cfg),
        loss=lambda p, b: transformer.loss_fn(p, cfg, b),
        prefill=prefill_fn,
        decode=lambda p, tok, cache: transformer.decode_step(p, cfg, tok, cache),
        init_cache=lambda batch, max_len, **kw: transformer.init_cache(cfg, batch, max_len, **kw),
        cache_axes=transformer.cache_axes,
        # the paged kernel has no local-window mask: windowed configs get no
        # zero-gather step rather than a silently-unwindowed one
        decode_paged=None if cfg.attn_window > 0 else (
            lambda p, tok, pool, bt, lens: transformer.decode_step_paged(
                p, cfg, tok, pool, bt, lens)),
        # windowed configs recompute from scratch rather than risking a
        # numerically different local-attention path on the warm side
        prefill_suffix=None if cfg.attn_window > 0 else (
            lambda p, b, pk, pv: transformer.prefill_suffix(
                p, cfg, b["tokens"], pk, pv)),
    )


def _simple_model(cfg: ModelConfig, mod) -> Model:
    return Model(
        cfg=cfg,
        init=lambda key: mod.init_params(cfg, key),
        param_axes=lambda: mod.param_axes(cfg),
        loss=lambda p, b: mod.loss_fn(p, cfg, b),
        prefill=lambda p, b: mod.prefill(p, cfg, b["tokens"]),
        decode=lambda p, tok, cache: mod.decode_step(p, cfg, tok, cache),
        init_cache=lambda batch, max_len=0, **kw: mod.init_cache(cfg, batch, max_len, **kw),
        cache_axes=mod.cache_axes,
    )


def _griffin_model(cfg: ModelConfig) -> Model:
    m = _simple_model(cfg, griffin)
    return dataclasses.replace(m, cache_axes=lambda: griffin.cache_axes(cfg))


def _encdec_model(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=lambda key: encdec.init_params(cfg, key),
        param_axes=lambda: encdec.param_axes(cfg),
        loss=lambda p, b: encdec.loss_fn(p, cfg, b),
        prefill=lambda p, b: encdec.prefill(p, cfg, b),
        decode=lambda p, tok, cache: encdec.decode_step(p, cfg, tok, cache),
        init_cache=lambda batch, max_len, enc_len=4096, **kw: encdec.init_cache(
            cfg, batch, max_len, enc_len, **kw),
        cache_axes=encdec.cache_axes,
    )


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------
ENC_MEMORY_LEN = 4096   # stub encoder length for enc-dec decode cells


def input_specs(cfg: ModelConfig, shape_kind: str, seq: int, batch: int
                ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Returns (specs, logical_axes) for one dry-run cell.

    ``specs`` mirrors the step function's batch argument; every leaf is a
    ``jax.ShapeDtypeStruct``.
    """
    i32 = jnp.int32
    bf16 = cfg.dtype
    S = jax.ShapeDtypeStruct

    if shape_kind == "train":
        if cfg.family == "encdec":
            dec = max(seq // 8, 128)
            specs = {"frames": S((batch, seq, cfg.d_model), bf16),
                     "tokens": S((batch, dec), i32),
                     "labels": S((batch, dec), i32)}
            axes = {"frames": ("batch", "seq", "embed"),
                    "tokens": ("batch", "seq"), "labels": ("batch", "seq")}
        elif cfg.frontend != "none":
            f = cfg.frontend_tokens
            specs = {"tokens": S((batch, seq - f), i32),
                     "labels": S((batch, seq), i32),
                     "frontend_embeds": S((batch, f, cfg.d_model), bf16)}
            axes = {"tokens": ("batch", "seq"), "labels": ("batch", "seq"),
                    "frontend_embeds": ("batch", "seq", "embed")}
        else:
            specs = {"tokens": S((batch, seq), i32), "labels": S((batch, seq), i32)}
            axes = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
        return specs, axes

    if shape_kind == "prefill":
        if cfg.family == "encdec":
            specs = {"frames": S((batch, seq, cfg.d_model), bf16),
                     "tokens": S((batch, 1), i32)}
            axes = {"frames": ("batch", "seq", "embed"), "tokens": ("batch", "seq")}
        elif cfg.frontend != "none":
            f = cfg.frontend_tokens
            specs = {"tokens": S((batch, seq - f), i32),
                     "frontend_embeds": S((batch, f, cfg.d_model), bf16)}
            axes = {"tokens": ("batch", "seq"),
                    "frontend_embeds": ("batch", "seq", "embed")}
        else:
            specs = {"tokens": S((batch, seq), i32)}
            axes = {"tokens": ("batch", "seq")}
        return specs, axes

    if shape_kind == "decode":
        model = get_model(cfg)
        if cfg.family == "encdec":
            cache = jax.eval_shape(lambda: model.init_cache(batch, seq, enc_len=ENC_MEMORY_LEN))
        else:
            cache = jax.eval_shape(lambda: model.init_cache(batch, seq))
        cache_axes = model.cache_axes()
        specs = {"token": S((batch,), i32), "cache": cache}
        axes = {"token": ("batch",), "cache": cache_axes}
        return specs, axes

    raise ValueError(f"unknown shape kind {shape_kind!r}")
