"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060).

Chunked SSD formulation: the sequence is split into chunks of Q tokens;
within a chunk the output is a masked, decay-weighted "attention" matmul
(MXU-friendly), and a recurrent state h (B, H, P, N) is carried across
chunks with a ``lax.scan``. Decode is the O(1) recurrent update
``h = a*h + B ⊗ x·dt; y = C·h``.

Per layer (ngroups = 1, B/C shared across heads):

    z, xs, Bm, Cm, dt = projections(u)
    xs, Bm, Cm <- causal depthwise conv (window 4) + silu
    dt = softplus(dt + dt_bias);  log a = -exp(A_log) * dt
    y = SSD(log a, Bm, Cm, xs * dt) + D * xs
    out = W_out @ rms_norm(y * silu(z))

P -> D serving transfer for this family ships the (conv_state, h) pair —
a single contiguous tensor per request, which FlowKV moves in one call
(see DESIGN.md §4, ssm row).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (ModelConfig, dense_init, embed, maybe_remat,
                                 rms_norm, softmax_cross_entropy, unembed)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    L, d = cfg.num_layers, cfg.d_model
    di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    cw = cfg.ssm_conv
    ks = jax.random.split(key, 12)

    def stack(k, shape, scale=None):
        return dense_init(k, (L, *shape), cfg.dtype, scale)

    layer = {
        "w_z": stack(ks[0], (d, di)),
        "w_x": stack(ks[1], (d, di)),
        "w_B": stack(ks[2], (d, n)),
        "w_C": stack(ks[3], (d, n)),
        "w_dt": stack(ks[4], (d, h)),
        "conv_x": stack(ks[5], (cw, di), scale=cw ** -0.5),
        "conv_B": stack(ks[6], (cw, n), scale=cw ** -0.5),
        "conv_C": stack(ks[7], (cw, n), scale=cw ** -0.5),
        "A_log": jnp.log(jnp.broadcast_to(jnp.linspace(1.0, 16.0, h), (L, h))).astype(jnp.float32),
        "D": jnp.ones((L, h), cfg.dtype),
        "dt_bias": jnp.zeros((L, h), jnp.float32),
        "norm": jnp.zeros((L, di), cfg.dtype),
        "w_out": stack(ks[8], (di, d)),
        "norm_in": jnp.zeros((L, d), cfg.dtype),
    }
    return {
        "embed": dense_init(ks[9], (cfg.vocab_size, d), cfg.dtype, 0.02),
        "final_norm": jnp.zeros((d,), cfg.dtype),
        "layers": layer,
    }


def param_axes(cfg: ModelConfig) -> Params:
    lx = {
        "w_z": ("layers", "embed", "inner"),
        "w_x": ("layers", "embed", "inner"),
        "w_B": ("layers", "embed", "state"),
        "w_C": ("layers", "embed", "state"),
        "w_dt": ("layers", "embed", "heads"),
        "conv_x": ("layers", "conv", "inner"),
        "conv_B": ("layers", "conv", "state"),
        "conv_C": ("layers", "conv", "state"),
        "A_log": ("layers", "heads"),
        "D": ("layers", "heads"),
        "dt_bias": ("layers", "heads"),
        "norm": ("layers", "inner"),
        "w_out": ("layers", "inner", "embed"),
        "norm_in": ("layers", "embed"),
    }
    return {"embed": ("vocab", "embed"), "final_norm": ("embed",), "layers": lx}


# ---------------------------------------------------------------------------
# Pieces
# ---------------------------------------------------------------------------
def _causal_conv(x: jax.Array, w: jax.Array, state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x (B,S,C), w (cw,C); state (B,cw-1,C) carries
    the last cw-1 inputs across calls. Returns (out, new_state)."""
    cw = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)          # (B, S+cw-1, C)
    out = sum(xx[:, i:i + x.shape[1]] * w[i][None, None] for i in range(cw))
    new_state = xx[:, -(cw - 1):] if cw > 1 else state
    return jax.nn.silu(out), new_state


def _ssd_chunked(log_a: jax.Array, Bm: jax.Array, Cm: jax.Array, xdt: jax.Array,
                 h0: jax.Array, chunk: int) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    log_a (B,S,H); Bm/Cm (B,S,N); xdt (B,S,H,P); h0 (B,H,P,N).
    Returns (y (B,S,H,P), h_final).
    """
    b, s, H = log_a.shape
    n = Bm.shape[-1]
    p = xdt.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = log_a.shape[1] // chunk

    def resh(x, trailing):
        return jnp.moveaxis(x.reshape(b, nc, chunk, *trailing), 1, 0)

    la_c = resh(log_a, (H,))          # (nc,B,Q,H)
    B_c = resh(Bm, (n,))
    C_c = resh(Cm, (n,))
    x_c = resh(xdt, (H, p))

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))          # s<=t (key idx, query idx)

    def body(h, inp):
        la, Bq, Cq, xq = inp                                  # (B,Q,H), (B,Q,N), (B,Q,H,P)
        la = la.astype(jnp.float32)
        cum = jnp.cumsum(la, axis=1)                          # (B,Q,H)
        # intra-chunk: y[t] = sum_{s<=t} exp(cum_t - cum_s) (C_t . B_s) x_s
        decay = cum[:, :, None, :] - cum[:, None, :, :]       # (B,T,S,H) t x s
        decay = jnp.where(tri[None, :, :, None], decay, -jnp.inf)
        gamma = jnp.exp(decay)                                # (B,T,S,H)
        scores = jnp.einsum("btn,bsn->bts", Cq, Bq)           # (B,T,S)
        y_intra = jnp.einsum("bts,btsh,bshp->bthp",
                             scores.astype(jnp.float32), gamma,
                             xq.astype(jnp.float32))
        # inter-chunk: y[t] += exp(cum_t) * (C_t . h0)
        y_inter = jnp.einsum("btn,bhpn,bth->bthp", Cq.astype(jnp.float32),
                             h, jnp.exp(cum))
        # state update: h' = exp(cum_Q) h + sum_s exp(cum_Q - cum_s) B_s x_s
        total = cum[:, -1]                                    # (B,H)
        w = jnp.exp(total[:, None, :] - cum)                  # (B,Q,H)
        h_new = h * jnp.exp(total)[:, :, None, None] + jnp.einsum(
            "bsn,bshp,bsh->bhpn", Bq.astype(jnp.float32),
            xq.astype(jnp.float32), w)
        return h_new, (y_intra + y_inter)

    h_final, ys = jax.lax.scan(body, h0.astype(jnp.float32), (la_c, B_c, C_c, x_c))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * chunk, H, p)[:, :s]
    return y.astype(xdt.dtype), h_final


def _layer(cfg: ModelConfig, lp: Params, u: jax.Array,
           conv_state=None, h0=None) -> Tuple[jax.Array, Tuple[jax.Array, ...], jax.Array]:
    """One mamba2 block on u (B,S,D). Returns (out, conv_states, h_final)."""
    b, s, _ = u.shape
    H, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    x_res = u
    u = rms_norm(u, lp["norm_in"], cfg.norm_eps)
    z = jnp.einsum("bsd,de->bse", u, lp["w_z"])
    xs = jnp.einsum("bsd,de->bse", u, lp["w_x"])
    Bm = jnp.einsum("bsd,dn->bsn", u, lp["w_B"])
    Cm = jnp.einsum("bsd,dn->bsn", u, lp["w_C"])
    dt = jnp.einsum("bsd,dh->bsh", u, lp["w_dt"]).astype(jnp.float32)

    cs_x, cs_B, cs_C = conv_state if conv_state is not None else (None, None, None)
    xs, cs_x = _causal_conv(xs, lp["conv_x"], cs_x)
    Bm, cs_B = _causal_conv(Bm, lp["conv_B"], cs_B)
    Cm, cs_C = _causal_conv(Cm, lp["conv_C"], cs_C)

    dt = jax.nn.softplus(dt + lp["dt_bias"][None, None])
    log_a = -jnp.exp(lp["A_log"].astype(jnp.float32))[None, None] * dt   # (B,S,H)
    xh = xs.reshape(b, s, H, p)
    xdt = xh * dt[..., None].astype(xh.dtype)

    if h0 is None:
        h0 = jnp.zeros((b, H, p, n), jnp.float32)
    y, h_final = _ssd_chunked(log_a, Bm, Cm, xdt, h0, cfg.ssm_chunk)
    y = y + lp["D"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(b, s, H * p)
    y = rms_norm(y * jax.nn.silu(z), lp["norm"], cfg.norm_eps)
    # TP note: w_out contracts the model-sharded inner dim -> partial sums
    # all-reduced per layer. tp_reduce_bf16 emits the dot (and thus the AR)
    # in bf16, halving per-layer collective bytes (§Perf iteration).
    pet = cfg.dtype if cfg.tp_reduce_bf16 else None
    out = jnp.einsum("bse,ed->bsd", y, lp["w_out"], preferred_element_type=pet)
    return x_res + out.astype(x_res.dtype), (cs_x, cs_B, cs_C), h_final


# ---------------------------------------------------------------------------
# Entry points (same protocol as models/transformer.py)
# ---------------------------------------------------------------------------
def forward_train(params: Params, cfg: ModelConfig, tokens: jax.Array,
                  frontend_embeds=None) -> Tuple[jax.Array, jax.Array]:
    x = embed(tokens, params["embed"], cfg.embed_scale)

    def body(h, lp):
        h, _, _ = _layer(cfg, lp, h)
        return h, None

    x, _ = jax.lax.scan(maybe_remat(body, cfg), x, params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(x, params["embed"]), jnp.zeros((), jnp.float32)


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    logits, _ = forward_train(params, cfg, batch["tokens"])
    return softmax_cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                                 batch.get("loss_mask", None) if batch.get("loss_mask") is None
                                 else batch["loss_mask"][:, 1:])


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array,
            frontend_embeds=None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x = embed(tokens, params["embed"], cfg.embed_scale)

    def body(h, lp):
        h, conv, hf = _layer(cfg, lp, h)
        return h, (conv, hf)

    x, (convs, hs) = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["embed"])[:, 0]
    cache = {
        "conv_x": convs[0], "conv_B": convs[1], "conv_C": convs[2],  # (L,B,cw-1,*)
        "h": hs,                                                      # (L,B,H,P,N)
        "length": jnp.full((tokens.shape[0],), tokens.shape[1], jnp.int32),
    }
    return logits, cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int = 0, dtype=None) -> Dict[str, jax.Array]:
    del max_len  # state size is O(1) in sequence length
    dtype = dtype or cfg.dtype
    L, cw = cfg.num_layers, cfg.ssm_conv
    di, n, H, p = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    return {
        "conv_x": jnp.zeros((L, batch, cw - 1, di), dtype),
        "conv_B": jnp.zeros((L, batch, cw - 1, n), dtype),
        "conv_C": jnp.zeros((L, batch, cw - 1, n), dtype),
        "h": jnp.zeros((L, batch, H, p, n), jnp.float32),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def cache_axes() -> Dict[str, Tuple[Optional[str], ...]]:
    return {
        "conv_x": ("layers", "batch", "conv", "inner"),
        "conv_B": ("layers", "batch", "conv", "state"),
        "conv_C": ("layers", "batch", "conv", "state"),
        "h": ("layers", "batch", "heads", "head_dim", "state"),
        "length": ("batch",),
    }


def decode_step(params: Params, cfg: ModelConfig, token: jax.Array,
                cache: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x = embed(token[:, None], params["embed"], cfg.embed_scale)

    def body(h, inp):
        lp, cx, cb, cc, hs = inp
        h, (cx, cb, cc), hf = _layer(cfg, lp, h, conv_state=(cx, cb, cc), h0=hs)
        return h, (cx, cb, cc, hf)

    x, (cx, cb, cc, hs) = jax.lax.scan(
        body, x, (params["layers"], cache["conv_x"], cache["conv_B"],
                  cache["conv_C"], cache["h"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["embed"])[:, 0]
    return logits, {"conv_x": cx, "conv_B": cb, "conv_C": cc, "h": hs,
                    "length": cache["length"] + 1}
