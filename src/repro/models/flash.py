"""Chunked (flash-style) attention in pure jnp.

Materializing (S, T) score matrices at 32k context would need hundreds of
GB per device, so every full-sequence attention in this repo goes through
this module: queries are processed in chunks with an online-softmax
accumulator over key/value chunks. Two variants:

* ``flash_attention(..., wedge=False)`` — baseline: scan over ALL kv chunks
  with causal masking (computes the upper triangle and masks it; ~2x causal
  FLOPs, fully scan-compact HLO).
* ``flash_attention(..., wedge=True)``  — beyond-paper perf variant: the
  query-chunk loop is unrolled in Python and each query chunk contracts only
  against its causal prefix (exact causal FLOPs, HLO grows with S/chunk).

* ``window > 0`` — local attention: each query chunk attends to a
  statically-sized key window (window + q_chunk), giving O(S * window) work —
  the sub-quadratic path required by recurrentgemma and long_500k.

All variants are GQA-aware and accumulate in fp32. They are reverse-mode
differentiable (scan + masking only, no while loops) so training uses the
same code path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = float(jnp.finfo(jnp.float32).min)


def _split_heads(q: jax.Array, kvh: int) -> jax.Array:
    b, s, h, d = q.shape
    return q.reshape(b, s, kvh, h // kvh, d)


def _chunk_attend(qg: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array,
                  m_prev: jax.Array, l_prev: jax.Array, acc: jax.Array):
    """One online-softmax accumulation step.

    qg (B,Sq,KV,G,hd); k/v (B,Tc,KV,hd); mask (B,1,1,Sq,Tc) or (Sq,Tc)-broadcastable.
    m/l (B,KV,G,Sq); acc (B,Sq,KV,G,hd).
    """
    hd = qg.shape[-1]
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd)
    scores = jnp.where(mask, scores, NEG_INF)
    m_cur = jnp.max(scores, axis=-1)                          # (B,KV,G,Sq)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (all NEG_INF)
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(scores - m_safe[..., None])
    p = jnp.where(mask, p, 0.0)
    scale = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_new = l_prev * scale + p.sum(axis=-1)
    pv = jnp.einsum("bkgst,btkd->bskgd", p.astype(v.dtype), v).astype(jnp.float32)
    acc_new = acc * jnp.transpose(scale, (0, 3, 1, 2))[..., None] + pv
    return m_new, l_new, acc_new


def _finalize(l: jax.Array, acc: jax.Array, dtype) -> jax.Array:
    denom = jnp.maximum(jnp.transpose(l, (0, 3, 1, 2))[..., None], 1e-30)
    out = acc / denom
    b, s, kv, g, hd = out.shape
    return out.reshape(b, s, kv * g, hd).astype(dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    q_chunk: int = 512, kv_chunk: int = 1024,
                    q_offset: int = 0, wedge: bool = False,
                    _mask_window: int = 0) -> jax.Array:
    """q (B,S,H,hd); k,v (B,T,KV,hd) -> (B,S,H,hd).

    ``q_offset``: global position of q[0] relative to k[0] (chunked prefill).
    ``_mask_window``: internal — apply a window mask without the sliced-KV
    local path (used when the sequence is shorter than the window span).
    """
    b, s, h, hd = q.shape
    t = k.shape[1]
    kvh = k.shape[2]
    if window > 0:
        return _local_flash(q, k, v, window=window, q_chunk=q_chunk, q_offset=q_offset)
    if wedge:
        return _wedge_flash(q, k, v, causal=causal, q_chunk=q_chunk, q_offset=q_offset)

    # pad S to a multiple of q_chunk
    q_chunk = min(q_chunk, s)
    pad_q = (-s) % q_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    nq = q.shape[1] // q_chunk
    kv_chunk = min(kv_chunk, t)
    pad_k = (-t) % kv_chunk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nk = k.shape[1] // kv_chunk

    qg = _split_heads(q, kvh).reshape(b, nq, q_chunk, kvh, h // kvh, hd)
    qg = jnp.moveaxis(qg, 1, 0)                               # (nq,B,qc,KV,G,hd)
    ks = jnp.moveaxis(k.reshape(b, nk, kv_chunk, kvh, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(b, nk, kv_chunk, kvh, hd), 1, 0)

    qpos_base = jnp.arange(q_chunk)
    kpos_base = jnp.arange(kv_chunk)

    def q_body(_, qi_and_chunk):
        qi, qc = qi_and_chunk
        qpos = q_offset + qi * q_chunk + qpos_base            # (qc,)

        def kv_body(carry, ki_and_kv):
            m, l, acc = carry
            ki, kc, vc = ki_and_kv
            kpos = ki * kv_chunk + kpos_base
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            mask &= kpos[None, :] < t                         # padding
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if _mask_window > 0:
                mask &= kpos[None, :] > qpos[:, None] - _mask_window
            m, l, acc = _chunk_attend(qc, kc, vc, mask[None, None, None], m, l, acc)
            return (m, l, acc), None

        g = h // kvh
        init = (jnp.full((b, kvh, g, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((b, kvh, g, q_chunk), jnp.float32),
                jnp.zeros((b, q_chunk, kvh, g, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_body, init, (jnp.arange(nk), ks, vs))
        return None, _finalize(l, acc, q.dtype)

    _, out = jax.lax.scan(q_body, None, (jnp.arange(nq), qg))
    out = jnp.moveaxis(out, 0, 1).reshape(b, nq * q_chunk, h, hd)
    return out[:, :s]


def _wedge_flash(q, k, v, *, causal: bool, q_chunk: int, q_offset: int):
    """Unrolled causal wedge: each query chunk sees a statically-sized causal
    prefix — exact causal FLOPs at the cost of HLO size O(S/q_chunk)."""
    b, s, h, hd = q.shape
    t = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    q_chunk = min(q_chunk, s)
    outs = []
    for qi in range(0, s, q_chunk):
        qc_len = min(q_chunk, s - qi)
        qc = _split_heads(q[:, qi:qi + qc_len], kvh)
        hi = min(t, q_offset + qi + qc_len) if causal else t
        kc, vc = k[:, :hi], v[:, :hi]
        qpos = q_offset + qi + jnp.arange(qc_len)
        mask = jnp.ones((qc_len, hi), bool)
        if causal:
            mask &= jnp.arange(hi)[None, :] <= qpos[:, None]
        m = jnp.full((b, kvh, g, qc_len), NEG_INF, jnp.float32)
        l = jnp.zeros((b, kvh, g, qc_len), jnp.float32)
        acc = jnp.zeros((b, qc_len, kvh, g, hd), jnp.float32)
        m, l, acc = _chunk_attend(qc, kc, vc, mask[None, None, None], m, l, acc)
        outs.append(_finalize(l, acc, q.dtype))
    return jnp.concatenate(outs, axis=1)


def _local_flash(q, k, v, *, window: int, q_chunk: int, q_offset: int):
    """Sliding-window causal attention, O(S * (window + q_chunk)).

    Each query chunk attends to a static-size key slice
    [chunk_start - window + 1, chunk_end) via dynamic_slice.
    """
    b, s, h, hd = q.shape
    t = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    q_chunk = min(q_chunk, s)
    span = window + q_chunk                     # static key-slice size
    if span >= t:
        return flash_attention(q, k, v, causal=True, window=0,
                               q_chunk=q_chunk, kv_chunk=max(128, min(1024, t)),
                               q_offset=q_offset, _mask_window=window)
    pad_q = (-s) % q_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    nq = q.shape[1] // q_chunk
    qg = jnp.moveaxis(_split_heads(q, kvh).reshape(b, nq, q_chunk, kvh, g, hd), 1, 0)
    qpos_base = jnp.arange(q_chunk)
    kpos_base = jnp.arange(span)

    def q_body(_, qi_and_chunk):
        qi, qc = qi_and_chunk
        qstart = q_offset + qi * q_chunk
        start = jnp.clip(qstart - window + 1, 0, t - span)
        kc = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
        qpos = qstart + qpos_base
        kpos = start + kpos_base
        mask = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] > qpos[:, None] - window)
        m = jnp.full((b, kvh, g, q_chunk), NEG_INF, jnp.float32)
        l = jnp.zeros((b, kvh, g, q_chunk), jnp.float32)
        acc = jnp.zeros((b, q_chunk, kvh, g, hd), jnp.float32)
        m, l, acc = _chunk_attend(qc, kc, vc, mask[None, None, None], m, l, acc)
        return None, _finalize(l, acc, q.dtype)

    _, out = jax.lax.scan(q_body, None, (jnp.arange(nq), qg))
    out = jnp.moveaxis(out, 0, 1).reshape(b, nq * q_chunk, h, hd)
    return out[:, :s]


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention_jit(q, k, v, causal: bool = True, window: int = 0):
    return flash_attention(q, k, v, causal=causal, window=window)
