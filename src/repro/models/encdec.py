"""Encoder–decoder backbone (seamless-m4t-large-v2 assignment).

Per the assignment spec the modality frontend is a STUB: the encoder
consumes precomputed frame embeddings (B, S_enc, d_model) supplied by
``input_specs()``. The decoder is a standard causal transformer with
cross-attention into the encoder output.

Serving split under FlowKV: prefill (P node) = encoder forward + cross-K/V
projection + decoder prompt prefill; the transferred "KV cache" is the
decoder self-attention cache PLUS the per-layer cross-attention K/V — both
are paged and shipped by the same planner (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import mlp as MLPM
from repro.models.common import (ModelConfig, dense_init, embed, maybe_remat,
                                 rms_norm, softmax_cross_entropy, unembed)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    ks = jax.random.split(key, 10)
    d = cfg.d_model
    Le, Ld = cfg.num_encoder_layers, cfg.num_layers

    def block(k, L, with_cross: bool):
        kk = jax.random.split(k, 16)
        p: Dict[str, jax.Array] = {}
        for i, (name, shape) in enumerate(A.attn_param_shapes(cfg).items()):
            p[name] = dense_init(kk[i], (L, *shape), cfg.dtype)
        p["norm_attn"] = jnp.zeros((L, d), cfg.dtype)
        p["norm_mlp"] = jnp.zeros((L, d), cfg.dtype)
        for i, (name, shape) in enumerate(MLPM.mlp_param_shapes(cfg).items()):
            p[name] = dense_init(kk[6 + i], (L, *shape), cfg.dtype)
        if with_cross:
            for i, (name, shape) in enumerate(A.cross_param_shapes(cfg).items()):
                p[f"x_{name}"] = dense_init(kk[10 + i], (L, *shape), cfg.dtype)
            p["norm_cross"] = jnp.zeros((L, d), cfg.dtype)
        return p

    return {
        "embed": dense_init(ks[0], (cfg.vocab_size, d), cfg.dtype, 0.02),
        "enc_in_norm": jnp.zeros((d,), cfg.dtype),
        "encoder": block(ks[1], Le, with_cross=False),
        "decoder": block(ks[2], Ld, with_cross=True),
        "enc_final_norm": jnp.zeros((d,), cfg.dtype),
        "final_norm": jnp.zeros((d,), cfg.dtype),
    }


def param_axes(cfg: ModelConfig) -> Params:
    def block_axes(with_cross: bool):
        ax = {name: ("layers", *a) for name, a in A.attn_param_axes(cfg).items()}
        ax["norm_attn"] = ("layers", "embed")
        ax["norm_mlp"] = ("layers", "embed")
        for name, a in MLPM.mlp_param_axes().items():
            ax[name] = ("layers", *a)
        if with_cross:
            ax.update({
                "x_wq": ("layers", "embed", "heads", "head_dim"),
                "x_wk": ("layers", "embed", "kv_heads", "head_dim"),
                "x_wv": ("layers", "embed", "kv_heads", "head_dim"),
                "x_wo": ("layers", "heads", "head_dim", "embed"),
                "norm_cross": ("layers", "embed"),
            })
        return ax

    return {
        "embed": ("vocab", "embed"),
        "enc_in_norm": ("embed",),
        "encoder": block_axes(False),
        "decoder": block_axes(True),
        "enc_final_norm": ("embed",),
        "final_norm": ("embed",),
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------
def encode(params: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames (B, S_enc, D) -> encoder output (B, S_enc, D). Bidirectional."""
    from repro.models.flash import flash_attention

    x = rms_norm(frames.astype(cfg.dtype), params["enc_in_norm"], cfg.norm_eps)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(h, lp):
        hn = rms_norm(h, lp["norm_attn"], cfg.norm_eps)
        q, k, v = A.qkv_project(lp, hn, cfg, positions)
        if x.shape[1] > cfg.flash_threshold:
            attn = flash_attention(q, k, v, causal=False,
                                   q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk)
        else:
            attn = A.attend(q, k, v, None)
        h = h + A.out_project(lp, attn)
        hn = rms_norm(h, lp["norm_mlp"], cfg.norm_eps)
        h = h + MLPM.gated_mlp({k2: lp[k2] for k2 in ("w_gate", "w_up", "w_down")},
                               hn, cfg.activation)
        return h, None

    x, _ = jax.lax.scan(maybe_remat(body, cfg), x, params["encoder"])
    return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def encode_cross_kv(params: Params, cfg: ModelConfig, memory: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """Project encoder output into per-decoder-layer cross K/V.

    Returns (xk, xv), each (L_dec, B, S_enc, KV, hd) — part of the
    transferred request state in FlowKV serving.
    """
    def body(_, lp):
        k, v = A.encode_memory({"wk": lp["x_wk"], "wv": lp["x_wv"]}, memory)
        return None, (k, v)

    _, (xk, xv) = jax.lax.scan(body, None, params["decoder"])
    return xk, xv


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------
def _decoder_layer(cfg: ModelConfig, lp: Params, h: jax.Array, positions,
                   xk: jax.Array, xv: jax.Array):
    hn = rms_norm(h, lp["norm_attn"], cfg.norm_eps)
    attn, (k, v) = A.self_attention(lp, hn, cfg, positions)
    h = h + attn
    hn = rms_norm(h, lp["norm_cross"], cfg.norm_eps)
    h = h + A.cross_attention({"wq": lp["x_wq"], "wo": lp["x_wo"]}, hn, (xk, xv), cfg)
    hn = rms_norm(h, lp["norm_mlp"], cfg.norm_eps)
    h = h + MLPM.gated_mlp({k2: lp[k2] for k2 in ("w_gate", "w_up", "w_down")},
                           hn, cfg.activation)
    return h, (k, v)


def forward_train(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]
                  ) -> Tuple[jax.Array, jax.Array]:
    """batch: frames (B,S_enc,D) + tokens (B,S_dec). Returns decoder logits."""
    memory = encode(params, cfg, batch["frames"])
    xk, xv = encode_cross_kv(params, cfg, memory)
    x = embed(batch["tokens"], params["embed"], cfg.embed_scale)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(h, inp):
        lp, xki, xvi = inp
        h, _ = _decoder_layer(cfg, lp, h, positions, xki, xvi)
        return h, None

    x, _ = jax.lax.scan(maybe_remat(body, cfg), x, (params["decoder"], xk, xv))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(x, params["embed"]), jnp.zeros((), jnp.float32)


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    logits, _ = forward_train(params, cfg, batch)
    mask = batch.get("loss_mask")
    return softmax_cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                                 None if mask is None else mask[:, 1:])


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Encoder + decoder-prompt prefill. Cache = dec self KV + cross KV."""
    memory = encode(params, cfg, batch["frames"])
    xk, xv = encode_cross_kv(params, cfg, memory)
    tokens = batch["tokens"]
    x = embed(tokens, params["embed"], cfg.embed_scale)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(h, inp):
        lp, xki, xvi = inp
        h, (k, v) = _decoder_layer(cfg, lp, h, positions, xki, xvi)
        return h, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, (params["decoder"], xk, xv))
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["embed"])[:, 0]
    cache = {"k": ks, "v": vs, "cross_k": xk, "cross_v": xv,
             "length": jnp.full((tokens.shape[0],), tokens.shape[1], jnp.int32)}
    return logits, cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int,
               dtype=None) -> Dict[str, jax.Array]:
    dtype = dtype or cfg.dtype
    L = cfg.num_layers
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((L, batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((L, batch, max_len, kv, hd), dtype),
        "cross_k": jnp.zeros((L, batch, enc_len, kv, hd), dtype),
        "cross_v": jnp.zeros((L, batch, enc_len, kv, hd), dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def cache_axes() -> Dict[str, Tuple[Optional[str], ...]]:
    return {
        "k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        "v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        "cross_k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        "cross_v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        "length": ("batch",),
    }


def decode_step(params: Params, cfg: ModelConfig, token: jax.Array,
                cache: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x = embed(token[:, None], params["embed"], cfg.embed_scale)
    position = cache["length"]

    def body(h, inp):
        lp, ck, cv, xki, xvi = inp
        hn = rms_norm(h, lp["norm_attn"], cfg.norm_eps)
        attn, (ck, cv) = A.decode_self_attention(lp, hn, cfg, ck, cv, position)
        h = h + attn
        hn = rms_norm(h, lp["norm_cross"], cfg.norm_eps)
        h = h + A.cross_attention({"wq": lp["x_wq"], "wo": lp["x_wo"]}, hn, (xki, xvi), cfg)
        hn = rms_norm(h, lp["norm_mlp"], cfg.norm_eps)
        h = h + MLPM.gated_mlp({k2: lp[k2] for k2 in ("w_gate", "w_up", "w_down")},
                               hn, cfg.activation)
        return h, (ck, cv)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["decoder"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["embed"])[:, 0]
    return logits, {"k": ks, "v": vs, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"], "length": cache["length"] + 1}
