"""AdamW with mixed-precision master weights, plus optional int8 gradient
compression with error feedback (the DCN-friendly distributed-optimization
path used across the pod axis).

Train state layout (pytree-parallel to params):

    params  — compute copy, model dtype (bf16 on the big configs)
    master  — fp32 master weights
    m, v    — fp32 Adam moments
    step    — int32

``compress_grads``/``decompress_grads`` implement per-tensor symmetric int8
quantization with an error-feedback accumulator, halving (vs bf16) the bytes
an all-reduce moves over DCN; the residual keeps the update unbiased over
time (Seide et al. style).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_state(params) -> Dict[str, Any]:
    zeros32 = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "params": params,
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    return cfg.lr * warm


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(state: Dict[str, Any], grads, cfg: AdamWConfig,
                  compute_dtype=None) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step. Returns (new_state, metrics)."""
    step = state["step"] + 1
    lr = _lr_at(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        master = master - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                                + cfg.weight_decay * master)
        return m, v, master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_w = treedef.flatten_up_to(state["master"])
    new_m, new_v, new_w = [], [], []
    for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w):
        m2, v2, w2 = upd(g, m, v, w)
        new_m.append(m2); new_v.append(v2); new_w.append(w2)

    master = jax.tree.unflatten(treedef, new_w)
    params_dtype = compute_dtype
    params = jax.tree.map(
        lambda w, p: w.astype(params_dtype or p.dtype), master, state["params"])
    new_state = {"params": params, "master": master,
                 "m": jax.tree.unflatten(treedef, new_m),
                 "v": jax.tree.unflatten(treedef, new_v),
                 "step": step}
    return new_state, {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# int8 gradient compression with error feedback
# ---------------------------------------------------------------------------
def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def compress_grads(grads, residual):
    """Per-tensor symmetric int8 quantization; returns (q, scales, new_residual)."""
    def one(g, r):
        g = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return q, scale, g - deq

    flat, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    qs, scales, rs = [], [], []
    for g, r in zip(flat, flat_r):
        q, s, nr = one(g, r)
        qs.append(q); scales.append(s); rs.append(nr)
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, scales),
            jax.tree.unflatten(treedef, rs))


def decompress_grads(q, scales):
    return jax.tree.map(lambda qq, s: qq.astype(jnp.float32) * s, q, scales)
