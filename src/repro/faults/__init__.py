"""Deterministic, seeded fault injection for the serving plane.

One injector drives chaos on BOTH runtimes — ``PDCluster.step`` polls it on
the cycle clock, ``ClusterSim`` schedules its specs on the event clock — so
a chaos run is exactly as replayable as a clean one: the spec list + seed
round-trip through capture/replay meta (:func:`FaultInjector.to_meta` /
:func:`FaultInjector.from_meta`), and ``reset()`` rewinds all internal state
so the same injector instance re-runs identically.

Fault kinds (:class:`FaultSpec.kind`):

* ``node_crash``          — kill ``node_id`` at time ``at`` (one-shot).
* ``transfer_fail``       — a transfer attempt at/after ``at`` fails before
                            any bytes move (``count`` attempts, or a seeded
                            per-attempt ``rate``).
* ``transfer_corrupt``    — the attempt completes but the payload is
                            corrupted in flight; the post-dispatch checksum
                            catches it (``count`` / ``rate`` as above).
* ``degraded_bandwidth``  — transfers in ``[at, at + duration)`` are priced
                            ``factor``× slower (link flap / congestion).
* ``heartbeat_loss``      — ``node_id`` stops heartbeating during
                            ``[at, at + duration)`` without dying; staleness
                            detection fires, the node's work is requeued.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, Iterable, List, Optional, Sequence, Union

KINDS = ("node_crash", "transfer_fail", "transfer_corrupt",
         "degraded_bandwidth", "heartbeat_loss")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    kind: str
    at: float = 0.0               # activation time (driving clock)
    node_id: Optional[int] = None  # node_crash / heartbeat_loss target
    count: int = 1                # transfer faults: budget of attempts hit
    factor: float = 1.0           # degraded_bandwidth: latency multiplier
    duration: float = 0.0         # degraded_bandwidth / heartbeat_loss window
    rate: float = 0.0             # transfer faults: per-attempt probability
    #                               (overrides count when > 0; seeded RNG)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; have {KINDS}")
        if self.kind in ("node_crash", "heartbeat_loss") and self.node_id is None:
            raise ValueError(f"{self.kind} needs a node_id")


class FaultInjector:
    """Schedules :class:`FaultSpec`\\ s against a driving clock.

    Stateful but rewindable: all mutable state (fired crashes, transfer
    budgets, the seeded RNG) reinitializes on :meth:`reset`, which both
    runtimes call at the start of a run.
    """

    def __init__(self, specs: Iterable[FaultSpec] = (), seed: int = 0):
        self.specs: List[FaultSpec] = list(specs)
        self.seed = int(seed)
        self.reset()

    def reset(self) -> None:
        self._fired: set = set()              # spec indices (node crashes)
        self._budget: Dict[int, int] = {
            i: s.count for i, s in enumerate(self.specs)
            if s.kind in ("transfer_fail", "transfer_corrupt") and s.rate <= 0}
        self._rng = random.Random(self.seed)

    # -- node crashes -------------------------------------------------------
    def due(self, now: float) -> List[FaultSpec]:
        """Unfired node_crash specs whose time has come (marks them fired)."""
        out = []
        for i, s in enumerate(self.specs):
            if s.kind == "node_crash" and i not in self._fired and now >= s.at:
                self._fired.add(i)
                out.append(s)
        return out

    def crash_specs(self) -> List[FaultSpec]:
        return [s for s in self.specs if s.kind == "node_crash"]

    # -- heartbeat loss -----------------------------------------------------
    def heartbeat_suppressed(self, node_id: int, now: float) -> bool:
        return any(s.kind == "heartbeat_loss" and s.node_id == node_id
                   and s.at <= now < s.at + s.duration for s in self.specs)

    def heartbeat_loss_specs(self) -> List[FaultSpec]:
        return [s for s in self.specs if s.kind == "heartbeat_loss"]

    # -- transfer faults ----------------------------------------------------
    def transfer_attempt(self, now: float) -> Optional[str]:
        """Verdict for ONE transfer attempt: None | "fail" | "corrupt".

        Deterministic: count-budgeted specs hit the first ``count`` attempts
        at/after ``at``; rate specs draw from the seeded RNG (the draw
        sequence is part of the replayable state)."""
        for i, s in enumerate(self.specs):
            if s.kind not in ("transfer_fail", "transfer_corrupt") or now < s.at:
                continue
            verdict = "fail" if s.kind == "transfer_fail" else "corrupt"
            if s.rate > 0:
                if self._rng.random() < s.rate:
                    return verdict
            elif self._budget.get(i, 0) > 0:
                self._budget[i] -= 1
                return verdict
        return None

    # -- degraded bandwidth -------------------------------------------------
    def bandwidth_factor(self, now: float) -> float:
        f = 1.0
        for s in self.specs:
            if s.kind == "degraded_bandwidth" and s.at <= now < s.at + s.duration:
                f *= s.factor
        return f

    # -- capture/replay meta ------------------------------------------------
    def to_meta(self) -> dict:
        return {"seed": self.seed,
                "specs": [dataclasses.asdict(s) for s in self.specs]}

    @classmethod
    def from_meta(cls, meta: dict) -> "FaultInjector":
        specs = [FaultSpec(**s) for s in meta.get("specs", [])]
        return cls(specs, seed=meta.get("seed", 0))


def as_injector(faults: Union[None, FaultInjector, dict,
                              Sequence[FaultSpec]]) -> Optional[FaultInjector]:
    """Normalize a runtime's ``faults=`` kwarg: an injector passes through,
    a meta dict (replay path) or a spec sequence builds a fresh one."""
    if faults is None or isinstance(faults, FaultInjector):
        return faults
    if isinstance(faults, dict):
        return FaultInjector.from_meta(faults)
    return FaultInjector(faults)
