"""Fit the cost-model coefficients from measured timings.

The simulator and the controller price everything with two small linear
models:

* :class:`repro.core.costmodel.TransportProfile` —
  ``latency = fixed_s + calls*per_call_s + bytes/bandwidth_Bps``
* :class:`repro.sim.hardware.HardwareProfile` prefill —
  ``predicted_ttft_s = overhead_s + flops / (peak_flops * mfu_prefill)``

Both are linear in their unknowns, so ordinary least squares over measured
``(calls, bytes, seconds)`` / ``(flops, seconds)`` samples recovers the
coefficients exactly on synthetic data (``tests/test_obs.py``) and
usefully on real data. FLOP counts come from the same sources the roofline
harness uses: ``launch/hlo_flops.py`` when a compiled HLO is at hand, the
``2 * active_params`` analytic model otherwise (they agree — that is what
``benchmarks/roofline.py``'s useful_ratio column audits).

``--check`` is the sim-vs-real gate: run a real (CPU-scale) prefill sweep,
fit a :class:`HardwareProfile` for THIS host on part of the sweep, then
predict the held-out points with ``predicted_ttft_s`` and require the
median relative error under :data:`TTFT_ERROR_BOUND`. The bound is wide
because shared CI hosts jitter; the point of the gate is that the
calibrated model and reality stay the same ORDER — a broken fit (sign
flip, unit slip, constant-only model) fails it immediately.

CLI::

    PYTHONPATH=src python -m repro.obs.calibrate --check
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.costmodel import TransportProfile, predicted_ttft_s
from repro.sim.hardware import TPU_V5E, HardwareProfile

# Documented sim-vs-real bound for --check (docs/observability.md): median
# relative error of predicted vs measured prefill TTFT on held-out lengths.
# Wide on purpose: sub-ms kernel timings on a shared CI host jitter by tens
# of percent, and the gate's job is catching structural breaks (sign flip,
# unit slip, constant-only fit) — those miss by integer factors.
TTFT_ERROR_BOUND = 0.75


# -- transport: latency = fixed + calls*per_call + bytes/bw ---------------------
def fit_transport(samples: Sequence[Tuple[int, int, float]],
                  name: str = "fitted") -> TransportProfile:
    """Least-squares fit of (num_calls, num_bytes, seconds) samples.

    Needs >= 3 samples spanning distinct calls AND bytes values (the design
    matrix [1, calls, bytes] must have full column rank) — synthetic
    recovery is exact, measured fits are clamped to physical (>= 0)
    coefficients.
    """
    if len(samples) < 3:
        raise ValueError(f"need >= 3 samples to fit 3 coefficients, "
                         f"got {len(samples)}")
    a = np.array([[1.0, c, b] for c, b, _ in samples])
    y = np.array([t for _, _, t in samples])
    (fixed, per_call, per_byte), *_ = np.linalg.lstsq(a, y, rcond=None)
    fixed, per_call, per_byte = (max(0.0, float(v))
                                 for v in (fixed, per_call, per_byte))
    return TransportProfile(
        name=name, per_call_s=per_call,
        bandwidth_Bps=1.0 / per_byte if per_byte > 0 else 1e15,
        fixed_s=fixed)


# -- compute: seconds = overhead + flops / effective_flops ----------------------
def fit_compute(samples: Sequence[Tuple[float, float]]
                ) -> Tuple[float, float]:
    """Fit (flops, seconds) samples; returns (effective_flops, overhead_s)."""
    if len(samples) < 2:
        raise ValueError(f"need >= 2 samples to fit 2 coefficients, "
                         f"got {len(samples)}")
    a = np.array([[1.0, f] for f, _ in samples])
    y = np.array([t for _, t in samples])
    (overhead, inv_eff), *_ = np.linalg.lstsq(a, y, rcond=None)
    overhead = max(0.0, float(overhead))
    eff = 1.0 / inv_eff if inv_eff > 0 else 1e18
    return float(eff), overhead


def fit_hardware(samples: Sequence[Tuple[float, float]],
                 base: HardwareProfile = TPU_V5E,
                 name: str = "fitted") -> HardwareProfile:
    """A HardwareProfile whose prefill_time() reproduces the samples.

    The fitted effective throughput lands in ``mfu_prefill`` (relative to
    ``base``'s peak), the fitted dispatch floor in ``step_overhead_s`` —
    i.e. exactly the two knobs ``predicted_ttft_s`` reads, so the
    controller's routing/admission estimates inherit the calibration
    unchanged.
    """
    eff, overhead = fit_compute(samples)
    return dataclasses.replace(base, name=name,
                               mfu_prefill=eff / base.peak_flops,
                               step_overhead_s=overhead)


# -- FLOP seeds -----------------------------------------------------------------
def prefill_flops(cfg, num_tokens: int, hlo_text: Optional[str] = None
                  ) -> float:
    """Prefill FLOPs for ``num_tokens``.

    Analytic model: the linear weight term (2 * active_params per token)
    PLUS the quadratic attention term (QK^T and AV are each
    2*n^2*heads*head_dim per layer). At smoke-model scale the quadratic
    term DOMINATES wall time, so dropping it would bend the x axis of the
    fit. When a compiled HLO is provided, ``launch/hlo_flops.py`` counts it
    too and the larger of the two wins — the HLO count is exact where it
    sees the dots, but CPU XLA lowers matmuls to oneDNN custom-calls the
    text counter cannot price, so it can only refine the analytic floor
    upward, never below it.
    """
    n_attn = cfg.num_attention_layers() or cfg.num_layers
    analytic = 2.0 * cfg.active_params() * num_tokens + \
        4.0 * n_attn * cfg.num_heads * cfg.head_dim * num_tokens ** 2
    if hlo_text is not None:
        from repro.launch.hlo_flops import analyze_hlo
        counts = analyze_hlo(hlo_text)
        return max(analytic, float(counts.flops))
    return analytic


# -- the sim-vs-real check -------------------------------------------------------
def measure_prefill(prompt_lens: Sequence[int] = (32, 64, 96, 128, 160,
                                                  192, 224, 256),
                    repeats: int = 5, arch: str = "qwen3-1.7b"):
    """Time real single-node prefills at several prompt lengths.

    Returns ``(cfg, [(flops, best_seconds)])``. Each length is compiled
    once and timed ``repeats`` times keeping the MINIMUM — the estimator
    least contaminated by CI-host noise; compile time is excluded (the
    cost model prices steady-state compute, not tracing).
    """
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.models.api import get_model

    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    step = jax.jit(lambda p, t: T.prefill(p, cfg, t)[0])
    samples = []
    for n in prompt_lens:
        tokens = jnp.zeros((1, n), jnp.int32)
        compiled = step.lower(params, tokens).compile()
        flops = prefill_flops(cfg, n, hlo_text=compiled.as_text())
        jax.block_until_ready(step(params, tokens))   # warm the cache
        best = min(_timed(step, params, tokens) for _ in range(repeats))
        samples.append((flops, best))
    return cfg, samples


def _timed(step, params, tokens) -> float:
    import jax

    t0 = time.monotonic()
    jax.block_until_ready(step(params, tokens))
    return time.monotonic() - t0


def check(bound: float = TTFT_ERROR_BOUND, arch: str = "qwen3-1.7b") -> dict:
    """Fit on the even sweep points, score prediction error on the odd ones."""
    cfg, samples = measure_prefill(arch=arch)
    train, held = samples[::2], samples[1::2]
    hw = fit_hardware(train, name=f"{arch}-cpu-fit")
    errors = []
    for flops, measured in held:
        pred = predicted_ttft_s(0.0, flops,
                                hw.peak_flops * hw.mfu_prefill,
                                hw.step_overhead_s)
        errors.append(abs(pred - measured) / measured)
    median = float(np.median(errors))
    return {
        "arch": arch,
        "effective_flops": hw.peak_flops * hw.mfu_prefill,
        "step_overhead_s": hw.step_overhead_s,
        "held_out_rel_errors": errors,
        "median_rel_error": median,
        "bound": bound,
        "ok": median <= bound,
    }


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Calibrate cost-model coefficients from measured timings")
    ap.add_argument("--check", action="store_true",
                    help="fail unless the fitted model predicts held-out "
                         f"prefill TTFT within {TTFT_ERROR_BOUND:.0%} "
                         "median relative error")
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)
    result = check(arch=args.arch)
    if args.json:
        print(json.dumps(result, indent=2, sort_keys=True))
    else:
        print(f"calibrated {result['arch']}: "
              f"effective {result['effective_flops']/1e9:.2f} GFLOP/s, "
              f"overhead {result['step_overhead_s']*1e3:.2f} ms, "
              f"median held-out TTFT error "
              f"{result['median_rel_error']:.1%} (bound {result['bound']:.0%})")
    if args.check and not result["ok"]:
        print(f"FAIL: median_rel_error {result['median_rel_error']:.3f} > "
              f"bound {result['bound']}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
