"""Cross-PR performance trajectory: append-only BENCH_<area>.json files.

Every gated benchmark (``--json``/``--check`` CLI contract) can also append
its headline metrics to a schema-versioned history file at the repo root —
``BENCH_transfer.json``, ``BENCH_decode.json``, ``BENCH_scenarios.json``,
``BENCH_prefix.json``, ``BENCH_breakdown.json``, ``BENCH_chunked.json``,
``BENCH_tiered.json``, ``BENCH_sharded.json``, ``BENCH_faults.json`` — via
its ``--history``
flag. The files are committed, so the repo carries its own perf trajectory:
each PR's CI run appends one entry, and ``tools/bench_history.py --check``
fails the build when the newest entry regresses against the committed
baseline.

File shape::

    {"schema": 1, "area": "transfer",
     "baseline": {metric: value, ...},          # the gate
     "entries": [{"ts": ..., "metrics": {...}}, ...]}   # the trajectory

Per-metric gating modes (:data:`AREAS`):

* ``exact`` — structural counters (dispatch counts, call counts): any
  drift is a data-plane change and must be acknowledged by editing the
  committed baseline in the same PR.
* ``le`` / ``ge`` — bounded metrics (latency fractions must not grow,
  goodput must not shrink) with a small relative tolerance; deterministic
  sim outputs get a tight one, analytics get zero.
* ``info`` — wall-clock measurements: recorded for the trajectory, never
  gated (shared CI hosts are not a benchmark machine).

The first ``record()`` for an area creates the file with the entry as
baseline; re-baselining after an intentional change = delete the file (or
edit ``baseline``) and re-record.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Any, Dict, List, Optional, Union

SCHEMA_VERSION = 1

# Repo root: src/repro/obs/history.py -> three parents up.
ROOT = pathlib.Path(__file__).resolve().parents[3]

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    mode: str            # "exact" | "le" | "ge" | "info"
    tol: float = 0.0     # relative tolerance for le/ge


# The gated surface per area. Metrics a benchmark emits beyond these are
# recorded in the trajectory but not checked (open schema, like spans).
AREAS: Dict[str, Dict[str, MetricSpec]] = {
    "transfer": {
        # planner/executor structure: exact by construction
        "flowkv_calls": MetricSpec("exact"),
        "blockwise_calls": MetricSpec("exact"),
        "layerwise_calls": MetricSpec("exact"),
        "flowkv_dispatches": MetricSpec("exact"),
        "blockwise_dispatches": MetricSpec("exact"),
        "layerwise_dispatches": MetricSpec("exact"),
        "flowkv_wall_s": MetricSpec("info"),
    },
    "decode": {
        "kernel_max_dispatches_per_step": MetricSpec("exact"),
        "dense_max_dispatches_per_step": MetricSpec("exact"),
        "kernel_compile_variants": MetricSpec("le"),   # buckets may shrink
        "kernel_min_tokens_per_s": MetricSpec("info"),
    },
    "scenarios": {
        # deterministic discrete-event sim: tight but not bit-exact bounds
        # (float accumulation order may shift across numpy/jax versions)
        "imbalance_load_aware_goodput": MetricSpec("ge", 0.02),
        "imbalance_load_aware_p95_ttft_s": MetricSpec("le", 0.05),
        "overload_load_aware_goodput": MetricSpec("ge", 0.02),
        "overload_load_aware_p95_ttft_s": MetricSpec("le", 0.05),
        "overload_rejected": MetricSpec("info"),
        "normal_load_aware_goodput": MetricSpec("ge", 0.0),
        "heterogeneous_load_aware_goodput": MetricSpec("ge", 0.02),
        "heterogeneous_starved_nodes": MetricSpec("exact"),
    },
    "chunked": {
        # long-prompt-mix A/B on the deterministic sim (benchmarks/
        # chunked_prefill.py): chunked+overlap must keep beating lockstep
        # on p95 TTFT, and layer-window streaming must keep hiding a
        # meaningful share of transfer wall time.
        "lockstep_p95_ttft_s": MetricSpec("info"),
        "chunked_p95_ttft_s": MetricSpec("le", 0.05),
        "overlap_p95_ttft_s": MetricSpec("le", 0.05),
        "overlap_p95_speedup": MetricSpec("ge", 0.02),
        "overlap_hidden_frac": MetricSpec("ge", 0.02),
        "overlap_windows_per_transfer": MetricSpec("exact"),
    },
    "prefix": {
        "engine_tokens_saved_total": MetricSpec("ge", 0.0),
        "engine_max_fetch_dispatches": MetricSpec("exact"),
        "sim_tokens_saved_share1": MetricSpec("ge", 0.0),
        "sim_mean_fetch_dispatches_share1": MetricSpec("exact"),
    },
    "breakdown": {
        # analytic single-request split: zero-tolerance bounds
        "flowkv_xfer_frac": MetricSpec("le", 0.0),
        "blockwise_xfer_frac": MetricSpec("info"),
        "flowkv_over_blockwise_xfer": MetricSpec("le", 0.0),
    },
    "tiered": {
        # multiturn-scenario A/B (benchmarks/tiered_kv.py): the host-DRAM
        # tier must keep beating the HBM-only pool on p95 TTFT and prefix
        # hit rate, with structurally zero leaked blocks on either tier.
        "p95_ttft_speedup": MetricSpec("ge", 0.02),
        "tiered_hit_rate": MetricSpec("ge", 0.02),
        "hbm_hit_rate": MetricSpec("info"),
        "tiered_p95_ttft_s": MetricSpec("le", 0.05),
        "leaked_blocks": MetricSpec("exact"),
        "demoted_blocks": MetricSpec("info"),
        "promoted_blocks": MetricSpec("info"),
        "engine_promoted_blocks": MetricSpec("exact"),
        "engine_wall_s": MetricSpec("info"),
    },
    "sharded": {
        # mesh-parallel serving (benchmarks/sharded_transfer.py): shard-pair
        # dispatch counts are structural (tp_src + tp_dst - gcd per plan),
        # token identity vs the single-device engine and byte conservation
        # across cross-degree transfers are exact-by-construction zeros.
        "dispatches_tp2_to_tp1": MetricSpec("exact"),
        "dispatches_tp1_to_tp2": MetricSpec("exact"),
        "dispatches_tp2_to_tp2": MetricSpec("exact"),
        "token_mismatches": MetricSpec("exact"),
        "transfer_byte_mismatches": MetricSpec("exact"),
        "sim_mean_transfer_dispatches": MetricSpec("exact"),
        "sharded_decode_wall_s": MetricSpec("info"),
    },
    "faults": {
        # chaos A/B (benchmarks/fault_tolerance.py): the failure scenario
        # vs its fault-free twin. Goodput under faults must stay a bounded
        # fraction of fault-free; divergence/leak counters are structural
        # zeros — any drift is a recovery-correctness bug, not noise.
        "goodput_ratio": MetricSpec("ge", 0.05),
        "token_divergence": MetricSpec("exact"),
        "leaked_blocks": MetricSpec("exact"),
        "unfinished": MetricSpec("exact"),
        "fault_kills": MetricSpec("exact"),
        "recoveries": MetricSpec("info"),
        "transfer_retries": MetricSpec("info"),
        "degraded_to_recompute": MetricSpec("info"),
    },
}


def bench_path(area: str, root: Optional[Union[str, pathlib.Path]] = None
               ) -> pathlib.Path:
    return pathlib.Path(root or ROOT) / f"BENCH_{area}.json"


def load(area: str, root=None) -> Optional[Dict[str, Any]]:
    path = bench_path(area, root)
    if not path.exists():
        return None
    data = json.loads(path.read_text())
    schema = int(data.get("schema", -1))
    if schema != SCHEMA_VERSION:
        raise ValueError(f"{path}: history schema {schema} != supported "
                         f"{SCHEMA_VERSION}")
    return data


def record(area: str, metrics: Dict[str, float], root=None,
           ts: Optional[str] = None) -> Dict[str, Any]:
    """Append one trajectory entry; first entry becomes the baseline."""
    if area not in AREAS:
        raise ValueError(f"unknown area {area!r}; have {sorted(AREAS)}")
    metrics = {k: float(v) for k, v in metrics.items()}
    data = load(area, root)
    if data is None:
        data = {"schema": SCHEMA_VERSION, "area": area,
                "baseline": dict(metrics), "entries": []}
    data["entries"].append({
        "ts": ts or time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "metrics": metrics,
    })
    path = bench_path(area, root)
    path.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")
    return data


def check_metrics(area: str, baseline: Dict[str, float],
                  metrics: Dict[str, float]) -> List[str]:
    """Compare one metrics dict against a baseline; returns failures."""
    failures = []
    for name, spec in AREAS[area].items():
        if name not in baseline:
            continue      # baseline predates the metric: nothing to gate on
        if name not in metrics:
            failures.append(f"{area}/{name}: missing from latest entry "
                            f"(baseline has {baseline[name]})")
            continue
        base, val = baseline[name], metrics[name]
        if spec.mode == "exact":
            if abs(val - base) > _EPS:
                failures.append(f"{area}/{name}: {val} != baseline {base} "
                                f"(exact metric — edit the baseline if the "
                                f"change is intentional)")
        elif spec.mode == "le":
            limit = base * (1.0 + spec.tol) + _EPS
            if val > limit:
                failures.append(f"{area}/{name}: {val} > baseline {base} "
                                f"(+{spec.tol:.0%} tolerance)")
        elif spec.mode == "ge":
            limit = base * (1.0 - spec.tol) - _EPS
            if val < limit:
                failures.append(f"{area}/{name}: {val} < baseline {base} "
                                f"(-{spec.tol:.0%} tolerance)")
        # "info": trajectory only
    return failures


def check(area: str, root=None) -> List[str]:
    """Gate an area's NEWEST entry against its committed baseline."""
    data = load(area, root)
    if data is None:
        return []        # no history for this area yet: nothing to gate
    if not data["entries"]:
        return [f"{area}: history file has a baseline but no entries"]
    return check_metrics(area, data["baseline"],
                         data["entries"][-1]["metrics"])


def check_all(areas: Optional[List[str]] = None, root=None
              ) -> Dict[str, List[str]]:
    """{area: failures} over the requested (default: all known) areas."""
    return {a: check(a, root) for a in (areas or sorted(AREAS))}
