"""Per-request span tracing for the disaggregated runtime.

A :class:`Span` is one phase of one request's life — ``queue``,
``admission``, ``prefill``, ``transfer``, ``decode`` or ``prefix_fetch`` —
stamped on BOTH timelines the system runs on:

* ``start_cycle`` / ``end_cycle`` — the driving scheduler clock. In the
  real runtime (``PDCluster``) this is the cluster cycle counter; in the
  discrete-event simulator (``ClusterSim``) it is simulated seconds.
* ``start_wall_s`` / ``end_wall_s`` — ``time.monotonic()`` stamps, so real
  runs report per-phase *seconds* without any cycle→s conversion. The
  simulator leaves these ``None`` (its virtual data plane consumes no wall
  time worth attributing).

The recorder is deliberately dumb — one list append per span, no locks, no
I/O on the hot path — so tracing can stay on during benchmarks. Export is
line-oriented JSON (one header record, then request-shape records, then
span records) so traces stream, diff and grep well; :func:`read_trace`
validates the schema version and round-trips exactly
(``tests/test_obs.py``).

Wiring: every producer (``PDCluster``, ``ClusterSim``, ``NodeEngine``,
``GlobalController``) reads an optional ``tracer`` attribute at emission
time, so :func:`attach_tracer` can instrument an already-constructed
cluster or simulator with no constructor plumbing.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Union

TRACE_SCHEMA_VERSION = 5

# Schema history: v1 had the six lifecycle span kinds; v2 (chunked prefill +
# layerwise overlap) added the fine-grained ``prefill_chunk`` and
# ``transfer_layer_window`` kinds; v3 (fault tolerance) added the
# ``failure`` / ``transfer_retry`` / ``recovery`` kinds; v4 (tiered KV)
# added ``tier_demote`` / ``tier_promote``; v5 (sharded serving) added the
# mesh-parallel transfer attrs (``src_tp`` / ``dst_tp`` / ``dispatches`` as
# shard-pair counts) on existing span kinds. Each bump is additive, so
# older traces still read.
SUPPORTED_SCHEMAS = (1, 2, 3, 4, 5)

# The span taxonomy (docs/observability.md). Producers are free to add new
# names — consumers must treat this as open — but these are the request
# lifecycle the replay/calibration tooling understands. ``prefill_chunk``
# and ``transfer_layer_window`` are sub-spans of ``prefill`` / ``transfer``:
# one per interleaved prompt chunk, one per layer-window sub-plan on the
# wire, so captured traces show the overlap instead of one opaque span.
# The fault kinds: ``failure`` marks a request drained off a dead node (or a
# transfer degraded to recompute), ``transfer_retry`` one failed/corrupt
# transfer attempt about to back off, ``recovery`` the failure-to-resumed
# interval (attrs carry replayed token counts).
# The tier kinds: ``tier_demote`` is one fused pool->host plan moving cold
# prefix blocks to DRAM under capacity pressure (trace_id -1: demotion is
# pressure-driven, not owned by any one request); ``tier_promote`` one fused
# host->pool plan bringing a prefix back for the request it serves.
SPAN_NAMES = ("queue", "admission", "prefill", "prefill_chunk", "transfer",
              "transfer_layer_window", "decode", "prefix_fetch",
              "failure", "transfer_retry", "recovery",
              "tier_demote", "tier_promote")


@dataclasses.dataclass
class Span:
    """One phase of one request, on both clocks (None = not applicable)."""

    trace_id: int                        # request_id
    name: str                            # see SPAN_NAMES
    start_cycle: Optional[float] = None
    end_cycle: Optional[float] = None
    start_wall_s: Optional[float] = None
    end_wall_s: Optional[float] = None
    node_id: Optional[int] = None
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def duration_cycles(self) -> Optional[float]:
        if self.start_cycle is None or self.end_cycle is None:
            return None
        return self.end_cycle - self.start_cycle

    def duration_wall_s(self) -> Optional[float]:
        if self.start_wall_s is None or self.end_wall_s is None:
            return None
        return self.end_wall_s - self.start_wall_s

    def to_record(self) -> Dict[str, Any]:
        rec = {"kind": "span", "trace_id": self.trace_id, "name": self.name}
        for key in ("start_cycle", "end_cycle", "start_wall_s", "end_wall_s",
                    "node_id"):
            val = getattr(self, key)
            if val is not None:
                rec[key] = val
        if self.attrs:
            rec["attrs"] = self.attrs
        return rec

    @classmethod
    def from_record(cls, rec: Dict[str, Any]) -> "Span":
        return cls(
            trace_id=int(rec["trace_id"]), name=rec["name"],
            start_cycle=rec.get("start_cycle"), end_cycle=rec.get("end_cycle"),
            start_wall_s=rec.get("start_wall_s"),
            end_wall_s=rec.get("end_wall_s"),
            node_id=rec.get("node_id"), attrs=dict(rec.get("attrs", {})))


class SpanRecorder:
    """Append-only span sink with a monotonic wall clock.

    ``wall()`` is the ONE wall-clock source every producer shares, so spans
    from different layers of the same process are comparable.
    """

    def __init__(self) -> None:
        self.spans: List[Span] = []

    def wall(self) -> float:
        return time.monotonic()

    def record(self, span: Span) -> None:
        self.spans.append(span)

    def emit(self, trace_id: int, name: str, **kw) -> Span:
        """Build-and-record in one call (the hot-path helper)."""
        span = Span(trace_id=trace_id, name=name, **kw)
        self.spans.append(span)
        return span

    # -- queries (post-run analysis; not hot-path) -----------------------------
    def for_trace(self, trace_id: int) -> List[Span]:
        return [s for s in self.spans if s.trace_id == trace_id]

    def by_name(self, name: str) -> List[Span]:
        return [s for s in self.spans if s.name == name]

    def clear(self) -> None:
        self.spans.clear()


@dataclasses.dataclass
class Trace:
    """A captured run: metadata + request shapes + spans.

    ``requests`` records are what :mod:`repro.obs.replay` rebuilds the
    arrival process from; ``spans`` are the measured phases of the run that
    produced the capture.
    """

    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    requests: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    spans: List[Span] = dataclasses.field(default_factory=list)

    @property
    def schema(self) -> int:
        return int(self.meta.get("schema", TRACE_SCHEMA_VERSION))


def request_record(request_id: int, arrival_time: float, prompt_len: int,
                   max_new_tokens: int,
                   prompt_tokens: Optional[Sequence[int]] = None
                   ) -> Dict[str, Any]:
    """The replayable shape of one request.

    ``prompt_tokens`` is optional: without it the replay harness regenerates
    token ids deterministically from the request id (identical shapes and
    arrivals, but cross-request prefix sharing is not preserved — capture
    with tokens when prefix-reuse behavior is what you are replaying).
    """
    rec = {"kind": "request", "request_id": int(request_id),
           "arrival_time": float(arrival_time), "prompt_len": int(prompt_len),
           "max_new_tokens": int(max_new_tokens)}
    if prompt_tokens is not None:
        rec["prompt_tokens"] = [int(t) for t in prompt_tokens]
    return rec


def write_trace(path: Union[str, pathlib.Path], spans: Iterable[Span],
                requests: Iterable[Dict[str, Any]] = (),
                meta: Optional[Dict[str, Any]] = None) -> pathlib.Path:
    """Write a trace as JSONL: header, then requests, then spans."""
    path = pathlib.Path(path)
    header = {"kind": "header", "schema": TRACE_SCHEMA_VERSION,
              **(meta or {})}
    with path.open("w") as f:
        f.write(json.dumps(header, sort_keys=True) + "\n")
        for rec in requests:
            f.write(json.dumps(rec, sort_keys=True) + "\n")
        for span in spans:
            f.write(json.dumps(span.to_record(), sort_keys=True) + "\n")
    return path


def read_trace(path: Union[str, pathlib.Path]) -> Trace:
    """Parse + schema-validate a trace written by :func:`write_trace`."""
    trace = Trace()
    with pathlib.Path(path).open() as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            kind = rec.get("kind")
            if i == 0:
                if kind != "header":
                    raise ValueError(
                        f"{path}: first record must be the trace header, "
                        f"got kind={kind!r}")
                schema = int(rec.get("schema", -1))
                if schema not in SUPPORTED_SCHEMAS:
                    raise ValueError(
                        f"{path}: trace schema {schema} not in supported "
                        f"{SUPPORTED_SCHEMAS}")
                trace.meta = {k: v for k, v in rec.items() if k != "kind"}
            elif kind == "request":
                trace.requests.append(rec)
            elif kind == "span":
                trace.spans.append(Span.from_record(rec))
            else:
                raise ValueError(f"{path}: unknown record kind {kind!r} "
                                 f"on line {i + 1}")
    if not trace.meta:
        raise ValueError(f"{path}: empty trace (no header)")
    return trace


def attach_tracer(target, recorder: Optional[SpanRecorder] = None
                  ) -> SpanRecorder:
    """Instrument a live ``PDCluster`` or ``ClusterSim`` (and its controller
    and engines) with a span recorder; returns the recorder.

    Producers read ``self.tracer`` at emission time, so attaching after
    construction instruments everything from the next event on.
    """
    recorder = recorder or SpanRecorder()
    target.tracer = recorder
    controller = getattr(target, "controller", None)
    if controller is not None:
        controller.tracer = recorder
    for engine in getattr(target, "engines", {}).values():
        engine.tracer = recorder
    return recorder
