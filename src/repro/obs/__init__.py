"""Observability plane: span tracing, trace capture/replay, calibrated cost
models, and the cross-PR perf trajectory.

The paper's headline numbers are *measured* claims; this package is what
lets the reproduction measure honestly:

* ``tracing``   — low-overhead per-request span recorder (queue / admission /
                  prefill / transfer / decode / prefix_fetch) with both
                  scheduler-clock and wall-clock timestamps, JSONL export,
                  and ``attach_tracer`` to wire a recorder into a live
                  ``PDCluster`` or ``ClusterSim``.
* ``calibrate`` — fits ``TransportProfile`` / ``HardwareProfile``
                  coefficients from measured kernel timings and asserts a
                  sim-vs-real predicted-TTFT error bound (CI gate).
* ``replay``    — deterministically re-runs a captured trace's arrival
                  process and request shapes through ``ClusterSim`` under
                  any routing policy.
* ``history``   — schema-versioned ``BENCH_<area>.json`` records appended by
                  every gated benchmark; ``tools/bench_history.py --check``
                  compares against committed baselines so the perf
                  trajectory exists across PRs.

See ``docs/observability.md`` for the span taxonomy, trace format and the
calibration workflow.
"""
from repro.obs.tracing import (Span, SpanRecorder, Trace, attach_tracer,
                               read_trace, write_trace)

__all__ = ["Span", "SpanRecorder", "Trace", "attach_tracer", "read_trace",
           "write_trace"]
