"""Segment primitives for FlowKV's contiguity-aware KV-cache management.

A *segment* is a run of consecutive physical block ids ``[start, start+length)``.
FlowKV (paper §3.3) manages KV-cache memory at segment granularity so that a
request's blocks land in as few contiguous runs as possible, which in turn
lets the transfer engine move the whole run with a single kernel call.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, List, Sequence, Tuple


@dataclasses.dataclass(frozen=True, order=True)
class Segment:
    """A contiguous run of physical block ids ``[start, start + length)``."""

    start: int
    length: int

    def __post_init__(self) -> None:
        if self.length <= 0:
            raise ValueError(f"segment length must be positive, got {self.length}")
        if self.start < 0:
            raise ValueError(f"segment start must be >= 0, got {self.start}")

    @property
    def end(self) -> int:
        """Exclusive end block id."""
        return self.start + self.length

    def blocks(self) -> range:
        return range(self.start, self.end)

    def contains(self, block_id: int) -> bool:
        return self.start <= block_id < self.end

    def overlaps(self, other: "Segment") -> bool:
        return self.start < other.end and other.start < self.end

    def adjacent_to(self, other: "Segment") -> bool:
        return self.end == other.start or other.end == self.start

    def merge(self, other: "Segment") -> "Segment":
        if not (self.adjacent_to(other) or self.overlaps(other)):
            raise ValueError(f"cannot merge non-adjacent segments {self} and {other}")
        start = min(self.start, other.start)
        end = max(self.end, other.end)
        return Segment(start, end - start)

    def split(self, length: int) -> Tuple["Segment", "Segment | None"]:
        """Take the first ``length`` blocks; return (taken, remainder)."""
        if not 0 < length <= self.length:
            raise ValueError(f"cannot take {length} blocks from {self}")
        taken = Segment(self.start, length)
        if length == self.length:
            return taken, None
        return taken, Segment(self.start + length, self.length - length)


def blocks_to_segments(block_ids: Sequence[int]) -> List[Segment]:
    """Run-length encode an *ordered* block-id list into segments.

    Order is preserved: ``[5, 6, 7, 2, 3]`` -> ``[Segment(5,3), Segment(2,2)]``.
    This is exactly the representation FlowKV's bidirectional segment
    alignment operates on (paper Fig. 5).
    """
    segments: List[Segment] = []
    for block_id in block_ids:
        if segments and block_id == segments[-1].end:
            last = segments[-1]
            segments[-1] = Segment(last.start, last.length + 1)
        else:
            segments.append(Segment(int(block_id), 1))
    return segments


def segments_to_blocks(segments: Iterable[Segment]) -> List[int]:
    """Inverse of :func:`blocks_to_segments` (order preserving)."""
    out: List[int] = []
    for seg in segments:
        out.extend(seg.blocks())
    return out


def total_blocks(segments: Iterable[Segment]) -> int:
    return sum(seg.length for seg in segments)


def iter_pairs(segments: Sequence[Segment]) -> Iterator[Tuple[Segment, Segment]]:
    for i in range(len(segments) - 1):
        yield segments[i], segments[i + 1]


def validate_disjoint(segments: Sequence[Segment]) -> None:
    """Raise if any two segments overlap (allocator invariant)."""
    ordered = sorted(segments)
    for a, b in iter_pairs(ordered):
        if a.overlaps(b):
            raise ValueError(f"overlapping segments: {a} and {b}")


def fragmentation(segments: Sequence[Segment]) -> float:
    """1 - 1/num_runs for a request's block list; 0.0 = fully contiguous.

    Used by benchmarks to report how contiguous an allocator keeps requests.
    """
    if not segments:
        return 0.0
    return 1.0 - 1.0 / len(segments)
