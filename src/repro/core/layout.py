"""KV-cache layouts and the FlowKV layout transform (paper Eq. 5).

The baseline (vLLM/PagedAttention) keys the cache by layer::

    VLLM layout:   K,V : (L, 2, B, H)

so the unit of contiguity is *one layer's half (K or V) of one block* — a
request spanning ``n`` blocks needs ``L * 2 * n`` contiguous-range transfers.

FlowKV transposes block to the major axis::

    FLOWKV layout: K,V : (B, L, 2, H)

making *one block* carry K and V for *all* layers contiguously, so the same
request needs only ``n`` transfers before alignment (and ideally 1 after).

``H`` here is the flattened per-(layer, k/v, block) payload:
``block_size * num_kv_heads * head_dim``.

Everything in this module is data-plane: the arrays are real ``jnp`` arrays
(tiny in tests, ShapeDtypeStructs in the dry-run).
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Tuple

import jax
import jax.numpy as jnp


class KVLayout(enum.Enum):
    VLLM = "vllm"        # (L, 2, B, H)  — layer-major baseline
    FLOWKV = "flowkv"    # (B, L, 2, H)  — block-major, paper Eq. 5

    @property
    def block_axis(self) -> int:
        return 2 if self is KVLayout.VLLM else 0


@dataclasses.dataclass(frozen=True)
class KVCacheSpec:
    """Static description of one node's paged KV pool."""

    num_layers: int
    num_blocks: int
    block_size: int          # tokens per block
    num_kv_heads: int
    head_dim: int
    dtype: jnp.dtype = jnp.bfloat16
    layout: KVLayout = KVLayout.FLOWKV

    @property
    def payload(self) -> int:
        """H — elements per (layer, k/v, block)."""
        return self.block_size * self.num_kv_heads * self.head_dim

    @property
    def shape(self) -> Tuple[int, ...]:
        if self.layout is KVLayout.VLLM:
            return (self.num_layers, 2, self.num_blocks, self.payload)
        return (self.num_blocks, self.num_layers, 2, self.payload)

    @property
    def bytes_per_block(self) -> int:
        """Bytes moved when one block (all layers, K+V) is transferred."""
        return self.num_layers * 2 * self.payload * jnp.dtype(self.dtype).itemsize

    @property
    def bytes_per_token(self) -> int:
        return self.bytes_per_block // self.block_size

    @property
    def total_bytes(self) -> int:
        return self.num_blocks * self.bytes_per_block

    def blocks_for_tokens(self, num_tokens: int) -> int:
        return max(1, math.ceil(num_tokens / self.block_size))

    def with_layout(self, layout: KVLayout) -> "KVCacheSpec":
        return dataclasses.replace(self, layout=layout)

    def transfer_calls_per_block(self) -> int:
        """Contiguous-range transfer calls needed to move ONE block.

        This is the paper's core observation: the vLLM layout pays L*2 calls
        per block; FlowKV pays 1.
        """
        return self.num_layers * 2 if self.layout is KVLayout.VLLM else 1

    def page_view_shape(self) -> Tuple[int, int, int, int]:
        """Per-block unflattened page shape (block_size, kv_heads, head_dim) x (L,2)."""
        return (self.num_layers, 2, self.block_size, self.num_kv_heads * self.head_dim)


def alloc_cache(spec: KVCacheSpec) -> jax.Array:
    return jnp.zeros(spec.shape, dtype=spec.dtype)


def vllm_to_flowkv(cache: jax.Array) -> jax.Array:
    """(L, 2, B, H) -> (B, L, 2, H)."""
    return jnp.transpose(cache, (2, 0, 1, 3))


def flowkv_to_vllm(cache: jax.Array) -> jax.Array:
    """(B, L, 2, H) -> (L, 2, B, H)."""
    return jnp.transpose(cache, (1, 2, 0, 3))


def convert(cache: jax.Array, src: KVLayout, dst: KVLayout) -> jax.Array:
    if src is dst:
        return cache
    if src is KVLayout.VLLM and dst is KVLayout.FLOWKV:
        return vllm_to_flowkv(cache)
    return flowkv_to_vllm(cache)


def write_block(cache: jax.Array, spec: KVCacheSpec, block_id, layer: int,
                k_page: jax.Array, v_page: jax.Array) -> jax.Array:
    """Write one (layer, block) K/V page. Pages are (block_size, kv*hd) flats."""
    k_flat = k_page.reshape(-1).astype(spec.dtype)
    v_flat = v_page.reshape(-1).astype(spec.dtype)
    if spec.layout is KVLayout.FLOWKV:
        cache = cache.at[block_id, layer, 0].set(k_flat)
        cache = cache.at[block_id, layer, 1].set(v_flat)
    else:
        cache = cache.at[layer, 0, block_id].set(k_flat)
        cache = cache.at[layer, 1, block_id].set(v_flat)
    return cache


def read_block(cache: jax.Array, spec: KVCacheSpec, block_id, layer: int) -> Tuple[jax.Array, jax.Array]:
    """Read one (layer, block) K/V page back as (block_size, kv_heads, head_dim)."""
    shape = (spec.block_size, spec.num_kv_heads, spec.head_dim)
    if spec.layout is KVLayout.FLOWKV:
        k = cache[block_id, layer, 0]
        v = cache[block_id, layer, 1]
    else:
        k = cache[layer, 0, block_id]
        v = cache[layer, 1, block_id]
    return k.reshape(shape), v.reshape(shape)


def gather_blocks(cache: jax.Array, spec: KVCacheSpec, block_ids) -> jax.Array:
    """Gather whole blocks (all layers, K+V) — the unit FlowKV transfers.

    Returns (n, L, 2, H) regardless of source layout.
    """
    idx = jnp.asarray(block_ids, dtype=jnp.int32)
    if spec.layout is KVLayout.FLOWKV:
        return jnp.take(cache, idx, axis=0)
    return jnp.transpose(jnp.take(cache, idx, axis=2), (2, 0, 1, 3))


def scatter_blocks(cache: jax.Array, spec: KVCacheSpec, block_ids, payload: jax.Array) -> jax.Array:
    """Scatter (n, L, 2, H) payload into the destination pool's blocks."""
    idx = jnp.asarray(block_ids, dtype=jnp.int32)
    if spec.layout is KVLayout.FLOWKV:
        return cache.at[idx].set(payload.astype(cache.dtype))
    return cache.at[:, :, idx, :].set(jnp.transpose(payload, (1, 2, 0, 3)).astype(cache.dtype))
