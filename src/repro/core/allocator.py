"""Block allocators for paged KV caches.

Two allocators, matching the paper's baseline/optimized pair:

* :class:`BlockAllocator` — the vLLM-style baseline: a LIFO free list of
  individual block ids. Under churn this scatters a request's blocks across
  the pool, which is exactly what makes block-wise KV transfer slow.

* :class:`SegmentAllocator` — FlowKV §3.3: free space is tracked as
  *segments* (runs of consecutive blocks) in size-bucketed min-heaps.
  Allocation is best-fit ("chooses the right segments ... to minimize
  waste"), preferring a single segment that covers the whole request;
  deallocation merges adjacent free segments ("merges adjacent free segments
  during deallocation to boost future allocation efficiency").

Both expose the same interface so the block manager / benchmarks can swap
them, and both are pure-Python control-plane objects — the data plane (the
actual KV pages) lives in device memory managed by ``serving/kv_cache.py``.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Sequence

from repro.core.segments import Segment, blocks_to_segments, segments_to_blocks


class OutOfBlocksError(RuntimeError):
    """Raised when an allocation cannot be satisfied."""


class BlockAllocator:
    """Baseline vLLM-style free-list allocator (block granularity, LIFO)."""

    name = "freelist"

    def __init__(self, num_blocks: int):
        if num_blocks <= 0:
            raise ValueError("num_blocks must be positive")
        self.num_blocks = num_blocks
        # LIFO free list: freshly freed (scattered) blocks are reused first,
        # replicating the fragmentation behaviour of block-level allocators.
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._allocated: set[int] = set()

    # -- interface -----------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    def allocate(self, n: int) -> List[int]:
        if n <= 0:
            raise ValueError("allocation size must be positive")
        if n > len(self._free):
            raise OutOfBlocksError(f"requested {n} blocks, only {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        self._allocated.update(out)
        return out

    def extend(self, block_ids: Sequence[int], n: int) -> List[int]:
        """Allocate ``n`` more blocks for an existing request (decode growth)."""
        del block_ids  # baseline ignores existing placement
        return self.allocate(n)

    def free(self, block_ids: Sequence[int]) -> None:
        for b in block_ids:
            if b not in self._allocated:
                raise ValueError(f"double free of block {b}")
            self._allocated.remove(b)
            self._free.append(b)

    def check_invariants(self) -> None:
        assert len(self._free) + len(self._allocated) == self.num_blocks
        assert not (set(self._free) & self._allocated)


class _SegmentHeaps:
    """Size-bucketed min-heaps over free segments.

    Buckets are power-of-two size classes; each bucket is a heap ordered by
    (length, start) so ``pop_best_fit`` returns the smallest segment that
    fits, lowest-addressed first. Stale entries (segments that have since
    been merged or split) are lazily discarded via a generation map.
    """

    def __init__(self) -> None:
        self._buckets: Dict[int, List[Segment]] = {}
        self._live: set[Segment] = set()

    @staticmethod
    def _bucket_of(length: int) -> int:
        return max(0, length.bit_length() - 1)

    def add(self, seg: Segment) -> None:
        self._live.add(seg)
        heapq.heappush(self._buckets.setdefault(self._bucket_of(seg.length), []), seg)

    def discard(self, seg: Segment) -> None:
        # Lazy removal: just mark dead; heaps skip dead entries on pop.
        self._live.discard(seg)

    def pop_best_fit(self, n: int) -> Optional[Segment]:
        """Smallest live segment with length >= n, or None."""
        best: Optional[Segment] = None
        start_bucket = self._bucket_of(n)
        for bucket_id in sorted(self._buckets):
            if bucket_id < start_bucket:
                continue
            heap = self._buckets[bucket_id]
            # Drop dead entries from the top.
            while heap and heap[0] not in self._live:
                heapq.heappop(heap)
            if not heap:
                continue
            cand = heap[0]
            if cand.length >= n and (best is None or (cand.length, cand.start) < (best.length, best.start)):
                best = cand
            if best is not None and bucket_id > self._bucket_of(best.length):
                break  # later buckets only hold larger segments
        if best is not None:
            self._live.discard(best)
            # Leave the heap entry; it is dead now and will be skipped later.
        return best

    def pop_largest(self) -> Optional[Segment]:
        best: Optional[Segment] = None
        for heap in self._buckets.values():
            for seg in heap:
                if seg in self._live and (best is None or seg.length > best.length):
                    best = seg
        if best is not None:
            self._live.discard(best)
        return best

    def live_segments(self) -> List[Segment]:
        return sorted(self._live)

    def __len__(self) -> int:
        return len(self._live)


class SegmentAllocator:
    """FlowKV segment allocator: best-fit over min-heaps, merge on free."""

    name = "flowkv-segment"

    def __init__(self, num_blocks: int):
        if num_blocks <= 0:
            raise ValueError("num_blocks must be positive")
        self.num_blocks = num_blocks
        self._heaps = _SegmentHeaps()
        self._heaps.add(Segment(0, num_blocks))
        # start -> segment and end -> segment maps for O(1) merge on free.
        self._by_start: Dict[int, Segment] = {0: Segment(0, num_blocks)}
        self._by_end: Dict[int, Segment] = {num_blocks: Segment(0, num_blocks)}
        self._num_free = num_blocks
        self._allocated: set[int] = set()

    # -- bookkeeping ---------------------------------------------------------
    def _insert_free(self, seg: Segment) -> None:
        self._heaps.add(seg)
        self._by_start[seg.start] = seg
        self._by_end[seg.end] = seg

    def _remove_free(self, seg: Segment) -> None:
        self._heaps.discard(seg)
        del self._by_start[seg.start]
        del self._by_end[seg.end]

    # -- interface -----------------------------------------------------------
    @property
    def num_free(self) -> int:
        return self._num_free

    def allocate(self, n: int) -> List[int]:
        """Allocate ``n`` blocks in as few contiguous segments as possible.

        Strategy (paper §3.3): try a single best-fit segment first; when the
        pool is too fragmented for that, repeatedly take the largest free
        segments (each one stays a contiguous run for the request).
        """
        if n <= 0:
            raise ValueError("allocation size must be positive")
        if n > self._num_free:
            raise OutOfBlocksError(f"requested {n} blocks, only {self._num_free} free")

        out_segments: List[Segment] = []
        remaining = n
        seg = self._heaps.pop_best_fit(remaining)
        if seg is not None:
            self._remove_or_split(seg, remaining, out_segments)
            remaining = 0
        while remaining > 0:
            seg = self._heaps.pop_largest()
            assert seg is not None, "num_free accounting broken"
            take = min(seg.length, remaining)
            self._remove_or_split(seg, take, out_segments)
            remaining -= take

        self._num_free -= n
        blocks = segments_to_blocks(out_segments)
        self._allocated.update(blocks)
        return blocks

    def _remove_or_split(self, seg: Segment, take: int, out: List[Segment]) -> None:
        # seg was already popped from the heaps; fix the address maps.
        del self._by_start[seg.start]
        del self._by_end[seg.end]
        taken, rest = seg.split(take)
        out.append(taken)
        if rest is not None:
            self._insert_free(rest)

    def extend(self, block_ids: Sequence[int], n: int) -> List[int]:
        """Grow an existing request, preferring blocks adjacent to its tail.

        Decode appends tokens one block at a time; extending in place keeps
        the request's run count low so later transfers stay cheap.
        """
        if n <= 0:
            raise ValueError("extension size must be positive")
        if n > self._num_free:
            raise OutOfBlocksError(f"requested {n} blocks, only {self._num_free} free")
        out: List[int] = []
        if block_ids:
            tail_end = int(block_ids[-1]) + 1
            adj = self._by_start.get(tail_end)
            if adj is not None:
                take = min(adj.length, n)
                self._heaps.discard(adj)
                segs: List[Segment] = []
                self._remove_or_split_from_maps(adj, take, segs)
                out.extend(segments_to_blocks(segs))
                self._num_free -= take
                self._allocated.update(out)
                n -= take
        if n > 0:
            out.extend(self.allocate(n))
        return out

    def _remove_or_split_from_maps(self, seg: Segment, take: int, out: List[Segment]) -> None:
        del self._by_start[seg.start]
        del self._by_end[seg.end]
        taken, rest = seg.split(take)
        out.append(taken)
        if rest is not None:
            self._insert_free(rest)

    def free(self, block_ids: Sequence[int]) -> None:
        """Free blocks, merging with adjacent free segments (paper §3.3)."""
        for b in block_ids:
            if b not in self._allocated:
                raise ValueError(f"double free of block {b}")
        for seg in blocks_to_segments(sorted(set(int(b) for b in block_ids))):
            self._allocated.difference_update(seg.blocks())
            merged = seg
            left = self._by_end.get(seg.start)
            if left is not None:
                self._remove_free(left)
                merged = merged.merge(left)
            right = self._by_start.get(seg.end)
            if right is not None:
                self._remove_free(right)
                merged = merged.merge(right)
            self._insert_free(merged)
            self._num_free += seg.length

    # -- introspection -------------------------------------------------------
    def free_segments(self) -> List[Segment]:
        return self._heaps.live_segments()

    def check_invariants(self) -> None:
        segs = self.free_segments()
        covered = sum(s.length for s in segs)
        assert covered == self._num_free, (covered, self._num_free)
        assert covered + len(self._allocated) == self.num_blocks
        for i in range(len(segs) - 1):
            a, b = segs[i], segs[i + 1]
            assert a.end < b.start, f"unmerged adjacent free segments {a}, {b}"
        for s in segs:
            assert not (set(s.blocks()) & self._allocated)


def make_allocator(kind: str, num_blocks: int):
    if kind in ("freelist", "vllm", "baseline"):
        return BlockAllocator(num_blocks)
    if kind in ("segment", "flowkv", "flowkv-segment"):
        return SegmentAllocator(num_blocks)
    raise ValueError(f"unknown allocator kind: {kind!r}")
