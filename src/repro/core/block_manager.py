"""Control-plane block manager: request -> physical block ids.

One per node, shared between that node's prefill and decode schedulers (the
paper's hybrid scheduler "share[s] a block manager"). The data-plane pool
(the device array holding pages) lives in ``serving/kv_cache.py`` and is
indexed by the ids handed out here.

Blocks are **ref-counted** so a prefix-cache hit can share the matched
prefix's blocks into a new request's table instead of copying them
(``allocate(..., prefix_blocks=...)``). The sharing rules:

* only FULL blocks are ever shared (the prefix index matches at block
  granularity), so a shared block is read-only by construction — writes
  land at token positions past the shared prefix, i.e. in blocks the
  request owns exclusively;
* a block whose refcount reaches zero is NOT returned to the allocator —
  it parks in an **LRU demotion queue** (``_cached``). Its KV pages stay
  valid (nothing reallocates them), so a prefix re-requested one cycle
  after its last holder finished still hits instead of recomputing from
  scratch. Cached blocks are reclaimed lazily under capacity pressure,
  oldest first; ``on_evict`` fires just before a reclaim so the tier plane
  can demote index-backed blocks to host DRAM instead of losing them, and
  ``on_free`` fires with exactly the physically-freed blocks — the prefix
  index hangs its HBM residency invalidation off this hook, so it can
  never advertise pool KV whose pages were recycled.

``check_invariants`` audits the bookkeeping: per-block refcounts must equal
the number of tables holding the block, the cached and refcounted sets must
be disjoint, and free + tabled + cached must tile the pool exactly.
"""
from __future__ import annotations

import collections
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.allocator import OutOfBlocksError, make_allocator
from repro.core.segments import blocks_to_segments, fragmentation


class BlockManager:
    def __init__(self, num_blocks: int, block_size: int, allocator: str = "flowkv"):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.allocator = make_allocator(allocator, num_blocks)
        self._table: Dict[int, List[int]] = {}   # request_id -> block ids (ordered)
        self._refcount: Dict[int, int] = {}      # block id -> holding tables
        # Refcount-zero blocks parked for reuse, oldest-freed first (LRU).
        # Still "allocated" from the allocator's point of view; their pages
        # hold the KV they held when their last table dropped them.
        self._cached: "collections.OrderedDict[int, None]" = collections.OrderedDict()
        # Fired with the block ids that PHYSICALLY freed (pages recycled).
        # serving/cluster.py and sim/cluster_sim.py wire this to
        # ``GlobalPrefixIndex.invalidate_blocks`` so stale HBM residency is
        # impossible by construction.
        self.on_free: Optional[Callable[[List[int]], None]] = None
        # Fired with cached blocks chosen for reclaim, BEFORE they free —
        # their pages are still intact here. The tier plane demotes
        # index-backed blocks to the host tier in this window; on_free then
        # invalidates whatever still advertises these pool blocks.
        self.on_evict: Optional[Callable[[List[int]], None]] = None
        # Trajectory counters for the cache itself.
        self.cached_reused = 0       # cached blocks revived into a table
        self.cached_evicted = 0      # cached blocks reclaimed under pressure

    # -- capacity ---------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return self.allocator.num_free

    @property
    def num_cached(self) -> int:
        return len(self._cached)

    @property
    def free_capacity(self) -> int:
        """Blocks obtainable right now: free pool + reclaimable LRU cache."""
        return self.allocator.num_free + len(self._cached)

    @property
    def utilization(self) -> float:
        """KV_u in the paper's load vector. Cached blocks are reclaimable on
        demand, so they count as free — a node full of cold cached prefixes
        must not look loaded to the router."""
        return 1.0 - self.free_capacity / self.num_blocks

    def blocks_needed(self, num_tokens: int) -> int:
        return -(-num_tokens // self.block_size)

    def can_allocate(self, num_tokens: int, shared_blocks: int = 0,
                     shared_block_ids: Optional[Sequence[int]] = None) -> bool:
        """Room for ``num_tokens``, of which ``shared_blocks`` full blocks
        come from a prefix-cache hit (shared or revived, not drawn from the
        free pool). Pass ``shared_block_ids`` for exact accounting: a shared
        block that is itself parked in the cache is revived, so it neither
        consumes a fresh block nor counts as reclaimable."""
        if shared_block_ids is not None:
            shared = {int(b) for b in shared_block_ids}
            reclaimable = len(self._cached.keys() - shared)
            return (self.blocks_needed(num_tokens) - len(shared)
                    <= self.allocator.num_free + reclaimable)
        # count-only callers: assume the worst (every shared block cached)
        reclaimable = max(0, len(self._cached) - shared_blocks)
        return (self.blocks_needed(num_tokens) - shared_blocks
                <= self.allocator.num_free + reclaimable)

    # -- cache reclaim ------------------------------------------------------------
    def _evict(self, blocks: List[int]) -> None:
        """Physically free cache-evicted blocks (on_evict -> free -> on_free)."""
        if not blocks:
            return
        self.cached_evicted += len(blocks)
        if self.on_evict is not None:
            self.on_evict(list(blocks))
        self.allocator.free(blocks)
        if self.on_free is not None:
            self.on_free(list(blocks))

    def _max_free_segment(self) -> int:
        """Longest contiguous free run (= num_free on the freelist baseline,
        where contiguity is moot)."""
        segs = getattr(self.allocator, "free_segments", None)
        if segs is None:
            return self.allocator.num_free
        return max((s.length for s in segs()), default=0)

    def _ensure_free(self, n: int) -> None:
        """Reclaim LRU-oldest cached blocks until ``n`` are free (best effort).

        Under the segment allocator this also chases CONTIGUITY, not just
        count: a pool left sufficient-but-fragmented by scattered cache holes
        defeats the merged-transfer win (paper §3.3), so reclaim continues —
        freed neighbours coalesce — until one free segment covers the
        request or the cache runs dry. Caching therefore only retains blocks
        the pool has genuine slack for, which is exactly the intended
        "until capacity pressure" policy.
        """
        deficit = n - self.allocator.num_free
        evict: List[int] = []
        while self._cached and deficit > 0:
            b, _ = self._cached.popitem(last=False)
            evict.append(b)
            deficit -= 1
        self._evict(evict)
        if n <= 1:
            return
        while self._cached and self._max_free_segment() < n:
            b, _ = self._cached.popitem(last=False)
            self._evict([b])

    def reclaim_cache(self, n: Optional[int] = None) -> List[int]:
        """Force-reclaim up to ``n`` (default: all) cached blocks, LRU first.

        The node-teardown and test paths; ordinary pressure reclaims lazily
        inside allocate/extend."""
        limit = len(self._cached) if n is None else min(n, len(self._cached))
        evict = [self._cached.popitem(last=False)[0] for _ in range(limit)]
        self._evict(evict)
        return evict

    def drop_cache(self) -> List[int]:
        """Free every cached block WITHOUT the demotion hook (node death:
        the host tier dies with the node, so there is nowhere to demote to).
        ``on_free`` still fires so index residency is invalidated."""
        blocks = list(self._cached)
        self._cached.clear()
        if blocks:
            self.allocator.free(blocks)
            if self.on_free is not None:
                self.on_free(list(blocks))
        return blocks

    def drop_cached(self, blocks: Sequence[int]) -> None:
        """Physically free SPECIFIC cached blocks without the demotion hook
        (``on_free`` still fires). For blocks whose pages hold nothing worth
        saving — e.g. ``take_for_cache`` surplus a promotion never filled."""
        drop = [int(b) for b in blocks]
        for b in drop:
            if b not in self._cached:
                raise ValueError(f"block {b} is not cached")
            del self._cached[b]
        if drop:
            self.allocator.free(drop)
            if self.on_free is not None:
                self.on_free(list(drop))

    def take_for_cache(self, n: int) -> List[int]:
        """Allocate ``n`` fresh blocks straight into the LRU cache.

        Promotion destinations: host-tier KV lands in blocks that belong to
        no request yet; the index re-points at them and a later
        ``allocate(prefix_blocks=...)`` revives them like any cached hit."""
        if n <= 0:
            return []
        if n > self.free_capacity:
            raise OutOfBlocksError(
                f"requested {n} blocks, only {self.free_capacity} obtainable")
        self._ensure_free(n)
        new = self.allocator.allocate(n)
        for b in new:
            self._cached[b] = None
        return new

    # -- request ops --------------------------------------------------------------
    def allocate(self, request_id: int, num_tokens: int,
                 prefix_blocks: Sequence[int] = ()) -> List[int]:
        """Build a request's block table.

        With ``prefix_blocks`` (a prefix-cache hit), those blocks are SHARED
        — live donors get a refcount bump, cached blocks are revived out of
        the LRU queue — and they become the head of the table; only the
        remaining suffix blocks are drawn from the allocator.
        """
        if request_id in self._table:
            raise ValueError(f"request {request_id} already has blocks")
        prefix = [int(b) for b in prefix_blocks]
        revive = []
        for b in prefix:
            if b in self._refcount:
                continue
            if b in self._cached:
                revive.append(b)
            else:
                raise ValueError(f"prefix block {b} is not allocated")
        fresh = self.blocks_needed(num_tokens) - len(prefix)
        if fresh < 0:
            raise ValueError(
                f"{len(prefix)} prefix blocks exceed the {num_tokens}-token table")
        if fresh > self.allocator.num_free + (len(self._cached) - len(revive)):
            raise OutOfBlocksError(
                f"requested {fresh} blocks, only {self.allocator.num_free} free "
                f"(+{len(self._cached) - len(revive)} reclaimable)")
        for b in revive:
            del self._cached[b]
        self.cached_reused += len(revive)
        new: List[int] = []
        if fresh:
            self._ensure_free(fresh)
            new = self.allocator.allocate(fresh)
        blocks = prefix + new
        for b in blocks:
            self._refcount[b] = self._refcount.get(b, 0) + 1
        self._table[request_id] = blocks
        return blocks

    def register(self, request_id: int, num_tokens: int) -> List[int]:
        """Allocate space on a *destination* node ahead of a KV transfer."""
        return self.allocate(request_id, num_tokens)

    def ensure_capacity(self, request_id: int, num_tokens: int) -> List[int]:
        """Grow a request's table to cover ``num_tokens``; returns new blocks.

        Used when a remote prefix fetch landed the prefix blocks ahead of
        admission: the scheduler tops the table up to the full prompt.
        """
        blocks = self._table[request_id]
        extra = self.blocks_needed(num_tokens) - len(blocks)
        if extra <= 0:
            return []
        self._ensure_free(extra)
        new = self.allocator.extend(blocks, extra)
        for b in new:
            self._refcount[b] = self._refcount.get(b, 0) + 1
        blocks.extend(new)
        return new

    def append_token(self, request_id: int, total_tokens: int) -> Optional[int]:
        """Ensure capacity for one more token; returns a new block id if grown."""
        blocks = self._table[request_id]
        needed = self.blocks_needed(total_tokens)
        if needed <= len(blocks):
            return None
        assert needed == len(blocks) + 1, "decode grows one block at a time"
        self._ensure_free(1)
        new = self.allocator.extend(blocks, 1)
        self._refcount[new[0]] = self._refcount.get(new[0], 0) + 1
        blocks.extend(new)
        return new[0]

    def free(self, request_id: int) -> None:
        """Drop a request's table; refcount-zero blocks park in the LRU cache.

        NOT a physical free: the pages stay intact and index entries stay
        valid, so a prefix re-requested after its last holder released it
        re-hits instead of recomputing (it is revived by the next
        ``allocate``). Physical frees happen only at reclaim time.
        """
        blocks = self._table.pop(request_id, None)
        if not blocks:
            return
        for b in blocks:
            n = self._refcount[b] - 1
            if n:
                self._refcount[b] = n
            else:
                del self._refcount[b]
                self._cached[b] = None       # newest at the MRU end

    def release_all(self) -> List[int]:
        """Free every request's blocks AND the cache (node death / teardown).

        Returns the request ids that held blocks. Safe to run before or
        after the controller's failure drain — ``free`` tolerates both
        orders.
        """
        rids = list(self._table)
        for rid in rids:
            self.free(rid)
        self.drop_cache()
        return rids

    def get(self, request_id: int) -> List[int]:
        return list(self._table[request_id])

    def owns(self, request_id: int) -> bool:
        return request_id in self._table

    def block_alive(self, block_id: int) -> bool:
        """True while the block's pages hold valid KV: held by some table OR
        parked in the LRU cache (cached blocks are revivable hits)."""
        return block_id in self._refcount or block_id in self._cached

    def is_cached(self, block_id: int) -> bool:
        return block_id in self._cached

    def cached_blocks(self) -> List[int]:
        """Cache contents, LRU-oldest first (the reclaim order)."""
        return list(self._cached)

    def refcount(self, block_id: int) -> int:
        return self._refcount.get(block_id, 0)

    # -- diagnostics -----------------------------------------------------------------
    def request_fragmentation(self, request_id: int) -> float:
        return fragmentation(blocks_to_segments(self._table[request_id]))

    def mean_fragmentation(self) -> float:
        if not self._table:
            return 0.0
        return sum(self.request_fragmentation(r) for r in self._table) / len(self._table)

    def check_invariants(self) -> None:
        self.allocator.check_invariants()
        counts: collections.Counter = collections.Counter()
        for rid, blocks in self._table.items():
            bs = set(blocks)
            assert len(bs) == len(blocks), f"duplicate blocks for request {rid}"
            counts.update(bs)
        # refcounts mirror table membership exactly: a block held by k tables
        # has refcount k; refcount 1 = exclusive (writable), > 1 = shared
        # prefix (read-only). No table block may be unaccounted and no
        # refcount may outlive its holders.
        assert dict(counts) == self._refcount, (
            f"refcount drift: tables={dict(counts)} refcounts={self._refcount}")
        # disjoint and exhaustive: every pool block is exactly one of
        # free-in-allocator, held by >= 1 table, or parked in the LRU cache.
        overlap = self._cached.keys() & self._refcount.keys()
        assert not overlap, f"blocks both cached and refcounted: {sorted(overlap)}"
        accounted = (self.allocator.num_free + len(set(counts))
                     + len(self._cached))
        assert accounted == self.num_blocks, (
            f"pool not tiled: free={self.allocator.num_free} "
            f"tabled={len(set(counts))} cached={len(self._cached)} "
            f"!= {self.num_blocks}")

    def assert_no_leaks(self, live_request_ids) -> None:
        """Fault-path audit: beyond the structural invariants, every table
        must belong to a request the cluster still considers live — a table
        for a finished/failed/cancelled request is a leaked allocation (the
        kill-mid-transfer bug class: partially-written dst blocks billed as
        valid after their request was requeued elsewhere). Cached blocks are
        NOT leaks: they belong to no request by design."""
        self.check_invariants()
        live = set(live_request_ids)
        leaked = [rid for rid in self._table if rid not in live]
        assert not leaked, (
            f"leaked block tables for dead requests {leaked}: "
            f"{ {rid: self._table[rid] for rid in leaked} }")


__all__ = ["BlockManager", "OutOfBlocksError"]
