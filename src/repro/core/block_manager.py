"""Control-plane block manager: request -> physical block ids.

One per node, shared between that node's prefill and decode schedulers (the
paper's hybrid scheduler "share[s] a block manager"). The data-plane pool
(the device array holding pages) lives in ``serving/kv_cache.py`` and is
indexed by the ids handed out here.

Blocks are **ref-counted** so a prefix-cache hit can share the matched
prefix's blocks into a new request's table instead of copying them
(``allocate(..., prefix_blocks=...)``). The sharing rules:

* only FULL blocks are ever shared (the prefix index matches at block
  granularity), so a shared block is read-only by construction — writes
  land at token positions past the shared prefix, i.e. in blocks the
  request owns exclusively;
* a block returns to the allocator only when its refcount reaches zero,
  and ``on_free`` fires with exactly the physically-freed blocks — the
  prefix index hangs its residency invalidation off this hook, so it can
  never advertise KV whose last holder released it.

``check_invariants`` audits the sharing bookkeeping: per-block refcounts
must equal the number of tables holding the block, and every table block
must be live in the allocator.
"""
from __future__ import annotations

import collections
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.allocator import OutOfBlocksError, make_allocator
from repro.core.segments import blocks_to_segments, fragmentation


class BlockManager:
    def __init__(self, num_blocks: int, block_size: int, allocator: str = "flowkv"):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.allocator = make_allocator(allocator, num_blocks)
        self._table: Dict[int, List[int]] = {}   # request_id -> block ids (ordered)
        self._refcount: Dict[int, int] = {}      # block id -> holding tables
        # Fired with the block ids that PHYSICALLY freed (refcount hit zero).
        # serving/cluster.py and sim/cluster_sim.py wire this to
        # ``PrefixCacheIndex.invalidate_blocks`` so stale residency is
        # impossible by construction.
        self.on_free: Optional[Callable[[List[int]], None]] = None

    # -- capacity ---------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return self.allocator.num_free

    @property
    def utilization(self) -> float:
        """KV_u in the paper's load vector."""
        return 1.0 - self.allocator.num_free / self.num_blocks

    def blocks_needed(self, num_tokens: int) -> int:
        return -(-num_tokens // self.block_size)

    def can_allocate(self, num_tokens: int, shared_blocks: int = 0) -> bool:
        """Room for ``num_tokens``, of which ``shared_blocks`` full blocks
        come from a prefix-cache hit (shared, not drawn from the free pool)."""
        return (self.blocks_needed(num_tokens) - shared_blocks
                <= self.allocator.num_free)

    # -- request ops --------------------------------------------------------------
    def allocate(self, request_id: int, num_tokens: int,
                 prefix_blocks: Sequence[int] = ()) -> List[int]:
        """Build a request's block table.

        With ``prefix_blocks`` (a prefix-cache hit), those blocks are SHARED
        — their refcount is bumped and they become the head of the table —
        and only the remaining suffix blocks are drawn from the allocator.
        """
        if request_id in self._table:
            raise ValueError(f"request {request_id} already has blocks")
        prefix = [int(b) for b in prefix_blocks]
        for b in prefix:
            if b not in self._refcount:
                raise ValueError(f"prefix block {b} is not allocated")
        fresh = self.blocks_needed(num_tokens) - len(prefix)
        if fresh < 0:
            raise ValueError(
                f"{len(prefix)} prefix blocks exceed the {num_tokens}-token table")
        blocks = prefix + (self.allocator.allocate(fresh) if fresh else [])
        for b in blocks:
            self._refcount[b] = self._refcount.get(b, 0) + 1
        self._table[request_id] = blocks
        return blocks

    def register(self, request_id: int, num_tokens: int) -> List[int]:
        """Allocate space on a *destination* node ahead of a KV transfer."""
        return self.allocate(request_id, num_tokens)

    def ensure_capacity(self, request_id: int, num_tokens: int) -> List[int]:
        """Grow a request's table to cover ``num_tokens``; returns new blocks.

        Used when a remote prefix fetch landed the prefix blocks ahead of
        admission: the scheduler tops the table up to the full prompt.
        """
        blocks = self._table[request_id]
        extra = self.blocks_needed(num_tokens) - len(blocks)
        if extra <= 0:
            return []
        new = self.allocator.extend(blocks, extra)
        for b in new:
            self._refcount[b] = self._refcount.get(b, 0) + 1
        blocks.extend(new)
        return new

    def append_token(self, request_id: int, total_tokens: int) -> Optional[int]:
        """Ensure capacity for one more token; returns a new block id if grown."""
        blocks = self._table[request_id]
        needed = self.blocks_needed(total_tokens)
        if needed <= len(blocks):
            return None
        assert needed == len(blocks) + 1, "decode grows one block at a time"
        new = self.allocator.extend(blocks, 1)
        self._refcount[new[0]] = self._refcount.get(new[0], 0) + 1
        blocks.extend(new)
        return new[0]

    def free(self, request_id: int) -> None:
        """Drop a request's table; physically free blocks at refcount zero."""
        blocks = self._table.pop(request_id, None)
        if not blocks:
            return
        dead: List[int] = []
        for b in blocks:
            n = self._refcount[b] - 1
            if n:
                self._refcount[b] = n
            else:
                del self._refcount[b]
                dead.append(b)
        if dead:
            self.allocator.free(dead)
            if self.on_free is not None:
                self.on_free(dead)

    def release_all(self) -> List[int]:
        """Free every request's blocks (node death / pool teardown).

        Returns the request ids that held blocks. Safe to run before or
        after the controller's failure drain — ``free`` tolerates both
        orders.
        """
        rids = list(self._table)
        for rid in rids:
            self.free(rid)
        return rids

    def get(self, request_id: int) -> List[int]:
        return list(self._table[request_id])

    def owns(self, request_id: int) -> bool:
        return request_id in self._table

    def block_alive(self, block_id: int) -> bool:
        """True while some request's table holds this block."""
        return block_id in self._refcount

    def refcount(self, block_id: int) -> int:
        return self._refcount.get(block_id, 0)

    # -- diagnostics -----------------------------------------------------------------
    def request_fragmentation(self, request_id: int) -> float:
        return fragmentation(blocks_to_segments(self._table[request_id]))

    def mean_fragmentation(self) -> float:
        if not self._table:
            return 0.0
        return sum(self.request_fragmentation(r) for r in self._table) / len(self._table)

    def check_invariants(self) -> None:
        self.allocator.check_invariants()
        counts: collections.Counter = collections.Counter()
        for rid, blocks in self._table.items():
            bs = set(blocks)
            assert len(bs) == len(blocks), f"duplicate blocks for request {rid}"
            counts.update(bs)
        # refcounts mirror table membership exactly: a block held by k tables
        # has refcount k; refcount 1 = exclusive (writable), > 1 = shared
        # prefix (read-only). No table block may be unaccounted and no
        # refcount may outlive its holders.
        assert dict(counts) == self._refcount, (
            f"refcount drift: tables={dict(counts)} refcounts={self._refcount}")

    def assert_no_leaks(self, live_request_ids) -> None:
        """Fault-path audit: beyond the structural invariants, every table
        must belong to a request the cluster still considers live — a table
        for a finished/failed/cancelled request is a leaked allocation (the
        kill-mid-transfer bug class: partially-written dst blocks billed as
        valid after their request was requeued elsewhere)."""
        self.check_invariants()
        live = set(live_request_ids)
        leaked = [rid for rid in self._table if rid not in live]
        assert not leaked, (
            f"leaked block tables for dead requests {leaked}: "
            f"{ {rid: self._table[rid] for rid in leaked} }")


__all__ = ["BlockManager", "OutOfBlocksError"]
