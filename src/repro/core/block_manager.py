"""Control-plane block manager: request -> physical block ids.

One per node, shared between that node's prefill and decode schedulers (the
paper's hybrid scheduler "share[s] a block manager"). The data-plane pool
(the device array holding pages) lives in ``serving/kv_cache.py`` and is
indexed by the ids handed out here.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.core.allocator import OutOfBlocksError, make_allocator
from repro.core.segments import blocks_to_segments, fragmentation


class BlockManager:
    def __init__(self, num_blocks: int, block_size: int, allocator: str = "flowkv"):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.allocator = make_allocator(allocator, num_blocks)
        self._table: Dict[int, List[int]] = {}   # request_id -> block ids (ordered)

    # -- capacity ---------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return self.allocator.num_free

    @property
    def utilization(self) -> float:
        """KV_u in the paper's load vector."""
        return 1.0 - self.allocator.num_free / self.num_blocks

    def blocks_needed(self, num_tokens: int) -> int:
        return -(-num_tokens // self.block_size)

    def can_allocate(self, num_tokens: int) -> bool:
        return self.blocks_needed(num_tokens) <= self.allocator.num_free

    # -- request ops --------------------------------------------------------------
    def allocate(self, request_id: int, num_tokens: int) -> List[int]:
        if request_id in self._table:
            raise ValueError(f"request {request_id} already has blocks")
        blocks = self.allocator.allocate(self.blocks_needed(num_tokens))
        self._table[request_id] = blocks
        return blocks

    def register(self, request_id: int, num_tokens: int) -> List[int]:
        """Allocate space on a *destination* node ahead of a KV transfer."""
        return self.allocate(request_id, num_tokens)

    def append_token(self, request_id: int, total_tokens: int) -> Optional[int]:
        """Ensure capacity for one more token; returns a new block id if grown."""
        blocks = self._table[request_id]
        needed = self.blocks_needed(total_tokens)
        if needed <= len(blocks):
            return None
        assert needed == len(blocks) + 1, "decode grows one block at a time"
        new = self.allocator.extend(blocks, 1)
        blocks.extend(new)
        return new[0]

    def free(self, request_id: int) -> None:
        blocks = self._table.pop(request_id, None)
        if blocks:
            self.allocator.free(blocks)

    def release_all(self) -> List[int]:
        """Free every request's blocks (node death / pool teardown).

        Returns the request ids that held blocks. Safe to run before or
        after the controller's failure drain — ``free`` tolerates both
        orders.
        """
        rids = list(self._table)
        for rid in rids:
            self.free(rid)
        return rids

    def get(self, request_id: int) -> List[int]:
        return list(self._table[request_id])

    def owns(self, request_id: int) -> bool:
        return request_id in self._table

    # -- diagnostics -----------------------------------------------------------------
    def request_fragmentation(self, request_id: int) -> float:
        return fragmentation(blocks_to_segments(self._table[request_id]))

    def mean_fragmentation(self) -> float:
        if not self._table:
            return 0.0
        return sum(self.request_fragmentation(r) for r in self._table) / len(self._table)

    def check_invariants(self) -> None:
        self.allocator.check_invariants()
        seen: set[int] = set()
        for rid, blocks in self._table.items():
            bs = set(blocks)
            assert len(bs) == len(blocks), f"duplicate blocks for request {rid}"
            assert not (bs & seen), f"block shared across requests (request {rid})"
            seen |= bs


__all__ = ["BlockManager", "OutOfBlocksError"]
