"""Bidirectional segment alignment (paper §3.3, Fig. 5).

Before a KV transfer, the sender and receiver exchange their block-id lists
for the request. The lists have identical length ``n`` (same tokens, same
block size) but independent physical placement. A *single* transfer call can
cover positions ``[i, i+m)`` iff the corresponding block ids are consecutive
on the sender **and** on the receiver — then both sides see one contiguous
memory range.

``align`` computes the maximal such runs in O(n): position ``j`` extends the
current run iff ``src[j] == src[j-1] + 1 and dst[j] == dst[j-1] + 1``.

The ideal case in the paper (both allocators segment-aware, low churn) yields
one run — O(n) calls become O(1).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

from repro.core.segments import Segment


@dataclasses.dataclass(frozen=True)
class AlignedRun:
    """A transferable run: src/dst segments of equal length."""

    src: Segment
    dst: Segment

    def __post_init__(self) -> None:
        if self.src.length != self.dst.length:
            raise ValueError(f"mismatched run lengths: {self.src} vs {self.dst}")

    @property
    def length(self) -> int:
        return self.src.length


@dataclasses.dataclass(frozen=True)
class AlignmentResult:
    runs: List[AlignedRun]
    num_blocks: int

    @property
    def num_calls(self) -> int:
        return len(self.runs)

    @property
    def merge_ratio(self) -> float:
        """blocks per call; num_blocks == num_calls means nothing merged."""
        return self.num_blocks / max(1, self.num_calls)


def align(src_blocks: Sequence[int], dst_blocks: Sequence[int]) -> AlignmentResult:
    """Bidirectional segment alignment of two equal-length block-id lists."""
    if len(src_blocks) != len(dst_blocks):
        raise ValueError(
            f"src and dst block lists must have equal length, got "
            f"{len(src_blocks)} vs {len(dst_blocks)}"
        )
    n = len(src_blocks)
    runs: List[AlignedRun] = []
    if n == 0:
        return AlignmentResult(runs=runs, num_blocks=0)

    run_start = 0
    for j in range(1, n + 1):
        extends = (
            j < n
            and src_blocks[j] == src_blocks[j - 1] + 1
            and dst_blocks[j] == dst_blocks[j - 1] + 1
        )
        if not extends:
            length = j - run_start
            runs.append(
                AlignedRun(
                    src=Segment(int(src_blocks[run_start]), length),
                    dst=Segment(int(dst_blocks[run_start]), length),
                )
            )
            run_start = j
    return AlignmentResult(runs=runs, num_blocks=n)


def reconstruct(result: AlignmentResult) -> tuple[List[int], List[int]]:
    """Inverse of :func:`align` — used by property tests."""
    src: List[int] = []
    dst: List[int] = []
    for run in result.runs:
        src.extend(run.src.blocks())
        dst.extend(run.dst.blocks())
    return src, dst
