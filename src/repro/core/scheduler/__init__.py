from repro.core.scheduler.global_controller import (AdmissionDecision,
                                                    AdmissionPolicy,
                                                    ControllerEvent,
                                                    GlobalController,
                                                    ModelCost, NodeHandle)
from repro.core.scheduler.hybrid_scheduler import (HybridScheduler,
                                                   ScheduleDecision)
from repro.core.scheduler.load_score import (DECODE_WEIGHTS, PREFILL_WEIGHTS,
                                             ScoreWeights, Thresholds,
                                             classify_regime, cluster_scores,
                                             node_score)
from repro.core.scheduler.metrics import NodeStatus, SlidingWindow, normalize

__all__ = [
    "AdmissionDecision", "AdmissionPolicy", "ControllerEvent",
    "GlobalController", "ModelCost", "NodeHandle",
    "HybridScheduler", "ScheduleDecision", "ScoreWeights", "Thresholds",
    "classify_regime", "cluster_scores", "node_score", "NodeStatus",
    "SlidingWindow", "normalize", "PREFILL_WEIGHTS", "DECODE_WEIGHTS",
]
