"""Per-node hybrid scheduler (paper §3.4).

Each node runs BOTH a prefill scheduler and a decode scheduler, "like vLLM's
scheduler, each one has separate running, waiting, swapped, and pending
queues ... They share a block manager with the hybrid scheduler. The hybrid
scheduler manages the inference process by coordinating the prefill and
decode schedulers. During each scheduling cycle, it can prioritize
sub-schedulers based on the global controller's instructions. By default,
prefill has priority".

This module is pure control plane: ``schedule()`` emits a
:class:`ScheduleDecision` that the real engine (``serving/engine.py``) or the
discrete-event simulator (``sim/cluster_sim.py``) executes. That split lets
the same scheduler logic drive CPU-scale real inference *and* cluster-scale
simulation — and makes Alg. 1 directly unit-testable.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Deque, Dict, List, Optional

from repro.core.block_manager import BlockManager
from repro.core.scheduler.metrics import NodeStatus, SlidingWindow
from repro.serving.request import Request, RequestState


@dataclasses.dataclass
class ScheduleDecision:
    """What the node should run this cycle."""

    kind: str                                  # "prefill" | "decode" | "idle"
    prefill_batch: List[Request] = dataclasses.field(default_factory=list)
    prefill_chunks: Dict[int, int] = dataclasses.field(default_factory=dict)  # rid -> tokens this cycle
    decode_batch: List[Request] = dataclasses.field(default_factory=list)
    preempted: List[Request] = dataclasses.field(default_factory=list)

    @property
    def num_prefill_tokens(self) -> int:
        return sum(self.prefill_chunks.get(r.request_id, r.prompt_len) for r in self.prefill_batch)


class SubScheduler:
    """One role's queue set (prefill or decode)."""

    def __init__(self, role: str):
        self.role = role
        self.waiting: Deque[Request] = collections.deque()
        self.running: List[Request] = []
        self.swapped: Deque[Request] = collections.deque()
        self.sending: Deque[Request] = collections.deque()   # FlowKV's new queue

    def queue_lengths(self) -> Dict[str, int]:
        return {
            "running": len(self.running),
            "waiting": len(self.waiting),
            "swapped": len(self.swapped),
            "sending": len(self.sending),
        }

    def drain_all(self) -> List[Request]:
        out = list(self.waiting) + list(self.running) + list(self.swapped) + list(self.sending)
        self.waiting.clear(); self.running.clear(); self.swapped.clear(); self.sending.clear()
        return out


class HybridScheduler:
    """Coordinates a node's prefill + decode sub-schedulers over one BlockManager."""

    def __init__(self, node_id: int, block_manager: BlockManager,
                 max_batch_tokens: int = 8192, max_running: int = 64,
                 chunked_prefill: bool = True, window: int = 8,
                 prefill_chunk_tokens: Optional[int] = None):
        self.node_id = node_id
        self.bm = block_manager
        self.max_batch_tokens = max_batch_tokens
        self.max_running = max_running
        self.chunked_prefill = chunked_prefill
        # Sarathi-style per-request chunk cap: no single prompt may claim
        # more than this many tokens per cycle, so a long prompt leaves
        # budget for the short prompts queued behind it instead of hogging
        # the whole cycle (head-of-line blocking). None = budget-only
        # chunking (a request may fill the entire cycle budget).
        self.prefill_chunk_tokens = prefill_chunk_tokens
        self.prefill = SubScheduler("prefill")
        self.decode = SubScheduler("decode")
        # Role priority: "prefill" (default), "decode", or "both" when the
        # controller enables hybrid computation during imbalance.
        self.priority: str = "prefill"
        self._priority_cycles_left: int = 0    # role-switch lease (imbalanced regime)
        self._window = SlidingWindow(window)
        self._progress: Dict[int, int] = {}    # rid -> prefill tokens already computed
        # utilization accounting, updated by the engine/simulator after each cycle
        self.last_compute_util = 0.0
        self.last_bandwidth_util = 0.0
        self.last_token_budget_used = 0.0
        # -- spill hooks (decode memory pressure) ---------------------------------
        # The swapped queue is a REAL spill path: before a preempted request's
        # blocks are freed the engine saves its KV (on_spill), and when the
        # request is re-admitted with fresh blocks the engine restores it
        # (on_resume) — generation continues token-identically. on_discard
        # fires when a request leaves the node for good (cancel / failover)
        # so saved spills never leak. Engines that keep request state outside
        # the pool (state-path pytrees, the simulator) leave these as None.
        self.on_spill: Optional[Callable[[Request], None]] = None
        self.on_resume: Optional[Callable[[Request], None]] = None
        self.on_discard: Optional[Callable[[Request], None]] = None
        # -- prefix-cache hook ------------------------------------------------------
        # Called at waiting-queue admission for requests that hold no blocks
        # yet. The runtime re-validates the request's prompt against the live
        # prefix index for THIS node, re-stamps
        # ``req.num_cached_prefix_tokens`` with the reuse actually available,
        # and returns the shareable full-prefix block ids (possibly empty).
        # When the hook is None the stamp is zeroed at admission: a routing
        # estimate must never bill compute the engine cannot actually skip
        # (the phantom-hit bug this replaces).
        self.resolve_prefix: Optional[Callable[[Request], List[int]]] = None

    # -- queue entry points (called by the controller / engine) -----------------
    def enqueue_prefill(self, req: Request) -> None:
        req.state = RequestState.WAITING
        req.prefill_node = self.node_id
        self.prefill.waiting.append(req)

    def enqueue_decode(self, req: Request) -> None:
        """Request arrives with its KV already on this node (post-transfer)."""
        req.state = RequestState.DECODING
        req.decode_node = self.node_id
        self.decode.running.append(req)

    def mark_sending(self, req: Request) -> None:
        req.state = RequestState.SENDING
        self.prefill.sending.append(req)

    def sending_done(self, req: Request, free: bool = True) -> None:
        """Transfer left this node. ``free=False`` keeps the blocks (local
        P->D handoff on a role-flexible node: same pool, nothing moved)."""
        try:
            self.prefill.sending.remove(req)
        except ValueError:
            pass
        if free:
            self.bm.free(req.request_id)   # P-side blocks are released after transfer

    def remove_request(self, req: Request) -> bool:
        """Expunge a request from every queue + free its blocks (cancel path)."""
        removed = False
        for sub in (self.prefill, self.decode):
            for q in (sub.waiting, sub.running, sub.swapped, sub.sending):
                try:
                    q.remove(req)
                    removed = True
                except ValueError:
                    pass
        self._progress.pop(req.request_id, None)
        if self.bm.owns(req.request_id):
            self.bm.free(req.request_id)
            removed = True
        if self.on_discard is not None:
            self.on_discard(req)        # drop any saved spill (no leaks)
        return removed

    # -- controller knobs ----------------------------------------------------------
    def set_priority(self, priority: str, cycles: int = 0) -> None:
        """Role switch (imbalanced regime): lease lasts ``cycles`` cycles (0 = sticky)."""
        assert priority in ("prefill", "decode", "both")
        self.priority = priority
        self._priority_cycles_left = cycles

    def _tick_priority_lease(self) -> None:
        if self._priority_cycles_left > 0:
            self._priority_cycles_left -= 1
            if self._priority_cycles_left == 0:
                self.priority = "prefill"   # paper default

    # -- the scheduling cycle ---------------------------------------------------------
    def schedule(self) -> ScheduleDecision:
        """Emit this cycle's decision.

        Chunked mode is CONTINUOUS BATCHING: both roles schedule every
        cycle (priority only orders who draws resources first), so decode
        requests join/leave the running batch between cycles and prefill
        chunks interleave with decode steps instead of the old lockstep
        where one prefill-heavy cycle starved the decode batch. With
        ``chunked_prefill=False`` (the distserve-style baseline) the first
        role to schedule work wins the whole cycle, as before.
        """
        self._tick_priority_lease()
        order = {
            "prefill": ("prefill", "decode"),
            "decode": ("decode", "prefill"),
            "both": ("prefill", "decode"),
        }[self.priority]
        decision = ScheduleDecision(kind="idle")
        for role in order:
            if role == "prefill":
                self._schedule_prefill(decision)
            else:
                self._schedule_decode(decision)
            if decision.kind != "idle" and self.priority != "both" \
                    and not self.chunked_prefill:
                break
        return decision

    def _chunk_cap(self, budget: int) -> int:
        """Per-request token cap for this admission (budget ∧ chunk knob)."""
        if self.prefill_chunk_tokens is None:
            return budget
        return min(budget, self.prefill_chunk_tokens)

    def _align_chunk(self, done: int, chunk: int, prompt_len: int,
                     first: bool = False) -> int:
        """Round a non-final chunk down to a block boundary.

        ``PagedKVCache.write_prefill(start=...)`` requires block-aligned
        suffix starts, so every intermediate chunk boundary must land on a
        multiple of ``block_size`` (``done`` is aligned by induction: prefix
        hits are capped to full blocks and prior chunks were aligned). The
        final chunk may be ragged. Returns 0 when the aligned chunk is
        empty — the request waits for budget next cycle — EXCEPT for the
        cycle's first prefill admission (``first``), which always gets at
        least one block: a token budget below ``block_size`` must throttle
        progress, never starve it (bounded overshoot < block_size tokens).
        """
        if done + chunk >= prompt_len:
            return chunk
        aligned = chunk - (done + chunk) % self.bm.block_size
        if aligned <= 0 and first:
            aligned = min(self.bm.block_size, prompt_len - done)
        return aligned

    def _schedule_prefill(self, decision: ScheduleDecision) -> None:
        budget = self.max_batch_tokens - decision.num_prefill_tokens
        # continue partially-prefilled (chunked) requests first
        for req in list(self.prefill.running):
            if budget <= 0:
                break
            done = self._progress.get(req.request_id, req.num_cached_prefix_tokens)
            remaining = req.prompt_len - done
            if remaining <= 0:
                continue
            chunk = min(remaining, self._chunk_cap(budget)) \
                if self.chunked_prefill else remaining
            if self.chunked_prefill:
                chunk = self._align_chunk(done, chunk, req.prompt_len,
                                          first=not decision.prefill_chunks)
                if chunk <= 0:
                    continue   # sub-block budget left: wait for next cycle
            self._admit_prefill(req, chunk, decision)
            budget -= chunk
        # resume swapped next (vLLM semantics), then admit waiting
        while self.prefill.swapped and budget > 0:
            req = self.prefill.swapped[0]
            done = self._progress.get(req.request_id, 0)
            need = req.prompt_len - done
            chunk = min(need, self._chunk_cap(budget)) \
                if self.chunked_prefill else need
            if chunk < need and not self.chunked_prefill:
                break
            if self.chunked_prefill:
                chunk = self._align_chunk(done, chunk, req.prompt_len,
                                          first=not decision.prefill_chunks)
                if chunk <= 0:
                    break
            # a spilled prefill holds no blocks — re-allocate before admission
            # (was: admitted without blocks, so a resumed spill would crash)
            if not self.bm.owns(req.request_id):
                if not self.bm.can_allocate(req.prompt_len + 1):
                    break
                req.block_ids = self.bm.allocate(req.request_id, req.prompt_len + 1)
                if self.on_resume is not None:
                    self.on_resume(req)
            self.prefill.swapped.popleft()
            self._admit_prefill(req, chunk, decision)
            budget -= chunk
        while self.prefill.waiting and budget > 0 and len(self.prefill.running) < self.max_running:
            req = self.prefill.waiting[0]
            owned = self.bm.owns(req.request_id)
            prefix_blocks: List[int] = []
            if owned:
                # a remote prefix fetch already landed this request's prefix
                # blocks; top the table up to the full prompt below and keep
                # the fetch-time ``num_cached_prefix_tokens`` stamp
                extra = self.bm.blocks_needed(req.prompt_len + 1) \
                    - len(self.bm.get(req.request_id))
                if extra > self.bm.free_capacity:
                    break   # KV pool full — leave in waiting
            else:
                if self.resolve_prefix is not None:
                    if req.prefix_src_node is not None and \
                            req.prefix_src_node != self.node_id:
                        # pending REMOTE fetch (e.g. destination pool was
                        # momentarily full): the runtime's fetch pass owns
                        # this request — re-stamping it local here would
                        # silently abandon the priced plan. Wait, like any
                        # other blocks-not-ready head-of-line case.
                        break
                    # re-validate the hit against the LIVE index and share
                    # those very blocks; re-stamps num_cached_prefix_tokens
                    prefix_blocks = list(self.resolve_prefix(req))
                else:
                    req.num_cached_prefix_tokens = 0
                if not self.bm.can_allocate(req.prompt_len + 1,
                                            shared_block_ids=prefix_blocks):
                    break   # KV pool full — leave in waiting
            new_tokens = req.prompt_len - req.num_cached_prefix_tokens
            chunk = min(new_tokens, self._chunk_cap(budget)) \
                if self.chunked_prefill else new_tokens
            if chunk < new_tokens and not self.chunked_prefill:
                break
            if self.chunked_prefill:
                chunk = self._align_chunk(req.num_cached_prefix_tokens, chunk,
                                          req.prompt_len,
                                          first=not decision.prefill_chunks)
                if chunk <= 0:
                    break   # sub-block budget: head-of-line waits

            self.prefill.waiting.popleft()
            if owned:
                self.bm.ensure_capacity(req.request_id, req.prompt_len + 1)
                req.block_ids = self.bm.get(req.request_id)
            else:
                # +1: prefill also writes the first generated token's KV;
                # the matched prefix's blocks are SHARED (ref-counted), only
                # the suffix draws fresh blocks
                req.block_ids = self.bm.allocate(req.request_id, req.prompt_len + 1,
                                                 prefix_blocks=prefix_blocks)
            self._admit_prefill(req, chunk, decision)
            budget -= chunk
        self.last_token_budget_used = decision.num_prefill_tokens / max(1, self.max_batch_tokens)

    def _admit_prefill(self, req: Request, chunk: int, decision: ScheduleDecision) -> None:
        req.state = RequestState.PREFILLING
        if req not in self.prefill.running:
            self.prefill.running.append(req)
        decision.prefill_batch.append(req)
        decision.prefill_chunks[req.request_id] = chunk
        decision.kind = "prefill" if decision.kind == "idle" else "mixed"

    def _schedule_decode(self, decision: ScheduleDecision) -> None:
        # resume swapped requests first when KV space frees up (vLLM order)
        while self.decode.swapped:
            req = self.decode.swapped[0]
            if not self.bm.can_allocate(req.total_len + 1):
                break
            self.decode.swapped.popleft()
            req.block_ids = self.bm.allocate(req.request_id, req.total_len + 1)
            if self.on_resume is not None:
                self.on_resume(req)     # restore spilled KV into fresh blocks
            req.state = RequestState.DECODING
            self.decode.running.append(req)
        if not self.decode.running:
            return
        batch: List[Request] = []
        for req in list(self.decode.running)[:self.max_running]:
            # Ensure one more token of KV space; preempt (swap) on pressure.
            try:
                self.bm.append_token(req.request_id, req.total_len + 1)
            except Exception:
                self._preempt(req, decision)
                continue
            batch.append(req)
        if batch:
            decision.decode_batch = batch
            decision.kind = "decode" if decision.kind == "idle" else "mixed"

    def _preempt(self, req: Request, decision: ScheduleDecision) -> None:
        """Swap out the youngest decode request under KV pressure.

        on_spill runs BEFORE the blocks are freed so the engine can save the
        request's KV off-pool; _schedule_decode's resume loop restores it
        after re-allocation (on_resume)."""
        self.decode.running.remove(req)
        if self.on_spill is not None:
            self.on_spill(req)
        self.bm.free(req.request_id)
        req.state = RequestState.SWAPPED
        req.block_ids = []
        self.decode.swapped.append(req)
        decision.preempted.append(req)

    # -- progress queries (engine + admission estimator) --------------------------------
    def prefill_tokens_done(self, req: Request) -> int:
        """Prompt tokens already resident for ``req`` (cached prefix +
        completed chunks) — the suffix offset the engine's next chunk
        executes from."""
        return self._progress.get(req.request_id, req.num_cached_prefix_tokens)

    def prefill_backlog_tokens(self) -> List[int]:
        """Per-request REMAINING prefill tokens queued on this node (running
        continuations first, then swapped, then waiting). The admission
        gate prices these as interleaved chunks, not whole prompts."""
        out: List[int] = []
        for req in self.prefill.running:
            rem = req.prompt_len - self.prefill_tokens_done(req)
            if rem > 0:
                out.append(rem)
        for req in self.prefill.swapped:
            out.append(req.prompt_len - self._progress.get(req.request_id, 0))
        for req in self.prefill.waiting:
            out.append(req.prompt_len - req.num_cached_prefix_tokens)
        return out

    # -- completion callbacks (engine/simulator) ---------------------------------------
    def prefill_progressed(self, req: Request, tokens: int) -> bool:
        """Record chunk completion; True when the whole prompt is prefitted."""
        done = self._progress.get(req.request_id, req.num_cached_prefix_tokens) + tokens
        self._progress[req.request_id] = done
        if done >= req.prompt_len:
            self.prefill.running.remove(req)
            self._progress.pop(req.request_id, None)
            return True
        # not finished: chunked prefill keeps it in running for the next cycle
        return False

    def decode_finished(self, req: Request) -> None:
        self.decode.running.remove(req)
        self.bm.free(req.request_id)
        req.state = RequestState.FINISHED

    # -- status sampling -----------------------------------------------------------------
    def sample_status(self) -> NodeStatus:
        p, d = self.prefill.queue_lengths(), self.decode.queue_lengths()
        status = NodeStatus(
            running_prefill=p["running"], waiting_prefill=p["waiting"],
            swapped_prefill=p["swapped"], sending_prefill=p["sending"],
            running_decode=d["running"], waiting_decode=d["waiting"],
            swapped_decode=d["swapped"], sending_decode=d["sending"],
            token_budget_used=self.last_token_budget_used,
            kv_utilization=self.bm.utilization,
            compute_utilization=self.last_compute_util,
            bandwidth_utilization=self.last_bandwidth_util,
        )
        self._window.push(status)
        return status

    def smoothed_status(self) -> NodeStatus:
        return self._window.smoothed()

    # -- fault path -----------------------------------------------------------------------
    def drain_for_failure(self) -> List[Request]:
        """Node died: return every live request for controller requeue."""
        reqs = self.prefill.drain_all() + self.decode.drain_all()
        for r in reqs:
            if self.bm.owns(r.request_id):
                self.bm.free(r.request_id)
            if self.on_discard is not None:
                self.on_discard(r)      # spilled KV dies with the node
            r.reset_for_retry()
        self._progress.clear()
        return reqs
