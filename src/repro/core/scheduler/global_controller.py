"""Global controller: regime detection, routing, role switching, elastic
scaling, and the fault path (paper §3.4, Alg. 1, App. B).

The controller is deliberately runtime-agnostic: it sees nodes through
:class:`NodeHandle` (role, topology coordinates, hardware, and the node's
:class:`HybridScheduler`), so the same controller drives the real CPU-scale
cluster (``serving/cluster.py``) and the discrete-event simulator
(``sim/cluster_sim.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.costmodel import (TransportProfile,
                                  estimate_overlapped_transfer_s,
                                  predicted_chunked_ttft_s, predicted_ttft_s,
                                  select_route, sharded_transfer_calls,
                                  tier_fetch_latency)
from repro.core.scheduler.hybrid_scheduler import HybridScheduler
from repro.core.scheduler.load_score import (Thresholds, classify_regime,
                                             cluster_scores, node_score)
from repro.core.scheduler.metrics import NodeStatus, normalize
from repro.serving.prefix_cache import TIER_HBM, PrefixCacheIndex
from repro.serving.request import Request, RequestState
from repro.sim.hardware import HardwareProfile


@dataclasses.dataclass
class NodeHandle:
    node_id: int
    role: str                      # "prefill" | "decode"
    host_id: int                   # GPU world: machine; TPU world: pod
    hardware: HardwareProfile
    scheduler: HybridScheduler
    alive: bool = True
    last_heartbeat: float = 0.0
    # False for engines whose data plane cannot reuse a resident prefix
    # (state-path families, windowed attention): routing never stamps a
    # prefix plan onto requests bound for such a node.
    supports_prefix_reuse: bool = True
    # Temporary role override (imbalanced regime role switch).
    switched_until_cycle: int = -1
    # Set when the flip policy reassigned this node away from its original
    # role; the controller flips it back once the cluster re-balances.
    home_role: Optional[str] = None
    # Mesh-parallel degrees: a tp>1 node runs its model sharded over
    # tp_degree devices (its hardware profile describes ONE device, so
    # capability/estimate terms scale by the degree); ep_degree is the
    # expert-parallel degree (MoE configs; 1 otherwise).
    tp_degree: int = 1
    ep_degree: int = 1


@dataclasses.dataclass
class ModelCost:
    """Per-token cost constants the controller uses for its estimates."""

    flops_per_token: float          # prefill FLOPs per prompt token (~2N)
    kv_bytes_per_token: float       # KV cache bytes per token (all layers)
    weight_bytes: float             # bytes read per decode step (weights)


@dataclasses.dataclass
class ControllerEvent:
    cycle: int
    kind: str                       # "role_switch" | "scale_up" | "scale_down" | "failover" | "regime" | "admission"
    detail: str


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Overload admission gate (Mooncake-style early rejection + P/D-Serve
    SLO gating). Disabled unless passed to :class:`GlobalController` — with
    no policy the controller admits everything, exactly as before.

    A request is ADMITTED when some prefill-capable node can still honor it:
    predicted TTFT (queued prefill work + own compute, capability-aware)
    within ``ttft_slo_s``, waiting depth below ``max_queue_depth``, and not
    every node's prefill score beyond ``Thresholds.overload`` (ε_overload).
    Otherwise it is DEFERRED (parked controller-side, re-evaluated every
    cycle — admitted as soon as load drains) unless the overload is deep
    (predicted TTFT beyond ``reject_factor`` x SLO) or the request has waited
    ``max_defer_cycles``, in which case it is REJECTED with a retry-after
    hint so the client backs off instead of piling on.
    """

    ttft_slo_s: float = 30.0        # predicted-TTFT admission budget
    max_queue_depth: int = 128      # per-node waiting+running prefill cap
    max_defer_cycles: int = 8       # deferred longer than this -> rejected
    reject_factor: float = 2.0      # predicted TTFT > factor*slo -> reject now
    retry_after_floor_s: float = 1.0


@dataclasses.dataclass
class AdmissionDecision:
    verdict: str                          # "admitted" | "deferred" | "rejected"
    predicted_ttft_s: float = 0.0
    retry_after_s: Optional[float] = None
    reason: str = ""
    route: Optional[Tuple[int, int]] = None   # (prefill, decode) when admitted

    @property
    def admitted(self) -> bool:
        return self.verdict == "admitted"


class GlobalController:
    def __init__(self, model_cost: ModelCost, block_size: int,
                 thresholds: Optional[Thresholds] = None,
                 target: str = "gpu",
                 heartbeat_timeout: float = 10.0,
                 role_switch_cycles: int = 4,
                 role_flip: bool = False,
                 node_factory: Optional[Callable[[str], NodeHandle]] = None,
                 admission: Optional[AdmissionPolicy] = None,
                 actions_enabled: bool = True,
                 layer_window: int = 0,
                 num_layers: int = 1):
        self.model_cost = model_cost
        # Layerwise transfer/compute overlap: when the runtime streams KV in
        # per-layer-window sub-plans, routing must price the EXPOSED (post-
        # prefill) latency, not the full wire time — otherwise load-aware
        # placement can't see the gain the data plane realizes. layer_window
        # <= 0 keeps the classic single-call estimate.
        self.layer_window = layer_window
        self.num_layers = max(1, num_layers)
        self.thresholds = thresholds or Thresholds()
        self.target = target
        self.heartbeat_timeout = heartbeat_timeout
        self.role_switch_cycles = role_switch_cycles
        # role_flip=True upgrades the imbalanced-regime response from a
        # bounded priority lease to a FULL role reassignment (set_role),
        # reverted automatically once the cluster re-balances.
        self.role_flip = role_flip
        self.node_factory = node_factory   # elastic scale-up hook
        # Overload admission gate; None admits everything (legacy behavior).
        self.admission = admission
        # actions_enabled=False makes the controller PASSIVE: it still
        # samples, scores, classifies and detects failures, but takes no
        # regime actions (role switch / flip / scale / admission). This is
        # how the scenario suite runs its round-robin / static-PD baselines
        # through the same code without load-aware behavior leaking in.
        self.actions_enabled = actions_enabled
        # Optional repro.obs.tracing.SpanRecorder: when set AND an admission
        # policy is armed, every gate verdict becomes an "admission" span.
        self.tracer = None
        self.nodes: Dict[int, NodeHandle] = {}
        self.prefix_index = PrefixCacheIndex(block_size)
        self.cycle = 0
        self.regime = "normal"
        self._extreme_streak = 0
        self._low_streak = 0
        self._normal_streak = 0   # flip-back hysteresis (see _flip_back)
        self.events: List[ControllerEvent] = []
        self.retry_queue: List[Request] = []
        # admission gate state: parked (deferred) requests and the rejected
        # outbox the runtime drains for bookkeeping (PDCluster / ClusterSim).
        self.deferred: List[Request] = []
        self.rejected_outbox: List[Request] = []
        # hook for event-driven runtimes: called with each request admitted
        # OUTSIDE submit (deferred admissions, failover reroutes) so the
        # simulator can poke the target node's scheduling loop.
        self.on_admit: Optional[Callable[[Request], None]] = None

    # -- membership ---------------------------------------------------------------
    def register_node(self, node: NodeHandle) -> None:
        self.nodes[node.node_id] = node

    def prefill_nodes(self) -> List[NodeHandle]:
        return [n for n in self.nodes.values() if n.alive and n.role == "prefill"]

    def decode_nodes(self) -> List[NodeHandle]:
        return [n for n in self.nodes.values() if n.alive and n.role == "decode"]

    # -- heterogeneous capability profiles -----------------------------------------
    def _capabilities(self) -> Dict[int, Tuple[float, float, float]]:
        """Per-node (compute, bandwidth, capacity) relative to the fleet max.

        Derived from each :class:`NodeHandle`'s hardware profile, so a mixed
        L20/H20/A100 fleet scores on a common scale: the strongest card in
        each dimension is 1.0 and weaker cards saturate proportionally
        earlier (see ``load_score.node_score``). Homogeneous fleets collapse
        to all-ones, i.e. the paper's original un-normalized formula.
        """
        alive = [n for n in self.nodes.values() if n.alive]
        if not alive:
            return {}
        # a node's hardware profile describes ONE device; a tp>1 node
        # aggregates tp devices' FLOPs, bandwidth and HBM, so its capability
        # terms scale by the degree (e.g. a TP=4 prefill node absorbs 4x the
        # backlog of a TP=1 node of the same card before saturating)
        max_f = max(n.hardware.peak_flops * n.tp_degree for n in alive)
        max_b = max(n.hardware.hbm_bandwidth * n.tp_degree for n in alive)
        max_m = max(n.hardware.hbm_bytes * n.tp_degree for n in alive)
        return {
            n.node_id: (n.hardware.peak_flops * n.tp_degree / max_f,
                        n.hardware.hbm_bandwidth * n.tp_degree / max_b,
                        n.hardware.hbm_bytes * n.tp_degree / max_m)
            for n in alive
        }

    def _scored_status(self, node: NodeHandle,
                       caps: Optional[Dict[int, Tuple[float, float, float]]] = None
                       ) -> NodeStatus:
        """A node's smoothed status with its capability profile stamped on."""
        caps = caps if caps is not None else self._capabilities()
        status = node.scheduler.smoothed_status()
        c = caps.get(node.node_id)
        return status.with_capability(*c) if c else status

    # -- node lifecycle -------------------------------------------------------------
    def set_role(self, node_id: int, role: str) -> bool:
        """Reassign a node P<->D mid-run.

        Routing sees the new role immediately; the node's scheduler gets a
        sticky priority matching it. In-flight work of the OLD role keeps
        running from the same block pool (NodeEngine is role-flexible), so
        no drain is needed. Returns True if the role actually changed.
        """
        if role not in ("prefill", "decode"):
            raise ValueError(f"role must be 'prefill' or 'decode', got {role!r}")
        node = self.nodes[node_id]
        if node.role == role:
            return False
        old = node.role
        node.role = role
        node.switched_until_cycle = -1
        node.scheduler.set_priority(role, cycles=0)   # sticky until next set_role
        self._log("set_role", f"node {node_id}: {old} -> {role}")
        return True

    # -- heartbeat / fault tolerance ---------------------------------------------------
    def heartbeat(self, node_id: int, now: float) -> None:
        if node_id in self.nodes:
            self.nodes[node_id].last_heartbeat = now

    def detect_failures(self, now: float) -> List[int]:
        """Mark STALE nodes dead, drain their requests into the retry queue.

        Liveness is pure staleness against ``heartbeat_timeout`` — there is
        no sentinel stamp; a killed node simply stops heartbeating and falls
        over this threshold like a genuinely crashed one would. Each drained
        request is stamped ``recovery_start`` (its failover clock starts
        here) and gets a ``failure`` span when a tracer is attached.
        """
        failed = []
        for node in self.nodes.values():
            if node.alive and now - node.last_heartbeat > self.heartbeat_timeout:
                node.alive = False
                failed.append(node.node_id)
                drained = node.scheduler.drain_for_failure()
                for req in drained:
                    self._stamp_failure(req, now, node.node_id,
                                        "heartbeat_timeout")
                self.retry_queue.extend(drained)
                self.prefix_index.evict_node(node.node_id)
                self._log("failover",
                          f"node {node.node_id} dead; requeued {len(drained)} requests")
        return failed

    def _stamp_failure(self, req: Request, now: float, node_id: int,
                       reason: str) -> None:
        """Start a request's recovery clock + emit its ``failure`` span."""
        if req.recovery_start is None:
            req.recovery_start = now
            if self.tracer is not None:
                req.recovery_start_wall = self.tracer.wall()
        if self.tracer is not None:
            wall = self.tracer.wall()
            self.tracer.emit(
                req.request_id, "failure",
                start_cycle=float(now), end_cycle=float(now),
                start_wall_s=wall, end_wall_s=wall, node_id=node_id,
                attrs={"reason": reason, "retries": req.retries,
                       "tokens_kept": len(req.output_tokens)})

    def reroute_retries(self) -> int:
        """Re-dispatch requests drained from failed nodes.

        Only FAILED requests are still owed a reroute (a client cancel in
        the retry queue flips the state and is dropped here). An unroutable
        request stays queued for a later cycle instead of being silently
        discarded — with zero alive nodes the queue simply waits.
        """
        n = 0
        pending = list(self.retry_queue)
        self.retry_queue = []
        while pending:
            req = pending.pop()
            if req.state is not RequestState.FAILED:
                continue
            if self.route_request(req) is None:
                self.retry_queue.append(req)
                self.retry_queue.extend(r for r in reversed(pending)
                                        if r.state is RequestState.FAILED)
                break
            n += 1
            if self.on_admit is not None:
                self.on_admit(req)
        return n

    # -- overload admission gate ---------------------------------------------------------
    def submit_request(self, req: Request) -> AdmissionDecision:
        """Front door: admission gate, then routing.

        With no :class:`AdmissionPolicy` this is exactly ``route_request``.
        With one, the request is admitted / deferred / early-rejected based
        on predicted TTFT, queue depth and ε_overload — overload never piles
        more work onto a cluster that cannot meet the SLO anyway.
        """
        decision = self._admission_check(req)
        self._trace_admission(req, decision)
        if decision.verdict == "admitted":
            decision.route = self.route_request(req)
        elif decision.verdict == "deferred":
            self.deferred.append(req)
            req.retry_after = decision.retry_after_s
            self._log("admission",
                      f"deferred request {req.request_id}: {decision.reason}")
        else:
            self._reject(req, decision)
        return decision

    def _admission_check(self, req: Request) -> AdmissionDecision:
        if self.admission is None or not self.actions_enabled:
            return AdmissionDecision("admitted")
        pol = self.admission
        pnodes = self.prefill_nodes() or \
            [n for n in self.nodes.values() if n.alive]
        if not pnodes:
            # no alive nodes: let route_request surface the hard failure
            return AdmissionDecision("admitted")
        best_ttft = min(self._ttft_estimate(n, req) for n in pnodes)
        depth_ok = any(
            len(n.scheduler.prefill.waiting) + len(n.scheduler.prefill.running)
            < pol.max_queue_depth for n in pnodes)
        # ε_overload compares on the SAME scale step() classifies on: queue
        # counts normalized across the fleet to [0, 1] (raw counts would
        # blow past the threshold at a handful of queued requests), then
        # capability-stamped.
        caps = self._capabilities()
        norm = normalize([n.scheduler.smoothed_status() for n in pnodes])
        min_score = min(
            node_score(s.with_capability(*caps.get(n.node_id, (1.0,) * 3)),
                       "prefill")
            for n, s in zip(pnodes, norm))
        overloaded = min_score > self.thresholds.overload
        if best_ttft <= pol.ttft_slo_s and depth_ok and not overloaded:
            return AdmissionDecision("admitted", predicted_ttft_s=best_ttft)
        if best_ttft > pol.ttft_slo_s:
            reason = f"predicted_ttft {best_ttft:.2f}s > slo {pol.ttft_slo_s:.2f}s"
        elif not depth_ok:
            reason = f"every node at queue depth >= {pol.max_queue_depth}"
        else:
            reason = (f"every node's C^p {min_score:.2f} > "
                      f"eps_overload {self.thresholds.overload:.2f}")
        retry = max(pol.retry_after_floor_s, best_ttft - pol.ttft_slo_s)
        deep = best_ttft > pol.reject_factor * pol.ttft_slo_s
        if deep or req.admission_defers >= pol.max_defer_cycles:
            return AdmissionDecision("rejected", best_ttft, retry, reason)
        return AdmissionDecision("deferred", best_ttft, retry, reason)

    def _trace_admission(self, req: Request,
                         decision: AdmissionDecision) -> None:
        """One instantaneous "admission" span per gate verdict (the QUEUE
        span covers the time a deferral costs; this records the decision)."""
        if self.tracer is None or self.admission is None \
                or not self.actions_enabled:
            return
        wall = self.tracer.wall()
        self.tracer.emit(
            req.request_id, "admission",
            start_cycle=float(self.cycle), end_cycle=float(self.cycle),
            start_wall_s=wall, end_wall_s=wall,
            attrs={"verdict": decision.verdict,
                   "predicted_ttft_s": decision.predicted_ttft_s,
                   "reason": decision.reason,
                   "defers": req.admission_defers})

    def _reject(self, req: Request, decision: AdmissionDecision) -> None:
        req.state = RequestState.REJECTED
        req.retry_after = decision.retry_after_s
        req.reject_reason = decision.reason
        self.rejected_outbox.append(req)
        self._log("admission",
                  f"rejected request {req.request_id}: {decision.reason}")

    def take_rejected(self) -> List[Request]:
        """Drain the rejected outbox (runtime bookkeeping hook)."""
        out, self.rejected_outbox = self.rejected_outbox, []
        return out

    def _drain_deferred(self) -> None:
        """Re-evaluate parked requests; admit as load drains, reject stale."""
        if not self.deferred:
            return
        still: List[Request] = []
        for req in self.deferred:
            req.admission_defers += 1
            decision = self._admission_check(req)
            self._trace_admission(req, decision)
            if decision.verdict == "admitted" and self.route_request(req) is not None:
                req.retry_after = None
                self._log("admission",
                          f"admitted deferred request {req.request_id} "
                          f"after {req.admission_defers} cycles")
                if self.on_admit is not None:
                    self.on_admit(req)
            elif decision.verdict == "rejected":
                self._reject(req, decision)
            else:
                still.append(req)
        self.deferred = still

    # -- normal-regime routing (Alg. 1 lines 18-23) --------------------------------------
    def _chain_for(self, req: Request) -> List[bytes]:
        """The request's prompt digest chain, hashed ONCE per request.

        Cached on the request (the prompt is immutable, so the chain
        survives retries): admission resolvers and fetch-validation retries
        probe every cycle, and re-hashing a long prompt each time would be
        pure control-plane overhead."""
        chain = req.prefix_chain_cache
        if chain is None:
            chain = self.prefix_index.chain(req.prompt_tokens)
            req.prefix_chain_cache = chain
        return chain

    def shareable_prefix(self, node_id: int, req: Request,
                         hashes=None) -> Tuple[int, List[int], List[str]]:
        """A node's SHAREABLE prefix for ``req``: full blocks only, capped so
        at least one suffix token is always computed (the last prompt token's
        forward emits the first output token). Returns ``(hit_tokens,
        block_ids, tiers)`` — ``tiers[i]`` names the tier backing
        ``block_ids[i]`` (``"hbm"`` pool blocks are directly shareable,
        ``"dram"`` host blocks must be promoted first)."""
        if hashes is None:
            hashes = self._chain_for(req)
        m = self.prefix_index.lookup(node_id, req.prompt_tokens, hashes)
        bs = self.prefix_index.block_size
        nb = min(len(m.block_ids), max(0, req.prompt_len - 1) // bs)
        return nb * bs, m.block_ids[:nb], m.tiers[:nb]

    def resolve_local_prefix(self, node_id: int, req: Request,
                             block_alive: Callable[[int], bool]) -> List[int]:
        """Admission-time prefix resolution (the ``resolve_prefix`` hook
        body, shared by ``PDCluster`` and ``ClusterSim`` so engine and sim
        semantics cannot drift): re-stamp the request with the reuse THIS
        node can actually deliver and return the shareable block ids.
        ``block_alive`` is the node's own liveness check (belt and braces —
        index drift past the on_free invalidation would be a bug).

        Only the leading HBM-backed, live run is shareable: a ``dram``
        entry mid-chain means the runtime's promote pass has not (or could
        not) lift it back into the pool, so the match truncates there —
        reuse degrades, it never dereferences a host block as a pool block.
        """
        hit, blocks, tiers = self.shareable_prefix(node_id, req)
        nb = 0
        for b, t in zip(blocks, tiers):
            if t != TIER_HBM or not block_alive(b):
                break
            nb += 1
        blocks = blocks[:nb]
        hit = min(hit, nb * self.prefix_index.block_size)
        req.num_cached_prefix_tokens = hit
        req.prefix_src_node = node_id if hit else None
        req.prefix_block_ids = list(blocks)
        return blocks

    def route_request(self, req: Request) -> Optional[Tuple[int, int]]:
        """Pick (prefill_node, decode_node); enqueue prefill; return ids.

        Prefix-aware (paper §3.2 "identifies global cache prefix matches"):
        for every prefill candidate the controller prices three plans —
        reuse the node's LOCAL resident prefix, FETCH a longer prefix from
        the best remote holder (one fused descriptor-table transfer, priced
        by ``core.costmodel``), or RECOMPUTE from scratch (the local plan
        with a zero hit) — and routes to the globally cheapest predicted
        TTFT. The winning plan is stamped on the request
        (``num_cached_prefix_tokens`` / ``prefix_src_node`` /
        ``prefix_block_ids``); the runtime executes the fetch and the node's
        scheduler re-validates local hits at admission time.
        """
        pnodes = self.prefill_nodes()
        dnodes = self.decode_nodes()
        if not pnodes or not dnodes:
            # Degenerate cluster (all one role): hybrid nodes take both stages.
            pnodes = pnodes or list(self.nodes.values())
            dnodes = dnodes or pnodes
            pnodes = [n for n in pnodes if n.alive]
            dnodes = [n for n in dnodes if n.alive]
            if not pnodes:
                return None
        # best remote prefix holder anywhere in the cluster (decode nodes
        # included: post-transfer re-homing parks prefixes there); the
        # prompt is hashed ONCE and the chain reused for every probe — and
        # not at all when nothing is resident or no node could reuse it
        probe = self.prefix_index.has_entries and \
            any(n.supports_prefix_reuse for n in pnodes)
        hashes = self._chain_for(req) if probe else []
        remote_best: Tuple[int, List[int], List[str], Optional[int]] = (0, [], [], None)
        if probe:
            for nid, _ in self.prefix_index.best_nodes(req.prompt_tokens, hashes):
                if nid in self.nodes and self.nodes[nid].alive:
                    hit, blocks, tiers = self.shareable_prefix(nid, req, hashes)
                    if hit > remote_best[0]:
                        remote_best = (hit, blocks, tiers, nid)
        best = None   # (ttft, node, hit, src_node, blocks)
        bs = self.prefix_index.block_size
        for n in pnodes:
            local_hit, local_blocks, local_tiers = (
                self.shareable_prefix(n.node_id, req, hashes)
                if probe and n.supports_prefix_reuse else (0, [], []))
            # DRAM-backed local blocks must be promoted before reuse: price
            # the host->HBM leg so a DRAM-local plan ranks between
            # HBM-remote and recompute (the tier lattice).
            dram_local = sum(1 for t in local_tiers if t != TIER_HBM) * bs
            t = self._ttft_estimate(n, req, hit=local_hit) + \
                tier_fetch_latency(select_route(True, self.target),
                                   0, int(self.model_cost.kv_bytes_per_token
                                          * dram_local), remote=False)
            cand = (t, n, local_hit, n.node_id if local_hit else None, local_blocks)
            if best is None or cand[0] < best[0]:
                best = cand
            r_hit, r_blocks, r_tiers, r_nid = remote_best
            if (n.supports_prefix_reuse and r_nid is not None
                    and r_nid != n.node_id and r_hit > local_hit):
                dram_remote = sum(1 for x in r_tiers if x != TIER_HBM) * bs
                t = self._ttft_estimate(n, req, hit=r_hit) + \
                    self._prefix_fetch_estimate(self.nodes[r_nid], n, r_hit,
                                                dram_tokens=dram_remote)
                if t < best[0]:
                    best = (t, n, r_hit, r_nid, r_blocks)
        _, p_best, hit, src, blocks = best
        req.num_cached_prefix_tokens = hit
        req.prefix_src_node = src
        req.prefix_block_ids = list(blocks)
        d_best = min(dnodes, key=lambda n: self._transfer_estimate(p_best, n, req))
        req.decode_node = d_best.node_id
        p_best.scheduler.enqueue_prefill(req)
        return p_best.node_id, d_best.node_id

    def validate_prefix_plan(self, req: Request) -> bool:
        """Re-check a stamped REMOTE prefix plan against the live index,
        immediately before the runtime fetches.

        One source of truth for staleness (shared by ``PDCluster`` and
        ``ClusterSim``, so sim pricing can never drift from engine
        behavior): the source must be alive and still hold at least the
        stamped hit with the very same leading blocks. Any mismatch clears
        the stamp — the plan degrades to recompute, never to garbage KV —
        and returns False.
        """
        src = self.nodes.get(req.prefix_src_node)
        hit = req.num_cached_prefix_tokens
        ok = src is not None and src.alive and hit > 0
        if ok:
            live, blocks, tiers = self.shareable_prefix(src.node_id, req)
            k = len(req.prefix_block_ids)
            # DRAM entries in the stamped range mean promotion has not run
            # (or failed): the pool->pool fetch cannot address host blocks,
            # so the plan is stale until re-stamped post-promotion.
            ok = (live >= hit
                  and blocks[:k] == list(req.prefix_block_ids)
                  and all(t == TIER_HBM for t in tiers[:k]))
        if not ok:
            req.clear_prefix_plan()
        return ok

    def refresh_prefix_plan(self, req: Request) -> bool:
        """Re-stamp a REMOTE prefix plan from the live index, after the
        source's promote pass may have re-pointed chain entries at fresh
        pool blocks (demote->promote changes physical ids, so the routed
        stamp goes stale even though the KV is present and correct).

        Keeps the plan honest rather than bigger: the refreshed hit is
        capped at the routed hit (pricing already happened), and only the
        leading HBM-backed run is kept. Clears the plan (returns False)
        when nothing shareable remains.
        """
        src = self.nodes.get(req.prefix_src_node)
        if src is None or not src.alive or req.num_cached_prefix_tokens <= 0:
            req.clear_prefix_plan()
            return False
        live, blocks, tiers = self.shareable_prefix(src.node_id, req)
        bs = self.prefix_index.block_size
        nb = 0
        cap = req.num_cached_prefix_tokens // bs
        for b, t in zip(blocks[:cap], tiers[:cap]):
            if t != TIER_HBM:
                break
            nb += 1
        if nb == 0:
            req.clear_prefix_plan()
            return False
        req.num_cached_prefix_tokens = nb * bs
        req.prefix_block_ids = list(blocks[:nb])
        return True

    def rehome_prefix(self, req: Request, node_id: int,
                      blocks: Sequence[int]) -> None:
        """Advertise a prompt's full-block prefix where its KV now lives
        (post-transfer decode node, local handoff, or a fetched copy)."""
        node = self.nodes.get(node_id)
        if node is None or not node.supports_prefix_reuse:
            return
        full_nb = req.prompt_len // self.prefix_index.block_size
        if full_nb and len(blocks) >= full_nb:
            self.record_prefix(node_id, req.prompt_tokens,
                               list(blocks)[:full_nb])

    def _prefix_fetch_estimate(self, src: NodeHandle, dst: NodeHandle,
                               hit_tokens: int, dram_tokens: int = 0) -> float:
        """Latency of pulling a resident prefix src -> dst: ONE fused
        descriptor-table dispatch over the wire, plus (when part of the
        prefix sits in the source's host tier) ONE promote dispatch on the
        HOST_DRAM leg first — the tier-aware fetch price."""
        profile = select_route(src.host_id == dst.host_id, self.target)
        bpt = self.model_cost.kv_bytes_per_token
        return tier_fetch_latency(
            profile,
            hbm_bytes=int(bpt * (hit_tokens - dram_tokens)),
            dram_bytes=int(bpt * dram_tokens), remote=True)

    def _ttft_estimate(self, node: NodeHandle, req: Request,
                       hit: Optional[int] = None) -> float:
        """Queued prefill work + this request's compute, on this node.

        Shared between routing (min-TTFT node pick) and the admission gate
        (predicted TTFT vs SLO) — both price the same queueing model from
        ``core.costmodel.predicted_ttft_s`` over the node's own hardware, so
        a weak card reports longer predicted TTFT for the same backlog.
        ``hit`` overrides the prefix-reuse length (routing evaluates several
        reuse plans per node); default = the node's own resident prefix.

        On a chunked-prefill node the whole-prompt occupancy model is a
        head-of-line fiction — queued prompts interleave in chunks, so a
        short request behind a long one must NOT be charged the long
        prompt's full prefill. ``predicted_chunked_ttft_s`` bounds each
        queued request's interference at the chunk work that can actually
        run before this request's first token (and prices REMAINING tokens,
        not re-counting prefill work already done).
        """
        if hit is None:
            hit, _, _ = self.shareable_prefix(node.node_id, req)
        sched = node.scheduler
        hw = node.hardware
        fpt = self.model_cost.flops_per_token
        # a tp>1 node prefills over tp devices' aggregate FLOPs
        eff = hw.peak_flops * hw.mfu_prefill * node.tp_degree
        new_tokens = req.prompt_len - hit
        if getattr(sched, "chunked_prefill", False):
            chunk = sched.prefill_chunk_tokens or sched.max_batch_tokens
            return predicted_chunked_ttft_s(
                sched.prefill_backlog_tokens(), new_tokens, chunk,
                fpt, eff, hw.step_overhead_s)
        backlog_tokens = sum(r.prompt_len for r in sched.prefill.waiting)
        backlog_tokens += sum(r.prompt_len for r in sched.prefill.running)
        return predicted_ttft_s(
            backlog_tokens * fpt, new_tokens * fpt, eff, hw.step_overhead_s)

    def _transfer_estimate(self, p: NodeHandle, d: NodeHandle, req: Request) -> float:
        """Expected KV transfer latency P->D + a decode-load tiebreak."""
        profile: TransportProfile = select_route(p.host_id == d.host_id, self.target)
        nbytes = self.model_cost.kv_bytes_per_token * (req.prompt_len + 1)
        # cross-degree transfers pay one fused dispatch per overlapping
        # (src_shard, dst_shard) head-range pair, bytes conserved
        calls = sharded_transfer_calls(p.tp_degree, d.tp_degree)
        if self.layer_window > 0:
            # Layer-window streaming: only the wire time that spills past the
            # producing prefill tail is exposed. The hide window is the LAST
            # prefill chunk's compute — the window whose layers the final
            # sub-plans wait on.
            sched = p.scheduler
            tail = req.prompt_len
            if getattr(sched, "chunked_prefill", False):
                tail = min(tail, sched.prefill_chunk_tokens
                           or sched.max_batch_tokens)
            prefill_s = p.hardware.prefill_time(
                tail * self.model_cost.flops_per_token / p.tp_degree)
            latency = estimate_overlapped_transfer_s(
                profile, int(nbytes), self.num_layers, self.layer_window,
                prefill_s, calls_per_window=calls)
        else:
            # FlowKV's segment allocator keeps requests ~1 segment => 1 call
            # per shard pair (1 flat when both sides are unsharded).
            latency = profile.latency(num_calls=calls, num_bytes=int(nbytes))
        load_penalty = node_score(self._scored_status(d), "decode")
        return latency * (1.0 + load_penalty)

    # -- the controller loop ---------------------------------------------------------------
    def step(self, now: float = 0.0) -> str:
        """One controller cycle: sample -> score -> classify -> act."""
        self.cycle += 1
        self.detect_failures(now)
        alive = [n for n in self.nodes.values() if n.alive]
        if not alive:
            return self.regime
        raw = {n.node_id: n.scheduler.sample_status() for n in alive}
        smoothed = {n.node_id: n.scheduler.smoothed_status() for n in alive}
        norm_list = normalize(list(smoothed.values()))
        statuses = dict(zip(smoothed.keys(), norm_list))
        del raw
        # stamp per-node hardware capability so heterogeneous fleets score
        # on one scale (load_score divides pending-work terms by capability)
        caps = self._capabilities()
        statuses = {nid: (s.with_capability(*caps[nid]) if nid in caps else s)
                    for nid, s in statuses.items()}
        # like capability_*, the mesh degrees are constants re-stamped AFTER
        # normalize() (which rebuilds statuses from STATUS_FIELDS only)
        statuses = {nid: s.with_sharding(self.nodes[nid].tp_degree,
                                         self.nodes[nid].ep_degree)
                    for nid, s in statuses.items()}
        cp, cd = cluster_scores(
            statuses,
            [n.node_id for n in self.prefill_nodes()],
            [n.node_id for n in self.decode_nodes()],
        )
        regime = classify_regime(cp, cd, self.thresholds)
        if regime != self.regime:
            self._log("regime", f"{self.regime} -> {regime} (C^p={cp:.3f}, C^d={cd:.3f})")
        self.regime = regime
        act = self.actions_enabled   # passive controllers observe, never act

        if regime == "imbalanced":
            if act:
                self._handle_imbalance(statuses, cp, cd)
            self._extreme_streak = 0
            self._low_streak = 0
            self._normal_streak = 0
        elif regime == "extreme":
            self._extreme_streak += 1
            self._low_streak = 0
            self._normal_streak = 0
            if self._extreme_streak >= self.thresholds.scale_patience:
                if act:
                    self._scale_up(cp, cd)
                self._extreme_streak = 0
        else:
            self._normal_streak += 1
            if act:
                self._flip_back(statuses)
            self._extreme_streak = 0
            if cp < 0.05 and cd < 0.05:
                self._low_streak += 1
                if self._low_streak >= 4 * self.thresholds.scale_patience:
                    if act:
                        self._scale_down()
                    self._low_streak = 0
            else:
                self._low_streak = 0
        self._drain_deferred()
        self.reroute_retries()
        return regime

    # -- imbalanced regime: role switching (App. B.1) ------------------------------------------
    def _handle_imbalance(self, statuses: Dict[int, NodeStatus], cp: float, cd: float) -> None:
        hot_role = "prefill" if cp >= cd else "decode"
        cold_role = "decode" if hot_role == "prefill" else "prefill"
        idle = [
            n for n in self.nodes.values()
            if n.alive and n.role == cold_role
            and node_score(statuses[n.node_id], cold_role) < self.thresholds.idle
        ]
        # Capability-weighted skew: on a heterogeneous fleet, borrow the
        # candidate best SUITED to the hot role first — compute-rich cards
        # for a prefill burst, bandwidth/memory-rich cards for a decode
        # burst — so a flip adds the most capacity per node moved.
        caps = self._capabilities()

        def suitability(n: NodeHandle) -> float:
            c, m, kv = caps.get(n.node_id, (1.0, 1.0, 1.0))
            return c if hot_role == "prefill" else 0.5 * (m + kv)

        idle.sort(key=suitability, reverse=True)
        hot_score, cold_score = (cp, cd) if hot_role == "prefill" else (cd, cp)
        for node in idle:
            if self.role_flip:
                if self.cycle < node.switched_until_cycle:
                    continue   # residency: a fresh flip may not be reversed yet
                # Full reassignment needs a decisive skew (flipping idle nodes
                # into the hot role dilutes its mean score, so a lukewarm
                # near-tie would otherwise ping-pong the hot side each cycle)
                # and must never strand the cold role at zero nodes.
                remaining = [n for n in self.nodes.values()
                             if n.alive and n.role == cold_role]
                if hot_score - cold_score > self.thresholds.idle and len(remaining) > 1:
                    if node.home_role is None:
                        node.home_role = cold_role
                    self.set_role(node.node_id, hot_role)
                    # minimum residency in the borrowed role (anti-thrash)
                    node.switched_until_cycle = self.cycle + self.role_switch_cycles
                    continue
            node.scheduler.set_priority(hot_role, cycles=self.role_switch_cycles)
            node.switched_until_cycle = self.cycle + self.role_switch_cycles
            self._log("role_switch",
                      f"node {node.node_id} ({cold_role}) -> priority {hot_role} "
                      f"for {self.role_switch_cycles} cycles")

    def _flip_back(self, statuses: Dict[int, NodeStatus]) -> None:
        """Normal regime: return flipped nodes to their home role.

        Guarded against thrash — flipping idle nodes INTO the hot role
        dilutes that role's mean score, which alone would read as "back to
        normal". A node only reverts after (a) a sustained normal streak,
        (b) its minimum residency in the borrowed role elapsed, and (c) it
        is idle in the borrowed role (no longer absorbing the burst).
        """
        if self._normal_streak < self.role_switch_cycles:
            return
        for node in self.nodes.values():
            if (node.alive and node.home_role is not None
                    and node.role != node.home_role
                    and self.cycle >= node.switched_until_cycle
                    and node_score(statuses.get(node.node_id, NodeStatus()),
                                   node.role) < self.thresholds.idle):
                # same stranding guard as the flip itself: never revert the
                # last node of its CURRENT role (a sequence of flips can
                # otherwise leave the cluster 100% one role)
                peers = [m for m in self.nodes.values()
                         if m.alive and m.role == node.role]
                if len(peers) <= 1:
                    continue
                home = node.home_role
                node.home_role = None
                self.set_role(node.node_id, home)

    # -- extreme regime: elastic scaling (App. B.1) ----------------------------------------------
    def _scale_up(self, cp: float, cd: float) -> None:
        if self.node_factory is None:
            self._log("scale_up", "requested but no node_factory configured")
            return
        role = "prefill" if cp >= cd else "decode"
        node = self.node_factory(role)
        self.register_node(node)
        self._log("scale_up", f"added node {node.node_id} as {role}")

    def _scale_down(self) -> None:
        # Remove the least-loaded node of the more numerous role, if >1 remain.
        for role_nodes in (self.prefill_nodes(), self.decode_nodes()):
            if len(role_nodes) > 1:
                victim = min(role_nodes,
                             key=lambda n: node_score(self._scored_status(n), n.role))
                sched = victim.scheduler
                busy = (sched.prefill.running or sched.decode.running
                        or sched.prefill.sending)
                if busy:
                    continue
                victim.alive = False
                self.retry_queue.extend(victim.scheduler.drain_for_failure())
                self.prefix_index.evict_node(victim.node_id)
                self._log("scale_down", f"removed idle node {victim.node_id} ({victim.role})")
                return

    # -- misc ------------------------------------------------------------------------------------
    def _log(self, kind: str, detail: str) -> None:
        self.events.append(ControllerEvent(self.cycle, kind, detail))

    def record_prefix(self, node_id: int, tokens: Sequence[int],
                      block_ids: Optional[Sequence[int]] = None) -> None:
        """Advertise a prompt's KV as resident on a node.

        ``block_ids`` (one per full block of ``tokens``) is what makes the
        entry shareable; without it the entry only biases routing estimates
        and the data plane never claims reuse from it.
        """
        self.prefix_index.insert(node_id, tokens, block_ids)
