"""Weighted load scores C^p / C^d (paper Alg. 1, lines 8-15).

    C_i^p = w_r L_r^prefill + w_w L_w^prefill + w_sw L_sw^prefill + w_se L_se^prefill
          + w_t T_b + w_kv KV_u + w_g G_u + w_mb MB_u
    (and symmetrically C_i^d over the decode queues)

The paper sets the weights empirically ("determined through several
successful experiments"); the defaults below encode its stated intent:
prefill load is compute-driven (waiting queue + token budget + compute
util dominate), decode load is memory-driven (running queue + KV util +
bandwidth util dominate), and the sending queue signals transfer pressure
on both.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

from repro.core.scheduler.metrics import NodeStatus


@dataclasses.dataclass(frozen=True)
class ScoreWeights:
    """Weights in the exact order of the paper's C^p/C^d sum (module
    docstring): w_r, w_w, w_sw, w_se, w_t, w_kv, w_g, w_mb. The field order
    IS the formula order — ``validate()`` guards the presets against silent
    drift (positional construction with reordered fields would change the
    score without any type error)."""

    running: float          # w_r   L_r   (running queue)
    waiting: float          # w_w   L_w   (waiting queue)
    swapped: float          # w_sw  L_sw  (swapped queue)
    sending: float          # w_se  L_se  (sending queue)
    token_budget: float     # w_t   T_b   (per-step token budget used)
    kv_util: float          # w_kv  KV_u  (KV pool occupancy)
    compute_util: float     # w_g   G_u   (MXU/SM busy fraction)
    bandwidth_util: float   # w_mb  MB_u  (HBM bandwidth busy fraction)

    def validate(self) -> "ScoreWeights":
        """Weights must be non-negative and sum to 1 (a convex combination:
        every feature is normalized to [0, 1], so scores stay comparable to
        the ε thresholds). Returns self so presets can validate inline."""
        vals = dataclasses.astuple(self)
        if any(v < 0.0 for v in vals):
            raise ValueError(f"score weights must be non-negative, got {self}")
        total = sum(vals)
        if abs(total - 1.0) > 1e-9:
            raise ValueError(
                f"score weights must sum to 1.0, got {total!r} for {self}")
        return self


# Prefill: compute-bound — queue backlog and compute utilization dominate.
PREFILL_WEIGHTS = ScoreWeights(
    running=0.20, waiting=0.30, swapped=0.05, sending=0.10,
    token_budget=0.15, kv_util=0.05, compute_util=0.15, bandwidth_util=0.00,
).validate()
# Decode: memory-bound — running batch, KV occupancy and HBM bw dominate.
DECODE_WEIGHTS = ScoreWeights(
    running=0.25, waiting=0.15, swapped=0.05, sending=0.05,
    token_budget=0.05, kv_util=0.25, compute_util=0.00, bandwidth_util=0.20,
).validate()


@dataclasses.dataclass(frozen=True)
class Thresholds:
    """Regime thresholds ε (paper Alg. 1 lines 17/24).

    The paper leaves ε unspecified ("determined through several successful
    experiments"); these defaults are calibrated so that a node with a
    saturated queue + hot utilization signals scores ~0.8 (prefill) / ~0.55
    (decode) under the default weights, placing the high marks just below
    full saturation.
    """

    low_p: float = 0.35
    low_d: float = 0.30
    high_p: float = 0.60
    high_d: float = 0.45
    idle: float = 0.15          # node considered idle (role-switch candidate)
    scale_patience: int = 3     # consecutive extreme observations before scaling
    # ε_overload: when EVERY prefill-capable node's score exceeds this, the
    # admission gate stops admitting (defer, then early-reject) — Mooncake's
    # predicted-load early rejection, arXiv:2407.00079 §5.
    overload: float = 0.85


def node_score(status: NodeStatus, role: str) -> float:
    """Scalar load score for one node in one role, from a *smoothed* status.

    Heterogeneous fleets: the queue-length and token-budget terms measure
    *pending work*, so they are divided by the node's relative capability
    for the role (compute for prefill, HBM bandwidth for decode) — ten
    waiting prompts on an L20 are more load than ten on an A100, and the
    weak card therefore saturates "earlier" under the same ε thresholds.
    The three utilization fractions (KV / compute / bandwidth) are already
    measured against the node's OWN hardware and are not rescaled — a small
    pool at 90% is genuinely at 90%. Capability defaults to 1.0 (homogeneous
    fleet ≡ the paper's original formula).
    """
    if role == "prefill":
        w, pre = PREFILL_WEIGHTS, "prefill"
        work_cap = status.capability_compute
    elif role == "decode":
        w, pre = DECODE_WEIGHTS, "decode"
        work_cap = status.capability_memory
    else:
        raise ValueError(f"role must be 'prefill' or 'decode', got {role!r}")
    work_cap = max(work_cap, 1e-6)
    queue_load = (
        w.running * getattr(status, f"running_{pre}")
        + w.waiting * getattr(status, f"waiting_{pre}")
        + w.swapped * getattr(status, f"swapped_{pre}")
        + w.sending * getattr(status, f"sending_{pre}")
        + w.token_budget * status.token_budget_used
    )
    return (
        queue_load / work_cap
        + w.kv_util * status.kv_utilization
        + w.compute_util * status.compute_utilization
        + w.bandwidth_util * status.bandwidth_utilization
    )


def cluster_scores(statuses: Dict[int, NodeStatus], prefill_nodes: Sequence[int],
                   decode_nodes: Sequence[int]) -> tuple[float, float]:
    """C^p = mean over P nodes, C^d = mean over D nodes (Alg. 1 lines 12-15)."""
    cp = (sum(node_score(statuses[i], "prefill") for i in prefill_nodes) / len(prefill_nodes)
          if prefill_nodes else 0.0)
    cd = (sum(node_score(statuses[i], "decode") for i in decode_nodes) / len(decode_nodes)
          if decode_nodes else 0.0)
    return cp, cd


def classify_regime(cp: float, cd: float, th: Thresholds) -> str:
    """normal | imbalanced | extreme  (Alg. 1 lines 16-31).

    normal:     both scores low.
    imbalanced: exactly one side hot (or moderately loaded but skewed).
    extreme:    both beyond the high threshold (overload) — or both ~zero
                for a long time (low-load; handled by the elastic manager).
    """
    if cp <= th.low_p and cd <= th.low_d:
        return "normal"
    if cp > th.high_p and cd > th.high_d:
        return "extreme"
    return "imbalanced"
