"""Node status vector S_i and sliding-window smoothing (paper App. B.2).

The paper samples, per node: running/waiting/swapped/sending queue lengths
for both roles, token budget, KV-cache utilization, compute utilization and
memory-bandwidth utilization, then smooths with a sliding window because
"instantaneous sampling can result in significant fluctuations".
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, Iterable, List

STATUS_FIELDS = (
    "running_prefill", "waiting_prefill", "swapped_prefill", "sending_prefill",
    "running_decode", "waiting_decode", "swapped_decode", "sending_decode",
    "token_budget_used",     # fraction of per-step token budget consumed
    "kv_utilization",        # KV_u
    "compute_utilization",   # G_u   (MXU busy fraction on TPU)
    "bandwidth_utilization", # MB_u  (HBM bw busy fraction)
)


@dataclasses.dataclass(frozen=True)
class NodeStatus:
    """One instantaneous sample of a node's load vector S_i.

    The ``capability_*`` fields are NOT part of the sampled load vector
    (they are hardware constants, not signals): the global controller stamps
    them onto every smoothed status before scoring, so a heterogeneous fleet
    scores comparably — see :func:`repro.core.scheduler.load_score.node_score`.
    They are relative to the fleet maximum, in (0, 1].
    """

    running_prefill: float = 0.0
    waiting_prefill: float = 0.0
    swapped_prefill: float = 0.0
    sending_prefill: float = 0.0
    running_decode: float = 0.0
    waiting_decode: float = 0.0
    swapped_decode: float = 0.0
    sending_decode: float = 0.0
    token_budget_used: float = 0.0
    kv_utilization: float = 0.0
    compute_utilization: float = 0.0
    bandwidth_utilization: float = 0.0
    # hardware capability relative to fleet max (stamped by the controller)
    capability_compute: float = 1.0     # peak FLOPs / fleet-max FLOPs
    capability_memory: float = 1.0      # HBM bandwidth / fleet-max bandwidth
    capability_kv: float = 1.0          # HBM capacity / fleet-max capacity
    # mesh-parallel topology (constants like capability_*, NOT load signals;
    # stamped by the controller after smoothing/normalization — anything
    # rebuilding a NodeStatus from STATUS_FIELDS drops them back to 1)
    tp_degree: float = 1.0              # tensor-parallel degree of the node
    ep_degree: float = 1.0              # expert-parallel degree (MoE; else 1)

    def as_dict(self) -> Dict[str, float]:
        return {f: getattr(self, f) for f in STATUS_FIELDS}

    def with_capability(self, compute: float, memory: float,
                        kv: float) -> "NodeStatus":
        """Stamp relative hardware capability onto a (smoothed) sample."""
        return dataclasses.replace(
            self, capability_compute=compute, capability_memory=memory,
            capability_kv=kv)

    def with_sharding(self, tp_degree: float, ep_degree: float) -> "NodeStatus":
        """Stamp the node's mesh-parallel degrees onto a (smoothed) sample."""
        return dataclasses.replace(
            self, tp_degree=tp_degree, ep_degree=ep_degree)


class SlidingWindow:
    """Per-field moving average over the last ``window`` samples."""

    def __init__(self, window: int = 8):
        if window < 1:
            raise ValueError("window must be >= 1")
        self.window = window
        self._hist: Deque[NodeStatus] = collections.deque(maxlen=window)

    def push(self, status: NodeStatus) -> None:
        self._hist.append(status)

    def __len__(self) -> int:
        return len(self._hist)

    def smoothed(self) -> NodeStatus:
        if not self._hist:
            return NodeStatus()
        acc = {f: 0.0 for f in STATUS_FIELDS}
        for s in self._hist:
            for f in STATUS_FIELDS:
                acc[f] += getattr(s, f)
        n = len(self._hist)
        return NodeStatus(**{f: v / n for f, v in acc.items()})


def normalize(statuses: List[NodeStatus]) -> List[NodeStatus]:
    """Cluster-wide max-normalization so heterogeneous nodes are comparable.

    Queue lengths are unbounded counts; utilizations are already in [0, 1].
    The paper: "we normalize all data to effectively assess each node's load
    status".
    """
    if not statuses:
        return []
    queue_fields = [f for f in STATUS_FIELDS
                    if f.startswith(("running", "waiting", "swapped", "sending"))]
    maxima = {f: max(1.0, max(getattr(s, f) for s in statuses)) for f in queue_fields}
    out = []
    for s in statuses:
        d = s.as_dict()
        for f in queue_fields:
            d[f] = d[f] / maxima[f]
        out.append(NodeStatus(**d))
    return out
