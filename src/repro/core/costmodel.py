"""Transfer cost models, calibrated against the paper's Table 3.

The quantity FlowKV optimizes is::

    latency = num_calls * per_call_overhead + bytes / bandwidth + fixed

``num_calls`` comes from the transfer planner (exact, not modeled); the
constants below are calibrated so the Table-3 grid reproduces within a few
percent (see ``benchmarks/transfer_latency.py`` and
``tests/test_costmodel.py``).

Calibration notes (Llama-3.1-8B: L=32, kv=8, hd=128, bf16, block=32 tokens,
so 128 KiB/token and ~23.5k layerwise calls at 11.7k ctx — matching the
paper's 23,469):

* ``nccl``        — per-call ~73 µs: FlowKV-Layerwise single-machine 12k ctx
                    = 1.72 s at 23.5k calls.
* ``ipc``         — ~23 GB/s: FlowKV single-machine 12k ctx = 0.068 s for
                    1.57 GB.
* ``nccl_eni``    — ~9 GB/s cross-machine: FlowKV multi-machine 12k = 0.176 s.
* ``vllm_merge``  — vLLM-Disagg's layer-buffer merge path: effective
                    ~0.75 GB/s (merge memcpy + per-layer calls), matching
                    2.19 s at 12k.
* ``mooncake``    — RDMA path without NIC-direct VRAM exchange: ~0.5 GB/s
                    effective plus high setup, matching 2.03 s at 8k.

TPU-side profiles (the *target* hardware) use the system constants:
ICI ~50 GB/s/link, DCN modeled at 25 GB/s/host, per-DMA-descriptor
dispatch ~8 µs. These drive the TPU columns of the benchmark and the
serving simulator.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class TransportProfile:
    """latency(calls, bytes) = calls*per_call + bytes/bandwidth + fixed."""

    name: str
    per_call_s: float          # per contiguous-range call (kernel/descriptor)
    bandwidth_Bps: float       # steady-state bandwidth for merged payloads
    fixed_s: float = 0.0       # handshake / metadata exchange
    per_byte_extra_s: float = 0.0  # extra per-byte work (e.g. merge memcpy)

    def latency(self, num_calls: int, num_bytes: int) -> float:
        return (
            self.fixed_s
            + num_calls * self.per_call_s
            + num_bytes / self.bandwidth_Bps
            + num_bytes * self.per_byte_extra_s
        )


# --- GPU-world profiles (paper's measurement environment) --------------------
NCCL_INTRA = TransportProfile(  # NCCL over NVLink, same machine
    name="nccl",
    per_call_s=73e-6,
    bandwidth_Bps=23e9,
    fixed_s=4e-4,
)
NCCL_ENI = TransportProfile(  # NCCL over Elastic Network Interface, cross machine
    name="nccl_eni",
    per_call_s=105e-6,
    bandwidth_Bps=9.2e9,
    fixed_s=1.5e-3,
)
IPC = TransportProfile(  # cudaIpc same-machine peer copy
    name="ipc",
    per_call_s=12e-6,
    bandwidth_Bps=23.5e9,
    fixed_s=2e-4,
)
VLLM_MERGE_INTRA = TransportProfile(  # vLLM-disagg: merge layer buffers, then send
    name="vllm_merge",
    per_call_s=73e-6,           # one NCCL call per layer buffer (2L calls)
    bandwidth_Bps=23e9,
    fixed_s=5e-4,
    per_byte_extra_s=1.0 / 0.80e9,  # small-chunk merge memcpy, effective ~0.8 GB/s
)
VLLM_MERGE_ENI = TransportProfile(
    name="vllm_merge_eni",
    per_call_s=105e-6,
    bandwidth_Bps=9.2e9,
    fixed_s=1.5e-3,
    per_byte_extra_s=1.0 / 0.85e9,
)
MOONCAKE_RDMA = TransportProfile(  # RDMA without NIC-direct VRAM exchange
    name="mooncake_rdma",
    per_call_s=30e-6,
    bandwidth_Bps=0.53e9,
    fixed_s=2.5e-2,
)

# --- TPU-world profiles (the port target) ------------------------------------
TPU_ICI = TransportProfile(  # same-pod, over ICI links
    name="tpu_ici",
    per_call_s=8e-6,           # DMA descriptor dispatch
    bandwidth_Bps=50e9,        # per-link ICI (system constant)
    fixed_s=5e-5,
)
TPU_DCN = TransportProfile(  # cross-pod, over data-center network
    name="tpu_dcn",
    per_call_s=20e-6,
    bandwidth_Bps=25e9,
    fixed_s=5e-4,
)

PROFILES: Dict[str, TransportProfile] = {
    p.name: p
    for p in (
        NCCL_INTRA,
        NCCL_ENI,
        IPC,
        VLLM_MERGE_INTRA,
        VLLM_MERGE_ENI,
        MOONCAKE_RDMA,
        TPU_ICI,
        TPU_DCN,
    )
}


def get_profile(name: str) -> TransportProfile:
    try:
        return PROFILES[name]
    except KeyError as e:
        raise ValueError(f"unknown transport profile {name!r}; have {sorted(PROFILES)}") from e


def predicted_ttft_s(queued_flops: float, new_flops: float,
                     effective_flops: float,
                     overhead_s: float = 0.0) -> float:
    """Admission-time TTFT prediction (Mooncake-style, arXiv:2407.00079 §5).

    Prefill is compute-bound, so time-to-first-token on a node is the queued
    prefill work plus this request's own compute over the node's *effective*
    throughput (peak FLOPs x achievable MFU). The global controller uses
    this both to pick the min-TTFT prefill node (Alg. 1 routing) and to gate
    admission: a predicted TTFT beyond the SLO means the request is doomed
    before it runs, and rejecting it NOW is cheaper than serving it late.
    ``HardwareProfile.prefill_time`` delegates here (queued_flops=0), so the
    simulator's step-time model and the controller's estimates are one
    formula.
    """
    return overhead_s + (queued_flops + new_flops) / max(effective_flops, 1.0)


def select_route(same_host: bool, target: str = "gpu") -> TransportProfile:
    """FlowKV §3.2: 'selects the best transfer pipeline based on hardware'.

    GPU world: IPC inside a machine, NCCL across. TPU world: ICI inside a
    pod, DCN across pods.
    """
    if target == "gpu":
        return IPC if same_host else NCCL_ENI
    if target == "tpu":
        return TPU_ICI if same_host else TPU_DCN
    raise ValueError(f"unknown target {target!r}")
