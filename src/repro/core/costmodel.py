"""Transfer cost models, calibrated against the paper's Table 3.

The quantity FlowKV optimizes is::

    latency = num_calls * per_call_overhead + bytes / bandwidth + fixed

``num_calls`` comes from the transfer planner (exact, not modeled); the
constants below are calibrated so the Table-3 grid reproduces within a few
percent (see ``benchmarks/transfer_latency.py`` and
``tests/test_costmodel.py``).

Calibration notes (Llama-3.1-8B: L=32, kv=8, hd=128, bf16, block=32 tokens,
so 128 KiB/token and ~23.5k layerwise calls at 11.7k ctx — matching the
paper's 23,469):

* ``nccl``        — per-call ~73 µs: FlowKV-Layerwise single-machine 12k ctx
                    = 1.72 s at 23.5k calls.
* ``ipc``         — ~23 GB/s: FlowKV single-machine 12k ctx = 0.068 s for
                    1.57 GB.
* ``nccl_eni``    — ~9 GB/s cross-machine: FlowKV multi-machine 12k = 0.176 s.
* ``vllm_merge``  — vLLM-Disagg's layer-buffer merge path: effective
                    ~0.75 GB/s (merge memcpy + per-layer calls), matching
                    2.19 s at 12k.
* ``mooncake``    — RDMA path without NIC-direct VRAM exchange: ~0.5 GB/s
                    effective plus high setup, matching 2.03 s at 8k.

TPU-side profiles (the *target* hardware) use the system constants:
ICI ~50 GB/s/link, DCN modeled at 25 GB/s/host, per-DMA-descriptor
dispatch ~8 µs. These drive the TPU columns of the benchmark and the
serving simulator.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class TransportProfile:
    """latency(calls, bytes) = calls*per_call + bytes/bandwidth + fixed."""

    name: str
    per_call_s: float          # per contiguous-range call (kernel/descriptor)
    bandwidth_Bps: float       # steady-state bandwidth for merged payloads
    fixed_s: float = 0.0       # handshake / metadata exchange
    per_byte_extra_s: float = 0.0  # extra per-byte work (e.g. merge memcpy)

    def latency(self, num_calls: int, num_bytes: int) -> float:
        return (
            self.fixed_s
            + num_calls * self.per_call_s
            + num_bytes / self.bandwidth_Bps
            + num_bytes * self.per_byte_extra_s
        )


# --- GPU-world profiles (paper's measurement environment) --------------------
NCCL_INTRA = TransportProfile(  # NCCL over NVLink, same machine
    name="nccl",
    per_call_s=73e-6,
    bandwidth_Bps=23e9,
    fixed_s=4e-4,
)
NCCL_ENI = TransportProfile(  # NCCL over Elastic Network Interface, cross machine
    name="nccl_eni",
    per_call_s=105e-6,
    bandwidth_Bps=9.2e9,
    fixed_s=1.5e-3,
)
IPC = TransportProfile(  # cudaIpc same-machine peer copy
    name="ipc",
    per_call_s=12e-6,
    bandwidth_Bps=23.5e9,
    fixed_s=2e-4,
)
VLLM_MERGE_INTRA = TransportProfile(  # vLLM-disagg: merge layer buffers, then send
    name="vllm_merge",
    per_call_s=73e-6,           # one NCCL call per layer buffer (2L calls)
    bandwidth_Bps=23e9,
    fixed_s=5e-4,
    per_byte_extra_s=1.0 / 0.80e9,  # small-chunk merge memcpy, effective ~0.8 GB/s
)
VLLM_MERGE_ENI = TransportProfile(
    name="vllm_merge_eni",
    per_call_s=105e-6,
    bandwidth_Bps=9.2e9,
    fixed_s=1.5e-3,
    per_byte_extra_s=1.0 / 0.85e9,
)
MOONCAKE_RDMA = TransportProfile(  # RDMA without NIC-direct VRAM exchange
    name="mooncake_rdma",
    per_call_s=30e-6,
    bandwidth_Bps=0.53e9,
    fixed_s=2.5e-2,
)

# --- TPU-world profiles (the port target) ------------------------------------
TPU_ICI = TransportProfile(  # same-pod, over ICI links
    name="tpu_ici",
    per_call_s=8e-6,           # DMA descriptor dispatch
    bandwidth_Bps=50e9,        # per-link ICI (system constant)
    fixed_s=5e-5,
)
TPU_DCN = TransportProfile(  # cross-pod, over data-center network
    name="tpu_dcn",
    per_call_s=20e-6,
    bandwidth_Bps=25e9,
    fixed_s=5e-4,
)

# --- tiered KV store (Mooncake direction) -------------------------------------
HOST_DRAM = TransportProfile(
    # Host-DRAM tier leg: pageable-host staging + pinning + page re-layout
    # on the way back into the pool. Deliberately SLOWER than every wire
    # profile ``select_route`` can return (IPC 23.5, NCCL_ENI 9.2, ICI 50,
    # DCN 25 GB/s) so the router's tier lattice holds by construction:
    # HBM-local < HBM-remote < DRAM-local < DRAM-remote < recompute.
    name="host_dram",
    per_call_s=150e-6,         # pin + descriptor-table staging per dispatch
    bandwidth_Bps=6.0e9,       # pageable H2D/D2H effective bandwidth
    fixed_s=3e-4,
)

PROFILES: Dict[str, TransportProfile] = {
    p.name: p
    for p in (
        NCCL_INTRA,
        NCCL_ENI,
        IPC,
        VLLM_MERGE_INTRA,
        VLLM_MERGE_ENI,
        MOONCAKE_RDMA,
        TPU_ICI,
        TPU_DCN,
        HOST_DRAM,
    )
}


def get_profile(name: str) -> TransportProfile:
    try:
        return PROFILES[name]
    except KeyError as e:
        raise ValueError(f"unknown transport profile {name!r}; have {sorted(PROFILES)}") from e


def predicted_ttft_s(queued_flops: float, new_flops: float,
                     effective_flops: float,
                     overhead_s: float = 0.0) -> float:
    """Admission-time TTFT prediction (Mooncake-style, arXiv:2407.00079 §5).

    Prefill is compute-bound, so time-to-first-token on a node is the queued
    prefill work plus this request's own compute over the node's *effective*
    throughput (peak FLOPs x achievable MFU). The global controller uses
    this both to pick the min-TTFT prefill node (Alg. 1 routing) and to gate
    admission: a predicted TTFT beyond the SLO means the request is doomed
    before it runs, and rejecting it NOW is cheaper than serving it late.
    ``HardwareProfile.prefill_time`` delegates here (queued_flops=0), so the
    simulator's step-time model and the controller's estimates are one
    formula.
    """
    return overhead_s + (queued_flops + new_flops) / max(effective_flops, 1.0)


def predicted_chunked_ttft_s(backlog_tokens: Sequence[float],
                             new_tokens: float, chunk_tokens: float,
                             flops_per_token: float, effective_flops: float,
                             overhead_s: float = 0.0) -> float:
    """Admission-time TTFT under CHUNKED prefill interleaving.

    The whole-prompt estimator (:func:`predicted_ttft_s`) charges a new
    request the node's ENTIRE queued prefill backlog — under chunking that
    is a head-of-line fiction: a long prompt only claims ``chunk_tokens``
    per cycle, so a short prompt queued behind it interleaves instead of
    waiting the long prompt out. While this request runs its own
    ``ceil(new_tokens / chunk_tokens)`` chunks, each queued request can
    delay it by AT MOST ``own_cycles * chunk_tokens`` of concurrent chunk
    work — any backlog beyond that executes after this request's first
    token and must not be priced into its TTFT.

    ``backlog_tokens`` is per-request REMAINING prefill tokens queued ahead
    (``HybridScheduler.prefill_backlog_tokens``). With ``chunk_tokens``
    >= every prompt the bound is inactive and this reduces exactly to
    :func:`predicted_ttft_s` over the same backlog.
    """
    chunk = max(1.0, float(chunk_tokens))
    own_cycles = max(1.0, -(-float(new_tokens) // chunk))   # ceil, >= 1
    delayed = sum(min(float(b), own_cycles * chunk) for b in backlog_tokens)
    return predicted_ttft_s(delayed * flops_per_token,
                            new_tokens * flops_per_token,
                            effective_flops, overhead_s)


def layer_window_overlap(window_latencies: Sequence[float],
                         window_layer_ends: Sequence[int],
                         num_layers: int,
                         prefill_s: float) -> Tuple[float, float]:
    """Price a layerwise-pipelined transfer: returns ``(exposed_s, hidden_s)``.

    Window w (layers ``[.., window_layer_ends[w])``) becomes sendable when
    the producing prefill pass finishes its last layer — modeled as the
    uniform-layer point ``prefill_s * end_w / num_layers`` — and windows
    serialize on one transport link::

        finish_w = max(finish_{w-1}, ready_w) + latency_w

    The request only WAITS for what spills past the end of prefill:
    ``exposed = max(0, finish_last - prefill_s)``; the rest of the wire
    time is hidden behind compute. This one function is the single pricing
    source for the real cluster (``PDCluster._transfer``), the simulator
    (``ClusterSim._start_transfer``) and the controller's routing estimate,
    so load-aware scheduling sees exactly the gain the data plane realizes.
    With one window ready at the end (``prefill_s * L/L``) nothing hides:
    ``exposed == total`` — the unoverlapped baseline.
    """
    finish = 0.0
    total = 0.0
    for end, lat in zip(window_layer_ends, window_latencies):
        ready = prefill_s * end / max(1, num_layers)
        finish = max(finish, ready) + lat
        total += lat
    exposed = max(0.0, finish - prefill_s)
    return exposed, total - exposed


def estimate_overlapped_transfer_s(profile: TransportProfile, num_bytes: int,
                                   num_layers: int, layer_window: int,
                                   prefill_s: float,
                                   calls_per_window: int = 1) -> float:
    """Routing-time estimate of the EXPOSED transfer latency under
    layer-window overlap, without a concrete plan: bytes split evenly over
    ``ceil(num_layers / layer_window)`` windows, each priced as its own
    transport call(s), then run through :func:`layer_window_overlap`.
    ``layer_window <= 0`` (overlap off) prices the classic single call.
    """
    if layer_window <= 0 or layer_window >= num_layers:
        return profile.latency(num_calls=calls_per_window,
                               num_bytes=int(num_bytes))
    ends = list(range(layer_window, num_layers, layer_window)) + [num_layers]
    lats = []
    prev = 0
    for end in ends:
        bytes_w = num_bytes * end // num_layers - num_bytes * prev // num_layers
        lats.append(profile.latency(num_calls=calls_per_window,
                                    num_bytes=int(bytes_w)))
        prev = end
    exposed, _ = layer_window_overlap(lats, ends, num_layers, prefill_s)
    return exposed


def sharded_transfer_calls(tp_src: int, tp_dst: int) -> int:
    """Fused dispatches a cross-degree pool transfer costs: one per
    overlapping (src_shard, dst_shard) pair of the two contiguous
    equal-width kv-head partitions.

    Merging the two partitions' cut points gives
    ``tp_src + tp_dst - gcd(tp_src, tp_dst)`` intervals (each interval is
    exactly one pair); same-degree transfers collapse to ``tp`` pairwise
    shard-local dispatches and the tp=1/tp=1 case to the classic single
    dispatch. This is the routing-time twin of
    ``core.transfer.TransferPlan.num_dispatches`` on a sharded plan.
    """
    return tp_src + tp_dst - math.gcd(tp_src, tp_dst)


def tier_fetch_latency(route: TransportProfile, hbm_bytes: int,
                       dram_bytes: int, remote: bool = True) -> float:
    """Price a tier-aware prefix fetch as its fused-dispatch legs.

    ``dram_bytes`` of the prefix sit in the source's host tier and must be
    PROMOTED first (one host->HBM descriptor-table dispatch on the
    :data:`HOST_DRAM` leg); then, when the source is ``remote``, the whole
    prefix (``hbm_bytes + dram_bytes``) crosses the wire as one more fused
    dispatch on ``route``. A local hit with no DRAM blocks is free (the
    blocks are shared, nothing moves), which is what keeps the lattice
    HBM-local < HBM-remote < DRAM-local < DRAM-remote.
    """
    latency = 0.0
    if dram_bytes > 0:
        latency += HOST_DRAM.latency(num_calls=1, num_bytes=int(dram_bytes))
    if remote:
        latency += route.latency(num_calls=1,
                                 num_bytes=int(hbm_bytes + dram_bytes))
    return latency


def select_route(same_host: bool, target: str = "gpu") -> TransportProfile:
    """FlowKV §3.2: 'selects the best transfer pipeline based on hardware'.

    GPU world: IPC inside a machine, NCCL across. TPU world: ICI inside a
    pod, DCN across pods.
    """
    if target == "gpu":
        return IPC if same_host else NCCL_ENI
    if target == "tpu":
        return TPU_ICI if same_host else TPU_DCN
    raise ValueError(f"unknown target {target!r}")
