"""FlowKV core: the paper's contribution.

C1 — low-latency KV-cache transfer: ``layout`` (Eq. 5 transform),
``allocator``/``block_manager`` (segment allocation), ``alignment``
(bidirectional segment alignment), ``transfer`` (planner + engine),
``costmodel`` (Table-3-calibrated transports).

C2 — load-aware scheduling: ``scheduler`` (metrics, scores, hybrid
scheduler, global controller).
"""
from repro.core.alignment import AlignedRun, AlignmentResult, align
from repro.core.allocator import (BlockAllocator, OutOfBlocksError,
                                  SegmentAllocator, make_allocator)
from repro.core.block_manager import BlockManager
from repro.core.layout import KVCacheSpec, KVLayout
from repro.core.segments import Segment, blocks_to_segments, segments_to_blocks
from repro.core.transfer import (TransferEngine, TransferPlan, TransferPlanner,
                                 transfer_request)

__all__ = [
    "AlignedRun", "AlignmentResult", "align", "BlockAllocator",
    "OutOfBlocksError", "SegmentAllocator", "make_allocator", "BlockManager",
    "KVCacheSpec", "KVLayout", "Segment", "blocks_to_segments",
    "segments_to_blocks", "TransferEngine", "TransferPlan", "TransferPlanner",
    "transfer_request",
]
