"""KV-cache transfer planning and execution.

Three transfer *schedules*, matching the paper's comparison set:

* ``layerwise`` (Splitwise-style baseline): one call per (layer, K/V, block)
  — ``2 * L * n`` calls. Overlappable with compute but call-bound.
* ``blockwise`` (vLLM-disagg-style): per-layer buffers are merged then sent
  — ``2 * L`` calls plus a per-byte merge cost.
* ``flowkv``: FlowKV layout + bidirectional segment alignment — one call per
  aligned run (ideally 1).

The planner produces an exact :class:`TransferPlan` (call count, bytes,
per-run descriptors). The engine executes a plan against real JAX arrays
(gather from the source pool, scatter into the destination pool) and the
cost model prices it for the benchmark tables.

On real TPU hardware each :class:`TransferOp` lowers to one DMA descriptor
(same-pod ICI) or one DCN send; on this CPU container execution is a faithful
data-plane copy and the *latency* is priced by ``core.costmodel``.

The TransferBackend protocol
----------------------------

Node-to-node request-state movement is dispatched through a small protocol so
runtimes never branch on *how* a model family stores its cache:

.. code-block:: python

    class TransferBackend:
        name: str
        def plan(self, req, src, dst) -> TransferJob: ...
        def execute(self, job, src, dst) -> None: ...
        def price(self, job, profile: TransportProfile) -> float: ...

``plan`` reserves destination capacity and returns a :class:`TransferJob`
(exact call count + byte count, plus any backend-specific payload);
``execute`` moves the data (a no-op for purely simulated backends); ``price``
converts the job into seconds under a :class:`TransportProfile`. ``src`` /
``dst`` are duck-typed *ports*: the real runtime passes
``repro.serving.engine.NodeEngine`` (which exposes ``kv``, ``states``,
``register_transfer_in`` …) and the simulator passes
``repro.sim.cluster_sim.SimNode`` (``bm`` / ``kv_spec`` / ``planner``).

Built-in backends, keyed in the module registry
(:func:`register_backend` / :func:`get_backend`):

* ``paged``  — :class:`PagedBackend`; block-granular plans for any of the
  three schedules above, executed against the paged pools.
* ``state``  — :class:`StateBackend`; whole-pytree movement for the
  ssm / hybrid / encdec families (one logical segment).
* ``sim``    — :class:`SimulatedBackend`; exact planning + pricing with a
  no-op data plane, for the discrete-event simulator (models e.g. a DCN hop
  without touching device memory).

Third-party backends (RDMA, object-store staging, …) plug in with
``register_backend("myname", MyBackend)`` and are selected per request via
:func:`backend_for_engine` or an explicit ``get_backend`` call.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Literal, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import layout as L
from repro.core.alignment import AlignmentResult, align
from repro.core.costmodel import TransportProfile
from repro.core.segments import Segment, blocks_to_segments

Schedule = Literal["layerwise", "blockwise", "flowkv"]


@dataclasses.dataclass(frozen=True)
class TransferOp:
    """One contiguous-range transfer call."""

    src: Segment              # block-id range on the sender
    dst: Segment              # block-id range on the receiver
    layer: Optional[int]      # None = all layers in one range (FlowKV layout)
    kv: Optional[int]         # None = both K and V; 0/1 for layerwise
    num_bytes: int


@dataclasses.dataclass(frozen=True)
class TransferPlan:
    schedule: Schedule
    ops: List[TransferOp]
    total_bytes: int
    num_blocks: int

    @property
    def num_calls(self) -> int:
        return len(self.ops)

    def latency(self, profile: TransportProfile) -> float:
        return profile.latency(self.num_calls, self.total_bytes)


class TransferPlanner:
    """Builds exact transfer plans for a request's block lists."""

    def __init__(self, spec: L.KVCacheSpec):
        self.spec = spec

    # -- plan builders ---------------------------------------------------------
    def plan(self, schedule: Schedule, src_blocks: Sequence[int],
             dst_blocks: Sequence[int]) -> TransferPlan:
        if schedule == "layerwise":
            return self.plan_layerwise(src_blocks, dst_blocks)
        if schedule == "blockwise":
            return self.plan_blockwise(src_blocks, dst_blocks)
        if schedule == "flowkv":
            return self.plan_flowkv(src_blocks, dst_blocks)
        raise ValueError(f"unknown schedule {schedule!r}")

    def plan_layerwise(self, src_blocks: Sequence[int], dst_blocks: Sequence[int]) -> TransferPlan:
        """2 * L calls per block: the per-(layer, k/v, block) baseline."""
        spec = self.spec
        per_call = spec.payload * jnp.dtype(spec.dtype).itemsize
        ops: List[TransferOp] = []
        for s, d in zip(src_blocks, dst_blocks):
            for layer in range(spec.num_layers):
                for kv in (0, 1):
                    ops.append(TransferOp(Segment(int(s), 1), Segment(int(d), 1),
                                          layer=layer, kv=kv, num_bytes=per_call))
        total = per_call * len(ops)
        return TransferPlan("layerwise", ops, total, len(list(src_blocks)))

    def plan_blockwise(self, src_blocks: Sequence[int], dst_blocks: Sequence[int]) -> TransferPlan:
        """2 * L calls total: per-layer buffers merged then sent (vLLM-disagg).

        The merge memcpy cost is priced by the ``vllm_merge`` transport
        profile, not counted as calls.
        """
        spec = self.spec
        n = len(list(src_blocks))
        layer_bytes = n * spec.payload * jnp.dtype(spec.dtype).itemsize
        ops: List[TransferOp] = []
        src_segs = blocks_to_segments(list(src_blocks))
        dst_segs = blocks_to_segments(list(dst_blocks))
        # One merged buffer per (layer, k/v); src/dst ranges recorded as the
        # covering span for bookkeeping (the buffer itself is staged).
        for layer in range(spec.num_layers):
            for kv in (0, 1):
                ops.append(TransferOp(src_segs[0] if src_segs else Segment(0, 1),
                                      dst_segs[0] if dst_segs else Segment(0, 1),
                                      layer=layer, kv=kv, num_bytes=layer_bytes))
        return TransferPlan("blockwise", ops, layer_bytes * len(ops), n)

    def plan_flowkv(self, src_blocks: Sequence[int], dst_blocks: Sequence[int]) -> TransferPlan:
        """Bidirectional segment alignment over the FlowKV layout."""
        if self.spec.layout is not L.KVLayout.FLOWKV:
            raise ValueError(
                "flowkv schedule requires the FLOWKV (B, L, 2, H) layout; "
                f"got {self.spec.layout}"
            )
        result: AlignmentResult = align(list(src_blocks), list(dst_blocks))
        ops = [
            TransferOp(run.src, run.dst, layer=None, kv=None,
                       num_bytes=run.length * self.spec.bytes_per_block)
            for run in result.runs
        ]
        total = sum(op.num_bytes for op in ops)
        return TransferPlan("flowkv", ops, total, result.num_blocks)


class TransferEngine:
    """Executes transfer plans against real device arrays.

    ``execute`` is layout-aware and schedule-faithful: FlowKV plans move whole
    block ranges; layerwise plans move per-(layer, kv) pages. The destination
    pool may use a different block placement (and on heterogeneous clusters a
    different total block count) — only the request's blocks move.
    """

    def __init__(self, src_spec: L.KVCacheSpec, dst_spec: Optional[L.KVCacheSpec] = None):
        self.src_spec = src_spec
        self.dst_spec = dst_spec or src_spec
        if self.src_spec.bytes_per_block != self.dst_spec.bytes_per_block:
            raise ValueError("src/dst pools must agree on per-block payload")
        self.planner = TransferPlanner(src_spec)

    def execute(self, plan: TransferPlan, src_cache: jax.Array,
                dst_cache: jax.Array) -> jax.Array:
        """Apply a plan: returns the updated destination pool."""
        for op in plan.ops:
            dst_cache = self._execute_op(op, plan.schedule, src_cache, dst_cache)
        return dst_cache

    def _execute_op(self, op: TransferOp, schedule: Schedule,
                    src_cache: jax.Array, dst_cache: jax.Array) -> jax.Array:
        src_ids = list(op.src.blocks())
        dst_ids = list(op.dst.blocks())
        if schedule == "flowkv":
            payload = L.gather_blocks(src_cache, self.src_spec, src_ids)
            return L.scatter_blocks(dst_cache, self.dst_spec, dst_ids, payload)
        # layerwise / blockwise: per-(layer, kv) page moves
        assert op.layer is not None and op.kv is not None
        for s, d in zip(src_ids, dst_ids):
            if self.src_spec.layout is L.KVLayout.FLOWKV:
                page = src_cache[s, op.layer, op.kv]
            else:
                page = src_cache[op.layer, op.kv, s]
            if self.dst_spec.layout is L.KVLayout.FLOWKV:
                dst_cache = dst_cache.at[d, op.layer, op.kv].set(page.astype(dst_cache.dtype))
            else:
                dst_cache = dst_cache.at[op.layer, op.kv, d].set(page.astype(dst_cache.dtype))
        return dst_cache

    # Blockwise plans replicate full-list moves per (layer, kv); execute them
    # faithfully by moving every block of the request for that layer slice.
    def execute_blockwise(self, src_blocks: Sequence[int], dst_blocks: Sequence[int],
                          src_cache: jax.Array, dst_cache: jax.Array) -> jax.Array:
        for layer in range(self.src_spec.num_layers):
            for kv in (0, 1):
                for s, d in zip(src_blocks, dst_blocks):
                    if self.src_spec.layout is L.KVLayout.FLOWKV:
                        page = src_cache[s, layer, kv]
                    else:
                        page = src_cache[layer, kv, s]
                    if self.dst_spec.layout is L.KVLayout.FLOWKV:
                        dst_cache = dst_cache.at[d, layer, kv].set(page.astype(dst_cache.dtype))
                    else:
                        dst_cache = dst_cache.at[layer, kv, d].set(page.astype(dst_cache.dtype))
        return dst_cache


def transfer_request(src_spec: L.KVCacheSpec, src_cache: jax.Array, src_blocks: Sequence[int],
                     dst_spec: L.KVCacheSpec, dst_cache: jax.Array, dst_blocks: Sequence[int],
                     schedule: Schedule = "flowkv",
                     profile: Optional[TransportProfile] = None):
    """One-shot convenience: plan + execute + (optionally) price.

    Returns (updated_dst_cache, plan, latency_seconds_or_None).
    """
    engine = TransferEngine(src_spec, dst_spec)
    plan = engine.planner.plan(schedule, src_blocks, dst_blocks)
    if schedule == "blockwise":
        dst_cache = engine.execute_blockwise(src_blocks, dst_blocks, src_cache, dst_cache)
    else:
        dst_cache = engine.execute(plan, src_cache, dst_cache)
    latency = plan.latency(profile) if profile is not None else None
    return dst_cache, plan, latency


# ---------------------------------------------------------------------------
# TransferBackend protocol (see module docstring)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TransferJob:
    """One request's planned transfer: exact costs + backend bookkeeping."""

    request_id: int
    backend: str                        # registry key that produced the job
    schedule: str                       # "flowkv" | "blockwise" | "layerwise" | "state"
    num_calls: int
    num_bytes: int
    num_blocks: int = 0
    plan: Optional[TransferPlan] = None          # paged backends
    src_blocks: Tuple[int, ...] = ()
    dst_blocks: Tuple[int, ...] = ()


class TransferBackend:
    """Protocol base: plan / execute / price one request's state movement."""

    name: str = "abstract"

    def plan(self, req, src, dst) -> TransferJob:
        raise NotImplementedError

    def execute(self, job: TransferJob, src, dst) -> None:
        raise NotImplementedError

    def price(self, job: TransferJob, profile: TransportProfile) -> float:
        if job.plan is not None:
            return job.plan.latency(profile)
        return profile.latency(num_calls=job.num_calls, num_bytes=job.num_bytes)


def _plan_block_job(backend: str, schedule: Schedule, planner: TransferPlanner,
                    spec: L.KVCacheSpec, req, src_bm, register_dst,
                    dst_bm) -> TransferJob:
    """Shared paged planning: get src blocks, register dst blocks (rolled
    back if planning fails), and build the priced job."""
    n = spec.blocks_for_tokens(req.prompt_len)
    src_blocks = src_bm.get(req.request_id)[:n]
    dst_blocks = register_dst(req)[:n]
    try:
        plan = planner.plan(schedule, src_blocks, dst_blocks)
    except BaseException:
        dst_bm.free(req.request_id)      # don't strand the registration
        raise
    return TransferJob(
        request_id=req.request_id, backend=backend, schedule=schedule,
        num_calls=plan.num_calls, num_bytes=plan.total_bytes,
        num_blocks=plan.num_blocks, plan=plan,
        src_blocks=tuple(int(b) for b in src_blocks),
        dst_blocks=tuple(int(b) for b in dst_blocks))


class PagedBackend(TransferBackend):
    """Block-granular KV movement between two paged pools.

    ``src`` / ``dst`` ports must expose ``kv`` (a pool with ``spec`` /
    ``pool`` / ``bm``) and ``dst.register_transfer_in(req, num_tokens)``.
    """

    name = "paged"

    def __init__(self, schedule: Schedule = "flowkv"):
        self.schedule: Schedule = schedule

    def plan(self, req, src, dst) -> TransferJob:
        spec = src.kv.spec
        return _plan_block_job(
            self.name, self.schedule, TransferPlanner(spec), spec, req,
            src.kv.bm, lambda r: dst.register_transfer_in(r, r.prompt_len + 1),
            dst.kv.bm)

    def execute(self, job: TransferJob, src, dst) -> None:
        engine = TransferEngine(src.kv.spec, dst.kv.spec)
        if self.schedule == "blockwise":
            dst.kv.pool = engine.execute_blockwise(
                list(job.src_blocks), list(job.dst_blocks), src.kv.pool, dst.kv.pool)
        else:
            dst.kv.pool = engine.execute(job.plan, src.kv.pool, dst.kv.pool)


class StateBackend(TransferBackend):
    """Whole-pytree movement for the state families (ssm / hybrid / encdec).

    The cache ships as one logical segment per leaf; the destination still
    reserves block-manager budget so admission control / KV_u accounting
    stays uniform with the paged path.
    """

    name = "state"

    def plan(self, req, src, dst) -> TransferJob:
        state = src.states[req.request_id]
        leaves = jax.tree.leaves(state)
        nbytes = sum(int(x.size) * x.dtype.itemsize for x in leaves)
        dst.register_transfer_in(req, req.prompt_len + 1)
        return TransferJob(request_id=req.request_id, backend=self.name,
                           schedule="state", num_calls=len(leaves),
                           num_bytes=nbytes)

    def execute(self, job: TransferJob, src, dst) -> None:
        dst.import_state_by_id(job.request_id, src.export_state_by_id(job.request_id))


class SimulatedBackend(TransferBackend):
    """Exact planning + pricing with a no-op data plane (e.g. a modeled DCN
    hop). Ports are ``SimNode``-shaped: ``bm`` / ``kv_spec`` / ``planner``.
    """

    name = "sim"

    def __init__(self, schedule: Schedule = "flowkv"):
        self.schedule: Schedule = schedule

    def plan(self, req, src, dst) -> TransferJob:
        return _plan_block_job(
            self.name, self.schedule, src.planner, src.kv_spec, req,
            src.bm, lambda r: dst.bm.register(r.request_id, r.prompt_len + 1),
            dst.bm)

    def execute(self, job: TransferJob, src, dst) -> None:
        pass   # data plane is virtual in the simulator


# -- registry ----------------------------------------------------------------
_BACKENDS: Dict[str, Callable[..., TransferBackend]] = {}


def register_backend(name: str, factory: Callable[..., TransferBackend]) -> None:
    _BACKENDS[name] = factory


def get_backend(name: str, **kwargs) -> TransferBackend:
    try:
        factory = _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown transfer backend {name!r}; "
            f"registered: {sorted(_BACKENDS)}") from None
    return factory(**kwargs)


def available_backends() -> List[str]:
    return sorted(_BACKENDS)


def backend_for_engine(engine, schedule: Schedule = "flowkv") -> TransferBackend:
    """Pick the backend matching an engine port's cache transport."""
    if getattr(engine, "paged", False):
        return get_backend("paged", schedule=schedule)
    return get_backend("state")


register_backend("paged", PagedBackend)
register_backend("state", StateBackend)
register_backend("sim", SimulatedBackend)
