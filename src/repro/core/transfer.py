"""KV-cache transfer planning and execution.

Three transfer *schedules*, matching the paper's comparison set:

* ``layerwise`` (Splitwise-style baseline): one call per (layer, K/V, block)
  — ``2 * L * n`` calls. Overlappable with compute but call-bound.
* ``blockwise`` (vLLM-disagg-style): per-layer buffers are merged then sent
  — ``2 * L`` calls plus a per-byte merge cost.
* ``flowkv``: FlowKV layout + bidirectional segment alignment — one call per
  aligned run (ideally 1).

The planner produces an exact :class:`TransferPlan` (call count, bytes,
per-run descriptors). Execution is schedule-INDEPENDENT: every plan lowers to
a :class:`DescriptorTable` — int32 arrays of (src block, dst block, layer,
k/v) page descriptors — and the engine runs the whole table as ONE fused,
jit-compiled Pallas gather–scatter dispatch (``kernels/kv_gather/kv_transfer``)
with the destination pool donated. Schedules therefore differ only in how
many *transport calls* the cost model prices (``num_calls``), never in Python
loop structure; the dispatch count is 1 per non-empty plan by construction.

On real TPU hardware each descriptor row lowers to one page DMA inside the
single dispatch (same-pod ICI) or one DCN send; on this CPU container the
kernel runs in interpret mode as a faithful data-plane copy and the *latency*
is priced by ``core.costmodel``.

The TransferBackend protocol
----------------------------

Node-to-node request-state movement is dispatched through a small protocol so
runtimes never branch on *how* a model family stores its cache:

.. code-block:: python

    class TransferBackend:
        name: str
        def plan(self, req, src, dst) -> TransferJob: ...
        def execute(self, job, src, dst) -> None: ...
        def price(self, job, profile: TransportProfile) -> float: ...

``plan`` reserves destination capacity and returns a :class:`TransferJob`
(exact call count + byte count, plus any backend-specific payload);
``execute`` moves the data (a no-op for purely simulated backends); ``price``
converts the job into seconds under a :class:`TransportProfile`. ``src`` /
``dst`` are duck-typed *ports*: the real runtime passes
``repro.serving.engine.NodeEngine`` (which exposes ``kv``, ``states``,
``register_transfer_in`` …) and the simulator passes
``repro.sim.cluster_sim.SimNode`` (``bm`` / ``kv_spec`` / ``planner``).

Built-in backends, keyed in the module registry
(:func:`register_backend` / :func:`get_backend`):

* ``paged``  — :class:`PagedBackend`; block-granular plans for any of the
  three schedules above, executed against the paged pools.
* ``state``  — :class:`StateBackend`; whole-pytree movement for the
  ssm / hybrid / encdec families (one logical segment).
* ``sim``    — :class:`SimulatedBackend`; exact planning + pricing with a
  no-op data plane, for the discrete-event simulator (models e.g. a DCN hop
  without touching device memory). Its call AND dispatch counts come from the
  same descriptor tables the real executor runs.

Third-party backends (RDMA, object-store staging, …) plug in with
``register_backend("myname", MyBackend)`` and are selected per request via
:func:`backend_for_engine` or an explicit ``get_backend`` call.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Literal, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layout as L
from repro.core.alignment import AlignmentResult, align
from repro.core.costmodel import TransportProfile
from repro.core.segments import Segment, blocks_to_segments
from repro.kernels.kv_gather import kv_transfer

Schedule = Literal["layerwise", "blockwise", "flowkv"]


# ---------------------------------------------------------------------------
# Shard topology: kv-head sharding of a paged pool (tensor parallelism)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """How one pool's kv_heads axis is partitioned over ``tp`` shards.

    Contiguous head ranges: shard ``s`` owns global kv-heads
    ``[s*K/tp, (s+1)*K/tp)`` — the same partition ``spec_for``'s
    ``kv_heads -> model`` rule induces on a mesh, so the transfer plane and
    the compute plane agree on which shard holds which head by construction.
    """

    tp: int = 1
    num_kv_heads: int = 1

    def __post_init__(self):
        if self.tp < 1:
            raise ValueError(f"tp must be >= 1, got {self.tp}")
        if self.num_kv_heads % self.tp != 0:
            raise ValueError(
                f"kv_heads={self.num_kv_heads} not divisible by tp={self.tp}")

    @property
    def heads_per_shard(self) -> int:
        return self.num_kv_heads // self.tp

    def head_range(self, shard: int) -> Tuple[int, int]:
        """Global [lo, hi) kv-head range owned by ``shard``."""
        lo = shard * self.heads_per_shard
        return lo, lo + self.heads_per_shard


def shard_pairs(src: ShardSpec, dst: ShardSpec
                ) -> List[Tuple[int, int, int, int]]:
    """Overlapping ``(src_shard, dst_shard, head_lo, head_hi)`` pairs.

    A cross-degree transfer moves each kv-head from the source shard that
    holds it to the destination shard that wants it; only pairs whose head
    ranges INTERSECT exchange any bytes, and each such pair moves exactly
    its intersection — so for divisible degrees the pair count is
    ``max(src.tp, dst.tp)`` (``tp_src * tp_dst`` when either side is
    unsharded), and the per-pair byte counts sum exactly to the unsharded
    transfer's bytes.
    """
    if src.num_kv_heads != dst.num_kv_heads:
        raise ValueError(
            f"src/dst pools must cover the same kv-heads; "
            f"got {src.num_kv_heads} vs {dst.num_kv_heads}")
    out: List[Tuple[int, int, int, int]] = []
    for s in range(src.tp):
        s_lo, s_hi = src.head_range(s)
        for d in range(dst.tp):
            d_lo, d_hi = dst.head_range(d)
            lo, hi = max(s_lo, d_lo), min(s_hi, d_hi)
            if lo < hi:
                out.append((s, d, lo, hi))
    return out


def shard_slice_spec(spec: L.KVCacheSpec, shard: ShardSpec) -> L.KVCacheSpec:
    """The per-shard pool spec: same blocks/layers, only its head slice."""
    if spec.num_kv_heads != shard.num_kv_heads:
        raise ValueError(
            f"spec has {spec.num_kv_heads} kv-heads, shard topology expects "
            f"{shard.num_kv_heads}")
    return dataclasses.replace(spec, num_kv_heads=shard.heads_per_shard)


def fine_page_rows(coarse_pages: np.ndarray, block_size: int,
                   local_heads: int, head_lo: int, head_hi: int) -> np.ndarray:
    """Rows of a shard pool's fine ``(-1, head_dim)`` view covered by a
    head-range slice of the given coarse pages.

    ``coarse_pages`` are flat page ids under the shard's per-shard spec
    (``DescriptorTable.page_ids``); each coarse page is ``block_size *
    local_heads`` fine rows, laid out slot-major then head-minor, so the row
    for (page p, slot t, local head h) is ``(p*block_size + t)*local_heads
    + h``. Restricting h to ``[head_lo, head_hi)`` (LOCAL indices) selects
    exactly one shard-pair's head intersection — the payload one fused
    ``kv_transfer`` dispatch moves.
    """
    t = np.arange(block_size, dtype=np.int64)
    h = np.arange(head_lo, head_hi, dtype=np.int64)
    rows = (coarse_pages.astype(np.int64)[:, None, None] * block_size
            + t[None, :, None]) * local_heads + h[None, None, :]
    return rows.reshape(-1).astype(np.int32)


def default_interpret() -> bool:
    """Pallas interpret mode everywhere except real TPU backends, where the
    kernel compiles to Mosaic (mirrors the donation check in _get_executor)."""
    return jax.default_backend() != "tpu"


@dataclasses.dataclass(frozen=True)
class TransferOp:
    """One contiguous-range transfer call (pricing/bookkeeping granularity)."""

    src: Segment              # block-id range on the sender
    dst: Segment              # block-id range on the receiver
    layer: Optional[int]      # None = all layers in one range (FlowKV layout)
    kv: Optional[int]         # None = both K and V; 0/1 for layerwise
    num_bytes: int


@dataclasses.dataclass(frozen=True)
class DescriptorTable:
    """Page-granular lowering of a plan: one row per (block, layer, k/v) page.

    The four row arrays are parallel int32 columns; ``src_block_seq`` /
    ``dst_block_seq`` keep the request's block-pair sequence (one entry per
    block, in plan order) so transport-call counts can be re-derived from the
    very table the executor runs.
    """

    src_block: np.ndarray     # (d,) int32 — sender block id per descriptor
    dst_block: np.ndarray     # (d,) int32
    layer: np.ndarray         # (d,) int32
    kv: np.ndarray            # (d,) int32
    src_block_seq: np.ndarray  # (n,) int32 — block-pair sequence, plan order
    dst_block_seq: np.ndarray  # (n,) int32
    num_layers: int

    def __len__(self) -> int:
        return int(self.src_block.shape[0])

    def page_ids(self, spec: L.KVCacheSpec, side: str) -> np.ndarray:
        """Flattened page ids for one side, honouring that side's layout.

        FLOWKV pools (B, L, 2, H) flatten to page ``block*L*2 + layer*2 + kv``;
        VLLM pools (L, 2, B, H) to ``(layer*2 + kv)*B + block``.
        """
        blocks = self.src_block if side == "src" else self.dst_block
        if spec.layout is L.KVLayout.FLOWKV:
            return (blocks * spec.num_layers + self.layer) * 2 + self.kv
        return (self.layer * 2 + self.kv) * np.int32(spec.num_blocks) + blocks

    def num_calls(self, schedule: Schedule) -> int:
        """Transport calls this table costs under a schedule (paper Table 3)."""
        n = int(self.src_block_seq.shape[0])
        if n == 0:
            return 0
        if schedule == "layerwise":
            return 2 * self.num_layers * n
        if schedule == "blockwise":
            return 2 * self.num_layers
        # flowkv: one call per bidirectionally-aligned run of block pairs —
        # delegated to align() so run detection has a single source of truth
        # shared with the planner's per-run ops/pricing.
        return align(self.src_block_seq.tolist(),
                     self.dst_block_seq.tolist()).num_calls


def _lower_descriptors(schedule: Schedule, num_layers: int,
                       src_blocks: Sequence[int],
                       dst_blocks: Sequence[int],
                       layer_lo: int = 0,
                       layer_hi: Optional[int] = None) -> DescriptorTable:
    """Expand a plan's block lists into its page-descriptor table.

    Row order is schedule-faithful (layerwise/flowkv are block-major, blockwise
    is (layer, k/v)-major) but execution is order-independent: destination
    pages within a plan are disjoint.

    ``layer_lo``/``layer_hi`` restrict the table to the layer window
    ``[lo, hi)`` — the lowering for a layer-window sub-plan (pipelined
    transfer/compute overlap). The default covers every layer, and the
    table's ``num_layers`` is always the count of layers it actually
    carries, so per-schedule call derivations stay window-faithful.
    """
    s = np.asarray(list(src_blocks), np.int32)
    d = np.asarray(list(dst_blocks), np.int32)
    n = s.shape[0]
    lo = layer_lo
    hi = num_layers if layer_hi is None else layer_hi
    Lr = hi - lo
    layers = np.arange(lo, hi, dtype=np.int32)
    lay_inner = np.repeat(layers, 2)                          # (2Lr,) per block
    kv_inner = np.tile(np.arange(2, dtype=np.int32), Lr)
    if schedule == "blockwise":
        src_block = np.tile(s, 2 * Lr)
        dst_block = np.tile(d, 2 * Lr)
        layer = np.repeat(layers, 2 * n)
        kv = np.tile(np.repeat(np.arange(2, dtype=np.int32), n), Lr)
    else:
        src_block = np.repeat(s, 2 * Lr)
        dst_block = np.repeat(d, 2 * Lr)
        layer = np.tile(lay_inner, n)
        kv = np.tile(kv_inner, n)
    return DescriptorTable(src_block=src_block, dst_block=dst_block,
                           layer=layer, kv=kv, src_block_seq=s,
                           dst_block_seq=d, num_layers=Lr)


@dataclasses.dataclass(frozen=True)
class TransferPlan:
    schedule: Schedule
    ops: List[TransferOp]
    total_bytes: int
    num_blocks: int
    num_layers: int
    src_blocks: Tuple[int, ...]
    dst_blocks: Tuple[int, ...]
    # Layer-window sub-plan bounds (transfer/compute overlap): the plan
    # covers layers [layer_lo, layer_hi). Defaults cover every layer — a
    # full plan is the layer_lo=0, layer_hi=None degenerate window, and
    # nothing downstream changes unless split_layer_windows() is used.
    layer_lo: int = 0
    layer_hi: Optional[int] = None
    # Shard topology of each side's pool (None = unsharded). When set, the
    # plan lowers to one fused dispatch per overlapping (src, dst) shard
    # pair; split_layer_windows carries the topology into every sub-plan
    # via dataclasses.replace, so layer-window overlap composes unchanged.
    src_shard: Optional[ShardSpec] = None
    dst_shard: Optional[ShardSpec] = None

    @functools.cached_property
    def _descriptors(self) -> DescriptorTable:
        return _lower_descriptors(self.schedule, self.num_layers,
                                  self.src_blocks, self.dst_blocks,
                                  self.layer_lo, self.layer_hi)

    def to_descriptors(self) -> DescriptorTable:
        """Lower to the page-descriptor table the fused executor consumes."""
        return self._descriptors

    @property
    def layer_span(self) -> Tuple[int, int]:
        """The [lo, hi) layer window this plan carries."""
        return (self.layer_lo,
                self.num_layers if self.layer_hi is None else self.layer_hi)

    @property
    def num_calls(self) -> int:
        """Transport calls priced by the cost model — derived from the SAME
        descriptor table the executor dispatches (not from ``ops``)."""
        return self.to_descriptors().num_calls(self.schedule)

    @property
    def sharded(self) -> bool:
        return self.src_shard is not None or self.dst_shard is not None

    def shard_pair_list(self) -> List[Tuple[int, int, int, int]]:
        """Overlapping shard pairs for this plan (one dispatch each); an
        unsharded side defaults to ShardSpec(tp=1) over the same heads."""
        heads = (self.src_shard or self.dst_shard).num_kv_heads
        return shard_pairs(self.src_shard or ShardSpec(1, heads),
                           self.dst_shard or ShardSpec(1, heads))

    @property
    def num_dispatches(self) -> int:
        """Kernel dispatches to execute this plan: 0 if empty; 1 unsharded;
        one per overlapping (src_shard, dst_shard) pair when sharded."""
        if not len(self.to_descriptors()):
            return 0
        if self.sharded:
            return len(self.shard_pair_list())
        return 1

    def latency(self, profile: TransportProfile) -> float:
        return profile.latency(self.num_calls, self.total_bytes)

    def split_layer_windows(self, window: int) -> List["TransferPlan"]:
        """Slice this plan into per-layer-window sub-plans for pipelined
        transfer/compute overlap (Mooncake-style layerwise KV streaming).

        Each sub-plan covers ``window`` consecutive layers of the SAME
        block pairs and executes as its own fused descriptor-table
        dispatch, so window w can be on the wire while layers >= w*window
        are still prefilling. Bytes partition exactly
        (``sum(sub.total_bytes) == total_bytes``); transport calls are
        counted per window, which is precisely the overlap's cost side —
        more, smaller calls. ``window <= 0`` or >= num_layers (or an empty
        plan) returns ``[self]`` unchanged.
        """
        L = self.num_layers
        if window <= 0 or window >= L or not self.src_blocks:
            return [self]
        out: List[TransferPlan] = []
        for lo in range(0, L, window):
            hi = min(lo + window, L)
            # cumulative-difference split so bytes sum exactly to the total
            bytes_w = (self.total_bytes * hi // L
                       - self.total_bytes * lo // L)
            if self.schedule == "flowkv":
                # flowkv ops are all-layer runs (layer=None): scale per run
                ops_w = [dataclasses.replace(
                    op, num_bytes=op.num_bytes * (hi - lo) // L)
                    for op in self.ops]
            else:
                ops_w = [op for op in self.ops
                         if op.layer is not None and lo <= op.layer < hi]
            out.append(dataclasses.replace(
                self, ops=ops_w, total_bytes=bytes_w,
                layer_lo=lo, layer_hi=hi))
        return out


class TransferPlanner:
    """Builds exact transfer plans for a request's block lists."""

    def __init__(self, spec: L.KVCacheSpec):
        self.spec = spec

    # -- plan builders ---------------------------------------------------------
    def plan(self, schedule: Schedule, src_blocks: Sequence[int],
             dst_blocks: Sequence[int]) -> TransferPlan:
        if schedule == "layerwise":
            return self.plan_layerwise(src_blocks, dst_blocks)
        if schedule == "blockwise":
            return self.plan_blockwise(src_blocks, dst_blocks)
        if schedule == "flowkv":
            return self.plan_flowkv(src_blocks, dst_blocks)
        raise ValueError(f"unknown schedule {schedule!r}")

    def _finish(self, schedule: Schedule, ops: List[TransferOp], total: int,
                num_blocks: int, src_blocks: Sequence[int],
                dst_blocks: Sequence[int]) -> TransferPlan:
        return TransferPlan(schedule, ops, total, num_blocks,
                            self.spec.num_layers,
                            tuple(int(b) for b in src_blocks),
                            tuple(int(b) for b in dst_blocks))

    def plan_layerwise(self, src_blocks: Sequence[int], dst_blocks: Sequence[int]) -> TransferPlan:
        """2 * L calls per block: the per-(layer, k/v, block) baseline."""
        spec = self.spec
        src_blocks, dst_blocks = list(src_blocks), list(dst_blocks)
        per_call = spec.payload * jnp.dtype(spec.dtype).itemsize
        ops: List[TransferOp] = []
        for s, d in zip(src_blocks, dst_blocks):
            for layer in range(spec.num_layers):
                for kv in (0, 1):
                    ops.append(TransferOp(Segment(int(s), 1), Segment(int(d), 1),
                                          layer=layer, kv=kv, num_bytes=per_call))
        total = per_call * len(ops)
        return self._finish("layerwise", ops, total, len(src_blocks),
                            src_blocks, dst_blocks)

    def plan_blockwise(self, src_blocks: Sequence[int], dst_blocks: Sequence[int]) -> TransferPlan:
        """2 * L calls total: per-layer buffers merged then sent (vLLM-disagg).

        The merge memcpy cost is priced by the ``vllm_merge`` transport
        profile, not counted as calls. An empty block list yields an empty
        plan (no calls, no bytes) — nothing was allocated, nothing moves.
        """
        spec = self.spec
        src_blocks, dst_blocks = list(src_blocks), list(dst_blocks)
        n = len(src_blocks)
        if n == 0:
            return self._finish("blockwise", [], 0, 0, [], [])
        layer_bytes = n * spec.payload * jnp.dtype(spec.dtype).itemsize
        ops: List[TransferOp] = []
        src_segs = blocks_to_segments(src_blocks)
        dst_segs = blocks_to_segments(dst_blocks)
        # One merged buffer per (layer, k/v); src/dst ranges recorded as the
        # first run for bookkeeping (the buffer itself is staged).
        for layer in range(spec.num_layers):
            for kv in (0, 1):
                ops.append(TransferOp(src_segs[0], dst_segs[0],
                                      layer=layer, kv=kv, num_bytes=layer_bytes))
        return self._finish("blockwise", ops, layer_bytes * len(ops), n,
                            src_blocks, dst_blocks)

    def plan_flowkv(self, src_blocks: Sequence[int], dst_blocks: Sequence[int]) -> TransferPlan:
        """Bidirectional segment alignment over the FlowKV layout."""
        if self.spec.layout is not L.KVLayout.FLOWKV:
            raise ValueError(
                "flowkv schedule requires the FLOWKV (B, L, 2, H) layout; "
                f"got {self.spec.layout}"
            )
        src_blocks, dst_blocks = list(src_blocks), list(dst_blocks)
        result: AlignmentResult = align(src_blocks, dst_blocks)
        ops = [
            TransferOp(run.src, run.dst, layer=None, kv=None,
                       num_bytes=run.length * self.spec.bytes_per_block)
            for run in result.runs
        ]
        total = sum(op.num_bytes for op in ops)
        return self._finish("flowkv", ops, total, result.num_blocks,
                            src_blocks, dst_blocks)


# ---------------------------------------------------------------------------
# Fused executor: one jitted Pallas dispatch per plan
# ---------------------------------------------------------------------------
_EXECUTOR_CACHE: Dict[Tuple, Callable] = {}

# Module-wide dispatch counter: every fused-kernel invocation anywhere in the
# process increments this exactly once (tests and benchmarks read it).
_TOTAL_DISPATCHES = 0


def total_dispatches() -> int:
    return _TOTAL_DISPATCHES


def reset_dispatch_counter() -> None:
    global _TOTAL_DISPATCHES
    _TOTAL_DISPATCHES = 0


def _get_executor(src_spec: L.KVCacheSpec, dst_spec: L.KVCacheSpec,
                  schedule: Schedule, interpret: bool) -> Callable:
    """One compiled executor per (src_spec, dst_spec, schedule).

    The executor body is schedule-independent by design — the cache key keeps
    schedule so per-schedule jit caches (and their donation bookkeeping) stay
    disjoint and countable. The destination pool is donated on accelerator
    backends; on CPU donation is skipped (XLA:CPU cannot honour it and would
    warn on every transfer).
    """
    key = (src_spec, dst_spec, schedule, interpret)
    fn = _EXECUTOR_CACHE.get(key)
    if fn is None:
        donate = (1,) if jax.default_backend() in ("tpu", "gpu") else ()

        @functools.partial(jax.jit, donate_argnums=donate)
        def fn(src_pool, dst_pool, src_pages, dst_pages):
            return kv_transfer(src_pool, dst_pool, src_pages, dst_pages,
                               interpret=interpret)

        _EXECUTOR_CACHE[key] = fn
    return fn


class TransferEngine:
    """Executes transfer plans against real device arrays.

    Every plan — any schedule, any src/dst layout pairing, any (possibly
    heterogeneous) pool sizes — executes as ONE fused descriptor-table
    dispatch: the plan lowers to flattened page ids on each side and the
    jitted Pallas ``kv_transfer`` kernel moves all pages in a single call,
    returning the updated destination pool (donated where the backend allows).
    ``num_dispatches`` counts the engine's kernel invocations.
    """

    def __init__(self, src_spec: L.KVCacheSpec, dst_spec: Optional[L.KVCacheSpec] = None,
                 *, interpret: Optional[bool] = None):
        self.src_spec = src_spec
        self.dst_spec = dst_spec or src_spec
        if self.src_spec.bytes_per_block != self.dst_spec.bytes_per_block:
            raise ValueError("src/dst pools must agree on per-block payload")
        if self.src_spec.num_layers != self.dst_spec.num_layers:
            raise ValueError("src/dst pools must agree on layer count")
        if self.src_spec.payload != self.dst_spec.payload:
            raise ValueError("src/dst pools must agree on page payload")
        self.interpret = default_interpret() if interpret is None else interpret
        self.planner = TransferPlanner(src_spec)
        self.num_dispatches = 0

    def execute(self, plan: TransferPlan, src_cache: jax.Array,
                dst_cache: jax.Array) -> jax.Array:
        """Apply a plan in one dispatch; returns the updated destination pool."""
        global _TOTAL_DISPATCHES
        table = plan.to_descriptors()
        if len(table) == 0:
            return dst_cache
        src_pages = jnp.asarray(table.page_ids(self.src_spec, "src"))
        dst_pages = jnp.asarray(table.page_ids(self.dst_spec, "dst"))
        executor = _get_executor(self.src_spec, self.dst_spec, plan.schedule,
                                 self.interpret)
        self.num_dispatches += 1
        _TOTAL_DISPATCHES += 1
        return executor(src_cache, dst_cache, src_pages, dst_pages)


class ShardedTransferEngine:
    """Executes plans between two kv-head-sharded pools, possibly of
    DIFFERENT tensor-parallel degrees (e.g. TP=4 prefill -> TP=2 decode).

    Each side's pool is a list of per-shard arrays (shard ``s`` holds its
    per-shard spec's FLOWKV pool — same blocks and layers, only its
    contiguous kv-head slice). A plan lowers to exactly ONE fused
    ``kv_transfer`` dispatch per overlapping (src_shard, dst_shard) pair:
    the pair's coarse descriptor pages expand to fine ``(-1, head_dim)``
    rows restricted to the pair's head intersection — the same flat-page
    trick the cross-layout engine uses, one granularity finer. head_dim is
    degree-invariant, so the fine payload matches on both sides for ANY
    (tp_src, tp_dst) combination; per-pair bytes sum exactly to the
    unsharded plan's bytes.
    """

    def __init__(self, src_spec: L.KVCacheSpec, dst_spec: L.KVCacheSpec,
                 src_shard: ShardSpec, dst_shard: ShardSpec,
                 *, interpret: Optional[bool] = None):
        if src_spec.head_dim != dst_spec.head_dim:
            raise ValueError("src/dst pools must agree on head_dim")
        if src_spec.block_size != dst_spec.block_size:
            raise ValueError("src/dst pools must agree on block_size")
        if src_spec.num_layers != dst_spec.num_layers:
            raise ValueError("src/dst pools must agree on layer count")
        if src_spec.num_kv_heads != dst_spec.num_kv_heads:
            raise ValueError("src/dst pools must cover the same kv-heads")
        self.src_spec = src_spec
        self.dst_spec = dst_spec
        self.src_shard = src_shard
        self.dst_shard = dst_shard
        self.interpret = default_interpret() if interpret is None else interpret
        self.planner = TransferPlanner(src_spec)
        self.num_dispatches = 0

    def plan(self, schedule: Schedule, src_blocks: Sequence[int],
             dst_blocks: Sequence[int]) -> TransferPlan:
        """A full-pool plan stamped with both sides' shard topology."""
        plan = self.planner.plan(schedule, src_blocks, dst_blocks)
        return dataclasses.replace(plan, src_shard=self.src_shard,
                                   dst_shard=self.dst_shard)

    def _pair_rows(self, table: DescriptorTable, pair: Tuple[int, int, int, int]
                   ) -> Tuple[np.ndarray, np.ndarray]:
        s, d, lo, hi = pair
        src_sspec = shard_slice_spec(self.src_spec, self.src_shard)
        dst_sspec = shard_slice_spec(self.dst_spec, self.dst_shard)
        src_rows = fine_page_rows(
            table.page_ids(src_sspec, "src"), self.src_spec.block_size,
            src_sspec.num_kv_heads, lo - self.src_shard.head_range(s)[0],
            hi - self.src_shard.head_range(s)[0])
        dst_rows = fine_page_rows(
            table.page_ids(dst_sspec, "dst"), self.dst_spec.block_size,
            dst_sspec.num_kv_heads, lo - self.dst_shard.head_range(d)[0],
            hi - self.dst_shard.head_range(d)[0])
        return src_rows, dst_rows

    def execute(self, plan: TransferPlan, src_pools: Sequence[jax.Array],
                dst_pools: Sequence[jax.Array]) -> List[jax.Array]:
        """Apply a plan pairwise; returns the updated per-shard dst pools."""
        global _TOTAL_DISPATCHES
        table = plan.to_descriptors()
        out = list(dst_pools)
        if len(table) == 0:
            return out
        hd = self.src_spec.head_dim
        src_sspec = shard_slice_spec(self.src_spec, self.src_shard)
        dst_sspec = shard_slice_spec(self.dst_spec, self.dst_shard)
        for pair in shard_pairs(self.src_shard, self.dst_shard):
            s, d, _, _ = pair
            src_rows, dst_rows = self._pair_rows(table, pair)
            src_flat = src_pools[s].reshape(-1, hd)
            dst_flat = out[d].reshape(-1, hd)
            executor = _get_executor(src_sspec, dst_sspec,
                                     plan.schedule, self.interpret)
            self.num_dispatches += 1
            _TOTAL_DISPATCHES += 1
            moved = executor(src_flat, dst_flat,
                             jnp.asarray(src_rows), jnp.asarray(dst_rows))
            out[d] = moved.reshape(out[d].shape)
        return out


# ---------------------------------------------------------------------------
# Payload integrity: per-plan checksums over the pages a plan moves
# ---------------------------------------------------------------------------
def payload_digest(pool: jax.Array, spec: L.KVCacheSpec,
                   page_ids: np.ndarray) -> bytes:
    """blake2b digest of the given flat pages of a pool.

    The pool is viewed as ``(num_pages, spec.payload)`` — the same flat-page
    view the fused executor gathers/scatters through — so a digest over a
    plan's page ids covers exactly the bytes that plan moves, regardless of
    layout (FLOWKV vs VLLM page orderings index the same view differently).
    """
    import hashlib
    flat = np.asarray(pool).reshape(-1, spec.payload)
    return hashlib.blake2b(np.ascontiguousarray(flat[page_ids]).tobytes(),
                           digest_size=16).digest()


def verify_transfer(plan: TransferPlan, src_spec: L.KVCacheSpec,
                    src_pool: jax.Array, dst_spec: L.KVCacheSpec,
                    dst_pool: jax.Array) -> bool:
    """Post-dispatch integrity check: did the dst pages land bit-identical?

    Digests the plan's source pages and destination pages (each through its
    own layout's page ordering, which pairs row-for-row by construction) and
    compares. An empty plan trivially verifies. This is the receiver-side
    checksum a real transport would carry per message; here both pools are
    addressable so the check is exact, not probabilistic framing.
    """
    table = plan.to_descriptors()
    if len(table) == 0:
        return True
    src_digest = payload_digest(src_pool, src_spec,
                                table.page_ids(src_spec, "src"))
    dst_digest = payload_digest(dst_pool, dst_spec,
                                table.page_ids(dst_spec, "dst"))
    return src_digest == dst_digest


def verify_sharded_transfer(plan: TransferPlan, src_spec: L.KVCacheSpec,
                            src_pools: Sequence[jax.Array],
                            dst_spec: L.KVCacheSpec,
                            dst_pools: Sequence[jax.Array]) -> bool:
    """Shard-aware twin of :func:`verify_transfer`.

    Digests each overlapping (src_shard, dst_shard) pair's fine
    ``(-1, head_dim)`` rows — exactly the rows the per-pair dispatch moved —
    and compares src vs dst. The plan must carry shard topology (see
    ``TransferPlan.src_shard`` / ``dst_shard``); pools are per-shard lists.
    """
    import hashlib
    table = plan.to_descriptors()
    if len(table) == 0:
        return True
    if not plan.sharded:
        raise ValueError("plan carries no shard topology; use verify_transfer")
    heads = (plan.src_shard or plan.dst_shard).num_kv_heads
    src_shard = plan.src_shard or ShardSpec(1, heads)
    dst_shard = plan.dst_shard or ShardSpec(1, heads)
    hd = src_spec.head_dim

    def digest(pool, spec, shard, shard_idx, lo, hi, side):
        sspec = shard_slice_spec(spec, shard)
        rows = fine_page_rows(table.page_ids(sspec, side), spec.block_size,
                              sspec.num_kv_heads,
                              lo - shard.head_range(shard_idx)[0],
                              hi - shard.head_range(shard_idx)[0])
        flat = np.asarray(pool).reshape(-1, hd)
        return hashlib.blake2b(np.ascontiguousarray(flat[rows]).tobytes(),
                               digest_size=16).digest()

    for s, d, lo, hi in shard_pairs(src_shard, dst_shard):
        if (digest(src_pools[s], src_spec, src_shard, s, lo, hi, "src")
                != digest(dst_pools[d], dst_spec, dst_shard, d, lo, hi, "dst")):
            return False
    return True


def _pools_of(kv) -> List[jax.Array]:
    """Per-shard pool list of a paged cache port (tp=1 -> one-entry list)."""
    pools = getattr(kv, "pools", None)
    return list(pools) if pools is not None else [kv.pool]


def pool_transfer_engine(src_kv, dst_kv, *, interpret: Optional[bool] = None):
    """Build the transfer engine matching two pool ports' shard topology.

    Both-unsharded stays on the classic :class:`TransferEngine` (whole-payload
    flat pages, one dispatch per plan); any sharded side lowers through
    :class:`ShardedTransferEngine` (one dispatch per overlapping shard pair).
    Ports expose ``spec`` and, when sharded, ``tp`` / ``pools``
    (serving/kv_cache.ShardedKVCache).
    """
    s_tp = getattr(src_kv, "tp", 1)
    d_tp = getattr(dst_kv, "tp", 1)
    if s_tp == 1 and d_tp == 1:
        return TransferEngine(src_kv.spec, dst_kv.spec, interpret=interpret)
    return ShardedTransferEngine(
        src_kv.spec, dst_kv.spec,
        ShardSpec(s_tp, src_kv.spec.num_kv_heads),
        ShardSpec(d_tp, dst_kv.spec.num_kv_heads), interpret=interpret)


def land_sharded_plan(engine: "ShardedTransferEngine", plan: TransferPlan,
                      src_kv, dst_kv) -> None:
    """Execute a sharded plan between two cache ports, either of which may
    be unsharded (treated as a 1-shard pool holding every kv head)."""
    src_pools = _pools_of(src_kv)
    if hasattr(dst_kv, "shards"):
        dst_kv.import_plan(engine, plan, src_pools)
    else:
        before = engine.num_dispatches
        new_pools = engine.execute(plan, src_pools, [dst_kv.pool])
        dst_kv.pool = new_pools[0]
        dst_kv.num_pool_dispatches += engine.num_dispatches - before


def verify_pool_transfer(plan: TransferPlan, src_kv, dst_kv) -> bool:
    """Integrity check dispatching on the plan's shard topology."""
    if plan is not None and plan.sharded:
        return verify_sharded_transfer(plan, src_kv.spec, _pools_of(src_kv),
                                       dst_kv.spec, _pools_of(dst_kv))
    return verify_transfer(plan, src_kv.spec, src_kv.pool,
                           dst_kv.spec, dst_kv.pool)


def transfer_request(src_spec: L.KVCacheSpec, src_cache: jax.Array, src_blocks: Sequence[int],
                     dst_spec: L.KVCacheSpec, dst_cache: jax.Array, dst_blocks: Sequence[int],
                     schedule: Schedule = "flowkv",
                     profile: Optional[TransportProfile] = None):
    """One-shot convenience: plan + execute + (optionally) price.

    Returns (updated_dst_cache, plan, latency_seconds_or_None).
    """
    engine = TransferEngine(src_spec, dst_spec)
    plan = engine.planner.plan(schedule, src_blocks, dst_blocks)
    dst_cache = engine.execute(plan, src_cache, dst_cache)
    latency = plan.latency(profile) if profile is not None else None
    return dst_cache, plan, latency


# ---------------------------------------------------------------------------
# TransferBackend protocol (see module docstring)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TransferJob:
    """One request's planned transfer: exact costs + backend bookkeeping."""

    request_id: int
    backend: str                        # registry key that produced the job
    schedule: str                       # "flowkv" | "blockwise" | "layerwise" | "state"
    num_calls: int
    num_bytes: int
    num_blocks: int = 0
    num_dispatches: int = 0             # fused kernel dispatches (paged: 0/1)
    plan: Optional[TransferPlan] = None          # paged backends
    src_blocks: Tuple[int, ...] = ()
    dst_blocks: Tuple[int, ...] = ()


class TransferBackend:
    """Protocol base: plan / execute / price one request's state movement."""

    name: str = "abstract"

    def plan(self, req, src, dst) -> TransferJob:
        raise NotImplementedError

    def execute(self, job: TransferJob, src, dst) -> None:
        raise NotImplementedError

    def price(self, job: TransferJob, profile: TransportProfile) -> float:
        if job.plan is not None:
            return job.plan.latency(profile)
        return profile.latency(num_calls=job.num_calls, num_bytes=job.num_bytes)


def _plan_block_job(backend: str, schedule: Schedule, planner: TransferPlanner,
                    spec: L.KVCacheSpec, req, src_bm, register_dst,
                    dst_bm) -> TransferJob:
    """Shared paged planning: get src blocks, register dst blocks (rolled
    back if planning fails), and build the priced job."""
    n = spec.blocks_for_tokens(req.prompt_len)
    src_blocks = src_bm.get(req.request_id)[:n]
    dst_blocks = register_dst(req)[:n]
    try:
        plan = planner.plan(schedule, src_blocks, dst_blocks)
    except BaseException:
        dst_bm.free(req.request_id)      # don't strand the registration
        raise
    return TransferJob(
        request_id=req.request_id, backend=backend, schedule=schedule,
        num_calls=plan.num_calls, num_bytes=plan.total_bytes,
        num_blocks=plan.num_blocks, num_dispatches=plan.num_dispatches,
        plan=plan,
        src_blocks=tuple(int(b) for b in src_blocks),
        dst_blocks=tuple(int(b) for b in dst_blocks))


class PagedBackend(TransferBackend):
    """Block-granular KV movement between two paged pools.

    ``src`` / ``dst`` ports must expose ``kv`` (a pool with ``spec`` /
    ``pool`` / ``bm`` / ``import_plan``) and
    ``dst.register_transfer_in(req, num_tokens)``.
    """

    name = "paged"

    def __init__(self, schedule: Schedule = "flowkv"):
        self.schedule: Schedule = schedule

    def plan(self, req, src, dst) -> TransferJob:
        spec = src.kv.spec
        job = _plan_block_job(
            self.name, self.schedule, TransferPlanner(spec), spec, req,
            src.kv.bm, lambda r: dst.register_transfer_in(r, r.prompt_len + 1),
            dst.kv.bm)
        s_tp = getattr(src.kv, "tp", 1)
        d_tp = getattr(dst.kv, "tp", 1)
        if s_tp > 1 or d_tp > 1:
            # stamp shard topology at PLAN time so verification / windowed
            # splits downstream see the pair structure; num_dispatches
            # becomes the pair count (one fused dispatch per overlap)
            job.plan = dataclasses.replace(
                job.plan,
                src_shard=ShardSpec(s_tp, src.kv.spec.num_kv_heads),
                dst_shard=ShardSpec(d_tp, dst.kv.spec.num_kv_heads))
            job.num_dispatches = job.plan.num_dispatches
        return job

    def execute(self, job: TransferJob, src, dst) -> None:
        if job.plan is not None and job.plan.sharded:
            engine = ShardedTransferEngine(
                src.kv.spec, dst.kv.spec,
                job.plan.src_shard or ShardSpec(1, src.kv.spec.num_kv_heads),
                job.plan.dst_shard or ShardSpec(1, dst.kv.spec.num_kv_heads))
            land_sharded_plan(engine, job.plan, src.kv, dst.kv)
        else:
            engine = TransferEngine(src.kv.spec, dst.kv.spec)
            dst.kv.import_plan(engine, job.plan, src.kv.pool)
        job.num_dispatches = engine.num_dispatches


class StateBackend(TransferBackend):
    """Whole-pytree movement for the state families (ssm / hybrid / encdec).

    The cache ships as one logical segment per leaf; the destination still
    reserves block-manager budget so admission control / KV_u accounting
    stays uniform with the paged path.
    """

    name = "state"

    def plan(self, req, src, dst) -> TransferJob:
        state = src.states[req.request_id]
        leaves = jax.tree.leaves(state)
        nbytes = sum(int(x.size) * x.dtype.itemsize for x in leaves)
        dst.register_transfer_in(req, req.prompt_len + 1)
        return TransferJob(request_id=req.request_id, backend=self.name,
                           schedule="state", num_calls=len(leaves),
                           num_bytes=nbytes, num_dispatches=1)

    def execute(self, job: TransferJob, src, dst) -> None:
        dst.import_state_by_id(job.request_id, src.export_state_by_id(job.request_id))


class SimulatedBackend(TransferBackend):
    """Exact planning + pricing with a no-op data plane (e.g. a modeled DCN
    hop). Ports are ``SimNode``-shaped: ``bm`` / ``kv_spec`` / ``planner``.
    Call and dispatch counts come from the same descriptor tables the real
    executor runs, so simulated tables match hardware tables exactly.
    """

    name = "sim"

    def __init__(self, schedule: Schedule = "flowkv"):
        self.schedule: Schedule = schedule

    def plan(self, req, src, dst) -> TransferJob:
        job = _plan_block_job(
            self.name, self.schedule, src.planner, src.kv_spec, req,
            src.bm, lambda r: dst.bm.register(r.request_id, r.prompt_len + 1),
            dst.bm)
        s_tp = getattr(src, "tp", 1)
        d_tp = getattr(dst, "tp", 1)
        if s_tp > 1 or d_tp > 1:
            # same plan-time stamping as PagedBackend: the priced dispatch
            # count becomes the shard-pair count, so simulated tables match
            # what the sharded executor would dispatch on hardware
            job.plan = dataclasses.replace(
                job.plan,
                src_shard=ShardSpec(s_tp, src.kv_spec.num_kv_heads),
                dst_shard=ShardSpec(d_tp, dst.kv_spec.num_kv_heads))
            job.num_dispatches = job.plan.num_dispatches
        return job

    def execute(self, job: TransferJob, src, dst) -> None:
        pass   # data plane is virtual in the simulator


# -- registry ----------------------------------------------------------------
_BACKENDS: Dict[str, Callable[..., TransferBackend]] = {}


def register_backend(name: str, factory: Callable[..., TransferBackend]) -> None:
    _BACKENDS[name] = factory


def get_backend(name: str, **kwargs) -> TransferBackend:
    try:
        factory = _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown transfer backend {name!r}; "
            f"registered: {sorted(_BACKENDS)}") from None
    return factory(**kwargs)


def available_backends() -> List[str]:
    return sorted(_BACKENDS)


def backend_for_engine(engine, schedule: Schedule = "flowkv") -> TransferBackend:
    """Pick the backend matching an engine port's cache transport."""
    if getattr(engine, "paged", False):
        return get_backend("paged", schedule=schedule)
    return get_backend("state")


register_backend("paged", PagedBackend)
register_backend("state", StateBackend)
register_backend("sim", SimulatedBackend)
