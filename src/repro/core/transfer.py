"""KV-cache transfer planning and execution.

Three transfer *schedules*, matching the paper's comparison set:

* ``layerwise`` (Splitwise-style baseline): one call per (layer, K/V, block)
  — ``2 * L * n`` calls. Overlappable with compute but call-bound.
* ``blockwise`` (vLLM-disagg-style): per-layer buffers are merged then sent
  — ``2 * L`` calls plus a per-byte merge cost.
* ``flowkv``: FlowKV layout + bidirectional segment alignment — one call per
  aligned run (ideally 1).

The planner produces an exact :class:`TransferPlan` (call count, bytes,
per-run descriptors). The engine executes a plan against real JAX arrays
(gather from the source pool, scatter into the destination pool) and the
cost model prices it for the benchmark tables.

On real TPU hardware each :class:`TransferOp` lowers to one DMA descriptor
(same-pod ICI) or one DCN send; on this CPU container execution is a faithful
data-plane copy and the *latency* is priced by ``core.costmodel``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Literal, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import layout as L
from repro.core.alignment import AlignmentResult, align
from repro.core.costmodel import TransportProfile
from repro.core.segments import Segment, blocks_to_segments

Schedule = Literal["layerwise", "blockwise", "flowkv"]


@dataclasses.dataclass(frozen=True)
class TransferOp:
    """One contiguous-range transfer call."""

    src: Segment              # block-id range on the sender
    dst: Segment              # block-id range on the receiver
    layer: Optional[int]      # None = all layers in one range (FlowKV layout)
    kv: Optional[int]         # None = both K and V; 0/1 for layerwise
    num_bytes: int


@dataclasses.dataclass(frozen=True)
class TransferPlan:
    schedule: Schedule
    ops: List[TransferOp]
    total_bytes: int
    num_blocks: int

    @property
    def num_calls(self) -> int:
        return len(self.ops)

    def latency(self, profile: TransportProfile) -> float:
        return profile.latency(self.num_calls, self.total_bytes)


class TransferPlanner:
    """Builds exact transfer plans for a request's block lists."""

    def __init__(self, spec: L.KVCacheSpec):
        self.spec = spec

    # -- plan builders ---------------------------------------------------------
    def plan(self, schedule: Schedule, src_blocks: Sequence[int],
             dst_blocks: Sequence[int]) -> TransferPlan:
        if schedule == "layerwise":
            return self.plan_layerwise(src_blocks, dst_blocks)
        if schedule == "blockwise":
            return self.plan_blockwise(src_blocks, dst_blocks)
        if schedule == "flowkv":
            return self.plan_flowkv(src_blocks, dst_blocks)
        raise ValueError(f"unknown schedule {schedule!r}")

    def plan_layerwise(self, src_blocks: Sequence[int], dst_blocks: Sequence[int]) -> TransferPlan:
        """2 * L calls per block: the per-(layer, k/v, block) baseline."""
        spec = self.spec
        per_call = spec.payload * jnp.dtype(spec.dtype).itemsize
        ops: List[TransferOp] = []
        for s, d in zip(src_blocks, dst_blocks):
            for layer in range(spec.num_layers):
                for kv in (0, 1):
                    ops.append(TransferOp(Segment(int(s), 1), Segment(int(d), 1),
                                          layer=layer, kv=kv, num_bytes=per_call))
        total = per_call * len(ops)
        return TransferPlan("layerwise", ops, total, len(list(src_blocks)))

    def plan_blockwise(self, src_blocks: Sequence[int], dst_blocks: Sequence[int]) -> TransferPlan:
        """2 * L calls total: per-layer buffers merged then sent (vLLM-disagg).

        The merge memcpy cost is priced by the ``vllm_merge`` transport
        profile, not counted as calls.
        """
        spec = self.spec
        n = len(list(src_blocks))
        layer_bytes = n * spec.payload * jnp.dtype(spec.dtype).itemsize
        ops: List[TransferOp] = []
        src_segs = blocks_to_segments(list(src_blocks))
        dst_segs = blocks_to_segments(list(dst_blocks))
        # One merged buffer per (layer, k/v); src/dst ranges recorded as the
        # covering span for bookkeeping (the buffer itself is staged).
        for layer in range(spec.num_layers):
            for kv in (0, 1):
                ops.append(TransferOp(src_segs[0] if src_segs else Segment(0, 1),
                                      dst_segs[0] if dst_segs else Segment(0, 1),
                                      layer=layer, kv=kv, num_bytes=layer_bytes))
        return TransferPlan("blockwise", ops, layer_bytes * len(ops), n)

    def plan_flowkv(self, src_blocks: Sequence[int], dst_blocks: Sequence[int]) -> TransferPlan:
        """Bidirectional segment alignment over the FlowKV layout."""
        if self.spec.layout is not L.KVLayout.FLOWKV:
            raise ValueError(
                "flowkv schedule requires the FLOWKV (B, L, 2, H) layout; "
                f"got {self.spec.layout}"
            )
        result: AlignmentResult = align(list(src_blocks), list(dst_blocks))
        ops = [
            TransferOp(run.src, run.dst, layer=None, kv=None,
                       num_bytes=run.length * self.spec.bytes_per_block)
            for run in result.runs
        ]
        total = sum(op.num_bytes for op in ops)
        return TransferPlan("flowkv", ops, total, result.num_blocks)


class TransferEngine:
    """Executes transfer plans against real device arrays.

    ``execute`` is layout-aware and schedule-faithful: FlowKV plans move whole
    block ranges; layerwise plans move per-(layer, kv) pages. The destination
    pool may use a different block placement (and on heterogeneous clusters a
    different total block count) — only the request's blocks move.
    """

    def __init__(self, src_spec: L.KVCacheSpec, dst_spec: Optional[L.KVCacheSpec] = None):
        self.src_spec = src_spec
        self.dst_spec = dst_spec or src_spec
        if self.src_spec.bytes_per_block != self.dst_spec.bytes_per_block:
            raise ValueError("src/dst pools must agree on per-block payload")
        self.planner = TransferPlanner(src_spec)

    def execute(self, plan: TransferPlan, src_cache: jax.Array,
                dst_cache: jax.Array) -> jax.Array:
        """Apply a plan: returns the updated destination pool."""
        for op in plan.ops:
            dst_cache = self._execute_op(op, plan.schedule, src_cache, dst_cache)
        return dst_cache

    def _execute_op(self, op: TransferOp, schedule: Schedule,
                    src_cache: jax.Array, dst_cache: jax.Array) -> jax.Array:
        src_ids = list(op.src.blocks())
        dst_ids = list(op.dst.blocks())
        if schedule == "flowkv":
            payload = L.gather_blocks(src_cache, self.src_spec, src_ids)
            return L.scatter_blocks(dst_cache, self.dst_spec, dst_ids, payload)
        # layerwise / blockwise: per-(layer, kv) page moves
        assert op.layer is not None and op.kv is not None
        for s, d in zip(src_ids, dst_ids):
            if self.src_spec.layout is L.KVLayout.FLOWKV:
                page = src_cache[s, op.layer, op.kv]
            else:
                page = src_cache[op.layer, op.kv, s]
            if self.dst_spec.layout is L.KVLayout.FLOWKV:
                dst_cache = dst_cache.at[d, op.layer, op.kv].set(page.astype(dst_cache.dtype))
            else:
                dst_cache = dst_cache.at[op.layer, op.kv, d].set(page.astype(dst_cache.dtype))
        return dst_cache

    # Blockwise plans replicate full-list moves per (layer, kv); execute them
    # faithfully by moving every block of the request for that layer slice.
    def execute_blockwise(self, src_blocks: Sequence[int], dst_blocks: Sequence[int],
                          src_cache: jax.Array, dst_cache: jax.Array) -> jax.Array:
        for layer in range(self.src_spec.num_layers):
            for kv in (0, 1):
                for s, d in zip(src_blocks, dst_blocks):
                    if self.src_spec.layout is L.KVLayout.FLOWKV:
                        page = src_cache[s, layer, kv]
                    else:
                        page = src_cache[layer, kv, s]
                    if self.dst_spec.layout is L.KVLayout.FLOWKV:
                        dst_cache = dst_cache.at[d, layer, kv].set(page.astype(dst_cache.dtype))
                    else:
                        dst_cache = dst_cache.at[layer, kv, d].set(page.astype(dst_cache.dtype))
        return dst_cache


def transfer_request(src_spec: L.KVCacheSpec, src_cache: jax.Array, src_blocks: Sequence[int],
                     dst_spec: L.KVCacheSpec, dst_cache: jax.Array, dst_blocks: Sequence[int],
                     schedule: Schedule = "flowkv",
                     profile: Optional[TransportProfile] = None):
    """One-shot convenience: plan + execute + (optionally) price.

    Returns (updated_dst_cache, plan, latency_seconds_or_None).
    """
    engine = TransferEngine(src_spec, dst_spec)
    plan = engine.planner.plan(schedule, src_blocks, dst_blocks)
    if schedule == "blockwise":
        dst_cache = engine.execute_blockwise(src_blocks, dst_blocks, src_cache, dst_cache)
    else:
        dst_cache = engine.execute(plan, src_cache, dst_cache)
    latency = plan.latency(profile) if profile is not None else None
    return dst_cache, plan, latency
