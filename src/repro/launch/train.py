"""Training driver: any registered arch (smoke-sized on CPU), AdamW with
fp32 master weights, crash-safe checkpointing + resume-from-latest, optional
int8 gradient compression (multi-pod DCN path).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --steps 50 \\
        --ckpt-dir /tmp/ckpt --ckpt-every 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full config (TPU-scale) instead of smoke")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.distributed import steps as ST
    from repro.launch.mesh import make_local_mesh
    from repro.models.api import get_model
    from repro.serving.checkpoint import (latest_checkpoint, load_train_state,
                                          save_train_state)
    from repro.training import optimizer as OPT

    cfg = get_config(args.arch) if args.full_config else get_smoke_config(args.arch)
    model = get_model(cfg)
    mesh = make_local_mesh(data=1, model=1)
    params = model.init(jax.random.PRNGKey(args.seed))
    state = OPT.init_state(params)
    start_step = 0
    if args.resume and args.ckpt_dir:
        path = latest_checkpoint(args.ckpt_dir)
        if path:
            template = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            state = load_train_state(template, path)
            start_step = int(state["step"])
            print(f"resumed from {path} at step {start_step}")

    train_step, _ = ST.make_train_step(
        model, mesh, jax.eval_shape(lambda: params),
        opt_cfg=OPT.AdamWConfig(lr=args.lr, warmup_steps=10))
    step_fn = jax.jit(train_step, donate_argnums=(0,))

    rng = np.random.RandomState(args.seed)

    def batch_at(i):
        toks = rng.randint(0, cfg.vocab_size, (args.batch, args.seq))
        b = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
        if cfg.family == "encdec":
            b["frames"] = jnp.asarray(rng.randn(args.batch, args.seq, cfg.d_model),
                                      jnp.float32)
        return b

    t0 = time.time()
    for i in range(start_step, start_step + args.steps):
        state, metrics = step_fn(state, batch_at(i))
        if i % 5 == 0 or i == start_step + args.steps - 1:
            print(f"step {i:5d}  loss={float(metrics['loss']):.4f}  "
                  f"gnorm={float(metrics['grad_norm']):.3f}  "
                  f"{(time.time()-t0):.1f}s")
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            p = save_train_state(state, i + 1, args.ckpt_dir)
            print(f"checkpointed -> {p}")
    if args.ckpt_dir:
        save_train_state(state, start_step + args.steps, args.ckpt_dir)


if __name__ == "__main__":
    main()
