import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST run before any jax import: jax locks device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x input-shape) cell on the
production meshes and record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch minitron-8b --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all          # every cell, both meshes (subprocess per cell)
    PYTHONPATH=src python -m repro.launch.dryrun --aggregate    # print the table from cached JSON

Each cell writes ``results/dryrun/<arch>__<shape>__<mesh>.json`` with:
bytes-per-device, HLO FLOPs, per-kind collective bytes, roofline terms, and
the compile wall time. Failures are recorded with the exception text —
a failed cell is a bug in the sharding config, not an acceptable outcome.
"""
import argparse
import json
import pathlib
import subprocess
import sys
import time

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

MESHES = ("single", "multi")


def _parse_opts(opt: str) -> dict:
    """'moe_dispatch=gshard,num_heads=64' -> typed dict of config overrides."""
    out = {}
    if not opt:
        return out
    for kv in opt.split(","):
        k, v = kv.split("=")
        if v in ("true", "false"):
            out[k] = v == "true"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                try:
                    out[k] = float(v)
                except ValueError:
                    out[k] = v
    return out


def _lower_cell(arch: str, shape_name: str, mesh_kind: str, extra_tag: str = "",
                opts: str = ""):
    import dataclasses

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import SHAPES, get_config, shape_applicable
    from repro.distributed import sharding as SH
    from repro.distributed import steps as ST
    from repro.launch import hlo_analysis as HA
    from repro.launch.mesh import make_production_mesh
    from repro.models.api import get_model, input_specs

    cfg = get_config(arch)
    if opts:
        cfg = dataclasses.replace(cfg, **_parse_opts(opts))
    kind, seq, batch = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "why": why}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    model = get_model(cfg)
    t0 = time.time()

    def ns(spec):
        return NamedSharding(mesh, spec)

    with mesh:
        if kind == "train":
            state = ST.abstract_train_state(model)
            train_step, state_spec = ST.make_train_step(model, mesh, state["params"])
            batch_specs, batch_axes = input_specs(cfg, "train", seq, batch)
            b_spec = SH.tree_specs(batch_specs, batch_axes, mesh)
            fn = jax.jit(
                train_step,
                in_shardings=(jax.tree.map(ns, state_spec), jax.tree.map(ns, b_spec)),
                out_shardings=(jax.tree.map(ns, state_spec), None),
                donate_argnums=(0,),
            )
            lowered = fn.lower(state, batch_specs)
            tokens = batch * seq
            model_flops = 6.0 * cfg.active_params() * tokens / n_chips
        elif kind == "prefill":
            params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
            p_spec = SH.tree_specs(params, model.param_axes(), mesh)
            batch_specs, batch_axes = input_specs(cfg, "prefill", seq, batch)
            b_spec = SH.tree_specs(batch_specs, batch_axes, mesh)
            step = ST.make_prefill_step(model, mesh)
            fn = jax.jit(step, in_shardings=(jax.tree.map(ns, p_spec),
                                             jax.tree.map(ns, b_spec)))
            lowered = fn.lower(params, batch_specs)
            model_flops = 2.0 * cfg.active_params() * batch * seq / n_chips
        else:  # decode
            params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
            p_spec = SH.tree_specs(params, model.param_axes(), mesh)
            specs, axes = input_specs(cfg, "decode", seq, batch)
            tok_spec = SH.tree_specs(specs["token"], axes["token"], mesh)
            cache_spec = SH.tree_specs(specs["cache"], axes["cache"], mesh)
            step = ST.make_decode_step(model, mesh)
            fn = jax.jit(step,
                         in_shardings=(jax.tree.map(ns, p_spec), ns(tok_spec),
                                       jax.tree.map(ns, cache_spec)),
                         out_shardings=(None, jax.tree.map(ns, cache_spec)),
                         donate_argnums=(2,))
            lowered = fn.lower(params, specs["token"], specs["cache"])
            model_flops = 2.0 * cfg.active_params() * batch / n_chips

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        cost = compiled.cost_analysis() or {}
        mem = HA.memory_analysis_dict(compiled)
        hlo = compiled.as_text()
        if os.environ.get("DRYRUN_DUMP_HLO"):
            dump = RESULTS_DIR.parent / "hlo" / f"{arch}__{shape_name}__{mesh_kind}{extra_tag}.hlo"
            dump.parent.mkdir(parents=True, exist_ok=True)
            dump.write_text(hlo)
        # XLA's HloCostAnalysis counts while bodies once; re-derive FLOPs,
        # bytes AND collective traffic with trip-count multiplication
        # (see launch/hlo_flops.py). Collectives are priced per traversed
        # fabric: intra-pod groups at ICI bw, pod-crossing groups at DCN bw.
        from repro.launch.hlo_flops import analyze_hlo
        parsed = analyze_hlo(hlo, pod_size=256)
        cost_fixed = {"flops": parsed.flops, "bytes accessed": parsed.bytes}
        coll = {**{k: int(v) for k, v in parsed.collectives.items()},
                "total": int(parsed.collective_total),
                "dcn_total": int(parsed.dcn_total),
                "ici_total": int(parsed.ici_total)}
        roof = HA.roofline_from(cost_fixed, coll, model_flops=model_flops,
                                link_bw=HA.ICI_BW)
        # re-price: ICI share at ICI bw + DCN share at DCN bw
        roof.collective_s = parsed.ici_total / HA.ICI_BW + parsed.dcn_total / HA.DCN_BW
        terms = {"compute": roof.compute_s, "memory": roof.memory_s,
                 "collective": roof.collective_s}
        roof.bottleneck = max(terms, key=terms.get)

        # bytes per device of the resident state (params or train state or cache)
        if kind == "train":
            resident = SH.bytes_per_device(state, state_spec, mesh)
        elif kind == "prefill":
            resident = SH.bytes_per_device(params, p_spec, mesh)
        else:
            resident = (SH.bytes_per_device(params, p_spec, mesh)
                        + SH.bytes_per_device(specs["cache"], cache_spec, mesh))

    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "status": "ok",
        "kind": kind, "seq": seq, "batch": batch, "chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "resident_bytes_per_device": resident,
        "cost_analysis_xla": {k: v for k, v in cost.items()
                              if k in ("flops", "bytes accessed", "transcendentals")},
        "hlo_parsed": {"flops": parsed.flops, "bytes": parsed.bytes,
                       "unknown_trip_counts": parsed.unknown_trip_counts},
        "memory_analysis": mem,
        "collective_bytes": coll,
        "roofline": roof.as_dict(),
        "tag": extra_tag,
    }


def _transfer_cell(arch: str):
    """Price the FlowKV P->D transfer through the descriptor-table plane.

    The old ring-shift (``ppermute`` over the "pod" axis) priced a whole-pool
    collective the executor never runs; the serving data plane moves KV via
    descriptor-table plans (``core/transfer.py``).  This cell sizes the pool
    from ``kv_transfer_specs`` (still the shard-layout source of truth) and
    reports the exact plan the executor would dispatch, including the
    per-shard-pair fused dispatch counts for mesh-parallel pools.
    """
    import jax

    from repro.configs import get_config
    from repro.core.costmodel import sharded_transfer_calls
    from repro.core.layout import KVCacheSpec
    from repro.core.transfer import TransferPlanner
    from repro.distributed import steps as ST
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=True)
    t0 = time.time()
    spec, pspec = ST.kv_transfer_specs(cfg, mesh, seq=32768, batch=128)
    pool_bytes = int(jax.numpy.dtype(cfg.dtype).itemsize
                     * __import__("numpy").prod(spec.shape))
    rec = {
        "arch": arch, "shape": "kv_transfer_32k", "mesh": "multi", "status": "ok",
        "kind": "transfer", "pool_bytes_global": pool_bytes,
    }
    n_attn = cfg.num_attention_layers()
    if n_attn > 0:
        kv_spec = KVCacheSpec(
            num_layers=n_attn,
            num_blocks=128 * -(-32768 // cfg.block_size),
            block_size=cfg.block_size,
            num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim,
            dtype=cfg.dtype,
        )
        blocks = list(range(kv_spec.num_blocks))
        plan = TransferPlanner(kv_spec).plan_flowkv(blocks, blocks)
        rec["plan"] = {
            "schedule": "flowkv",
            "num_calls": plan.num_calls,
            "total_bytes": plan.total_bytes,
            "num_blocks": plan.num_blocks,
            "shard_pair_dispatches": {
                f"tp{s}->tp{d}": sharded_transfer_calls(s, d)
                for s, d in ((1, 1), (2, 1), (4, 1), (4, 2))
                if cfg.num_kv_heads % max(s, d) == 0
            },
        }
    rec["compile_s"] = round(time.time() - t0, 2)
    return rec


def cell_path(arch: str, shape: str, mesh: str, tag: str = "") -> pathlib.Path:
    if tag:
        d = RESULTS_DIR.parent / "perf"
        d.mkdir(parents=True, exist_ok=True)
        return d / f"{arch}__{shape}__{mesh}__{tag}.json"
    return RESULTS_DIR / f"{arch}__{shape}__{mesh}.json"


def run_cell(arch: str, shape: str, mesh: str, tag: str = "", opts: str = "") -> dict:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    try:
        if shape == "kv_transfer_32k":
            rec = _transfer_cell(arch)
        else:
            rec = _lower_cell(arch, shape, mesh, extra_tag=tag, opts=opts)
            rec["opts"] = opts
    except Exception as e:  # noqa: BLE001 — recorded, cell marked failed
        rec = {"arch": arch, "shape": shape, "mesh": mesh, "tag": tag,
               "opts": opts, "status": "failed",
               "error": f"{type(e).__name__}: {e}"}
    cell_path(arch, shape, mesh, tag).write_text(json.dumps(rec, indent=1))
    return rec


def run_all(archs=None, force: bool = False):
    """Drive every cell in a fresh subprocess (isolates XLA state/memory).

    Cells are ordered smallest-arch-first so the bulk of the table fills in
    early even if the giant configs compile slowly.
    """
    from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config
    order = sorted(archs or ASSIGNED_ARCHS,
                   key=lambda a: get_config(a).num_params())
    cells = [(a, s, m) for a in order for s in SHAPES for m in MESHES]
    cells += [(a, "kv_transfer_32k", "multi") for a in order]
    for arch, shape, mesh in cells:
        path = cell_path(arch, shape, mesh)
        if path.exists() and not force:
            rec = json.loads(path.read_text())
            if rec.get("status") in ("ok", "skipped"):
                print(f"[cached] {arch} {shape} {mesh}: {rec['status']}")
                continue
        t0 = time.time()
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape, "--mesh", mesh],
            capture_output=True, text=True,
            env={**os.environ, "PYTHONPATH": "src"},
            cwd=str(pathlib.Path(__file__).resolve().parents[3]),
        )
        status = "?"
        if path.exists():
            status = json.loads(path.read_text()).get("status")
        print(f"[{time.time()-t0:7.1f}s] {arch} {shape} {mesh}: {status}"
              + ("" if proc.returncode == 0 else f" (rc={proc.returncode})"))
        if proc.returncode != 0 and not path.exists():
            path.write_text(json.dumps({
                "arch": arch, "shape": shape, "mesh": mesh, "status": "failed",
                "error": proc.stderr[-2000:]}, indent=1))


def aggregate() -> list:
    recs = []
    for p in sorted(RESULTS_DIR.glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--aggregate", action="store_true")
    ap.add_argument("--tag", default="", help="perf-run tag (writes results/perf/)")
    ap.add_argument("--opt", default="",
                    help="config overrides, e.g. moe_dispatch=gshard,attn_wedge=true")
    args = ap.parse_args()

    if args.aggregate:
        for r in aggregate():
            line = f"{r['arch']:26s} {r['shape']:16s} {r['mesh']:6s} {r['status']}"
            if r["status"] == "ok" and "roofline" in r:
                rf = r["roofline"]
                line += (f"  comp={rf['compute_s']:.4f}s mem={rf['memory_s']:.4f}s "
                         f"coll={rf['collective_s']:.4f}s -> {rf['bottleneck']}")
            print(line)
        return
    if args.all:
        run_all(archs=[args.arch] if args.arch else None, force=args.force)
        return
    assert args.arch and args.shape, "--arch and --shape required"
    rec = run_cell(args.arch, args.shape, args.mesh, tag=args.tag, opts=args.opt)
    print(json.dumps(rec, indent=1)[:4000])
    if rec["status"] == "failed":
        sys.exit(1)


if __name__ == "__main__":
    main()
