"""End-to-end serving driver (the paper is a serving system, so this is the
primary launcher).

Two modes:

* ``--engine real``  — CPU-scale: real JAX compute through the PD cluster
  (smoke-sized model) via the :class:`repro.serving.api.FlowKVClient`
  streaming facade, token-correct generation, real FlowKV page transfers.
* ``--engine sim``   — cluster-scale: discrete-event simulation driving the
  same control plane with calibrated hardware costs (A100/L20/H20/TPUv5e).

Examples:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --engine real --requests 8
    PYTHONPATH=src python -m repro.launch.serve --arch llama31-8b --engine sim \\
        --system flowkv --workload 10k --rps 1.0
"""
from __future__ import annotations

import argparse
import json

import numpy as np


def run_real(args) -> dict:
    import jax

    from repro.configs import get_smoke_config
    from repro.models.api import get_model
    from repro.serving.api import FlowKVClient
    from repro.serving.request import SamplingParams

    cfg = get_smoke_config(args.arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    client = FlowKVClient(cfg, params, num_prefill=args.num_prefill,
                          num_decode=args.num_decode, num_blocks=args.blocks,
                          transfer_schedule=args.schedule,
                          role_flip=args.role_flip)
    rng = np.random.RandomState(args.seed)
    handles = [client.submit(rng.randint(0, cfg.vocab_size,
                                         size=rng.randint(8, 48)).tolist(),
                             SamplingParams(max_new_tokens=args.max_new_tokens))
               for _ in range(args.requests)]
    client.drain(max_cycles=500)
    stats = client.stats()
    stats["outputs"] = {h.request_id: h.request.output_tokens
                        for h in handles[:4]}
    stats["timing"] = {h.request_id: h.stats() for h in handles[:4]}
    return stats


def run_sim(args) -> dict:
    from repro.configs import get_config
    from repro.sim.cluster_sim import ClusterSim
    from repro.sim.hardware import get_hardware
    from repro.sim.workload import LONGBENCH, SIMULATED, generate

    cfg = get_config(args.arch)
    wl = {**SIMULATED, **LONGBENCH}[args.workload]
    sim = ClusterSim(cfg, args.system, num_prefill=args.num_prefill,
                     num_decode=args.num_decode,
                     hw_prefill=get_hardware(args.hw_prefill),
                     hw_decode=get_hardware(args.hw_decode),
                     same_host=args.same_host, tp=args.tp)
    return sim.run(generate(wl, rps=args.rps, seed=args.seed), t_max=100_000)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--engine", choices=("real", "sim"), default="real")
    ap.add_argument("--system", default="flowkv")
    ap.add_argument("--schedule", default="flowkv",
                    choices=("flowkv", "layerwise", "blockwise"))
    ap.add_argument("--workload", default="1k")
    ap.add_argument("--rps", type=float, default=1.0)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--num-prefill", type=int, default=1)
    ap.add_argument("--num-decode", type=int, default=1)
    ap.add_argument("--blocks", type=int, default=256)
    ap.add_argument("--role-flip", action="store_true",
                    help="let the load-aware scheduler reassign P<->D roles "
                         "under imbalance (real engine)")
    ap.add_argument("--hw-prefill", default="a100")
    ap.add_argument("--hw-decode", default="a100")
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--same-host", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    stats = run_real(args) if args.engine == "real" else run_sim(args)
    print(json.dumps(stats, indent=1, default=str))


if __name__ == "__main__":
    main()
