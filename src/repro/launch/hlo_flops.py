"""HLO-text FLOP/byte counter with while-loop trip-count multiplication.

XLA's ``HloCostAnalysis`` (what ``compiled.cost_analysis()`` reports) counts
the body of a ``while`` op ONCE when it cannot derive the trip count — which
is systematically the case for the nested scans our models compile to
(layers-scan x flash q-chunk scan x kv-chunk scan). That undercounts prefill
FLOPs by >30x and would make the roofline report meaningless.

This module re-derives the two roofline inputs from the optimized HLO text:

* **flops** — 2 * numel(result) * contracted-size for every ``dot``
  (plus convolutions), accumulated over the call graph with ``while`` bodies
  multiplied by their parsed trip counts.
* **bytes** — operand + result bytes of top-level ops per computation
  (fusion internals excluded, matching HloCostAnalysis's optimistic model),
  same multipliers.

Trip counts are parsed from each while's condition computation: JAX scans
lower to ``compare(iv, bound), direction=LT`` with a scalar constant bound.
When no bound is found the multiplier defaults to 1 and the while is
reported in ``unknown_trip_counts`` so the caller can flag it.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0, "tuple": 0,
}

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_TRIP_COUNT = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE_TOKEN = re.compile(r"^\(?\s*(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"\)?\s*([a-z][a-z0-9\-]*)\(")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_COND_CONST = re.compile(r"constant\((\d+)\)")
_DIMS_ATTR = re.compile(r"(\w+_contracting_dims)=\{([\d,]*)\}")
_BATCH_ATTR = re.compile(r"(\w+_batch_dims)=\{([\d,]*)\}")


def _parse_shape(text: str) -> Tuple[Optional[str], List[int]]:
    m = _SHAPE_TOKEN.match(text.strip())
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


def _all_shapes(text: str) -> List[Tuple[str, List[int]]]:
    """All dtype[dims] tokens in a string (for tuple shapes)."""
    out = []
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", text):
        if m.group(1) in _DTYPE_BYTES:
            dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
            out.append((m.group(1), dims))
    return out


def _bytes_of(text: str) -> int:
    return sum(_DTYPE_BYTES[t] * math.prod(d) for t, d in _all_shapes(text))


@dataclasses.dataclass
class _Op:
    name: str
    kind: str
    result_text: str
    rest: str        # everything after '=' (shape + op + operands + attrs)
    operands: List[str]


@dataclasses.dataclass
class _Computation:
    name: str
    ops: List[_Op]
    shapes: Dict[str, Tuple[str, List[int]]]


def _split_computations(hlo: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            if line.endswith("{") and "->" in line:
                m = _COMP_HEADER.match(line.strip())
                if m:
                    cur = _Computation(m.group(1), [], {})
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name, rest = dm.group(1), dm.group(2)
        dtype, dims = _parse_shape(rest)
        if dtype is not None:
            cur.shapes[name] = (dtype, dims)
        om = _OP_RE.search(rest)
        kind = om.group(1) if om else ""
        # operand names: %foo tokens inside the first (...) after op name
        operands = re.findall(r"%?([\w\.\-]+)", rest[om.end():].split(")")[0]) if om else []
        # result text = everything up to the op name (the shape part)
        result_text = rest[:om.start()] if om else rest
        cur.ops.append(_Op(name, kind, result_text, rest, operands))
    return comps


def _dot_flops(op: _Op, comp: _Computation) -> float:
    # result numel
    res_shapes = _all_shapes(op.result_text)
    if not res_shapes:
        return 0.0
    numel = math.prod(res_shapes[0][1]) if res_shapes[0][1] else 1
    # contracted size from lhs shape + lhs_contracting_dims
    lhs_name = op.operands[0] if op.operands else None
    lhs = comp.shapes.get(lhs_name)
    csize = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    if lhs and m and m.group(1):
        for d in m.group(1).split(","):
            idx = int(d)
            if idx < len(lhs[1]):
                csize *= lhs[1][idx]
    return 2.0 * numel * csize


def _conv_flops(op: _Op, comp: _Computation) -> float:
    res = _all_shapes(op.result_text)
    if not res:
        return 0.0
    numel = math.prod(res[0][1]) if res[0][1] else 1
    rhs = comp.shapes.get(op.operands[1]) if len(op.operands) > 1 else None
    k = math.prod(rhs[1]) if rhs and rhs[1] else 1
    out_feat = res[0][1][-1] if res[0][1] else 1
    return 2.0 * numel * max(1, k // max(1, out_feat))


_CALL_KINDS = ("fusion", "call", "custom-call", "reduce", "map", "scatter",
               "reduce-window", "select-and-scatter", "sort", "all-reduce",
               "reduce-scatter")


_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
# iota form: replica_groups=[num_groups,group_size]<=[d0,d1,...]T(perm)?
_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_PAIRS_RE = re.compile(r"source_target_pairs=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")


def crosses_pod(op_rest: str, pod_size: int) -> bool:
    """True if a collective's replica groups span a pod boundary.

    With the production meshes, devices [0, pod_size) are pod 0 — a group
    containing ids from different pod_size-blocks crosses DCN; otherwise the
    collective rides intra-pod ICI. Handles both explicit {{...},{...}} and
    iota [G,S]<=[dims]T(perm) group encodings.
    """
    m = _IOTA_RE.search(op_rest)
    if m:
        import numpy as _np
        ngroups, gsize = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        order = _np.arange(_np.prod(dims)).reshape(dims)
        if m.group(4):
            order = order.transpose([int(p) for p in m.group(4).split(",")])
        flat = order.reshape(ngroups, gsize)
        pods = flat // pod_size
        return bool((pods != pods[:, :1]).any())
    m = _GROUPS_RE.search(op_rest) or _PAIRS_RE.search(op_rest)
    if not m:
        return False
    for grp in re.findall(r"\{([^}]*)\}", m.group(1)):
        ids = [int(x) for x in grp.replace(" ", "").split(",") if x]
        if ids and len({i // pod_size for i in ids}) > 1:
            return True
    return False


@dataclasses.dataclass
class HloCounts:
    flops: float = 0.0
    bytes: float = 0.0
    unknown_trip_counts: int = 0
    collectives: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVE_KINDS})
    # subset of the above that crosses the pod boundary (rides DCN)
    collectives_dcn: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVE_KINDS})

    @property
    def collective_total(self) -> float:
        return sum(self.collectives.values())

    @property
    def dcn_total(self) -> float:
        return sum(self.collectives_dcn.values())

    @property
    def ici_total(self) -> float:
        return self.collective_total - self.dcn_total


def analyze_hlo(hlo: str, entry: Optional[str] = None,
                pod_size: int = 256) -> HloCounts:
    comps = _split_computations(hlo)
    # find entry computation
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
        entry = m.group(1) if m else next(iter(comps))
    memo: Dict[str, Tuple[float, float, int]] = {}

    def trip_count(cond_name: str) -> Optional[int]:
        cond = comps.get(cond_name)
        if cond is None:
            return None
        consts = []
        for op in cond.ops:
            if op.kind == "constant":
                mm = _COND_CONST.search(op.rest)
                if mm:
                    consts.append(int(mm.group(1)))
        has_compare = any(op.kind == "compare" for op in cond.ops)
        if has_compare and consts:
            return max(consts)
        return None

    def _zero():
        return {k: 0.0 for k in _COLLECTIVE_KINDS}

    def _fusion_operand_bytes(callee_name: str) -> Optional[Dict[int, float]]:
        """Per-parameter-index effective read bytes inside a fusion.

        A parameter consumed ONLY by slice-family ops reads just the sliced
        regions; anything else reads the full operand (None entry = full).
        """
        callee = comps.get(callee_name)
        if callee is None:
            return None
        param_idx: Dict[str, int] = {}
        for op in callee.ops:
            if op.kind == "parameter":
                m = re.search(r"parameter\((\d+)\)", op.rest)
                if m:
                    param_idx[op.name] = int(m.group(1))
        reads: Dict[int, float] = {}
        full: set = set()
        for op in callee.ops:
            for o in op.operands:
                if o not in param_idx:
                    continue
                idx = param_idx[o]
                if op.kind in ("dynamic-slice", "slice", "gather"):
                    reads[idx] = reads.get(idx, 0.0) + _bytes_of(op.result_text)
                elif op.kind == "dynamic-update-slice" and op.operands and op.operands[0] == o:
                    # in-place destination: reads ~update-sized region
                    upd = callee.shapes.get(op.operands[1]) if len(op.operands) > 1 else None
                    if upd:
                        reads[idx] = reads.get(idx, 0.0) + _DTYPE_BYTES.get(
                            upd[0], 0) * math.prod(upd[1] or [1])
                    else:
                        full.add(idx)
                elif op.kind == "get-tuple-element":
                    full.add(idx)   # conservatively full
                else:
                    full.add(idx)
        for idx in full:
            reads.pop(idx, None)
            reads[idx] = -1.0   # sentinel: full read
        return reads

    def _fusion_root_write_bytes(callee_name: str) -> Optional[float]:
        """If the fusion root is a dynamic-update-slice, only the update
        region is written (the rest aliases in place)."""
        callee = comps.get(callee_name)
        if callee is None or not callee.ops:
            return None
        root = callee.ops[-1]
        if root.kind == "dynamic-update-slice" and len(root.operands) > 1:
            upd = callee.shapes.get(root.operands[1])
            if upd:
                return float(_DTYPE_BYTES.get(upd[0], 0) * math.prod(upd[1] or [1]))
        return None

    def visit(name: str) -> Tuple[float, float, int, Dict[str, float]]:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None:
            return (0.0, 0.0, 0, _zero(), _zero())
        memo[name] = (0.0, 0.0, 0, _zero(), _zero())   # cycle guard
        flops = 0.0
        nbytes = 0.0
        unknown = 0
        coll = _zero()
        dcn = _zero()
        for op in comp.ops:
            kind_base = op.kind.replace("-start", "")
            if kind_base in _COLLECTIVE_KINDS:
                nb_c = _bytes_of(op.result_text)
                coll[kind_base] += nb_c
                if crosses_pod(op.rest, pod_size):
                    dcn[kind_base] += nb_c
            if op.kind == "dot":
                flops += _dot_flops(op, comp)
            elif op.kind == "convolution":
                flops += _conv_flops(op, comp)
            elif op.kind == "while":
                wm = _WHILE_RE.search(op.rest)
                if wm:
                    tm = _TRIP_COUNT.search(op.rest)
                    tc = int(tm.group(1)) if tm else trip_count(wm.group(1))
                    if tc is None:
                        tc = 1
                        unknown += 1
                    bf, bb, bu, bc, bd = visit(wm.group(2))
                    flops += tc * bf
                    nbytes += tc * bb
                    unknown += bu
                    for k in coll:
                        coll[k] += tc * bc[k]
                        dcn[k] += tc * bd[k]
                continue
            elif op.kind == "conditional":
                for callee in re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                         r"(?:true|false)_computation=%?([\w\.\-]+))", op.rest):
                    for c in ",".join(x for x in callee if x).split(","):
                        c = c.strip().lstrip("%")
                        if c:
                            bf, bb, bu, bc, bd = visit(c)
                            flops += bf; nbytes += bb; unknown += bu
                            for k in coll:
                                coll[k] += bc[k]
                                dcn[k] += bd[k]
                continue
            # callee flops for fusions etc.
            cm = _CALLS_RE.search(op.rest)
            if cm and op.kind in _CALL_KINDS:
                bf, _, bu, bc, bd = visit(cm.group(1))
                flops += bf
                unknown += bu
                for k in coll:
                    coll[k] += bc[k]
                    dcn[k] += bd[k]
            # bytes: operands + result of this top-level op
            if op.kind in ("parameter", "constant", "get-tuple-element",
                           "tuple", "bitcast"):
                continue
            if op.kind in ("dynamic-slice", "slice", "gather"):
                # reads only the sliced region, not the full operand
                nbytes += 2.0 * _bytes_of(op.result_text)
                continue
            if op.kind in ("dynamic-update-slice", "scatter"):
                # in-place update: read+write of the update region only
                upd = comp.shapes.get(op.operands[1]) if len(op.operands) > 1 else None
                if upd:
                    nbytes += 2.0 * _DTYPE_BYTES.get(upd[0], 0) * math.prod(upd[1] or [1])
                else:
                    nbytes += 2.0 * _bytes_of(op.result_text)
                continue
            if op.kind == "fusion" and cm:
                # fusion: writes = root (DUS-aware); reads = per-param
                # effective bytes (slice-only params read slices, not fulls)
                w = _fusion_root_write_bytes(cm.group(1))
                nbytes += w if w is not None else _bytes_of(op.result_text)
                reads = _fusion_operand_bytes(cm.group(1)) or {}
                for i, o in enumerate(op.operands):
                    sh = comp.shapes.get(o)
                    if not sh:
                        continue
                    full_b = _DTYPE_BYTES.get(sh[0], 0) * math.prod(sh[1] or [1])
                    eff = reads.get(i)
                    nbytes += full_b if (eff is None or eff < 0) else min(eff, full_b)
                continue
            nbytes += _bytes_of(op.result_text)
            for o in op.operands:
                sh = comp.shapes.get(o)
                if sh:
                    nbytes += _DTYPE_BYTES.get(sh[0], 0) * math.prod(sh[1] or [1])
        memo[name] = (flops, nbytes, unknown, coll, dcn)
        return memo[name]

    f, b, u, c, dc = visit(entry)
    return HloCounts(flops=f, bytes=b, unknown_trip_counts=u, collectives=c,
                     collectives_dcn=dc)
