"""Production mesh builders.

Kept as FUNCTIONS so importing this module never touches jax device state
(the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Single-pod axes: ("data", "model"). Multi-pod adds a leading "pod" axis —
    in training it is extra data parallelism over DCN; in FlowKV serving it
    is the P/D boundary (pod 0 = prefill cluster, pod 1 = decode cluster).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """CPU-scale mesh for tests/examples (requires devices to exist)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
