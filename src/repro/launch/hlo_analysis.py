"""Post-compile HLO analysis: collective bytes, roofline terms.

``cost_analysis()`` gives FLOPs and HBM traffic but not collective traffic,
so we parse the optimized HLO text and sum result-buffer sizes of every
communication op, bucketed by kind. Roofline terms then follow from the
hardware constants (TPU v5e targets):

    compute    = HLO_FLOPs / (chips * 197e12)
    memory     = HLO_bytes / (chips * 819e9)
    collective = coll_bytes / (chips * 50e9)      # per-link ICI

All quantities from cost_analysis / HLO text are *per partition* (SPMD
module is single-device), so the "/chips" division is already implicit —
we report per-chip seconds directly.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link
DCN_BW = 25e9              # bytes/s / host (pod-crossing collectives)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# e.g. "  %foo = bf16[16,2048,128]{2,1,0} all-gather(...)", possibly a tuple
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(\((?:[^()]|\([^()]*\))*\)|[\w\[\]{},: ]+?)\s*"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum per-partition result bytes of each collective kind."""
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        kind = m.group(2).replace("-start", "")
        out[kind] += _shape_bytes(m.group(1))
    out["total"] = sum(out[k] for k in COLLECTIVE_OPS)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-chip HLO flops
    hbm_bytes: float             # per-chip bytes accessed
    coll_bytes: float            # per-chip collective bytes
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: Optional[float] = None
    useful_ratio: Optional[float] = None

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline_from(cost: Dict, coll: Dict[str, int], model_flops: Optional[float] = None,
                  link_bw: float = ICI_BW) -> Roofline:
    flops = float(cost.get("flops", 0.0) or 0.0)
    # cost_analysis 'bytes accessed' is per-partition HBM traffic
    hbm = float(cost.get("bytes accessed", 0.0) or 0.0)
    cb = float(coll.get("total", 0))
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    collective_s = cb / link_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = None
    if model_flops:
        useful = model_flops / flops if flops else None
    return Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=cb,
                    compute_s=compute_s, memory_s=memory_s,
                    collective_s=collective_s, bottleneck=bottleneck,
                    model_flops=model_flops, useful_ratio=useful)


def memory_analysis_dict(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # CPU backend may not implement it
        return {"error": str(e)}
    if ma is None:
        return {}
    out = {}
    for field in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "alias_size_in_bytes",
                  "temp_size_in_bytes"):
        if hasattr(ma, field):
            out[field] = float(getattr(ma, field))
    return out
