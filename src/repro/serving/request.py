"""Request lifecycle for the PD-disaggregated serving runtime.

FlowKV extends the usual vLLM state machine with a SENDING stage (paper
App. B.2): requests that finished prefill and are waiting for their KV cache
to reach the decode node sit in the sending queue, and the sending-queue
length is one of the load-score features.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import List, Optional, Sequence

_req_counter = itertools.count()


class RequestState(enum.Enum):
    WAITING = "waiting"          # queued, not yet scheduled for prefill
    PREFILLING = "prefilling"    # running prefill on a P-role scheduler
    SENDING = "sending"          # prefill done; KV cache transfer in flight
    DECODING = "decoding"        # running decode on a D-role scheduler
    SWAPPED = "swapped"          # preempted, KV swapped out
    FINISHED = "finished"
    CANCELLED = "cancelled"      # client cancel; blocks freed on every node
    FAILED = "failed"            # node died; will be requeued by the controller
    REJECTED = "rejected"        # admission gate: overload early-rejection
    #                              (terminal; retry_after hints when to resubmit)

# States that occupy KV blocks on some node.
LIVE_STATES = (RequestState.PREFILLING, RequestState.SENDING,
               RequestState.DECODING, RequestState.SWAPPED)


@dataclasses.dataclass
class SamplingParams:
    max_new_tokens: int = 256
    temperature: float = 0.0
    top_k: int = 1
    eos_token_id: Optional[int] = None


@dataclasses.dataclass
class Request:
    prompt_tokens: List[int]
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    request_id: int = dataclasses.field(default_factory=lambda: next(_req_counter))
    arrival_time: float = 0.0

    # --- mutable lifecycle state ---------------------------------------------
    state: RequestState = RequestState.WAITING
    output_tokens: List[int] = dataclasses.field(default_factory=list)
    prefill_node: Optional[int] = None
    decode_node: Optional[int] = None
    block_ids: List[int] = dataclasses.field(default_factory=list)   # on current node
    num_cached_prefix_tokens: int = 0   # prefix-cache hit length (skipped compute)
    # Winning prefix-reuse plan from routing: the node holding the matched
    # blocks (== prefill_node for a local hit, another node for a remote
    # fetch, None for recompute) and the matched block ids on that node.
    # Local hits are RE-validated at admission against the live index;
    # remote plans are executed by the runtime as one fused transfer.
    prefix_src_node: Optional[int] = None
    prefix_block_ids: List[int] = dataclasses.field(default_factory=list)
    # Set when a remote prefix fetch actually ran (its cost shows in stats()).
    prefix_fetch_dispatches: int = 0
    # Memoized prompt digest chain (prompt is immutable): the controller
    # hashes once per request instead of once per probe/retry cycle.
    prefix_chain_cache: Optional[List[bytes]] = None

    # --- timing (set by engine / simulator clocks) ----------------------------
    prefill_start: Optional[float] = None
    prefill_end: Optional[float] = None
    transfer_start: Optional[float] = None
    transfer_end: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    retries: int = 0

    # --- wall-clock timing (time.monotonic(), stamped by the real runtime) -----
    # The fields above run on the driving scheduler clock (cluster CYCLES in
    # PDCluster, simulated seconds in ClusterSim). These parallel stamps are
    # real seconds, so per-phase durations are reportable without the sim's
    # cycle->s conversion. The simulator leaves them None.
    arrival_wall: Optional[float] = None
    prefill_start_wall: Optional[float] = None
    prefill_end_wall: Optional[float] = None
    transfer_start_wall: Optional[float] = None
    transfer_end_wall: Optional[float] = None
    first_token_wall: Optional[float] = None
    finish_wall: Optional[float] = None

    # --- admission gate (set when the controller defers/rejects) ---------------
    retry_after: Optional[float] = None   # hint: resubmit after this many seconds
    reject_reason: Optional[str] = None   # e.g. "predicted_ttft 42.1s > slo 30.0s"
    admission_defers: int = 0             # cycles spent in the deferred queue

    # --- transfer data-plane counters (set when the KV transfer runs) ----------
    transfer_calls: Optional[int] = None        # transport calls priced
    transfer_dispatches: Optional[int] = None   # fused kernel dispatches
    # tokens in the FINAL prefill chunk (== prompt_len when unchunked): the
    # compute window layer-window transfer overlap can hide behind
    last_prefill_chunk_tokens: Optional[int] = None

    # --- decode data-plane counters (accumulated per decode cycle) --------------
    decode_steps: int = 0          # decode cycles this request participated in
    decode_dispatches: int = 0     # device dispatches those cycles issued
    #                                (1/step zero-gather; O(batch)/step oracle)

    # --- fault tolerance (set on failover / transfer retry) ----------------------
    # The prompt length the CLIENT submitted. Recovery rewrites prompt_tokens
    # to prompt + already-emitted tokens (teacher-forced re-prefill), so the
    # original boundary must be remembered the first time that happens.
    client_prompt_len: Optional[int] = None
    # Emitted tokens folded back into the prompt by the last recovery; they
    # are counted once in prompt_len AND once in num_output, so total_len
    # subtracts them out.
    replayed_tokens: int = 0
    transfer_retries: int = 0      # failed/corrupt transfer attempts retried
    recoveries: int = 0            # completed failovers (recovery span emitted)
    recovery_start: Optional[float] = None        # set at failure detection,
    recovery_start_wall: Optional[float] = None   # cleared when work resumes
    recovery_s: float = 0.0                       # accumulated recovery time
    recovery_wall_s: Optional[float] = None

    # -- derived ----------------------------------------------------------------
    @property
    def prompt_len(self) -> int:
        return len(self.prompt_tokens)

    @property
    def num_output(self) -> int:
        return len(self.output_tokens)

    @property
    def total_len(self) -> int:
        # replayed tokens live in BOTH prompt_tokens (recovery re-prefill)
        # and output_tokens (exactly-once client delivery): count them once.
        return self.prompt_len + self.num_output - self.replayed_tokens

    def num_blocks(self, block_size: int) -> int:
        return -(-self.total_len // block_size)

    @property
    def done(self) -> bool:
        return self.state is RequestState.FINISHED

    # -- metrics ------------------------------------------------------------------
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def e2e(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time

    def tpot(self) -> Optional[float]:
        """Time per output token, excluding the first (paper's TPOT).

        ``first_token_time`` is stamped when prefill emits the first token,
        so in disaggregated runs the first decode interval — and therefore
        TPOT — includes the P->D transfer gap. That is the latency a client
        actually observes between tokens 1 and 2.
        """
        if self.finish_time is None or self.first_token_time is None or self.num_output < 2:
            return None
        return (self.finish_time - self.first_token_time) / (self.num_output - 1)

    def transfer_latency(self) -> Optional[float]:
        if self.transfer_start is None or self.transfer_end is None:
            return None
        return self.transfer_end - self.transfer_start

    def timing_breakdown(self) -> dict:
        """Per-stage timing split (None where the stage hasn't happened):
        queue -> prefill -> transfer -> decode, plus ttft / e2e.

        The unsuffixed entries run on the driving scheduler clock (cycles in
        the real cluster, simulated seconds in the sim); the ``*_wall_s``
        entries are monotonic wall-clock SECONDS stamped by the real runtime
        (None in the simulator)."""
        def span(a: Optional[float], b: Optional[float]) -> Optional[float]:
            return None if a is None or b is None else b - a
        return {
            "queue_s": span(self.arrival_time, self.prefill_start),
            "prefill_s": span(self.prefill_start, self.prefill_end),
            "transfer_s": self.transfer_latency(),
            "decode_s": span(self.transfer_end, self.finish_time),
            "ttft_s": self.ttft(),
            "e2e_s": self.e2e(),
            "queue_wall_s": span(self.arrival_wall, self.prefill_start_wall),
            "prefill_wall_s": span(self.prefill_start_wall,
                                   self.prefill_end_wall),
            "transfer_wall_s": span(self.transfer_start_wall,
                                    self.transfer_end_wall),
            "decode_wall_s": span(self.transfer_end_wall, self.finish_wall),
            "ttft_wall_s": span(self.arrival_wall, self.first_token_wall),
            "e2e_wall_s": span(self.arrival_wall, self.finish_wall),
            "num_calls": self.transfer_calls,
            "num_dispatches": self.transfer_dispatches,
        }

    def clear_prefix_plan(self) -> None:
        """Degrade a routed prefix-reuse plan to recompute (staleness paths:
        source died, blocks freed, fetch impossible). One helper so the
        controller, cluster and simulator can never clear half a plan."""
        self.num_cached_prefix_tokens = 0
        self.prefix_src_node = None
        self.prefix_block_ids = []

    def reset_for_retry(self) -> None:
        """Requeue after a node failure — WITHOUT losing emitted tokens.

        Token-exact recovery: tokens already delivered to the client cannot
        be un-sent, so the retry must regenerate the same continuation. All
        generated tokens except the newest are folded into the prompt
        (teacher-forced re-prefill through the ordinary suffix path); the
        newest token is re-predicted by the recovery prefill's final forward
        (the engine skips the duplicate append) and decode resumes from it.
        ``output_tokens`` is kept verbatim, so the streaming handle's
        emitted-counter delivers each token exactly once across a failover.
        """
        if self.client_prompt_len is None:
            self.client_prompt_len = self.prompt_len
        if self.output_tokens:
            self.prompt_tokens = (self.prompt_tokens[:self.client_prompt_len]
                                  + self.output_tokens[:-1])
            self.replayed_tokens = len(self.output_tokens) - 1
            self.prefix_chain_cache = None    # prompt changed: re-hash
        else:
            self.first_token_time = None
            self.first_token_wall = None
        # FAILED while parked in the retry queue (so a client cancel is
        # distinguishable there); enqueue_prefill flips it back to WAITING.
        self.state = RequestState.FAILED
        self.block_ids = []
        self.prefill_node = None
        self.decode_node = None
        self.clear_prefix_plan()
        self.prefix_fetch_dispatches = 0
        self.prefill_start = self.prefill_end = None
        self.transfer_start = self.transfer_end = None
        self.prefill_start_wall = self.prefill_end_wall = None
        self.transfer_start_wall = self.transfer_end_wall = None
        self.transfer_calls = self.transfer_dispatches = None
        self.decode_steps = self.decode_dispatches = 0
        self.retry_after = None
        self.reject_reason = None
        self.retries += 1


def make_batch(prompts: Sequence[Sequence[int]], arrival_times: Optional[Sequence[float]] = None,
               max_new_tokens: int = 256) -> List[Request]:
    out = []
    for i, p in enumerate(prompts):
        out.append(Request(
            prompt_tokens=list(p),
            sampling=SamplingParams(max_new_tokens=max_new_tokens),
            arrival_time=0.0 if arrival_times is None else float(arrival_times[i]),
        ))
    return out
