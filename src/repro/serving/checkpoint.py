"""Checkpoint/restore for the serving cluster and training state.

Serving: captures every node's pool array, block tables, queue contents and
in-flight request lifecycle so a controller restart resumes mid-stream.
Training: params/opt-state/step with atomic rename (crash-safe), plus
``latest()`` discovery for resume-from-latest.

Format: numpy ``.npz`` for arrays + msgpack for structure (both available
offline).
"""
from __future__ import annotations

import dataclasses
import os
import pathlib
import tempfile
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.serving.request import Request, RequestState, SamplingParams


# ---------------------------------------------------------------------------
# pytree <-> flat npz helpers
# ---------------------------------------------------------------------------
def _flatten(tree, prefix: str = "") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        key = prefix + "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                                for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            out[key + "@bf16"] = arr.astype(np.float32)
        else:
            out[key] = arr
    return out


def _unflatten_into(template, flat: Dict[str, np.ndarray], prefix: str = ""):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = prefix + "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                                for p in path)
        if key + "@bf16" in flat:
            leaves.append(jnp.asarray(flat[key + "@bf16"], jnp.bfloat16))
        else:
            leaves.append(jnp.asarray(flat[key]))
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Request (de)serialization
# ---------------------------------------------------------------------------
def request_to_dict(r: Request) -> dict:
    return {
        "request_id": int(r.request_id),
        "prompt_tokens": [int(t) for t in r.prompt_tokens],
        "output_tokens": [int(t) for t in r.output_tokens],
        "state": r.state.value,
        "prefill_node": r.prefill_node,
        "decode_node": r.decode_node,
        "block_ids": [int(b) for b in r.block_ids],
        "arrival_time": r.arrival_time,
        "max_new_tokens": r.sampling.max_new_tokens,
        "retries": r.retries,
        "retry_after": r.retry_after,
        "reject_reason": r.reject_reason,
        "num_cached_prefix_tokens": int(r.num_cached_prefix_tokens),
        "prefix_src_node": r.prefix_src_node,
        "prefix_block_ids": [int(b) for b in r.prefix_block_ids],
    }


def request_from_dict(d: dict) -> Request:
    r = Request(prompt_tokens=list(d["prompt_tokens"]),
                sampling=SamplingParams(max_new_tokens=d["max_new_tokens"]),
                request_id=d["request_id"], arrival_time=d["arrival_time"])
    r.output_tokens = list(d["output_tokens"])
    r.state = RequestState(d["state"])
    r.prefill_node = d["prefill_node"]
    r.decode_node = d["decode_node"]
    r.block_ids = list(d["block_ids"])
    r.retries = d["retries"]
    r.retry_after = d.get("retry_after")
    r.reject_reason = d.get("reject_reason")
    r.num_cached_prefix_tokens = int(d.get("num_cached_prefix_tokens", 0))
    r.prefix_src_node = d.get("prefix_src_node")
    r.prefix_block_ids = list(d.get("prefix_block_ids", []))
    return r


# ---------------------------------------------------------------------------
# Serving cluster checkpoint
# ---------------------------------------------------------------------------
def cluster_state(cluster) -> dict:
    nodes = {}
    for nid, engine in cluster.engines.items():
        sched = engine.scheduler
        node = {
            "role": cluster.controller.nodes[nid].role,
            "alive": cluster.controller.nodes[nid].alive,
            # role-lifecycle state (set_role / role_flip policy)
            "home_role": cluster.controller.nodes[nid].home_role,
            "priority": sched.priority,
            "priority_cycles_left": sched._priority_cycles_left,
            "queues": {
                "prefill_waiting": [request_to_dict(r) for r in sched.prefill.waiting],
                "prefill_running": [request_to_dict(r) for r in sched.prefill.running],
                "sending": [request_to_dict(r) for r in sched.prefill.sending],
                "decode_running": [request_to_dict(r) for r in sched.decode.running],
                "decode_swapped": [request_to_dict(r) for r in sched.decode.swapped],
            },
            "block_table": {str(rid): [int(b) for b in engine.scheduler.bm.get(rid)]
                            for rid in list(engine.scheduler.bm._table)},
            # spill-path bookkeeping: lengths here, arrays in pools.npz —
            # a checkpoint taken mid-swap must not lose the saved KV
            "spilled": {str(rid): int(length)
                        for rid, (_, _, length) in engine.spilled.items()},
        }
        nodes[str(nid)] = node
    return {"clock": cluster.clock, "nodes": nodes,
            "finished": [request_to_dict(r) for r in cluster.finished],
            "cancelled": [request_to_dict(r) for r in getattr(cluster, "cancelled", [])],
            "rejected": [request_to_dict(r) for r in getattr(cluster, "rejected", [])]}


def save_cluster(cluster, path: str) -> None:
    path = pathlib.Path(path)
    path.mkdir(parents=True, exist_ok=True)
    meta = cluster_state(cluster)
    _atomic_write_bytes(path / "meta.msgpack", msgpack.packb(meta))
    arrays = {}
    for nid, engine in cluster.engines.items():
        if engine.paged:
            arrays[f"pool_{nid}"] = np.asarray(engine.kv.pool.astype(jnp.float32))
        for rid, (k, v, _) in engine.spilled.items():
            arrays[f"spill_k_{nid}_{rid}"] = np.asarray(k, np.float32)
            arrays[f"spill_v_{nid}_{rid}"] = np.asarray(v, np.float32)
    _atomic_savez(path / "pools.npz", arrays)


def load_cluster(cluster, path: str) -> dict:
    """Restore pools + queues into an already-constructed cluster."""
    path = pathlib.Path(path)
    meta = msgpack.unpackb((path / "meta.msgpack").read_bytes(), strict_map_key=False)
    pools = np.load(path / "pools.npz")
    cluster.clock = meta["clock"]
    for nid_s, node in meta["nodes"].items():
        nid = int(nid_s)
        engine = cluster.engines[nid]
        # roles are runtime state since set_role / the role-flip policy:
        # restore them (plus scheduler priority) so routing and flip-back
        # resume where the checkpoint left off
        handle = cluster.controller.nodes[nid]
        handle.role = node.get("role", handle.role)
        handle.alive = bool(node.get("alive", handle.alive))
        handle.home_role = node.get("home_role")
        if node.get("priority"):
            # re-arm the lease countdown too, else a temporary priority
            # (imbalanced-regime lease) would become sticky across restore
            engine.scheduler.set_priority(node["priority"],
                                          cycles=node.get("priority_cycles_left", 0))
        if engine.paged and f"pool_{nid}" in pools:
            engine.kv.pool = jnp.asarray(pools[f"pool_{nid}"], engine.kv.spec.dtype)
        engine.spilled = {
            int(rid_s): (pools[f"spill_k_{nid}_{rid_s}"],
                         pools[f"spill_v_{nid}_{rid_s}"], length)
            for rid_s, length in node.get("spilled", {}).items()}
        sched = engine.scheduler
        sched.prefill.waiting.clear(); sched.prefill.running.clear()
        sched.prefill.sending.clear(); sched.prefill.swapped.clear()
        sched.decode.running.clear(); sched.decode.swapped.clear()
        bm = sched.bm
        # the checkpoint is authoritative: release every live allocation
        # THROUGH the allocator first (a used cluster's post-save tables
        # would otherwise strand blocks as allocated-forever, or alias
        # since-freed blocks between a restored table and a new request),
        # then rebuild table + refcounts from the snapshot (a block in k
        # tables is a prefix shared k ways, matching check_invariants)
        bm.release_all()
        # the snapshot carries no prefix-index state: residency recorded
        # for this node — before OR since the save — now names blocks whose
        # contents the restore just rewrote. Evict rather than advertise
        # another request's KV; entries repopulate as restored traffic
        # finishes prefill.
        if getattr(cluster, "controller", None) is not None:
            cluster.controller.prefix_index.evict_node(nid)
        for rid_s, blocks in node["block_table"].items():
            bm._table[int(rid_s)] = list(blocks)
            for b in blocks:
                bm._refcount[b] = bm._refcount.get(b, 0) + 1
                if isinstance(bm.allocator.__dict__.get("_free"), list):
                    try:
                        bm.allocator._free.remove(b)
                        bm.allocator._allocated.add(b)
                    except ValueError:
                        pass
        if hasattr(bm.allocator, "free_segments"):
            _rebuild_segment_allocator(bm)
        for qname, target in (("prefill_waiting", sched.prefill.waiting),
                              ("prefill_running", sched.prefill.running),
                              ("sending", sched.prefill.sending),
                              ("decode_running", sched.decode.running),
                              ("decode_swapped", sched.decode.swapped)):
            for rd in node["queues"][qname]:
                req = request_from_dict(rd)
                if isinstance(target, list):
                    target.append(req)
                else:
                    target.append(req)
    cluster.finished = [request_from_dict(d) for d in meta["finished"]]
    cluster.cancelled = [request_from_dict(d) for d in meta.get("cancelled", [])]
    cluster.rejected = [request_from_dict(d) for d in meta.get("rejected", [])]
    return meta


def _rebuild_segment_allocator(bm) -> None:
    """Reconstruct a SegmentAllocator's free heaps from the block table."""
    from repro.core.allocator import SegmentAllocator
    if not isinstance(bm.allocator, SegmentAllocator):
        return
    allocated = set()
    for blocks in bm._table.values():
        allocated.update(blocks)
    fresh = SegmentAllocator(bm.num_blocks)
    if allocated:
        # carve out the allocated ids
        fresh._allocated = set()
        fresh._heaps.__init__()
        fresh._by_start.clear(); fresh._by_end.clear()
        free_runs = []
        cur = None
        for b in range(bm.num_blocks):
            if b in allocated:
                if cur is not None:
                    free_runs.append((cur, b - cur))
                    cur = None
            else:
                if cur is None:
                    cur = b
        if cur is not None:
            free_runs.append((cur, bm.num_blocks - cur))
        from repro.core.segments import Segment
        for start, length in free_runs:
            fresh._insert_free(Segment(start, length))
        fresh._num_free = sum(l for _, l in free_runs)
        fresh._allocated = set(allocated)
    bm.allocator = fresh


# ---------------------------------------------------------------------------
# Training checkpoint (atomic, resume-from-latest)
# ---------------------------------------------------------------------------
def save_train_state(state, step: int, ckpt_dir: str) -> str:
    d = pathlib.Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    flat = _flatten(state)
    final = d / f"step_{step:08d}.npz"
    _atomic_savez(final, flat)
    return str(final)


def latest_checkpoint(ckpt_dir: str) -> Optional[str]:
    d = pathlib.Path(ckpt_dir)
    if not d.exists():
        return None
    ckpts = sorted(d.glob("step_*.npz"))
    return str(ckpts[-1]) if ckpts else None


def load_train_state(template, path: str):
    flat = dict(np.load(path))
    return _unflatten_into(template, flat)


# ---------------------------------------------------------------------------
# atomic writes
# ---------------------------------------------------------------------------
def _atomic_write_bytes(path: pathlib.Path, data: bytes) -> None:
    fd, tmp = tempfile.mkstemp(dir=str(path.parent))
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def _atomic_savez(path: pathlib.Path, arrays: Dict[str, np.ndarray]) -> None:
    fd, tmp = tempfile.mkstemp(dir=str(path.parent), suffix=".npz")
    os.close(fd)
    try:
        np.savez(tmp, **arrays)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
