"""Per-node inference engine: executes the hybrid scheduler's decisions with
real JAX compute against the paged pool.

Two request-state transports, per DESIGN.md §4:

* paged KV path (transformer families) — prefill writes pages, decode
  gathers pages into the dense cache format (reference path for the Pallas
  paged-attention kernel) and appends the new token's K/V back to pages.
* state path (ssm / hybrid / encdec) — the request's cache pytree is held
  whole and shipped whole (one logical segment).

The engine is deliberately synchronous and single-host-scale: the paper's
*timing* claims are reproduced by ``sim/cluster_sim.py`` with calibrated
cost models; this engine proves the *data path* is correct (disaggregated
generation must be token-identical to monolithic generation — see
tests/test_cluster.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.block_manager import BlockManager
from repro.core.scheduler.hybrid_scheduler import HybridScheduler, ScheduleDecision
from repro.models.api import Model, get_model
from repro.models.common import ModelConfig
from repro.serving.kv_cache import PagedKVCache, spec_for_model
from repro.serving.request import Request, RequestState

PAGED_FAMILIES = ("dense", "moe", "vlm", "audio")


class NodeEngine:
    """Role-flexible node: serves prefill AND decode from ONE block pool.

    A node's *role* ("prefill"/"decode") lives in the controller's
    ``NodeHandle`` and only biases routing and scheduler priority — the
    engine itself runs whatever its ``HybridScheduler`` admits, which is
    what lets ``GlobalController.set_role`` flip a node P<->D mid-run
    without draining it: in-flight work of the old role finishes from the
    same pool while new work of the new role is admitted.
    """

    def __init__(self, node_id: int, cfg: ModelConfig, params,
                 num_blocks: int = 256, allocator: str = "flowkv",
                 max_batch_tokens: int = 2048, max_model_len: int = 512):
        self.node_id = node_id
        self.cfg = cfg
        self.model: Model = get_model(cfg)
        self.params = params
        self.max_model_len = max_model_len
        self.paged = cfg.family in PAGED_FAMILIES
        if self.paged:
            self.kv = PagedKVCache(spec_for_model(cfg, num_blocks), allocator)
            bm = self.kv.bm
        else:
            # state path: block manager still gates admission (token budget),
            # but state lives in a per-request pytree store.
            self.kv = None
            bm = BlockManager(num_blocks, cfg.block_size, allocator)
        self.states: Dict[int, Any] = {}        # request_id -> cache pytree (state path)
        self.scheduler = HybridScheduler(node_id, bm,
                                         max_batch_tokens=max_batch_tokens)

    # -- prefill ------------------------------------------------------------------
    def run_prefill(self, decision: ScheduleDecision,
                    now: Optional[float] = None) -> List[Request]:
        """Execute the prefill batch; returns requests that finished prefill.

        The first output token is produced HERE (prefill's last forward
        emits it), so this is also where TTFT is stamped when a clock is
        supplied — not at transfer time.
        """
        done: List[Request] = []
        for req in decision.prefill_batch:   # simple per-request prefill (no padding waste)
            if now is not None and req.prefill_start is None:
                req.prefill_start = now
            tokens = jnp.asarray([req.prompt_tokens], jnp.int32)
            logits, cache = self.model.prefill(self.params, {"tokens": tokens})
            first = int(jnp.argmax(logits[0]))
            req.output_tokens.append(first)
            if self.paged:
                k = cache["k"][:, 0]
                v = cache["v"][:, 0]
                self.kv.write_prefill(req.request_id, k, v, req.prompt_len)
            else:
                self.states[req.request_id] = jax.tree.map(lambda x: x, cache)
            if self.scheduler.prefill_progressed(req, req.prompt_len):
                if now is not None and req.first_token_time is None:
                    req.first_token_time = now
                done.append(req)
        self.scheduler.last_compute_util = 1.0 if decision.prefill_batch else 0.0
        return done

    # -- decode --------------------------------------------------------------------
    def run_decode(self, decision: ScheduleDecision) -> List[Request]:
        """One decode step for the running batch; returns finished requests."""
        batch = decision.decode_batch
        if not batch:
            return []
        finished: List[Request] = []
        if self.paged:
            self._decode_paged(batch)
        else:
            self._decode_state(batch)
        for req in batch:
            last = req.output_tokens[-1]
            eos = req.sampling.eos_token_id
            if req.num_output >= req.sampling.max_new_tokens or (eos is not None and last == eos):
                finished.append(req)
                if not self.paged:
                    self.states.pop(req.request_id, None)
                self.scheduler.decode_finished(req)
        self.scheduler.last_bandwidth_util = 1.0
        return finished

    def _decode_paged(self, batch: List[Request]) -> None:
        max_len = max(r.total_len for r in batch) + 1
        ks, vs, lens, toks = [], [], [], []
        for r in batch:
            k, v = self.kv.gather_dense(r.request_id, max_len)
            ks.append(k); vs.append(v)
            # KV stored so far = prompt + all outputs except the newest token,
            # whose KV is written by THIS decode step at position total-1.
            lens.append(r.total_len - 1)
            toks.append(r.output_tokens[-1])
        cache = {
            "k": jnp.stack(ks, axis=1),            # (L, B, T, KV, hd)
            "v": jnp.stack(vs, axis=1),
            "length": jnp.asarray(lens, jnp.int32),
        }
        logits, new_cache = self.model.decode(
            self.params, jnp.asarray(toks, jnp.int32), cache)
        nxt = jnp.argmax(logits, axis=-1)
        for i, r in enumerate(batch):
            pos = lens[i]
            k_new = new_cache["k"][:, i, pos]
            v_new = new_cache["v"][:, i, pos]
            self.kv.append_token(r.request_id, k_new, v_new, pos)
            r.output_tokens.append(int(nxt[i]))

    def _decode_state(self, batch: List[Request]) -> None:
        for r in batch:   # state caches are per-request pytrees
            cache = self.states[r.request_id]
            logits, cache = self.model.decode(
                self.params, jnp.asarray([r.output_tokens[-1]], jnp.int32), cache)
            self.states[r.request_id] = cache
            r.output_tokens.append(int(jnp.argmax(logits[0])))

    # -- transfer hooks (TransferBackend ports; see core/transfer.py) -------------------
    def export_state(self, req: Request):
        """State-path transfer payload (shipped whole, one segment)."""
        return self.export_state_by_id(req.request_id)

    def import_state(self, req: Request, state) -> None:
        self.import_state_by_id(req.request_id, state)

    def export_state_by_id(self, request_id: int):
        return self.states.pop(request_id)

    def import_state_by_id(self, request_id: int, state) -> None:
        self.states[request_id] = state

    def register_transfer_in(self, req: Request, num_tokens: int) -> List[int]:
        """Destination-side block registration ahead of a paged transfer."""
        return self.scheduler.bm.register(req.request_id, num_tokens)

    # -- lifecycle -----------------------------------------------------------------------
    def release(self, req: Request) -> bool:
        """Drop every trace of a request from this node (cancel path).

        Frees KV blocks, removes the request from all scheduler queues and
        discards any state-path pytree. Safe to call on nodes that never saw
        the request. Returns True if anything was released.
        """
        removed = self.scheduler.remove_request(req)
        if self.states.pop(req.request_id, None) is not None:
            removed = True
        return removed

    # -- cycle -----------------------------------------------------------------------
    def step(self, now: Optional[float] = None) -> Tuple[List[Request], List[Request]]:
        """One scheduling cycle. Returns (prefill_done, decode_finished)."""
        decision = self.scheduler.schedule()
        pre = self.run_prefill(decision, now=now) if decision.prefill_batch else []
        fin = self.run_decode(decision) if decision.decode_batch else []
        if not decision.prefill_batch:
            self.scheduler.last_compute_util = 0.0
        if not decision.decode_batch:
            self.scheduler.last_bandwidth_util = 0.0
        return pre, fin
