"""Per-node inference engine: executes the hybrid scheduler's decisions with
real JAX compute against the paged pool.

Two request-state transports, per DESIGN.md §4:

* paged KV path (transformer families) — prefill writes pages; decode runs
  the ZERO-GATHER step: one jitted ``Model.decode_paged`` call per cycle
  that reads pages in place through the Pallas paged-attention kernel and
  appends the batch's new K/V with one fused descriptor-table scatter, the
  pool donated. No dense cache is materialized; device dispatches per
  decode cycle are O(1) regardless of batch size or context length. The
  old gather-dense bridge survives as the test/benchmark oracle
  (``paged_decode="dense"``) and as the fallback for windowed attention.
* state path (ssm / hybrid / encdec) — the request's cache pytree is held
  whole and shipped whole (one logical segment).

Ragged batches are padded to power-of-two buckets in BOTH batch size and
block-table width (pad lanes replicate lane 0, so their duplicate append
descriptors are idempotent), keeping the jit cache bounded at
``O(log2(max_batch) * log2(max_blocks))`` variants. ``decode_dispatches`` /
``decode_steps`` / ``decode_compile_variants`` surface through
``RequestHandle.stats()`` and ``PDCluster.stats()``.

The engine is deliberately synchronous and single-host-scale: the paper's
*timing* claims are reproduced by ``sim/cluster_sim.py`` with calibrated
cost models; this engine proves the *data path* is correct (disaggregated
generation must be token-identical to monolithic generation — see
tests/test_cluster.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.block_manager import BlockManager
from repro.core.scheduler.hybrid_scheduler import HybridScheduler, ScheduleDecision
from repro.distributed import tp as tp_mod
from repro.models.api import Model, get_model
from repro.models.common import ModelConfig
from repro.serving.kv_cache import PagedKVCache, ShardedKVCache, spec_for_model
from repro.serving.request import Request, RequestState

PAGED_FAMILIES = ("dense", "moe", "vlm", "audio")


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1)).bit_length()


# One jitted zero-gather step per (config, donation) — engines of the same
# config share it, so a cluster of N nodes compiles each (batch, table-width)
# bucket once, not N times.
_PAGED_STEP_CACHE: Dict[Tuple[ModelConfig, bool], Any] = {}


def _paged_step_for(model: Model, cfg: ModelConfig):
    donate = jax.default_backend() in ("tpu", "gpu")
    key = (cfg, donate)
    fn = _PAGED_STEP_CACHE.get(key)
    if fn is None:
        fn = jax.jit(model.decode_paged,
                     donate_argnums=(2,) if donate else ())
        _PAGED_STEP_CACHE[key] = fn
    return fn


# Sharded twin, keyed additionally by tp degree: one jitted step covers all
# shards (per-shard kernels + full-width merge inside a single artifact).
_SHARDED_STEP_CACHE: Dict[Tuple[ModelConfig, int, bool], Any] = {}


def _sharded_step_for(cfg: ModelConfig, tp_degree: int):
    donate = jax.default_backend() in ("tpu", "gpu")
    key = (cfg, tp_degree, donate)
    fn = _SHARDED_STEP_CACHE.get(key)
    if fn is None:
        def step(shards, tok, pools, bt, lens):
            return tp_mod.sharded_decode_step_paged(
                shards, cfg, tok, pools, bt, lens)
        fn = jax.jit(step, donate_argnums=(2,) if donate else ())
        _SHARDED_STEP_CACHE[key] = fn
    return fn


class NodeEngine:
    """Role-flexible node: serves prefill AND decode from ONE block pool.

    A node's *role* ("prefill"/"decode") lives in the controller's
    ``NodeHandle`` and only biases routing and scheduler priority — the
    engine itself runs whatever its ``HybridScheduler`` admits, which is
    what lets ``GlobalController.set_role`` flip a node P<->D mid-run
    without draining it: in-flight work of the old role finishes from the
    same pool while new work of the new role is admitted.
    """

    def __init__(self, node_id: int, cfg: ModelConfig, params,
                 num_blocks: int = 256, allocator: str = "flowkv",
                 max_batch_tokens: int = 2048, max_model_len: int = 512,
                 paged_decode: str = "auto", chunked_prefill: bool = True,
                 prefill_chunk_tokens: Optional[int] = None,
                 tp_degree: int = 1):
        self.node_id = node_id
        self.cfg = cfg
        self.model: Model = get_model(cfg)
        self.params = params
        self.max_model_len = max_model_len
        self.paged = cfg.family in PAGED_FAMILIES
        # -- mesh parallelism ---------------------------------------------------------
        # tp_degree > 1 runs the model sharded over a model axis (TP for
        # attention/MLP, EP for MoE experts) with the pool split into
        # per-kv-head-slice shard pools; see distributed/tp.py for why the
        # result is bit-identical to the tp=1 engine.
        self.tp_degree = tp_degree
        self.ep_degree = tp_mod.ep_degree(cfg, tp_degree)
        self.shard_params: Optional[List[Any]] = None
        if tp_degree > 1:
            if not self.paged:
                raise ValueError("tp_degree > 1 requires a paged-KV family, "
                                 f"got {cfg.family!r}")
            tp_mod.validate_tp(cfg, tp_degree)
            self.shard_params = tp_mod.shard_params(params, cfg, tp_degree)
        if self.paged:
            if tp_degree > 1:
                self.kv = ShardedKVCache(spec_for_model(cfg, num_blocks),
                                         tp_degree, allocator)
            else:
                self.kv = PagedKVCache(spec_for_model(cfg, num_blocks),
                                       allocator)
            bm = self.kv.bm
        else:
            # state path: block manager still gates admission (token budget),
            # but state lives in a per-request pytree store.
            self.kv = None
            bm = BlockManager(num_blocks, cfg.block_size, allocator)
        self.states: Dict[int, Any] = {}        # request_id -> cache pytree (state path)
        # Chunked prefill needs the suffix data plane: an intermediate chunk
        # is exactly a suffix prefill (q_offset = tokens done) over the
        # paged pool. State families and windowed-attention configs have no
        # suffix kernel, so their scheduler runs whole-prompt admission.
        self.supports_chunked_prefill = \
            self.paged and self.model.prefill_suffix is not None
        self.scheduler = HybridScheduler(
            node_id, bm, max_batch_tokens=max_batch_tokens,
            chunked_prefill=chunked_prefill and self.supports_chunked_prefill,
            prefill_chunk_tokens=prefill_chunk_tokens)
        # -- spill path (decode memory pressure) --------------------------------------
        # request_id -> (k, v, length) saved host-side when the scheduler
        # preempts a decode request; restored into fresh blocks on resume so
        # generation continues token-identically. Paged engines only — the
        # state path keeps its pytree in ``self.states`` across a swap.
        self.spilled: Dict[int, Tuple[np.ndarray, np.ndarray, int]] = {}
        if self.paged:
            self.scheduler.on_spill = self._spill_kv
            self.scheduler.on_resume = self._restore_kv
            self.scheduler.on_discard = \
                lambda req: self.spilled.pop(req.request_id, None)
        # -- zero-gather decode plane ------------------------------------------------
        # paged_decode: "auto" (kernel when supported), "kernel", "dense" (oracle).
        if paged_decode not in ("auto", "kernel", "dense"):
            raise ValueError(f"paged_decode must be auto|kernel|dense, got {paged_decode!r}")
        # decode_paged is None for both state families and windowed-attention
        # configs (the kernel has no window mask) — see models/api.py
        kernel_ok = self.paged and self.model.decode_paged is not None
        if paged_decode == "kernel" and not kernel_ok:
            raise ValueError("paged_decode='kernel' unsupported for this config "
                             "(state family or windowed attention)")
        self.use_paged_decode = kernel_ok and paged_decode != "dense"
        self._paged_step = None
        if self.use_paged_decode:
            self._paged_step = (_sharded_step_for(cfg, tp_degree)
                                if tp_degree > 1
                                else _paged_step_for(self.model, cfg))
        self.decode_steps = 0          # decode cycles executed
        self.decode_dispatches = 0     # device dispatches those cycles issued
        self._decode_cache_keys: Set[Tuple[int, int]] = set()   # jit buckets seen
        # -- prefix-reuse data plane ---------------------------------------------------
        # A prefix-cache hit only skips work on the paged path with a
        # suffix-capable model (windowed attention and state families
        # recompute); the runtime consults this before wiring the node into
        # the reuse plane (resolver hook + index recording).
        self.supports_prefix_reuse = self.paged and self.model.prefill_suffix is not None
        # Optional repro.serving.host_tier.TierManager, attached by the
        # cluster when host_tier_blocks > 0 (paged, reuse-capable engines
        # only): the node's host-DRAM tier for demoted prefix blocks. The
        # engine itself never branches on it — demotion hangs off
        # bm.on_evict and promotion runs from the cluster's pre-admission
        # pass — but checkpoint/teardown tooling finds it here.
        self.tier = None
        self.prefill_tokens_computed = 0   # prompt tokens actually forwarded
        self.prefix_hits = 0               # prefills that reused a resident prefix
        self.prefix_tokens_reused = 0      # prompt tokens NOT recomputed
        # -- observability ------------------------------------------------------------
        # Optional repro.obs.tracing.SpanRecorder; read at emission time, so
        # attach_tracer() can instrument a live engine. The engine emits the
        # "prefill" span (it is where prefill runs and where wall-clock
        # stamps originate); queue/transfer/decode spans come from the
        # cluster, admission spans from the controller.
        self.tracer = None

    @property
    def decode_compile_variants(self) -> int:
        """Distinct (batch, block-table-width) buckets the step compiled."""
        return len(self._decode_cache_keys)

    # -- prefill ------------------------------------------------------------------
    def run_prefill(self, decision: ScheduleDecision,
                    now: Optional[float] = None) -> List[Request]:
        """Execute the prefill batch; returns requests that finished prefill.

        Honors the scheduler's per-request CHUNK budget
        (``decision.prefill_chunks``): an intermediate chunk runs as a
        suffix prefill — prefix K/V gathered from the paged pool, the
        chunk's tokens forwarded at ``q_offset = tokens_done``, the new
        pages written back at ``start = tokens_done`` — which is
        bit-identical to the monolithic forward over the same positions
        (tests/test_chunked_prefill.py). A chunk that starts at 0 and
        covers the whole prompt takes the monolithic path, so unchunked
        behavior is byte-for-byte the old code.

        The first output token is produced by the FINAL chunk (prefill's
        last forward emits it), so this is also where TTFT is stamped when
        a clock is supplied — not at transfer time.
        """
        done: List[Request] = []
        for req in decision.prefill_batch:   # simple per-request prefill (no padding waste)
            if now is not None and req.prefill_start is None:
                req.prefill_start = now
            if req.prefill_start_wall is None:
                req.prefill_start_wall = time.monotonic()
            offset = self.scheduler.prefill_tokens_done(req)
            chunk = decision.prefill_chunks.get(
                req.request_id, req.prompt_len - offset)
            chunk = min(chunk, req.prompt_len - offset)
            if chunk <= 0:
                continue
            final = offset + chunk == req.prompt_len
            if final:
                req.last_prefill_chunk_tokens = chunk
            cached = req.num_cached_prefix_tokens if self.supports_prefix_reuse else 0
            chunk_wall = time.monotonic()
            if offset > 0:
                # Suffix chunk: resident prefix = cached-prefix blocks
                # (shared ref-counted or landed by a remote fetch) plus any
                # previously-executed chunks' pages. Forward ONLY
                # prompt[offset:offset+chunk], attending over the resident
                # K/V, and write only this chunk's pages — a prefix-cache
                # hit skips real compute, a chunk continuation resumes it.
                k_pre, v_pre = self.kv.gather_prefix(req.request_id, offset)
                tokens = jnp.asarray(
                    [req.prompt_tokens[offset:offset + chunk]], jnp.int32)
                if self.tp_degree > 1:
                    logits, cache = tp_mod.sharded_prefill_suffix(
                        self.shard_params, self.cfg, tokens,
                        k_pre[:, None], v_pre[:, None])
                else:
                    logits, cache = self.model.prefill_suffix(
                        self.params, {"tokens": tokens},
                        k_pre[:, None], v_pre[:, None])
                self.kv.write_prefill(req.request_id, cache["k"][:, 0],
                                      cache["v"][:, 0], chunk, start=offset)
                if offset == cached and cached > 0:
                    # first executed chunk of a prefix-hit request
                    self.prefix_hits += 1
                    self.prefix_tokens_reused += cached
            else:
                tokens = jnp.asarray([req.prompt_tokens[:chunk]], jnp.int32)
                if self.tp_degree > 1:
                    logits, cache = tp_mod.sharded_prefill(
                        self.shard_params, self.cfg, tokens)
                else:
                    logits, cache = self.model.prefill(self.params,
                                                       {"tokens": tokens})
                if self.paged:
                    self.kv.write_prefill(req.request_id, cache["k"][:, 0],
                                          cache["v"][:, 0], chunk)
                else:
                    self.states[req.request_id] = jax.tree.map(lambda x: x, cache)
            if final and not req.output_tokens:
                # only the last chunk's last position is the real next-token
                # distribution; intermediate chunks' logits are discarded.
                # A RECOVERY prefill (reset_for_retry folded emitted tokens
                # into the prompt) re-predicts a token the client already
                # has — output_tokens is non-empty, so the duplicate append
                # is skipped and decode resumes from the kept token.
                req.output_tokens.append(int(jnp.argmax(logits[0])))
            self.prefill_tokens_computed += chunk
            if self.tracer is not None:
                self.tracer.emit(
                    req.request_id, "prefill_chunk",
                    start_cycle=now, end_cycle=now,
                    start_wall_s=chunk_wall, end_wall_s=time.monotonic(),
                    node_id=self.node_id,
                    attrs={"offset": offset, "tokens": chunk,
                           "prompt_len": req.prompt_len, "final": final})
            # report ONLY the tokens this cycle actually forwarded:
            # prefill_progressed seeds progress at num_cached_prefix_tokens,
            # so reporting prompt_len here double-counted the hit and let the
            # chunked-prefill budget diverge from executed work
            if self.scheduler.prefill_progressed(req, chunk):
                if now is not None and req.first_token_time is None:
                    req.first_token_time = now
                wall = time.monotonic()
                req.prefill_end_wall = wall
                if req.first_token_wall is None:
                    req.first_token_wall = wall
                if self.tracer is not None:
                    self.tracer.emit(
                        req.request_id, "prefill",
                        start_cycle=req.prefill_start, end_cycle=now,
                        start_wall_s=req.prefill_start_wall,
                        end_wall_s=wall, node_id=self.node_id,
                        attrs={"prompt_len": req.prompt_len,
                               "cached_prefix_tokens": cached})
                done.append(req)
        self.scheduler.last_compute_util = 1.0 if decision.prefill_batch else 0.0
        return done

    # -- decode --------------------------------------------------------------------
    def run_decode(self, decision: ScheduleDecision) -> List[Request]:
        """One decode step for the running batch; returns finished requests."""
        batch = decision.decode_batch
        if not batch:
            return []
        finished: List[Request] = []
        if self.paged:
            decoded = self._decode_paged(batch)
        else:
            decoded = self._decode_state(batch)
        for req in batch:
            last = req.output_tokens[-1]
            eos = req.sampling.eos_token_id
            if req.num_output >= req.sampling.max_new_tokens or (eos is not None and last == eos):
                finished.append(req)
                if not self.paged:
                    self.states.pop(req.request_id, None)
                self.scheduler.decode_finished(req)
        # bandwidth pressure = fraction of the admitted batch that actually
        # decoded a token this cycle (was: pinned 1.0 before checking whether
        # the batch progressed). A fully-progressing batch still reads 1.0 —
        # decode streams the full weights regardless of batch size — but any
        # future path where requests stall mid-cycle now shows up in the load
        # scorer instead of being masked.
        self.scheduler.last_bandwidth_util = decoded / max(1, len(batch))
        return finished

    def _decode_paged(self, batch: List[Request]) -> int:
        if self.use_paged_decode:
            return self._decode_paged_kernel(batch)
        return self._decode_paged_dense(batch)

    def _decode_paged_kernel(self, batch: List[Request]) -> int:
        """Zero-gather step: ONE jitted dispatch for the whole batch.

        Batch and block-table width are padded to power-of-two buckets; pad
        lanes replicate lane 0 (same token / length / block-table row), so
        their append descriptors duplicate lane 0's writes bit-identically
        instead of aiming at block 0.
        """
        b = len(batch)
        # KV cached so far = prompt + all outputs except the newest token,
        # whose KV is written by THIS step at position total-1.
        lens = [r.total_len - 1 for r in batch]
        toks = [r.output_tokens[-1] for r in batch]
        rids = [r.request_id for r in batch]
        tables = self.kv.export_block_tables(rids)
        bp = _next_pow2(b)
        wp = _next_pow2(tables.shape[1])
        bt = np.zeros((bp, wp), np.int32)
        bt[:b, :tables.shape[1]] = tables
        bt[b:] = bt[0]
        tok_arr = np.full((bp,), toks[0], np.int32)
        tok_arr[:b] = toks
        len_arr = np.full((bp,), lens[0], np.int32)
        len_arr[:b] = lens
        self._decode_cache_keys.add((bp, wp))
        # decode_dispatches counts host-issued device computations, by
        # construction: this branch launches exactly ONE (the jitted step —
        # paged attention + fused append inside a single artifact; the argmax
        # below is a host read, not a launch). Anyone adding a second device
        # call to this path must bump the increment or the O(1) claim that
        # benchmarks/decode_throughput.py --check enforces becomes a lie.
        if self.tp_degree > 1:
            logits, new_pools = self._paged_step(
                self.shard_params, jnp.asarray(tok_arr),
                tuple(s.pool for s in self.kv.shards),
                jnp.asarray(bt), jnp.asarray(len_arr))
            for shard, pool in zip(self.kv.shards, new_pools):
                shard.pool = pool
        else:
            logits, self.kv.pool = self._paged_step(
                self.params, jnp.asarray(tok_arr), self.kv.pool,
                jnp.asarray(bt), jnp.asarray(len_arr))
        self.kv.num_pool_dispatches += 1
        self.decode_steps += 1
        self.decode_dispatches += 1
        nxt = np.argmax(np.asarray(logits, np.float32)[:b], axis=-1)
        for i, r in enumerate(batch):
            r.output_tokens.append(int(nxt[i]))
            r.decode_steps += 1
            r.decode_dispatches += 1
        return b

    def _decode_paged_dense(self, batch: List[Request]) -> int:
        """Gather-dense oracle: densify pages per request, decode, write back
        per request — O(batch) dispatches per step. Kept as the reference
        the zero-gather step must match token-for-token."""
        max_len = max(r.total_len for r in batch) + 1
        ks, vs, lens, toks = [], [], [], []
        for r in batch:
            k, v = self.kv.gather_dense(r.request_id, max_len)
            ks.append(k); vs.append(v)
            lens.append(r.total_len - 1)
            toks.append(r.output_tokens[-1])
        cache = {
            "k": jnp.stack(ks, axis=1),            # (L, B, T, KV, hd)
            "v": jnp.stack(vs, axis=1),
            "length": jnp.asarray(lens, jnp.int32),
        }
        logits, new_cache = self.model.decode(
            self.params, jnp.asarray(toks, jnp.int32), cache)
        nxt = jnp.argmax(logits, axis=-1)
        step_dispatches = 2 * len(batch) + 1   # B gathers + decode + B appends
        for i, r in enumerate(batch):
            pos = lens[i]
            k_new = new_cache["k"][:, i, pos]
            v_new = new_cache["v"][:, i, pos]
            self.kv.append_token(r.request_id, k_new, v_new, pos)
            r.output_tokens.append(int(nxt[i]))
            r.decode_steps += 1
            r.decode_dispatches += step_dispatches
        self.decode_steps += 1
        self.decode_dispatches += step_dispatches
        return len(batch)

    def _decode_state(self, batch: List[Request]) -> int:
        n = len(batch)
        for r in batch:   # state caches are per-request pytrees
            cache = self.states[r.request_id]
            logits, cache = self.model.decode(
                self.params, jnp.asarray([r.output_tokens[-1]], jnp.int32), cache)
            self.states[r.request_id] = cache
            r.output_tokens.append(int(jnp.argmax(logits[0])))
            r.decode_steps += 1
            # per-request semantics match serving/api.py: dispatches issued
            # by the cycles this request rode in — the state path runs one
            # decode per request, so every rider is charged the whole cycle
            r.decode_dispatches += n
        self.decode_steps += 1
        self.decode_dispatches += n
        return n

    # -- spill path (scheduler hooks) ------------------------------------------------
    def _spill_kv(self, req: Request) -> None:
        """Save a preempted request's KV off-pool before its blocks free.

        KV cached at preemption time covers positions [0, total_len-1): the
        newest output token's KV would have been written by the decode step
        that could not run (same accounting as ``_decode_paged_kernel``).
        """
        length = req.total_len - 1
        k, v = self.kv.gather_dense(req.request_id, length)
        self.spilled[req.request_id] = (np.asarray(k), np.asarray(v), length)

    def _restore_kv(self, req: Request) -> None:
        """Refill fresh blocks with the saved KV when a swap resumes."""
        entry = self.spilled.pop(req.request_id, None)
        if entry is None:
            return   # nothing was spilled (e.g. prefill-side swap, no KV yet)
        k, v, length = entry
        self.kv.write_prefill(req.request_id, jnp.asarray(k), jnp.asarray(v),
                              length)

    # -- transfer hooks (TransferBackend ports; see core/transfer.py) -------------------
    def export_state(self, req: Request):
        """State-path transfer payload (shipped whole, one segment)."""
        return self.export_state_by_id(req.request_id)

    def import_state(self, req: Request, state) -> None:
        self.import_state_by_id(req.request_id, state)

    def export_state_by_id(self, request_id: int):
        return self.states.pop(request_id)

    def import_state_by_id(self, request_id: int, state) -> None:
        self.states[request_id] = state

    def register_transfer_in(self, req: Request, num_tokens: int) -> List[int]:
        """Destination-side block registration ahead of a paged transfer."""
        return self.scheduler.bm.register(req.request_id, num_tokens)

    # -- lifecycle -----------------------------------------------------------------------
    def release(self, req: Request) -> bool:
        """Drop every trace of a request from this node (cancel path).

        Frees KV blocks, removes the request from all scheduler queues and
        discards any state-path pytree. Safe to call on nodes that never saw
        the request. Returns True if anything was released.
        """
        removed = self.scheduler.remove_request(req)
        if self.states.pop(req.request_id, None) is not None:
            removed = True
        return removed

    # -- cycle -----------------------------------------------------------------------
    def step(self, now: Optional[float] = None) -> Tuple[List[Request], List[Request]]:
        """One scheduling cycle. Returns (prefill_done, decode_finished)."""
        decision = self.scheduler.schedule()
        pre = self.run_prefill(decision, now=now) if decision.prefill_batch else []
        fin = self.run_decode(decision) if decision.decode_batch else []
        if not decision.prefill_batch:
            self.scheduler.last_compute_util = 0.0
        if not decision.decode_batch:
            self.scheduler.last_bandwidth_util = 0.0
        return pre, fin
