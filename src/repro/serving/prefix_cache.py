"""Cluster-wide prefix index (paper §3.2: the controller "identifies global
cache prefix matches to boost throughput and reduce KV Cache transfer
latency"), grown tier-aware in the Mooncake/KVCache-centric direction.

Prefixes are tracked at block granularity: a chain of rolling hashes, one per
full block of tokens, per node. Each entry also records **which tier** holds
that block's KV on the node and the *physical block id in that tier's
namespace*, which is what makes a hit actionable:

* ``"hbm"`` entries point at pool blocks — the scheduler shares those very
  blocks (ref-counted) into the new request's block table, or the runtime
  pulls them from a remote node's pool as one fused descriptor-table
  transfer (see ``serving/cluster.py``);
* ``"dram"`` entries point at host-tier blocks — cold prefixes demoted out
  of the pool by LRU pressure (``serving/host_tier.py``). They are promoted
  back to pool blocks (one fused host->HBM dispatch) before any reuse, so
  the data plane only ever shares HBM blocks.

Honesty rules (the three phantom-hit bugs this module used to have):

* **Stable hashing** — the chain uses ``blake2b`` over the rolling digest and
  the block's token ids, NOT Python's per-process-salted builtin ``hash()``,
  so index state means the same thing across processes and checkpoint
  restores (``PYTHONHASHSEED``-independent, tested).
* **Residency is block-backed** — an entry only advertises KV that a live
  block holds. ``invalidate_blocks`` is called from every pool-recycle path
  (``BlockManager.on_free``) and ``invalidate_host_blocks`` from every
  host-tier eviction; demotion re-points the entry (pool block -> host
  block) BEFORE the pool block frees, so the handoff never advertises dead
  KV in either tier.
* **Re-homing** — after a P->D transfer the KV lives on the decode node, so
  the runtime re-inserts the entry there with the destination block ids and
  the source-side entry dies with the source blocks.

Entries inserted without block ids (``block_ids=None``) still *match* — they
support routing-signal-only callers and tests — but ``lookup`` reports no
shareable blocks for them, so the data plane never pretends to reuse KV it
cannot address.
"""
from __future__ import annotations

import dataclasses
import hashlib
import struct
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

TIER_HBM = "hbm"
TIER_DRAM = "dram"


def _block_hashes(tokens: Sequence[int], block_size: int) -> List[bytes]:
    """Rolling per-block digest chain: hash(i) covers tokens[0 : (i+1)*block).

    ``blake2b`` over (previous digest, token ids) — deterministic across
    processes and Python versions (no interpreter hash salt).
    """
    hashes: List[bytes] = []
    h = b"\x00" * 16
    n_full = len(tokens) - len(tokens) % block_size
    for i in range(0, n_full, block_size):
        m = hashlib.blake2b(h, digest_size=16)
        m.update(struct.pack(f"<{block_size}q", *tokens[i:i + block_size]))
        h = m.digest()
        hashes.append(h)
    return hashes


@dataclasses.dataclass
class PrefixMatch:
    """A node's longest resident prefix for a prompt.

    ``num_tokens`` counts every matched full block; ``block_ids[i]`` /
    ``tiers[i]`` hold the physical block (in its tier's namespace) and the
    tier name per matched block *when known* — shorter (or empty) lists than
    ``num_tokens/block_size`` mean the tail of the match came from entries
    without block backing and is NOT shareable.
    """

    num_tokens: int = 0
    block_ids: List[int] = dataclasses.field(default_factory=list)
    tiers: List[str] = dataclasses.field(default_factory=list)

    @property
    def dram_blocks(self) -> int:
        return sum(1 for t in self.tiers if t == TIER_DRAM)


class GlobalPrefixIndex:
    """chain digest -> (node, tier, block) over every node in the cluster."""

    def __init__(self, block_size: int):
        self.block_size = block_size
        # node_id -> {chain digest -> (tier, block id) or None (unbacked)}
        self._node_hashes: Dict[int, Dict[bytes, Optional[Tuple[str, int]]]] = {}
        # node_id -> {pool block id -> chain digest} (HBM invalidation path)
        self._node_blocks: Dict[int, Dict[int, bytes]] = {}
        # node_id -> {host block id -> chain digest} (DRAM invalidation path)
        self._node_host_blocks: Dict[int, Dict[int, bytes]] = {}
        # node_id -> callback(host_block_ids): fired when a re-insert
        # re-points a digest AWAY from its DRAM backing (e.g. the prefix
        # re-homed to fresh pool blocks after a transfer) — the host tier
        # registers here so orphaned host blocks free instead of squatting
        # resident-but-unbacked forever.
        self.on_host_orphan: Dict[int, "object"] = {}

    @property
    def has_entries(self) -> bool:
        """True when ANY node advertises residency — routers check this
        before paying a full-prompt hashing pass that can only miss."""
        return any(self._node_hashes.values())

    def chain(self, tokens: Sequence[int]) -> List[bytes]:
        """The prompt's digest chain — compute ONCE per routing decision and
        pass to ``lookup``/``best_nodes``: routing probes every node, and
        re-hashing the whole prompt per probe is pure waste."""
        return _block_hashes(tokens, self.block_size)

    # -- updates ------------------------------------------------------------------
    def insert(self, node_id: int, tokens: Sequence[int],
               block_ids: Optional[Sequence[int]] = None,
               tier: str = TIER_HBM) -> None:
        """Record ``tokens``'s full-block prefix chain as resident on a node.

        ``block_ids[i]`` is the physical block (in ``tier``'s namespace)
        holding chain position ``i``; when given it must cover at least every
        full block of ``tokens``. Re-inserting an existing digest re-points
        it at the newest block (the copy most recently written, i.e. the one
        that lives longest).
        """
        hashes = _block_hashes(tokens, self.block_size)
        if block_ids is not None and len(block_ids) < len(hashes):
            raise ValueError(
                f"{len(hashes)} full blocks but only {len(block_ids)} block ids")
        by_hash = self._node_hashes.setdefault(node_id, {})
        for i, h in enumerate(hashes):
            if block_ids is None:
                # an unbacked insert must never disturb a backed entry's
                # block mapping (it would orphan the invalidation path)
                by_hash.setdefault(h, None)
                continue
            self._point(node_id, h, tier, int(block_ids[i]))

    def _point(self, node_id: int, digest: bytes, tier: str, block: int) -> None:
        """Re-point a digest's entry at (tier, block), unmapping the old one."""
        by_hash = self._node_hashes.setdefault(node_id, {})
        old = by_hash.get(digest)
        if old is not None and old != (tier, block):
            self._backmap(node_id, old[0]).pop(old[1], None)
            if old[0] == TIER_DRAM:
                cb = self.on_host_orphan.get(node_id)
                if cb is not None:
                    cb([old[1]])
        by_hash[digest] = (tier, block)
        self._backmap(node_id, tier)[block] = digest

    def _backmap(self, node_id: int, tier: str) -> Dict[int, bytes]:
        if tier == TIER_HBM:
            return self._node_blocks.setdefault(node_id, {})
        if tier == TIER_DRAM:
            return self._node_host_blocks.setdefault(node_id, {})
        raise ValueError(f"unknown tier {tier!r}")

    def demote_block(self, node_id: int, pool_block: int,
                     host_block: int) -> Optional[bytes]:
        """Pool block's KV moved to the host tier: re-point its entry.

        Runs BEFORE the pool block physically frees (``on_evict`` window),
        so the later ``on_free`` -> ``invalidate_blocks`` finds no mapping
        for the pool block and the dram entry survives. Returns the digest,
        or None when the pool block backed no entry (nothing to demote).
        """
        h = self._node_blocks.get(node_id, {}).pop(int(pool_block), None)
        if h is None:
            return None
        self._node_hashes[node_id][h] = (TIER_DRAM, int(host_block))
        self._backmap(node_id, TIER_DRAM)[int(host_block)] = h
        return h

    def promote_entry(self, node_id: int, host_block: int,
                      pool_block: int) -> Optional[bytes]:
        """Host block's KV copied back into a pool block: re-point its entry."""
        h = self._node_host_blocks.get(node_id, {}).pop(int(host_block), None)
        if h is None:
            return None
        self._node_hashes[node_id][h] = (TIER_HBM, int(pool_block))
        self._backmap(node_id, TIER_HBM)[int(pool_block)] = h
        return h

    def invalidate_blocks(self, node_id: int, block_ids: Iterable[int]) -> None:
        """Drop every entry whose backing POOL block was recycled.

        Wired as ``BlockManager.on_free`` so cache-evict / node teardown
        stop advertising dead HBM KV. Demoted entries are immune: demotion
        unmapped the pool block before it freed.
        """
        self._invalidate(node_id, block_ids, self._node_blocks)

    def invalidate_host_blocks(self, node_id: int,
                               block_ids: Iterable[int]) -> None:
        """Drop every entry whose backing HOST block was evicted/overwritten."""
        self._invalidate(node_id, block_ids, self._node_host_blocks)

    def _invalidate(self, node_id: int, block_ids: Iterable[int],
                    backmaps: Dict[int, Dict[int, bytes]]) -> None:
        by_hash = self._node_hashes.get(node_id)
        by_block = backmaps.get(node_id)
        if not by_block:
            return
        for b in block_ids:
            h = by_block.pop(int(b), None)
            if h is not None:
                by_hash.pop(h, None)

    def evict_node(self, node_id: int) -> None:
        self._node_hashes.pop(node_id, None)
        self._node_blocks.pop(node_id, None)
        self._node_host_blocks.pop(node_id, None)

    # -- queries ------------------------------------------------------------------
    def lookup(self, node_id: int, tokens: Sequence[int],
               hashes: Optional[List[bytes]] = None) -> PrefixMatch:
        """Longest resident prefix on ``node_id``, with its backing blocks.

        ``block_ids``/``tiers`` stop at the first unbacked entry: only a
        contiguous block-backed run is shareable by the data plane.
        ``hashes`` takes a precomputed :meth:`chain` (routing probes many
        nodes per request). Hit/miss rates are NOT counted here —
        speculative routing probes would swamp them; the runtimes count real
        hits at execution time.
        """
        resident = self._node_hashes.get(node_id)
        if not resident:
            return PrefixMatch()
        match = PrefixMatch()
        blocks_ok = True
        for h in (self.chain(tokens) if hashes is None else hashes):
            if h not in resident:
                break
            match.num_tokens += self.block_size
            entry = resident[h]
            if blocks_ok and entry is not None:
                match.block_ids.append(entry[1])
                match.tiers.append(entry[0])
            else:
                blocks_ok = False
        return match

    def match(self, node_id: int, tokens: Sequence[int]) -> int:
        """Longest cached prefix (in tokens) resident on ``node_id``."""
        return self.lookup(node_id, tokens).num_tokens

    def best_nodes(self, tokens: Sequence[int],
                   hashes: Optional[List[bytes]] = None) -> List[Tuple[int, int]]:
        """(node_id, matched_tokens) sorted by match length, desc."""
        hashes = self.chain(tokens) if hashes is None else hashes
        out = [(nid, self.lookup(nid, tokens, hashes).num_tokens)
               for nid in self._node_hashes]
        out.sort(key=lambda t: -t[1])
        return out

    def backed_block(self, node_id: int, block_id: int,
                     tier: str = TIER_HBM) -> bool:
        """True when this (tier, block) physically backs an index entry —
        the demotion filter: an unbacked pool block holds no advertised
        prefix, so evicting it loses nothing worth a DRAM copy."""
        maps = (self._node_blocks if tier == TIER_HBM
                else self._node_host_blocks)
        return int(block_id) in maps.get(node_id, {})

    def entry_tier(self, node_id: int, digest: bytes) -> Optional[str]:
        """The tier backing one digest on a node (None = absent/unbacked)."""
        entry = self._node_hashes.get(node_id, {}).get(digest)
        return None if entry is None else entry[0]

    def stats(self) -> Dict[str, int]:
        return {
            "nodes": len(self._node_hashes),
            "total_entries": sum(len(s) for s in self._node_hashes.values()),
            "backed_entries": sum(len(s) for s in self._node_blocks.values())
            + sum(len(s) for s in self._node_host_blocks.values()),
            "dram_entries": sum(len(s)
                                for s in self._node_host_blocks.values()),
        }


# PR 5 name: same object, pre-tier API is a strict subset.
PrefixCacheIndex = GlobalPrefixIndex
