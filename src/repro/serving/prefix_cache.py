"""Global prefix-cache index (paper §3.2: the controller "identifies global
cache prefix matches to boost throughput and reduce KV Cache transfer
latency").

Prefixes are tracked at block granularity: a chain of rolling hashes, one per
full block of tokens, per node. The controller queries the index when routing
a prefill request; a hit lets the target node skip recomputing the matched
prefix (``Request.num_cached_prefix_tokens``).
"""
from __future__ import annotations

import collections
from typing import Dict, List, Sequence, Tuple


def _block_hashes(tokens: Sequence[int], block_size: int) -> List[int]:
    """Rolling per-block hash chain: hash(i) covers tokens[0 : (i+1)*block)."""
    hashes: List[int] = []
    h = 0
    for i in range(0, len(tokens) - len(tokens) % block_size, block_size):
        h = hash((h, tuple(tokens[i:i + block_size])))
        hashes.append(h)
    return hashes


class PrefixCacheIndex:
    def __init__(self, block_size: int):
        self.block_size = block_size
        # node_id -> set of block-chain hashes resident on that node
        self._node_hashes: Dict[int, set[int]] = collections.defaultdict(set)
        # hash -> ref count across nodes (for stats)
        self._refcount: collections.Counter = collections.Counter()

    # -- updates ------------------------------------------------------------------
    def insert(self, node_id: int, tokens: Sequence[int]) -> None:
        for h in _block_hashes(tokens, self.block_size):
            if h not in self._node_hashes[node_id]:
                self._node_hashes[node_id].add(h)
                self._refcount[h] += 1

    def evict_node(self, node_id: int) -> None:
        for h in self._node_hashes.pop(node_id, set()):
            self._refcount[h] -= 1
            if self._refcount[h] <= 0:
                del self._refcount[h]

    # -- queries ------------------------------------------------------------------
    def match(self, node_id: int, tokens: Sequence[int]) -> int:
        """Longest cached prefix (in tokens) resident on ``node_id``."""
        resident = self._node_hashes.get(node_id)
        if not resident:
            return 0
        matched = 0
        for h in _block_hashes(tokens, self.block_size):
            if h in resident:
                matched += self.block_size
            else:
                break
        return matched

    def best_nodes(self, tokens: Sequence[int]) -> List[Tuple[int, int]]:
        """(node_id, matched_tokens) sorted by match length, desc."""
        out = [(nid, self.match(nid, tokens)) for nid in self._node_hashes]
        out.sort(key=lambda t: -t[1])
        return out

    def stats(self) -> Dict[str, int]:
        return {
            "nodes": len(self._node_hashes),
            "unique_prefixes": len(self._refcount),
            "total_entries": sum(len(s) for s in self._node_hashes.values()),
        }
