"""Global prefix-cache index (paper §3.2: the controller "identifies global
cache prefix matches to boost throughput and reduce KV Cache transfer
latency").

Prefixes are tracked at block granularity: a chain of rolling hashes, one per
full block of tokens, per node. Each entry also records the *physical block
id* holding that block's KV on the node, which is what makes a hit actionable:
the scheduler shares those very blocks (ref-counted) into the new request's
block table, or the runtime pulls them from a remote node as one fused
descriptor-table transfer (see ``serving/cluster.py``).

Honesty rules (the three phantom-hit bugs this module used to have):

* **Stable hashing** — the chain uses ``blake2b`` over the rolling digest and
  the block's token ids, NOT Python's per-process-salted builtin ``hash()``,
  so index state means the same thing across processes and checkpoint
  restores (``PYTHONHASHSEED``-independent, tested).
* **Residency is block-backed** — an entry only advertises KV that a live
  block holds. ``invalidate_blocks`` is called from every block-free path
  (``BlockManager.on_free``): transfer-done frees, decode finish, cancel,
  preemption spill, node release. A block shared by several requests only
  frees (and only invalidates) when its refcount reaches zero.
* **Re-homing** — after a P->D transfer the KV lives on the decode node, so
  the runtime re-inserts the entry there with the destination block ids and
  the source-side entry dies with the source blocks.

Entries inserted without block ids (``block_ids=None``) still *match* — they
support routing-signal-only callers and tests — but ``lookup`` reports no
shareable blocks for them, so the data plane never pretends to reuse KV it
cannot address.
"""
from __future__ import annotations

import dataclasses
import hashlib
import struct
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


def _block_hashes(tokens: Sequence[int], block_size: int) -> List[bytes]:
    """Rolling per-block digest chain: hash(i) covers tokens[0 : (i+1)*block).

    ``blake2b`` over (previous digest, token ids) — deterministic across
    processes and Python versions (no interpreter hash salt).
    """
    hashes: List[bytes] = []
    h = b"\x00" * 16
    n_full = len(tokens) - len(tokens) % block_size
    for i in range(0, n_full, block_size):
        m = hashlib.blake2b(h, digest_size=16)
        m.update(struct.pack(f"<{block_size}q", *tokens[i:i + block_size]))
        h = m.digest()
        hashes.append(h)
    return hashes


@dataclasses.dataclass
class PrefixMatch:
    """A node's longest resident prefix for a prompt.

    ``num_tokens`` counts every matched full block; ``block_ids`` holds the
    physical block per matched block *when known* — a shorter (or empty)
    ``block_ids`` than ``num_tokens/block_size`` means the tail of the match
    came from entries without block backing and is NOT shareable.
    """

    num_tokens: int = 0
    block_ids: List[int] = dataclasses.field(default_factory=list)


class PrefixCacheIndex:
    def __init__(self, block_size: int):
        self.block_size = block_size
        # node_id -> {chain digest -> physical block id or None (unbacked)}
        self._node_hashes: Dict[int, Dict[bytes, Optional[int]]] = {}
        # node_id -> {physical block id -> chain digest} (invalidation path)
        self._node_blocks: Dict[int, Dict[int, bytes]] = {}

    @property
    def has_entries(self) -> bool:
        """True when ANY node advertises residency — routers check this
        before paying a full-prompt hashing pass that can only miss."""
        return any(self._node_hashes.values())

    def chain(self, tokens: Sequence[int]) -> List[bytes]:
        """The prompt's digest chain — compute ONCE per routing decision and
        pass to ``lookup``/``best_nodes``: routing probes every node, and
        re-hashing the whole prompt per probe is pure waste."""
        return _block_hashes(tokens, self.block_size)

    # -- updates ------------------------------------------------------------------
    def insert(self, node_id: int, tokens: Sequence[int],
               block_ids: Optional[Sequence[int]] = None) -> None:
        """Record ``tokens``'s full-block prefix chain as resident on a node.

        ``block_ids[i]`` is the physical block holding chain position ``i``;
        when given it must cover at least every full block of ``tokens``.
        Re-inserting an existing digest re-points it at the newest block (the
        copy most recently written, i.e. the one that lives longest).
        """
        hashes = _block_hashes(tokens, self.block_size)
        if block_ids is not None and len(block_ids) < len(hashes):
            raise ValueError(
                f"{len(hashes)} full blocks but only {len(block_ids)} block ids")
        by_hash = self._node_hashes.setdefault(node_id, {})
        by_block = self._node_blocks.setdefault(node_id, {})
        for i, h in enumerate(hashes):
            if block_ids is None:
                # an unbacked insert must never disturb a backed entry's
                # block mapping (it would orphan the invalidation path)
                by_hash.setdefault(h, None)
                continue
            b = int(block_ids[i])
            old = by_hash.get(h)
            if old is not None and old != b:
                by_block.pop(old, None)
            by_hash[h] = b
            by_block[b] = h

    def invalidate_blocks(self, node_id: int, block_ids: Iterable[int]) -> None:
        """Drop every entry whose backing block was freed (refcount zero).

        Wired as ``BlockManager.on_free`` so release / cancel / preemption /
        transfer-done / node teardown all stop advertising dead KV.
        """
        by_hash = self._node_hashes.get(node_id)
        by_block = self._node_blocks.get(node_id)
        if not by_block:
            return
        for b in block_ids:
            h = by_block.pop(int(b), None)
            if h is not None:
                by_hash.pop(h, None)

    def evict_node(self, node_id: int) -> None:
        self._node_hashes.pop(node_id, None)
        self._node_blocks.pop(node_id, None)

    # -- queries ------------------------------------------------------------------
    def lookup(self, node_id: int, tokens: Sequence[int],
               hashes: Optional[List[bytes]] = None) -> PrefixMatch:
        """Longest resident prefix on ``node_id``, with its backing blocks.

        ``block_ids`` stops at the first unbacked entry: only a contiguous
        block-backed run is shareable by the data plane. ``hashes`` takes a
        precomputed :meth:`chain` (routing probes many nodes per request).
        Hit/miss rates are NOT counted here — speculative routing probes
        would swamp them; the runtimes count real hits at execution time.
        """
        resident = self._node_hashes.get(node_id)
        if not resident:
            return PrefixMatch()
        match = PrefixMatch()
        blocks_ok = True
        for h in (self.chain(tokens) if hashes is None else hashes):
            if h not in resident:
                break
            match.num_tokens += self.block_size
            b = resident[h]
            if blocks_ok and b is not None:
                match.block_ids.append(b)
            else:
                blocks_ok = False
        return match

    def match(self, node_id: int, tokens: Sequence[int]) -> int:
        """Longest cached prefix (in tokens) resident on ``node_id``."""
        return self.lookup(node_id, tokens).num_tokens

    def best_nodes(self, tokens: Sequence[int],
                   hashes: Optional[List[bytes]] = None) -> List[Tuple[int, int]]:
        """(node_id, matched_tokens) sorted by match length, desc."""
        hashes = self.chain(tokens) if hashes is None else hashes
        out = [(nid, self.lookup(nid, tokens, hashes).num_tokens)
               for nid in self._node_hashes]
        out.sort(key=lambda t: -t[1])
        return out

    def stats(self) -> Dict[str, int]:
        return {
            "nodes": len(self._node_hashes),
            "total_entries": sum(len(s) for s in self._node_hashes.values()),
            "backed_entries": sum(len(s) for s in self._node_blocks.values()),
        }
