"""Device-side paged KV cache in the FlowKV block-major layout.

The pool is ONE array ``(num_blocks, L, 2, payload)`` (paper Eq. 5) so a
request's KV for all layers lives in its blocks contiguously — the transfer
engine moves whole block ranges with single calls. The control plane
(which blocks belong to whom) is ``core.block_manager.BlockManager``.

``write_prefill`` / ``gather_dense`` / ``append_token`` bridge between the
model's dense cache format (L, S, KV, hd) and pages. At serving time the
decode plane does NOT use the bridge: ``models/transformer.decode_step_paged``
reads pages in place through ``kernels/paged_attention`` and appends the
batch's new K/V with one fused scatter (``export_block_tables`` /
``append_tokens`` are its host-side ports). The dense bridge here is the
reference data path — the oracle the paged step is tested against.

``num_pool_dispatches`` counts host-issued device ops against the pool
(dense bridge calls + fused imports/appends); the decode benchmark reads it
to show the O(batch) -> O(1) collapse.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.block_manager import BlockManager
from repro.core.layout import KVCacheSpec, KVLayout, alloc_cache
from repro.models.common import ModelConfig


def spec_for_model(cfg: ModelConfig, num_blocks: int,
                   layout: KVLayout = KVLayout.FLOWKV) -> KVCacheSpec:
    return KVCacheSpec(
        num_layers=cfg.num_attention_layers() or cfg.num_layers,
        num_blocks=num_blocks,
        block_size=cfg.block_size,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim,
        dtype=cfg.dtype,
        layout=layout,
    )


class PagedKVCache:
    """One node's paged pool + block manager.

    ``bm`` shares an existing BlockManager instead of owning one: the
    sharded cache below keeps ONE control plane (global page ids) over
    ``tp`` per-shard pools, so every shard's PagedKVCache is built around
    the same manager.
    """

    def __init__(self, spec: KVCacheSpec, allocator: str = "flowkv",
                 bm: Optional[BlockManager] = None):
        self.spec = spec
        self.pool = alloc_cache(spec)
        self.bm = bm if bm is not None else BlockManager(
            spec.num_blocks, spec.block_size, allocator)
        self.num_pool_dispatches = 0     # host-issued device ops on the pool

    # -- write path -------------------------------------------------------------
    def write_prefill(self, request_id: int, k: jax.Array, v: jax.Array,
                      length: int, start: int = 0) -> List[int]:
        """Store a request's prefill KV. k/v: (L, S, KV, hd), S >= length.

        Blocks must already be allocated (scheduler does it at admission).
        K and V land in ONE pool update (whole blocks, all layers), not one
        per cache half.

        ``start`` (block-aligned) writes a SUFFIX: k/v cover tokens
        ``start..start+length`` and land in the table's blocks after the
        shared prefix — a prefix-cache hit writes only the tokens it
        actually computed, never touching the shared (read-only) blocks.
        """
        spec = self.spec
        assert start % spec.block_size == 0, "suffix writes are block-aligned"
        first = start // spec.block_size
        blocks = self.bm.get(request_id)[first:]
        nb = spec.blocks_for_tokens(length)
        assert nb <= len(blocks), (nb, len(blocks))
        pad = nb * spec.block_size - length
        k = k[:, :length]
        v = v[:, :length]
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        L = spec.num_layers
        # (L, nb, bs, KV, hd) -> (nb, L, bs*KV*hd)
        kp = k.reshape(L, nb, spec.block_size, -1).transpose(1, 0, 2, 3).reshape(nb, L, -1)
        vp = v.reshape(L, nb, spec.block_size, -1).transpose(1, 0, 2, 3).reshape(nb, L, -1)
        idx = jnp.asarray(blocks[:nb], jnp.int32)
        kv = jnp.stack([kp, vp], axis=2).astype(spec.dtype)   # (nb, L, 2, payload)
        self.pool = self.pool.at[idx].set(kv)
        self.num_pool_dispatches += 1
        return blocks[:nb]

    def append_token(self, request_id: int, k_new: jax.Array, v_new: jax.Array,
                     position: int) -> None:
        """Write one token's K/V (L, KV, hd) at absolute position.

        Reference path only — one pool rewrite PER REQUEST per step. The
        serving decode plane appends the whole batch in one fused dispatch
        (:meth:`append_tokens` / ``kv_append_tokens``).
        """
        spec = self.spec
        blocks = self.bm.get(request_id)
        block = blocks[position // spec.block_size]
        slot = position % spec.block_size
        L = spec.num_layers
        pv = self.pool[block].reshape(L, 2, spec.block_size, -1)
        pv = pv.at[:, 0, slot].set(k_new.reshape(L, -1).astype(spec.dtype))
        pv = pv.at[:, 1, slot].set(v_new.reshape(L, -1).astype(spec.dtype))
        self.pool = self.pool.at[block].set(pv.reshape(L, 2, -1))
        self.num_pool_dispatches += 1

    def append_tokens(self, request_ids: Sequence[int], k_new: jax.Array,
                      v_new: jax.Array, positions: Sequence[int]) -> None:
        """Fused batch append: every request's token in ONE dispatch.

        k_new / v_new (L, B, KV, hd); positions are absolute token indices.
        """
        from repro.kernels.kv_gather import kv_append_tokens

        tables = self.export_block_tables(request_ids)
        pos = jnp.asarray(list(positions), jnp.int32)
        self.pool = kv_append_tokens(self.pool, jnp.asarray(tables), pos,
                                     k_new, v_new,
                                     block_size=self.spec.block_size)
        self.num_pool_dispatches += 1

    # -- read path ---------------------------------------------------------------
    def export_block_tables(self, request_ids: Sequence[int]) -> np.ndarray:
        """Padded (B, W) int32 block table for a batch of requests, W = the
        longest table. Rows shorter than W are zero-padded; the paged kernel
        masks them by length, and the fused append never addresses them.
        """
        tables = [self.bm.get(rid) for rid in request_ids]
        w = max((len(t) for t in tables), default=1)
        out = np.zeros((len(tables), max(1, w)), np.int32)
        for i, t in enumerate(tables):
            out[i, :len(t)] = t
        return out

    def gather_prefix(self, request_id: int, length: int
                      ) -> Tuple[jax.Array, jax.Array]:
        """Dense K/V of a request's first ``length`` tokens — reads ONLY the
        blocks holding them (the shared prefix of a cache hit), so fresh
        suffix blocks full of garbage are never touched."""
        nb = self.spec.blocks_for_tokens(length)
        return self.gather_dense(request_id, length, num_blocks=nb)

    def gather_dense(self, request_id: int, max_len: int,
                     num_blocks: Optional[int] = None
                     ) -> Tuple[jax.Array, jax.Array]:
        """Rebuild (L, max_len, KV, hd) dense K/V from pages (reference path)."""
        spec = self.spec
        blocks = self.bm.get(request_id)
        if num_blocks is not None:
            blocks = blocks[:num_blocks]
        idx = jnp.asarray(blocks, jnp.int32)
        pages = jnp.take(self.pool, idx, axis=0)          # (nb, L, 2, payload)
        self.num_pool_dispatches += 1
        nb = pages.shape[0]
        L = spec.num_layers
        pages = pages.reshape(nb, L, 2, spec.block_size, spec.num_kv_heads, spec.head_dim)
        k = pages[:, :, 0].transpose(1, 0, 2, 3, 4).reshape(L, nb * spec.block_size,
                                                            spec.num_kv_heads, spec.head_dim)
        v = pages[:, :, 1].transpose(1, 0, 2, 3, 4).reshape(L, nb * spec.block_size,
                                                            spec.num_kv_heads, spec.head_dim)
        cur = k.shape[1]
        if cur < max_len:
            k = jnp.pad(k, ((0, 0), (0, max_len - cur), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, max_len - cur), (0, 0), (0, 0)))
        return k[:, :max_len], v[:, :max_len]

    # -- transfer path -----------------------------------------------------------
    def import_plan(self, engine, plan, src_pool: jax.Array) -> None:
        """Land one transfer plan in this pool as ONE fused dispatch.

        Replaces per-page copies: the engine lowers the plan to its descriptor
        table and the whole table executes in a single jitted Pallas call,
        updating the pool in place (donated where the backend allows).
        """
        self.pool = engine.execute(plan, src_pool, self.pool)
        self.num_pool_dispatches += 1

    # -- capacity / bookkeeping -----------------------------------------------------
    @property
    def utilization(self) -> float:
        return self.bm.utilization

    def free(self, request_id: int) -> None:
        self.bm.free(request_id)

    def check_invariants(self) -> None:
        self.bm.check_invariants()


class ShardedKVCache:
    """``tp`` per-shard pools over ONE block manager (mesh-parallel pool).

    Shard ``s`` holds the FLOWKV pool for its contiguous kv-head slice —
    same ``(num_blocks, L, 2, ·)`` geometry, payload ``block_size *
    (num_kv_heads/tp) * head_dim``. Page ids are GLOBAL: one BlockManager
    allocates for all shards (a request's block i is block i in every
    shard's pool), which is what lets a cross-degree transfer plan address
    both sides with one descriptor table (core/transfer.ShardedTransferEngine)
    and keeps the leak/invariant audit a single-control-plane problem.

    The dense bridge (write/gather) presents FULL-width K/V to callers and
    slices/concats on the kv-head axis at the boundary, so the engine's
    prefill, spill and prefix-reuse paths are shard-agnostic.

    ``num_pool_dispatches`` counts host-issued device ops, matching
    PagedKVCache semantics per ROLE not per shard (one fused decode step is
    one dispatch from the host even though it touches ``tp`` pools — on a
    real mesh those are the same launch). ``shard_dispatches`` counts the
    per-(src_shard, dst_shard)-pair fused transfer dispatches landed here.
    """

    def __init__(self, spec: KVCacheSpec, tp: int, allocator: str = "flowkv"):
        from repro.core.transfer import ShardSpec, shard_slice_spec

        self.spec = spec                       # FULL-width spec
        self.tp = tp
        self.shard_spec = ShardSpec(tp, spec.num_kv_heads)
        self.bm = BlockManager(spec.num_blocks, spec.block_size, allocator)
        self.shards = [
            PagedKVCache(shard_slice_spec(spec, self.shard_spec), allocator,
                         bm=self.bm)
            for _ in range(tp)]
        self.num_pool_dispatches = 0
        self.shard_dispatches = 0              # per-shard-pair transfer lands

    @property
    def pools(self) -> List[jax.Array]:
        return [s.pool for s in self.shards]

    def _head_slices(self, arr: jax.Array, axis: int) -> List[jax.Array]:
        width = arr.shape[axis] // self.tp
        return [jax.lax.slice_in_dim(arr, s * width, (s + 1) * width,
                                     axis=axis)
                for s in range(self.tp)]

    # -- write path -------------------------------------------------------------
    def write_prefill(self, request_id: int, k: jax.Array, v: jax.Array,
                      length: int, start: int = 0) -> List[int]:
        """Full-width (L, S, KV, hd) K/V: each shard writes its head slice."""
        ks, vs = self._head_slices(k, 2), self._head_slices(v, 2)
        blocks: List[int] = []
        for shard, k_s, v_s in zip(self.shards, ks, vs):
            blocks = shard.write_prefill(request_id, k_s, v_s, length,
                                         start=start)
        self.num_pool_dispatches += 1
        return blocks

    def append_token(self, request_id: int, k_new: jax.Array,
                     v_new: jax.Array, position: int) -> None:
        for shard, k_s, v_s in zip(self.shards,
                                   self._head_slices(k_new, 1),
                                   self._head_slices(v_new, 1)):
            shard.append_token(request_id, k_s, v_s, position)
        self.num_pool_dispatches += 1

    def append_tokens(self, request_ids: Sequence[int], k_new: jax.Array,
                      v_new: jax.Array, positions: Sequence[int]) -> None:
        for shard, k_s, v_s in zip(self.shards,
                                   self._head_slices(k_new, 2),
                                   self._head_slices(v_new, 2)):
            shard.append_tokens(request_ids, k_s, v_s, positions)
        self.num_pool_dispatches += 1

    # -- read path ---------------------------------------------------------------
    def export_block_tables(self, request_ids: Sequence[int]) -> np.ndarray:
        return self.shards[0].export_block_tables(request_ids)

    def gather_prefix(self, request_id: int, length: int
                      ) -> Tuple[jax.Array, jax.Array]:
        nb = self.spec.blocks_for_tokens(length)
        return self.gather_dense(request_id, length, num_blocks=nb)

    def gather_dense(self, request_id: int, max_len: int,
                     num_blocks: Optional[int] = None
                     ) -> Tuple[jax.Array, jax.Array]:
        parts = [s.gather_dense(request_id, max_len, num_blocks=num_blocks)
                 for s in self.shards]
        self.num_pool_dispatches += 1
        return (jnp.concatenate([k for k, _ in parts], axis=2),
                jnp.concatenate([v for _, v in parts], axis=2))

    # -- transfer path -----------------------------------------------------------
    def import_plan(self, engine, plan, src_pools: Sequence[jax.Array]) -> None:
        """Land a sharded transfer plan: one fused dispatch per shard pair.

        ``engine`` is a :class:`~repro.core.transfer.ShardedTransferEngine`;
        ``src_pools`` are the source node's per-shard pools (any tp degree).
        """
        before = engine.num_dispatches
        new_pools = engine.execute(plan, list(src_pools), self.pools)
        for shard, pool in zip(self.shards, new_pools):
            shard.pool = pool
        landed = engine.num_dispatches - before
        self.shard_dispatches += landed
        self.num_pool_dispatches += landed

    # -- capacity / bookkeeping -----------------------------------------------------
    @property
    def utilization(self) -> float:
        return self.bm.utilization

    def free(self, request_id: int) -> None:
        self.bm.free(request_id)

    def check_invariants(self) -> None:
        self.bm.check_invariants()
