"""Device-side paged KV cache in the FlowKV block-major layout.

The pool is ONE array ``(num_blocks, L, 2, payload)`` (paper Eq. 5) so a
request's KV for all layers lives in its blocks contiguously — the transfer
engine moves whole block ranges with single calls. The control plane
(which blocks belong to whom) is ``core.block_manager.BlockManager``.

``write_prefill`` / ``gather_dense`` / ``append_token`` bridge between the
model's dense cache format (L, S, KV, hd) and pages. At serving time the
decode plane does NOT use the bridge: ``models/transformer.decode_step_paged``
reads pages in place through ``kernels/paged_attention`` and appends the
batch's new K/V with one fused scatter (``export_block_tables`` /
``append_tokens`` are its host-side ports). The dense bridge here is the
reference data path — the oracle the paged step is tested against.

``num_pool_dispatches`` counts host-issued device ops against the pool
(dense bridge calls + fused imports/appends); the decode benchmark reads it
to show the O(batch) -> O(1) collapse.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.block_manager import BlockManager
from repro.core.layout import KVCacheSpec, KVLayout, alloc_cache
from repro.models.common import ModelConfig


def spec_for_model(cfg: ModelConfig, num_blocks: int,
                   layout: KVLayout = KVLayout.FLOWKV) -> KVCacheSpec:
    return KVCacheSpec(
        num_layers=cfg.num_attention_layers() or cfg.num_layers,
        num_blocks=num_blocks,
        block_size=cfg.block_size,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim,
        dtype=cfg.dtype,
        layout=layout,
    )


class PagedKVCache:
    """One node's paged pool + block manager."""

    def __init__(self, spec: KVCacheSpec, allocator: str = "flowkv"):
        self.spec = spec
        self.pool = alloc_cache(spec)
        self.bm = BlockManager(spec.num_blocks, spec.block_size, allocator)
        self.num_pool_dispatches = 0     # host-issued device ops on the pool

    # -- write path -------------------------------------------------------------
    def write_prefill(self, request_id: int, k: jax.Array, v: jax.Array,
                      length: int, start: int = 0) -> List[int]:
        """Store a request's prefill KV. k/v: (L, S, KV, hd), S >= length.

        Blocks must already be allocated (scheduler does it at admission).
        K and V land in ONE pool update (whole blocks, all layers), not one
        per cache half.

        ``start`` (block-aligned) writes a SUFFIX: k/v cover tokens
        ``start..start+length`` and land in the table's blocks after the
        shared prefix — a prefix-cache hit writes only the tokens it
        actually computed, never touching the shared (read-only) blocks.
        """
        spec = self.spec
        assert start % spec.block_size == 0, "suffix writes are block-aligned"
        first = start // spec.block_size
        blocks = self.bm.get(request_id)[first:]
        nb = spec.blocks_for_tokens(length)
        assert nb <= len(blocks), (nb, len(blocks))
        pad = nb * spec.block_size - length
        k = k[:, :length]
        v = v[:, :length]
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        L = spec.num_layers
        # (L, nb, bs, KV, hd) -> (nb, L, bs*KV*hd)
        kp = k.reshape(L, nb, spec.block_size, -1).transpose(1, 0, 2, 3).reshape(nb, L, -1)
        vp = v.reshape(L, nb, spec.block_size, -1).transpose(1, 0, 2, 3).reshape(nb, L, -1)
        idx = jnp.asarray(blocks[:nb], jnp.int32)
        kv = jnp.stack([kp, vp], axis=2).astype(spec.dtype)   # (nb, L, 2, payload)
        self.pool = self.pool.at[idx].set(kv)
        self.num_pool_dispatches += 1
        return blocks[:nb]

    def append_token(self, request_id: int, k_new: jax.Array, v_new: jax.Array,
                     position: int) -> None:
        """Write one token's K/V (L, KV, hd) at absolute position.

        Reference path only — one pool rewrite PER REQUEST per step. The
        serving decode plane appends the whole batch in one fused dispatch
        (:meth:`append_tokens` / ``kv_append_tokens``).
        """
        spec = self.spec
        blocks = self.bm.get(request_id)
        block = blocks[position // spec.block_size]
        slot = position % spec.block_size
        L = spec.num_layers
        pv = self.pool[block].reshape(L, 2, spec.block_size, -1)
        pv = pv.at[:, 0, slot].set(k_new.reshape(L, -1).astype(spec.dtype))
        pv = pv.at[:, 1, slot].set(v_new.reshape(L, -1).astype(spec.dtype))
        self.pool = self.pool.at[block].set(pv.reshape(L, 2, -1))
        self.num_pool_dispatches += 1

    def append_tokens(self, request_ids: Sequence[int], k_new: jax.Array,
                      v_new: jax.Array, positions: Sequence[int]) -> None:
        """Fused batch append: every request's token in ONE dispatch.

        k_new / v_new (L, B, KV, hd); positions are absolute token indices.
        """
        from repro.kernels.kv_gather import kv_append_tokens

        tables = self.export_block_tables(request_ids)
        pos = jnp.asarray(list(positions), jnp.int32)
        self.pool = kv_append_tokens(self.pool, jnp.asarray(tables), pos,
                                     k_new, v_new,
                                     block_size=self.spec.block_size)
        self.num_pool_dispatches += 1

    # -- read path ---------------------------------------------------------------
    def export_block_tables(self, request_ids: Sequence[int]) -> np.ndarray:
        """Padded (B, W) int32 block table for a batch of requests, W = the
        longest table. Rows shorter than W are zero-padded; the paged kernel
        masks them by length, and the fused append never addresses them.
        """
        tables = [self.bm.get(rid) for rid in request_ids]
        w = max((len(t) for t in tables), default=1)
        out = np.zeros((len(tables), max(1, w)), np.int32)
        for i, t in enumerate(tables):
            out[i, :len(t)] = t
        return out

    def gather_prefix(self, request_id: int, length: int
                      ) -> Tuple[jax.Array, jax.Array]:
        """Dense K/V of a request's first ``length`` tokens — reads ONLY the
        blocks holding them (the shared prefix of a cache hit), so fresh
        suffix blocks full of garbage are never touched."""
        nb = self.spec.blocks_for_tokens(length)
        return self.gather_dense(request_id, length, num_blocks=nb)

    def gather_dense(self, request_id: int, max_len: int,
                     num_blocks: Optional[int] = None
                     ) -> Tuple[jax.Array, jax.Array]:
        """Rebuild (L, max_len, KV, hd) dense K/V from pages (reference path)."""
        spec = self.spec
        blocks = self.bm.get(request_id)
        if num_blocks is not None:
            blocks = blocks[:num_blocks]
        idx = jnp.asarray(blocks, jnp.int32)
        pages = jnp.take(self.pool, idx, axis=0)          # (nb, L, 2, payload)
        self.num_pool_dispatches += 1
        nb = pages.shape[0]
        L = spec.num_layers
        pages = pages.reshape(nb, L, 2, spec.block_size, spec.num_kv_heads, spec.head_dim)
        k = pages[:, :, 0].transpose(1, 0, 2, 3, 4).reshape(L, nb * spec.block_size,
                                                            spec.num_kv_heads, spec.head_dim)
        v = pages[:, :, 1].transpose(1, 0, 2, 3, 4).reshape(L, nb * spec.block_size,
                                                            spec.num_kv_heads, spec.head_dim)
        cur = k.shape[1]
        if cur < max_len:
            k = jnp.pad(k, ((0, 0), (0, max_len - cur), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, max_len - cur), (0, 0), (0, 0)))
        return k[:, :max_len], v[:, :max_len]

    # -- transfer path -----------------------------------------------------------
    def import_plan(self, engine, plan, src_pool: jax.Array) -> None:
        """Land one transfer plan in this pool as ONE fused dispatch.

        Replaces per-page copies: the engine lowers the plan to its descriptor
        table and the whole table executes in a single jitted Pallas call,
        updating the pool in place (donated where the backend allows).
        """
        self.pool = engine.execute(plan, src_pool, self.pool)
        self.num_pool_dispatches += 1

    # -- capacity / bookkeeping -----------------------------------------------------
    @property
    def utilization(self) -> float:
        return self.bm.utilization

    def free(self, request_id: int) -> None:
        self.bm.free(request_id)

    def check_invariants(self) -> None:
        self.bm.check_invariants()
