"""PD-disaggregated cluster runtime (CPU-scale, real compute).

Wires together: NodeEngines (role-flexible P/D nodes) + GlobalController
(routing, regimes, role lifecycle, failover) + the TransferBackend registry
(``core/transfer.py``: paged FlowKV transfer between node pools, whole-state
transfer for ssm/hybrid/encdec, or any registered third-party transport).

The runtime is the *correctness* half of the reproduction: disaggregated
generation must be token-identical to monolithic generation on one engine.
Fault tolerance: ``kill_node`` simulates a node death mid-flight; the
controller's heartbeat scan drains and re-routes its requests.
``checkpoint``/``restore`` round-trip the full cluster state.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple, Union

from repro.core.costmodel import layer_window_overlap, select_route
from repro.core.scheduler.global_controller import (AdmissionDecision,
                                                    AdmissionPolicy,
                                                    GlobalController, ModelCost,
                                                    NodeHandle)
from repro.core.transfer import (ShardedTransferEngine, TransferEngine,
                                 backend_for_engine, land_sharded_plan,
                                 pool_transfer_engine, verify_pool_transfer)
from repro.faults import as_injector
from repro.models.common import ModelConfig
from repro.serving.engine import NodeEngine
from repro.serving.host_tier import TierManager
from repro.serving.request import Request, RequestState
from repro.sim.hardware import HardwareProfile, TPU_V5E


@dataclasses.dataclass
class TransferRecord:
    request_id: int
    schedule: str
    num_calls: int
    num_bytes: int
    est_latency_s: float        # EXPOSED latency (post-prefill wire time)
    num_dispatches: int = 0
    kind: str = "kv"            # "kv" (P->D cache move) | "prefix_fetch"
    # wire time hidden behind the producer's prefill compute by layer-window
    # streaming (0.0 on the unoverlapped path); est_latency_s + hidden_s is
    # the total time on the wire
    hidden_s: float = 0.0
    num_windows: int = 1
    src_node: int = -1
    dst_node: int = -1
    # "ok" | "aborted_dst_dead" (dst died mid-stream; retried to a new dst
    # next cycle) | "degraded" (every retry failed; recomputed on the decode
    # node). Latency aggregates only count "ok" records.
    status: str = "ok"
    retries: int = 0            # failed attempts absorbed by THIS transfer


class PDCluster:
    def __init__(self, cfg: ModelConfig, params, *, num_prefill: int = 1,
                 num_decode: int = 1, num_blocks: int = 256,
                 allocator: str = "flowkv", transfer_schedule: str = "flowkv",
                 hardware: Union[HardwareProfile,
                                 Dict[int, HardwareProfile]] = TPU_V5E,
                 target: str = "tpu",
                 max_batch_tokens: int = 2048, hosts: Optional[Dict[int, int]] = None,
                 role_flip: bool = False, paged_decode: str = "auto",
                 admission: Optional[AdmissionPolicy] = None,
                 prefix_reuse: bool = True, tracer=None,
                 host_tier_blocks: int = 0,
                 chunked_prefill: bool = True,
                 prefill_chunk_tokens: Optional[int] = None,
                 layer_window: int = 0,
                 faults=None,
                 heartbeat_timeout_cycles: float = 10.0,
                 transfer_max_retries: int = 3,
                 transfer_backoff_cycles: float = 0.5,
                 tp_degrees: Optional[Dict[int, int]] = None):
        self.cfg = cfg
        # Per-node mesh-parallel degree ({node_id: tp}, missing ids -> 1):
        # a heterogeneous fleet runs e.g. TP=4 prefill nodes feeding TP=1
        # decode nodes; the transfer plane lowers each cross-degree move to
        # one fused dispatch per overlapping (src_shard, dst_shard) pair.
        self.tp_degrees = dict(tp_degrees or {})
        self.transfer_schedule = transfer_schedule
        self.target = target
        # Fault plane: an optional repro.faults.FaultInjector (or spec list /
        # capture meta dict) drives deterministic chaos — node crashes applied
        # at the top of step(), transfer fail/corrupt verdicts per attempt,
        # bandwidth degradation, heartbeat suppression. None = no faults.
        self.faults = as_injector(faults)
        # Transfer hardening: every fused dispatch is checksum-verified; a
        # failed/corrupt attempt retries with exponential backoff (priced
        # into the transfer's exposed latency), and after
        # transfer_max_retries + 1 failed attempts the request degrades to
        # recompute-on-the-decode-node instead of wedging the sending queue.
        self.transfer_max_retries = transfer_max_retries
        self.transfer_backoff_cycles = transfer_backoff_cycles
        # Layerwise transfer/compute overlap: layer_window > 0 streams each
        # P->D transfer as ceil(L / layer_window) per-layer-window sub-plans
        # (own fused dispatch each), so completed layers' KV is on the wire
        # while later layers still prefill. 0 = classic one-plan transfer.
        self.layer_window = layer_window
        # Optional repro.obs.tracing.SpanRecorder (also settable post-hoc
        # via repro.obs.tracing.attach_tracer): the cluster emits queue /
        # transfer / decode / prefix_fetch spans, engines emit prefill,
        # the controller emits admission.
        self.tracer = tracer
        # prefix_reuse=False disables the reuse DATA PLANE (no recording, no
        # sharing, no fetches) — the A/B switch the token-identity tests and
        # benchmarks/prefix_reuse.py flip. Invalidation stays wired either
        # way; an empty index just never matches.
        self.prefix_reuse = prefix_reuse
        # host_tier_blocks > 0 adds a per-node host-DRAM tier behind the
        # pool: cold index-backed blocks demote there under capacity
        # pressure and promote back (one fused dispatch each way) on re-use.
        self.host_tier_blocks = host_tier_blocks
        self.tiers: Dict[int, TierManager] = {}
        self.engines: Dict[int, NodeEngine] = {}
        model_cost = ModelCost(
            flops_per_token=2.0 * cfg.active_params(),
            kv_bytes_per_token=float(cfg.kv_bytes_per_token() or 1024),
            weight_bytes=2.0 * cfg.num_params(),
        )
        n_attn = cfg.num_attention_layers() or cfg.num_layers
        self.controller = GlobalController(model_cost, cfg.block_size, target=target,
                                           role_flip=role_flip,
                                           admission=admission,
                                           layer_window=layer_window,
                                           num_layers=n_attn,
                                           heartbeat_timeout=heartbeat_timeout_cycles)
        self.controller.tracer = tracer
        self.clock = 0.0
        self.submitted = 0
        self._dead: set = set()      # killed engines stop heartbeating/working
        self.transfers: List[TransferRecord] = []
        self.finished: List[Request] = []
        self.cancelled: List[Request] = []
        self.rejected: List[Request] = []
        # fleet-level fault counters (stats())
        self.fault_kills = 0
        self.transfer_retry_count = 0
        self.degraded_to_recompute = 0
        self.recoveries = 0

        for i in range(num_prefill + num_decode):
            role = "prefill" if i < num_prefill else "decode"
            engine = NodeEngine(i, cfg, params, num_blocks=num_blocks,
                                allocator=allocator, max_batch_tokens=max_batch_tokens,
                                paged_decode=paged_decode,
                                chunked_prefill=chunked_prefill,
                                prefill_chunk_tokens=prefill_chunk_tokens,
                                tp_degree=self.tp_degrees.get(i, 1))
            engine.tracer = tracer
            self.engines[i] = engine
            host = (hosts or {}).get(i, i)
            # heterogeneous fleets: hardware may be one profile for every
            # node or a {node_id: profile} map (missing ids get TPU_V5E)
            hw = hardware.get(i, TPU_V5E) if isinstance(hardware, dict) \
                else hardware
            reuse = prefix_reuse and engine.supports_prefix_reuse
            self.controller.register_node(NodeHandle(
                node_id=i, role=role, host_id=host, hardware=hw,
                scheduler=engine.scheduler, supports_prefix_reuse=reuse,
                tp_degree=engine.tp_degree, ep_degree=engine.ep_degree))
            # residency honesty: ANY path that physically frees blocks
            # (transfer done, decode finish, cancel, preemption, teardown)
            # drops the freed blocks' index entries on this node
            engine.scheduler.bm.on_free = \
                (lambda blocks, nid=i:
                 self.controller.prefix_index.invalidate_blocks(nid, blocks))
            if reuse:
                engine.scheduler.resolve_prefix = self._make_resolver(engine)
            # host tier stays tp=1-only: demotion/promotion move whole-payload
            # pages and would need the per-shard fine-row plumbing to span a
            # sharded pool — not worth it for a cold-prefix cache
            if reuse and host_tier_blocks > 0 and engine.tp_degree == 1 and \
                    getattr(engine, "kv", None) is not None:
                self.tiers[i] = engine.tier = TierManager(
                    i, engine.scheduler.bm, self.controller.prefix_index,
                    engine.kv.spec, host_tier_blocks, kv=engine.kv,
                    schedule=transfer_schedule,
                    get_tracer=lambda: self.tracer,
                    get_clock=lambda: self.clock).attach()

    def _make_resolver(self, engine: NodeEngine):
        """Admission-time prefix resolution for one node (scheduler hook):
        the shared controller helper re-validates the routing-time stamp
        against the LIVE index and this node's block liveness."""
        nid, bm = engine.node_id, engine.scheduler.bm
        return lambda req: self.controller.resolve_local_prefix(
            nid, req, bm.block_alive)

    # -- request entry ------------------------------------------------------------
    def submit(self, req: Request) -> AdmissionDecision:
        """Admission gate + routing. With no AdmissionPolicy every request
        is admitted (legacy behavior); with one, the decision may be
        "deferred" (parked controller-side, admitted as load drains) or
        "rejected" (terminal REJECTED state + retry-after hint)."""
        if req.arrival_wall is None:
            req.arrival_wall = time.monotonic()
        decision = self.controller.submit_request(req)
        if decision.admitted and decision.route is None:
            raise RuntimeError("no alive nodes to route to")
        self.submitted += 1
        self._collect_rejected()
        return decision

    def _collect_rejected(self) -> None:
        for req in self.controller.take_rejected():
            req.finish_time = self.clock
            req.finish_wall = time.monotonic()
            self.rejected.append(req)

    # -- the FlowKV transfer (P pool -> D pool) -------------------------------------
    def _transfer(self, req: Request) -> None:
        """Move one request's cache P->D via the TransferBackend registry.

        The backend (paged vs state vs anything third-party) is resolved
        from the source engine — this method never branches on the cache
        transport itself.
        """
        src = self.engines[req.prefill_node]
        # Failover re-target: the decode node chosen at routing time may
        # have died while the request prefilled. Re-pick BEFORE planning so
        # the dst-side registration lands on a live pool.
        if req.decode_node in self._dead or \
                not self.controller.nodes[req.decode_node].alive:
            nd = self._pick_decode_node(exclude={req.decode_node})
            req.decode_node = nd if nd is not None else src.node_id
        dst = self.engines[req.decode_node]
        req.transfer_start = self.clock
        req.transfer_start_wall = time.monotonic()
        if src is dst:
            # Role-flexible node serving both stages: the cache is already
            # in this node's pool — hand off locally, keep the blocks.
            req.transfer_end = self.clock
            req.transfer_end_wall = req.transfer_start_wall
            req.transfer_calls = req.transfer_dispatches = 0
            src.scheduler.sending_done(req, free=False)
            dst.scheduler.enqueue_decode(req)
            self._rehome_prefix(req, src.node_id,
                                src.scheduler.bm.get(req.request_id))
            if self.tracer is not None:
                self.tracer.emit(
                    req.request_id, "transfer",
                    start_cycle=req.transfer_start, end_cycle=req.transfer_end,
                    start_wall_s=req.transfer_start_wall,
                    end_wall_s=req.transfer_end_wall, node_id=src.node_id,
                    attrs={"schedule": "local", "calls": 0, "dispatches": 0,
                           "bytes": 0, "est_latency_s": 0.0})
            return
        profile = select_route(
            self.controller.nodes[src.node_id].host_id ==
            self.controller.nodes[dst.node_id].host_id, self.target)
        backend = backend_for_engine(src, self.transfer_schedule)
        job = backend.plan(req, src, dst)
        hidden = 0.0
        windows = 1
        retries_before = req.transfer_retries
        if self.layer_window > 0 and job.plan is not None and \
                job.plan.num_layers > self.layer_window:
            outcome, latency, hidden = self._transfer_windowed(
                req, src, dst, job, profile)
            windows = -(-job.plan.num_layers // self.layer_window)
            if outcome != "ok":
                self._abort_transfer(req, src, dst, job, outcome,
                                     req.transfer_retries - retries_before)
                return
        else:
            penalty = self._attempt_unit(
                req, src, dst, lambda: backend.execute(job, src, dst),
                job.plan)
            if penalty is None:
                self._abort_transfer(req, src, dst, job, "exhausted",
                                     req.transfer_retries - retries_before)
                return
            latency = backend.price(job, profile) * self._bandwidth_factor() \
                + penalty
        self.transfers.append(TransferRecord(
            req.request_id, job.schedule, job.num_calls, job.num_bytes, latency,
            job.num_dispatches, hidden_s=hidden, num_windows=windows,
            src_node=src.node_id, dst_node=dst.node_id,
            retries=req.transfer_retries - retries_before))
        req.transfer_end = self.clock + latency
        req.transfer_end_wall = time.monotonic()
        req.transfer_calls = job.num_calls
        req.transfer_dispatches = job.num_dispatches
        if self.tracer is not None:
            self.tracer.emit(
                req.request_id, "transfer",
                start_cycle=req.transfer_start, end_cycle=req.transfer_end,
                start_wall_s=req.transfer_start_wall,
                end_wall_s=req.transfer_end_wall, node_id=src.node_id,
                attrs={"schedule": job.schedule, "calls": job.num_calls,
                       "dispatches": job.num_dispatches,
                       "bytes": job.num_bytes, "est_latency_s": latency,
                       "hidden_s": hidden, "windows": windows,
                       "dst_node": dst.node_id,
                       "src_tp": src.tp_degree, "dst_tp": dst.tp_degree,
                       "retries": req.transfer_retries - retries_before})
        # The prompt's KV now lives on the DECODE node; sending_done below
        # frees the prefill-side blocks (and invalidates their entries), so
        # the index entry is re-homed to where the KV actually is.
        self._rehome_prefix(req, dst.node_id, list(job.dst_blocks))
        src.scheduler.sending_done(req)
        dst.scheduler.enqueue_decode(req)

    # -- transfer hardening (retry / integrity / degradation) -------------------------
    def _bandwidth_factor(self) -> float:
        return self.faults.bandwidth_factor(self.clock) \
            if self.faults is not None else 1.0

    def _pick_decode_node(self, exclude=()) -> Optional[int]:
        """Least-loaded live decode node (any live node as fallback)."""
        cands = [n for n in self.controller.nodes.values()
                 if n.alive and n.node_id not in self._dead
                 and n.node_id not in exclude]
        if not cands:
            return None
        decode = [n for n in cands if n.role == "decode"] or cands
        return min(decode,
                   key=lambda n: len(n.scheduler.decode.running)).node_id

    def _attempt_unit(self, req: Request, src: NodeEngine, dst: NodeEngine,
                      execute, plan) -> Optional[float]:
        """Run one transfer unit (a full plan, or one layer-window sub-plan)
        under the fault injector with post-dispatch integrity checking.

        Every executed dispatch is checksum-verified (src pages vs dst pages
        through the plan's descriptor table); a failed or corrupt attempt
        retries with exponential backoff. Returns the latency penalty the
        retries accrued, or None when all ``transfer_max_retries + 1``
        attempts failed (caller degrades to recompute). An injected "fail"
        drops the attempt before any bytes move; an injected "corrupt" lands
        the payload then flips one destination element, so the checksum —
        not the injector — is what catches it, and the clean retry's
        re-execution overwrites (repairs) the damage.
        """
        penalty = 0.0
        verifiable = (plan is not None and src.kv is not None
                      and dst.kv is not None)
        for attempt in range(self.transfer_max_retries + 1):
            fault = self.faults.transfer_attempt(self.clock) \
                if self.faults is not None else None
            corrupting = fault == "corrupt" and verifiable
            if fault is not None and not corrupting:
                ok = False          # dropped on the wire: nothing reached dst
            else:
                execute()
                if corrupting:
                    self._corrupt_dst(dst, plan)
                ok = verify_pool_transfer(plan, src.kv, dst.kv) \
                    if verifiable else True
            if ok:
                return penalty
            req.transfer_retries += 1
            self.transfer_retry_count += 1
            backoff = self.transfer_backoff_cycles * (2.0 ** attempt)
            penalty += backoff
            if self.tracer is not None:
                wall = self.tracer.wall()
                self.tracer.emit(
                    req.request_id, "transfer_retry",
                    start_cycle=self.clock, end_cycle=self.clock + backoff,
                    start_wall_s=wall, end_wall_s=wall, node_id=src.node_id,
                    attrs={"attempt": attempt, "fault": fault or "checksum",
                           "backoff_s": backoff})
        return None

    def _corrupt_dst(self, dst: NodeEngine, plan) -> None:
        """Injected in-flight corruption: flip one element of the first page
        this plan wrote on the destination (so the checksum genuinely
        mismatches against the source pages)."""
        table = plan.to_descriptors()
        if len(table) == 0:
            return
        # sharded pool: flip an element in shard 0's slice (the per-pair
        # digest covering (src?, dst_shard=0) must catch it)
        kv = dst.kv.shards[0] if hasattr(dst.kv, "shards") else dst.kv
        spec = kv.spec
        pid = int(table.page_ids(spec, "dst")[0])
        pool = kv.pool
        flat = pool.reshape(-1, spec.payload)
        kv.pool = flat.at[pid, 0].add(1.0).reshape(pool.shape)

    def _abort_transfer(self, req: Request, src: NodeEngine, dst: NodeEngine,
                        job, reason: str, retries: int) -> None:
        """A transfer could not complete. Two cases:

        * ``dst_dead`` — the destination died mid-stream. Partial dst state
          is already freed; the request STAYS in the sending queue, so next
          cycle's drain re-picks a live destination and re-plans (the source
          still holds the full KV).
        * ``exhausted`` — every retry of some dispatch failed. Degrade to
          recompute: drop both sides' blocks and re-prefill (token-exact)
          on the decode node, pricing recovery as real prefill compute.
        """
        status = "aborted_dst_dead" if reason == "dst_dead" else "degraded"
        self.transfers.append(TransferRecord(
            req.request_id, job.schedule, job.num_calls, job.num_bytes, 0.0,
            job.num_dispatches, src_node=src.node_id, dst_node=dst.node_id,
            status=status, retries=retries))
        if reason == "dst_dead":
            if dst.scheduler.bm.owns(req.request_id):
                dst.scheduler.bm.free(req.request_id)
            return
        self._degrade_to_recompute(req, src, dst)

    def _degrade_to_recompute(self, req: Request, src: NodeEngine,
                              dst: NodeEngine) -> None:
        """Retry-exhausted transfer: stop moving KV, recompute it instead.

        Frees the partially-written dst registration AND the src blocks,
        then re-enqueues the request as a fresh prefill on the decode node
        (or the source if the destination is gone) — recovery re-prefills
        prompt + already-emitted tokens teacher-forced, so the stream stays
        token-exact, and the cost is honest prefill compute on that node.
        """
        if dst.scheduler.bm.owns(req.request_id):
            dst.scheduler.bm.free(req.request_id)
        src.scheduler.sending_done(req, free=True)
        self.degraded_to_recompute += 1
        target = dst if (dst.node_id not in self._dead and
                         self.controller.nodes[dst.node_id].alive) else src
        self.controller._stamp_failure(req, self.clock, target.node_id,
                                       "transfer_retries_exhausted")
        req.reset_for_retry()
        req.prefill_node = target.node_id
        req.decode_node = target.node_id
        target.scheduler.enqueue_prefill(req)

    def _finish_recovery(self, req: Request, node_id: int) -> None:
        """Close the failure→re-prefilled window (the request is live again,
        its replayed tokens recomputed token-exactly): accumulate the
        failover cost on both clocks and emit the ``recovery`` span."""
        req.recovery_s += self.clock - req.recovery_start
        wall = time.monotonic()
        if req.recovery_start_wall is not None:
            req.recovery_wall_s = (req.recovery_wall_s or 0.0) + \
                (wall - req.recovery_start_wall)
        req.recoveries += 1
        self.recoveries += 1
        if self.tracer is not None:
            self.tracer.emit(
                req.request_id, "recovery",
                start_cycle=req.recovery_start, end_cycle=self.clock,
                start_wall_s=req.recovery_start_wall, end_wall_s=wall,
                node_id=node_id,
                attrs={"replayed_tokens": req.replayed_tokens,
                       "retries": req.retries})
        req.recovery_start = None
        req.recovery_start_wall = None

    def _prefill_tail_s(self, req: Request) -> float:
        """Compute window available for hiding transfer: the duration of
        this request's FINAL prefill chunk on its prefill node (the pass
        whose early layers' KV the first sub-plans ship). Chunking shrinks
        it — the real trade-off: smaller chunks cut queueing TTFT but leave
        less compute to hide wire time behind."""
        tokens = req.last_prefill_chunk_tokens or req.prompt_len
        hw = self.controller.nodes[req.prefill_node].hardware
        return hw.prefill_time(
            tokens * self.controller.model_cost.flops_per_token)

    def _transfer_windowed(self, req: Request, src: NodeEngine,
                           dst: NodeEngine, job, profile
                           ) -> Tuple[str, float, float]:
        """Execute one P->D transfer as per-layer-window sub-plans (each its
        own fused descriptor-table dispatch) and price the pipeline:
        window w goes on the wire as soon as its layers finish prefilling,
        so only the spill past the end of prefill is exposed latency.
        Returns ``(status, exposed_s, hidden_s)``; status "dst_dead" means
        the destination died between sub-plans (its partially-written blocks
        are freed here — the kill-mid-transfer leak class), "exhausted"
        means some sub-plan failed every retry. Mutates ``job``'s
        call/dispatch counts to the windowed totals (more, smaller calls —
        the cost side of overlap, priced honestly; retried dispatches
        count too)."""
        subs = job.plan.split_layer_windows(self.layer_window)
        sharded = job.plan.sharded
        if sharded:
            engine_t = ShardedTransferEngine(
                src.kv.spec, dst.kv.spec, job.plan.src_shard,
                job.plan.dst_shard)
        else:
            engine_t = TransferEngine(src.kv.spec, dst.kv.spec)
        bw = self._bandwidth_factor()
        lats = []
        penalty = 0.0
        for sub in subs:
            if req.decode_node in self._dead or \
                    not self.controller.nodes[dst.node_id].alive:
                # mid-stream death: windows already imported landed in a
                # dead pool — drop the partial registration so those blocks
                # are neither billed nor ever advertised as resident
                if dst.scheduler.bm.owns(req.request_id):
                    dst.scheduler.bm.free(req.request_id)
                return "dst_dead", 0.0, 0.0
            if sharded:
                unit = lambda s=sub: land_sharded_plan(engine_t, s,
                                                       src.kv, dst.kv)
            else:
                unit = lambda s=sub: dst.kv.import_plan(engine_t, s,
                                                        src.kv.pool)
            p = self._attempt_unit(req, src, dst, unit, sub)
            if p is None:
                return "exhausted", 0.0, 0.0
            penalty += p
            lats.append(sub.latency(profile) * bw)
        job.num_dispatches = engine_t.num_dispatches
        job.num_calls = sum(sub.num_calls for sub in subs)
        L = job.plan.num_layers
        prefill_s = self._prefill_tail_s(req)
        ends = [sub.layer_span[1] for sub in subs]
        exposed, hidden = layer_window_overlap(lats, ends, L, prefill_s)
        if self.tracer is not None:
            # Per-window spans on the notional [clock - prefill_s, clock]
            # prefill tail: windows that ran during compute visibly precede
            # the parent transfer span's start — that's the overlap.
            t0 = self.clock - prefill_s
            finish = 0.0
            wall = time.monotonic()
            for sub, lat in zip(subs, lats):
                lo, hi = sub.layer_span
                start = max(finish, prefill_s * hi / L)
                finish = start + lat
                self.tracer.emit(
                    req.request_id, "transfer_layer_window",
                    start_cycle=t0 + start, end_cycle=t0 + finish,
                    start_wall_s=wall, end_wall_s=wall, node_id=src.node_id,
                    attrs={"layer_lo": lo, "layer_hi": hi,
                           "bytes": sub.total_bytes, "est_latency_s": lat,
                           "hidden": finish <= prefill_s})
        return "ok", exposed + penalty, hidden

    def _rehome_prefix(self, req: Request, node_id: int,
                       blocks: List[int]) -> None:
        """Advertise a prompt's full-block prefix as resident on ``node_id``."""
        if self.prefix_reuse:
            self.controller.rehome_prefix(req, node_id, blocks)

    # -- tier promotion (host DRAM -> pool, ahead of reuse) --------------------------
    def _promote_pending(self, engine: NodeEngine) -> None:
        """Lift the head-of-line waiting request's LOCAL host-tier prefix
        back into the pool before this node schedules, so admission-time
        resolution sees HBM blocks. Head-of-line only, like the remote
        fetch pass — and when promotion cannot run (pool genuinely full),
        ``resolve_local_prefix`` truncates at the first dram entry and the
        request recomputes that tail instead of deadlocking."""
        tm = self.tiers.get(engine.node_id)
        if tm is None or not engine.scheduler.prefill.waiting:
            return
        req = engine.scheduler.prefill.waiting[0]
        if engine.scheduler.bm.owns(req.request_id):
            return
        if req.prefix_src_node is not None and \
                req.prefix_src_node != engine.node_id:
            return   # remote plan: promotion happens at the SOURCE node
        tm.promote_match(req.prompt_tokens, trace_id=req.request_id)

    # -- the prefix fetch (remote resident prefix -> local pool) ---------------------
    def _fetch_pending_prefixes(self, engine: NodeEngine) -> None:
        """Execute the remote-prefix plan for this node's next admission.

        Runs each cycle BEFORE the node schedules, so a fetched prefix is in
        the pool by the time admission shares it into the block table. Only
        the HEAD of the waiting queue fetches — admission is head-of-line,
        and letting queue-tail requests grab prefix blocks early could
        starve a large head request of the free blocks it needs to ever
        admit (fetched blocks only free on admission progress)."""
        if not engine.scheduler.prefill.waiting:
            return
        req = engine.scheduler.prefill.waiting[0]
        src = req.prefix_src_node
        if src is None or src == engine.node_id or \
                engine.scheduler.bm.owns(req.request_id):
            return
        self._fetch_prefix(engine, req)

    def _fetch_prefix(self, engine: NodeEngine, req: Request) -> None:
        """Pull a remote resident prefix into this node's pool as ONE fused
        descriptor-table dispatch (the same data plane as a P->D transfer),
        priced by ``core.costmodel``. On any staleness — source died, blocks
        freed, pool full — the plan degrades to recompute (stamp cleared;
        admission re-resolves locally)."""
        src_id = req.prefix_src_node
        src = self.engines.get(src_id)
        if src is None or src_id in self._dead:
            # runtime knows the engine is gone before the controller's
            # heartbeat scan does — clear the plan (recompute)
            req.clear_prefix_plan()
            return
        # Source-side promotion: any of the plan's blocks that demoted to
        # the source's host tier come back to pool blocks first (one fused
        # host->HBM dispatch), then the stamp is refreshed — demote->promote
        # changes physical ids, so the routed block list is stale even
        # though the KV is intact.
        src_tm = self.tiers.get(src_id)
        if src_tm is not None and \
                src_tm.promote_match(req.prompt_tokens,
                                     trace_id=req.request_id):
            if not self.controller.refresh_prefix_plan(req):
                return   # nothing shareable survived promotion
        if not self.controller.validate_prefix_plan(req):
            return   # stale plan cleared by the shared validator
        hit = req.num_cached_prefix_tokens
        bm = engine.scheduler.bm
        if not bm.can_allocate(hit):
            return   # destination pool full — retry next cycle
        dst_blocks = bm.allocate(req.request_id, hit)
        engine_t = pool_transfer_engine(src.kv, engine.kv)
        if isinstance(engine_t, ShardedTransferEngine):
            plan = engine_t.plan(self.transfer_schedule,
                                 req.prefix_block_ids, dst_blocks)
            land_sharded_plan(engine_t, plan, src.kv, engine.kv)
        else:
            plan = engine_t.planner.plan(self.transfer_schedule,
                                         req.prefix_block_ids, dst_blocks)
            engine.kv.import_plan(engine_t, plan, src.kv.pool)
        profile = select_route(
            self.controller.nodes[src_id].host_id ==
            self.controller.nodes[engine.node_id].host_id, self.target)
        latency = plan.latency(profile)
        self.transfers.append(TransferRecord(
            req.request_id, plan.schedule, plan.num_calls, plan.total_bytes,
            latency, plan.num_dispatches, kind="prefix_fetch"))
        req.prefix_fetch_dispatches = plan.num_dispatches
        if self.tracer is not None:
            wall = self.tracer.wall()
            self.tracer.emit(
                req.request_id, "prefix_fetch",
                start_cycle=self.clock, end_cycle=self.clock + latency,
                start_wall_s=wall, end_wall_s=wall,
                node_id=engine.node_id,
                attrs={"src_node": src_id, "tokens": hit,
                       "dispatches": plan.num_dispatches,
                       "bytes": plan.total_bytes, "est_latency_s": latency})
        # the fetched copy is itself resident, shareable KV on this node
        self.controller.record_prefix(engine.node_id,
                                      req.prompt_tokens[:hit], dst_blocks)
        req.prefix_src_node = engine.node_id
        req.prefix_block_ids = dst_blocks

    # -- main loop -------------------------------------------------------------------
    def step(self) -> None:
        """One cluster cycle: faults due + controller + every node + transfers."""
        self.clock += 1.0
        if self.faults is not None:
            for spec in self.faults.due(self.clock):
                if spec.node_id not in self._dead:
                    self.kill_node(spec.node_id)
        for nid, engine in self.engines.items():
            if nid in self._dead or not self.controller.nodes[nid].alive:
                continue
            if self.faults is None or \
                    not self.faults.heartbeat_suppressed(nid, self.clock):
                self.controller.heartbeat(nid, self.clock)
            if self.prefix_reuse and engine.supports_prefix_reuse:
                self._promote_pending(engine)
                self._fetch_pending_prefixes(engine)
            # engine stamps prefill_start / first_token_time (the first token
            # is emitted by prefill itself, not by the transfer)
            pre_done, finished = engine.step(now=self.clock)
            for req in pre_done:
                req.prefill_end = self.clock
                if req.recovery_start is not None:
                    # re-prefill after a failure completed: the request is
                    # caught up (replayed tokens recomputed token-exactly)
                    self._finish_recovery(req, nid)
                if self.tracer is not None:
                    # queue span closes when prefill started (stamped by the
                    # engine); emitted here because the engine does not see
                    # the request until it leaves the waiting queue
                    self.tracer.emit(
                        req.request_id, "queue",
                        start_cycle=req.arrival_time,
                        end_cycle=req.prefill_start,
                        start_wall_s=req.arrival_wall,
                        end_wall_s=req.prefill_start_wall, node_id=nid,
                        attrs={"defers": req.admission_defers,
                               "retries": req.retries})
                engine.scheduler.mark_sending(req)
                # NOTE: the prefix is recorded where the KV ends up (see
                # _rehome_prefix), not here — these blocks free the moment
                # the transfer below completes
            # drain sending queue (transfer is synchronous at this scale)
            for req in list(engine.scheduler.prefill.sending):
                self._transfer(req)
            for req in finished:
                req.finish_time = self.clock
                req.finish_wall = time.monotonic()
                if self.tracer is not None:
                    self.tracer.emit(
                        req.request_id, "decode",
                        start_cycle=req.transfer_end, end_cycle=self.clock,
                        start_wall_s=req.transfer_end_wall,
                        end_wall_s=req.finish_wall, node_id=nid,
                        attrs={"new_tokens": req.num_output,
                               "decode_steps": req.decode_steps,
                               "decode_dispatches": req.decode_dispatches})
                self.finished.append(req)
        self.controller.step(self.clock)
        self._collect_rejected()   # deferred requests the gate gave up on

    def run(self, requests: List[Request], max_cycles: int = 1000) -> List[Request]:
        """Batch compatibility wrapper over submit()/step().

        New code should use :class:`repro.serving.api.FlowKVClient`, which
        exposes the same loop through streaming per-request handles.
        """
        for r in requests:
            self.submit(r)
        for _ in range(max_cycles):
            self.step()
            if self.submitted and \
                    len(self.finished) + len(self.cancelled) + \
                    len(self.rejected) >= self.submitted:
                break
        return self.finished

    # -- request lifecycle --------------------------------------------------------------
    def cancel(self, req: Request) -> bool:
        """Abort a request wherever it is; frees its blocks/state on EVERY
        node (prefill, decode, or mid-transfer). Returns False if the
        request already finished."""
        if req.state in (RequestState.FINISHED, RequestState.CANCELLED,
                         RequestState.REJECTED):
            return False
        for engine in self.engines.values():
            engine.release(req)
        # a FAILED request may be parked controller-side awaiting reroute —
        # cancellation must beat the reroute, not race it
        for q in (self.controller.retry_queue, self.controller.deferred):
            try:
                q.remove(req)
            except ValueError:
                pass
        req.state = RequestState.CANCELLED
        req.finish_time = self.clock
        req.finish_wall = time.monotonic()
        self.cancelled.append(req)
        return True

    def set_role(self, node_id: int, role: str) -> bool:
        """Reassign a node P<->D mid-run (delegates to the controller)."""
        return self.controller.set_role(node_id, role)

    # -- fault tolerance ----------------------------------------------------------------
    def kill_node(self, node_id: int) -> None:
        """Simulate node death: it stops heartbeating and doing work; the
        controller's next heartbeat scan drains and re-routes its requests.

        Every paged-KV allocation on the dead node is released immediately —
        the controller's drain only frees requests still sitting in the
        scheduler queues, so without this the dead pool reports phantom
        utilization after checkpoint/restore or pool reuse.

        Note the node simply STOPS heartbeating — detection is pure
        staleness against ``heartbeat_timeout_cycles``, no sentinel stamp —
        so the detection latency the controller pays is the real knob."""
        self._dead.add(node_id)
        self.fault_kills += 1
        engine = self.engines[node_id]
        tm = self.tiers.get(node_id)
        if tm is not None:
            # the host tier dies with the node: detach the demotion hook
            # FIRST so release_all's cache drop cannot copy into a pool that
            # no longer exists, then drop its residency advertisements
            engine.scheduler.bm.on_evict = None
            tm.clear()
        engine.scheduler.bm.release_all()
        engine.states.clear()
        engine.spilled.clear()

    def checkpoint(self) -> dict:
        from repro.serving.checkpoint import cluster_state
        return cluster_state(self)

    # -- leak auditing ------------------------------------------------------------------
    def live_request_ids(self) -> set:
        """Cluster-wide live set: every request still in ANY node's queues
        or parked controller-side. The union matters: a SENDING request's
        dst-side registration lives on the destination bm while the request
        itself sits in the SOURCE's sending queue."""
        live = set()
        for engine in self.engines.values():
            s = engine.scheduler
            for sub in (s.prefill, s.decode):
                for q in (sub.waiting, sub.running, sub.swapped, sub.sending):
                    live.update(r.request_id for r in q)
        live.update(r.request_id for r in self.controller.retry_queue)
        live.update(r.request_id for r in self.controller.deferred)
        return live

    def audit_blocks(self) -> int:
        """Count leaked block tables fleet-wide (0 on a healthy cluster),
        checking each allocator's structural invariants on the way."""
        live = self.live_request_ids()
        leaked = 0
        for engine in self.engines.values():
            bm = engine.scheduler.bm
            bm.check_invariants()
            leaked += sum(1 for rid in bm._table if rid not in live)
        for tm in self.tiers.values():
            if tm.node_id not in self._dead:
                tm.check_invariants()
        return leaked

    def assert_no_leaks(self) -> None:
        """Hard audit (tests / chaos gate): raise on any leaked table."""
        live = self.live_request_ids()
        for engine in self.engines.values():
            engine.scheduler.bm.assert_no_leaks(live)

    def stats(self) -> Dict[str, float]:
        kv_xfers = [t for t in self.transfers
                    if t.kind == "kv" and t.status == "ok"]
        lat = [t.est_latency_s for t in kv_xfers]
        calls = [t.num_calls for t in kv_xfers]
        disp = [t.num_dispatches for t in kv_xfers]
        hidden = sum(t.hidden_s for t in kv_xfers)
        wire = hidden + sum(lat)
        ttfts = [t for t in (r.ttft() for r in self.finished) if t is not None]
        d_steps = sum(e.decode_steps for e in self.engines.values())
        d_disp = sum(e.decode_dispatches for e in self.engines.values())
        return {
            # prefix-reuse data plane: compute the cluster actually ran vs
            # skipped, and how the hits were sourced
            "prefill_tokens_computed": sum(
                e.prefill_tokens_computed for e in self.engines.values()),
            "prefix_hits": sum(e.prefix_hits for e in self.engines.values()),
            "prefix_tokens_reused": sum(
                e.prefix_tokens_reused for e in self.engines.values()),
            "prefix_fetches": sum(
                1 for t in self.transfers if t.kind == "prefix_fetch"),
            "finished": len(self.finished),
            "cancelled": len(self.cancelled),
            "rejected": len(self.rejected),
            "deferred": len(self.controller.deferred),
            "transfers": len(kv_xfers),
            "mean_transfer_s": sum(lat) / len(lat) if lat else 0.0,
            "mean_transfer_calls": sum(calls) / len(calls) if calls else 0.0,
            "mean_transfer_dispatches": sum(disp) / len(disp) if disp else 0.0,
            # layer-window overlap: wire time hidden behind prefill compute
            # (est_latency_s above is the EXPOSED remainder)
            "transfer_hidden_s": hidden,
            "transfer_hidden_frac": hidden / wire if wire else 0.0,
            "mean_ttft_cycles": sum(ttfts) / len(ttfts) if ttfts else 0.0,
            # decode data plane: dispatches per cycle is the zero-gather
            # invariant (1.0 on the paged-kernel path, O(batch) on the oracle)
            "decode_steps": d_steps,
            "decode_dispatches": d_disp,
            "mean_decode_dispatches_per_step": d_disp / d_steps if d_steps else 0.0,
            # union, not sum: same-config engines share one jitted step, so a
            # bucket two nodes both hit compiled once
            "decode_compile_variants": len(set().union(
                *(e._decode_cache_keys for e in self.engines.values()))),
            "events": len(self.controller.events),
            # mesh-parallel plane: nodes running sharded (tp>1), the largest
            # degree in the fleet, and per-shard-pair fused transfer
            # dispatches landed in sharded pools
            "sharded_nodes": sum(
                1 for e in self.engines.values() if e.tp_degree > 1),
            "max_tp_degree": max(
                (e.tp_degree for e in self.engines.values()), default=1),
            "shard_dispatches": sum(
                getattr(e.kv, "shard_dispatches", 0)
                for e in self.engines.values() if e.kv is not None),
            # fault plane: injected kills, failed transfer attempts retried,
            # transfers that gave up and recomputed, completed failovers —
            # and the leak audit (must stay 0.0, chaos or not)
            "fault_kills": self.fault_kills,
            "transfer_retries": self.transfer_retry_count,
            "degraded_to_recompute": self.degraded_to_recompute,
            "recoveries": self.recoveries,
            "leaked_blocks": float(self.audit_blocks()),
            # tier plane: pool blocks demoted to / promoted from host DRAM,
            # and the LRU cache's own reuse/eviction traffic
            "tier_demoted_blocks": sum(
                t.demoted_blocks for t in self.tiers.values()),
            "tier_promoted_blocks": sum(
                t.promoted_blocks for t in self.tiers.values()),
            "tier_host_resident": sum(
                t.host.num_resident for t in self.tiers.values()),
            "cached_reused": sum(
                e.scheduler.bm.cached_reused for e in self.engines.values()),
            "cached_evicted": sum(
                e.scheduler.bm.cached_evicted for e in self.engines.values()),
        }
