"""PD-disaggregated cluster runtime (CPU-scale, real compute).

Wires together: NodeEngines (P and D roles) + GlobalController (routing,
regimes, failover) + TransferEngine (paged FlowKV transfer between node
pools, or whole-state transfer for ssm/hybrid/encdec).

The runtime is the *correctness* half of the reproduction: disaggregated
generation must be token-identical to monolithic generation on one engine.
Fault tolerance: ``kill_node`` simulates a node death mid-flight; the
controller's heartbeat scan drains and re-routes its requests.
``checkpoint``/``restore`` round-trip the full cluster state.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.costmodel import select_route
from repro.core.scheduler.global_controller import (GlobalController, ModelCost,
                                                    NodeHandle)
from repro.core.transfer import TransferEngine
from repro.models.common import ModelConfig
from repro.serving.engine import NodeEngine
from repro.serving.request import Request, RequestState
from repro.sim.hardware import HardwareProfile, TPU_V5E


@dataclasses.dataclass
class TransferRecord:
    request_id: int
    schedule: str
    num_calls: int
    num_bytes: int
    est_latency_s: float


class PDCluster:
    def __init__(self, cfg: ModelConfig, params, *, num_prefill: int = 1,
                 num_decode: int = 1, num_blocks: int = 256,
                 allocator: str = "flowkv", transfer_schedule: str = "flowkv",
                 hardware: HardwareProfile = TPU_V5E, target: str = "tpu",
                 max_batch_tokens: int = 2048, hosts: Optional[Dict[int, int]] = None):
        self.cfg = cfg
        self.transfer_schedule = transfer_schedule
        self.target = target
        self.engines: Dict[int, NodeEngine] = {}
        model_cost = ModelCost(
            flops_per_token=2.0 * cfg.active_params(),
            kv_bytes_per_token=float(cfg.kv_bytes_per_token() or 1024),
            weight_bytes=2.0 * cfg.num_params(),
        )
        self.controller = GlobalController(model_cost, cfg.block_size, target=target)
        self.clock = 0.0
        self.submitted = 0
        self._dead: set = set()      # killed engines stop heartbeating/working
        self.transfers: List[TransferRecord] = []
        self.finished: List[Request] = []

        for i in range(num_prefill + num_decode):
            role = "prefill" if i < num_prefill else "decode"
            engine = NodeEngine(i, cfg, params, num_blocks=num_blocks,
                                allocator=allocator, max_batch_tokens=max_batch_tokens)
            self.engines[i] = engine
            host = (hosts or {}).get(i, i)
            self.controller.register_node(NodeHandle(
                node_id=i, role=role, host_id=host, hardware=hardware,
                scheduler=engine.scheduler))

    # -- request entry ------------------------------------------------------------
    def submit(self, req: Request) -> None:
        routed = self.controller.route_request(req)
        if routed is None:
            raise RuntimeError("no alive nodes to route to")
        self.submitted += 1

    # -- the FlowKV transfer (P pool -> D pool) -------------------------------------
    def _transfer(self, req: Request) -> None:
        src = self.engines[req.prefill_node]
        dst = self.engines[req.decode_node]
        profile = select_route(
            self.controller.nodes[src.node_id].host_id ==
            self.controller.nodes[dst.node_id].host_id, self.target)
        req.transfer_start = self.clock
        if src.paged:
            spec = src.kv.spec
            n = spec.blocks_for_tokens(req.prompt_len)
            src_blocks = src.kv.bm.get(req.request_id)[:n]
            dst_blocks = dst.register_transfer_in(req, req.prompt_len + 1)[:n]
            engine = TransferEngine(spec, dst.kv.spec)
            plan = engine.planner.plan(self.transfer_schedule, src_blocks, dst_blocks)
            if self.transfer_schedule == "blockwise":
                dst.kv.pool = engine.execute_blockwise(src_blocks, dst_blocks,
                                                       src.kv.pool, dst.kv.pool)
            else:
                dst.kv.pool = engine.execute(plan, src.kv.pool, dst.kv.pool)
            latency = plan.latency(profile)
            self.transfers.append(TransferRecord(
                req.request_id, self.transfer_schedule, plan.num_calls,
                plan.total_bytes, latency))
        else:
            state = src.export_state(req)
            dst.import_state(req, state)
            # state path still reserves block-manager budget on the D node so
            # admission control / KV_u accounting stays uniform across paths
            dst.scheduler.bm.register(req.request_id, req.prompt_len + 1)
            nbytes = sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(state))
            latency = profile.latency(num_calls=len(jax.tree.leaves(state)),
                                      num_bytes=nbytes)
            self.transfers.append(TransferRecord(
                req.request_id, "state", len(jax.tree.leaves(state)), nbytes, latency))
        req.transfer_end = self.clock + latency
        src.scheduler.sending_done(req)
        dst.scheduler.enqueue_decode(req)
        if req.first_token_time is None:
            req.first_token_time = self.clock

    # -- main loop -------------------------------------------------------------------
    def step(self) -> None:
        """One cluster cycle: controller + every node + transfers."""
        self.clock += 1.0
        for nid, engine in self.engines.items():
            if nid in self._dead or not self.controller.nodes[nid].alive:
                continue
            self.controller.heartbeat(nid, self.clock)
            pre_done, finished = engine.step()
            for req in pre_done:
                req.prefill_end = self.clock
                engine.scheduler.mark_sending(req)
                self.controller.record_prefix(nid, req.prompt_tokens)
            # drain sending queue (transfer is synchronous at this scale)
            for req in list(engine.scheduler.prefill.sending):
                self._transfer(req)
            for req in finished:
                req.finish_time = self.clock
                self.finished.append(req)
        self.controller.step(self.clock)

    def run(self, requests: List[Request], max_cycles: int = 1000) -> List[Request]:
        for r in requests:
            self.submit(r)
        for _ in range(max_cycles):
            self.step()
            if self.submitted and len(self.finished) >= self.submitted:
                break
        return self.finished

    # -- fault tolerance ----------------------------------------------------------------
    def kill_node(self, node_id: int) -> None:
        """Simulate node death: it stops heartbeating and doing work; the
        controller's next heartbeat scan drains and re-routes its requests."""
        self._dead.add(node_id)
        self.controller.nodes[node_id].last_heartbeat = -1e9
        self.engines[node_id].states.clear()

    def checkpoint(self) -> dict:
        from repro.serving.checkpoint import cluster_state
        return cluster_state(self)

    def stats(self) -> Dict[str, float]:
        lat = [t.est_latency_s for t in self.transfers]
        calls = [t.num_calls for t in self.transfers]
        return {
            "finished": len(self.finished),
            "transfers": len(self.transfers),
            "mean_transfer_s": sum(lat) / len(lat) if lat else 0.0,
            "mean_transfer_calls": sum(calls) / len(calls) if calls else 0.0,
            "events": len(self.controller.events),
        }
