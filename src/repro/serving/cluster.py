"""PD-disaggregated cluster runtime (CPU-scale, real compute).

Wires together: NodeEngines (role-flexible P/D nodes) + GlobalController
(routing, regimes, role lifecycle, failover) + the TransferBackend registry
(``core/transfer.py``: paged FlowKV transfer between node pools, whole-state
transfer for ssm/hybrid/encdec, or any registered third-party transport).

The runtime is the *correctness* half of the reproduction: disaggregated
generation must be token-identical to monolithic generation on one engine.
Fault tolerance: ``kill_node`` simulates a node death mid-flight; the
controller's heartbeat scan drains and re-routes its requests.
``checkpoint``/``restore`` round-trip the full cluster state.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple, Union

from repro.core.costmodel import select_route
from repro.core.scheduler.global_controller import (AdmissionDecision,
                                                    AdmissionPolicy,
                                                    GlobalController, ModelCost,
                                                    NodeHandle)
from repro.core.transfer import backend_for_engine
from repro.models.common import ModelConfig
from repro.serving.engine import NodeEngine
from repro.serving.request import Request, RequestState
from repro.sim.hardware import HardwareProfile, TPU_V5E


@dataclasses.dataclass
class TransferRecord:
    request_id: int
    schedule: str
    num_calls: int
    num_bytes: int
    est_latency_s: float
    num_dispatches: int = 0


class PDCluster:
    def __init__(self, cfg: ModelConfig, params, *, num_prefill: int = 1,
                 num_decode: int = 1, num_blocks: int = 256,
                 allocator: str = "flowkv", transfer_schedule: str = "flowkv",
                 hardware: Union[HardwareProfile,
                                 Dict[int, HardwareProfile]] = TPU_V5E,
                 target: str = "tpu",
                 max_batch_tokens: int = 2048, hosts: Optional[Dict[int, int]] = None,
                 role_flip: bool = False, paged_decode: str = "auto",
                 admission: Optional[AdmissionPolicy] = None):
        self.cfg = cfg
        self.transfer_schedule = transfer_schedule
        self.target = target
        self.engines: Dict[int, NodeEngine] = {}
        model_cost = ModelCost(
            flops_per_token=2.0 * cfg.active_params(),
            kv_bytes_per_token=float(cfg.kv_bytes_per_token() or 1024),
            weight_bytes=2.0 * cfg.num_params(),
        )
        self.controller = GlobalController(model_cost, cfg.block_size, target=target,
                                           role_flip=role_flip,
                                           admission=admission)
        self.clock = 0.0
        self.submitted = 0
        self._dead: set = set()      # killed engines stop heartbeating/working
        self.transfers: List[TransferRecord] = []
        self.finished: List[Request] = []
        self.cancelled: List[Request] = []
        self.rejected: List[Request] = []

        for i in range(num_prefill + num_decode):
            role = "prefill" if i < num_prefill else "decode"
            engine = NodeEngine(i, cfg, params, num_blocks=num_blocks,
                                allocator=allocator, max_batch_tokens=max_batch_tokens,
                                paged_decode=paged_decode)
            self.engines[i] = engine
            host = (hosts or {}).get(i, i)
            # heterogeneous fleets: hardware may be one profile for every
            # node or a {node_id: profile} map (missing ids get TPU_V5E)
            hw = hardware.get(i, TPU_V5E) if isinstance(hardware, dict) \
                else hardware
            self.controller.register_node(NodeHandle(
                node_id=i, role=role, host_id=host, hardware=hw,
                scheduler=engine.scheduler))

    # -- request entry ------------------------------------------------------------
    def submit(self, req: Request) -> AdmissionDecision:
        """Admission gate + routing. With no AdmissionPolicy every request
        is admitted (legacy behavior); with one, the decision may be
        "deferred" (parked controller-side, admitted as load drains) or
        "rejected" (terminal REJECTED state + retry-after hint)."""
        decision = self.controller.submit_request(req)
        if decision.admitted and decision.route is None:
            raise RuntimeError("no alive nodes to route to")
        self.submitted += 1
        self._collect_rejected()
        return decision

    def _collect_rejected(self) -> None:
        for req in self.controller.take_rejected():
            req.finish_time = self.clock
            self.rejected.append(req)

    # -- the FlowKV transfer (P pool -> D pool) -------------------------------------
    def _transfer(self, req: Request) -> None:
        """Move one request's cache P->D via the TransferBackend registry.

        The backend (paged vs state vs anything third-party) is resolved
        from the source engine — this method never branches on the cache
        transport itself.
        """
        src = self.engines[req.prefill_node]
        dst = self.engines[req.decode_node]
        req.transfer_start = self.clock
        if src is dst:
            # Role-flexible node serving both stages: the cache is already
            # in this node's pool — hand off locally, keep the blocks.
            req.transfer_end = self.clock
            req.transfer_calls = req.transfer_dispatches = 0
            src.scheduler.sending_done(req, free=False)
            dst.scheduler.enqueue_decode(req)
            return
        profile = select_route(
            self.controller.nodes[src.node_id].host_id ==
            self.controller.nodes[dst.node_id].host_id, self.target)
        backend = backend_for_engine(src, self.transfer_schedule)
        job = backend.plan(req, src, dst)
        backend.execute(job, src, dst)
        latency = backend.price(job, profile)
        self.transfers.append(TransferRecord(
            req.request_id, job.schedule, job.num_calls, job.num_bytes, latency,
            job.num_dispatches))
        req.transfer_end = self.clock + latency
        req.transfer_calls = job.num_calls
        req.transfer_dispatches = job.num_dispatches
        src.scheduler.sending_done(req)
        dst.scheduler.enqueue_decode(req)

    # -- main loop -------------------------------------------------------------------
    def step(self) -> None:
        """One cluster cycle: controller + every node + transfers."""
        self.clock += 1.0
        for nid, engine in self.engines.items():
            if nid in self._dead or not self.controller.nodes[nid].alive:
                continue
            self.controller.heartbeat(nid, self.clock)
            # engine stamps prefill_start / first_token_time (the first token
            # is emitted by prefill itself, not by the transfer)
            pre_done, finished = engine.step(now=self.clock)
            for req in pre_done:
                req.prefill_end = self.clock
                engine.scheduler.mark_sending(req)
                self.controller.record_prefix(nid, req.prompt_tokens)
            # drain sending queue (transfer is synchronous at this scale)
            for req in list(engine.scheduler.prefill.sending):
                self._transfer(req)
            for req in finished:
                req.finish_time = self.clock
                self.finished.append(req)
        self.controller.step(self.clock)
        self._collect_rejected()   # deferred requests the gate gave up on

    def run(self, requests: List[Request], max_cycles: int = 1000) -> List[Request]:
        """Batch compatibility wrapper over submit()/step().

        New code should use :class:`repro.serving.api.FlowKVClient`, which
        exposes the same loop through streaming per-request handles.
        """
        for r in requests:
            self.submit(r)
        for _ in range(max_cycles):
            self.step()
            if self.submitted and \
                    len(self.finished) + len(self.cancelled) + \
                    len(self.rejected) >= self.submitted:
                break
        return self.finished

    # -- request lifecycle --------------------------------------------------------------
    def cancel(self, req: Request) -> bool:
        """Abort a request wherever it is; frees its blocks/state on EVERY
        node (prefill, decode, or mid-transfer). Returns False if the
        request already finished."""
        if req.state in (RequestState.FINISHED, RequestState.CANCELLED,
                         RequestState.REJECTED):
            return False
        for engine in self.engines.values():
            engine.release(req)
        req.state = RequestState.CANCELLED
        req.finish_time = self.clock
        self.cancelled.append(req)
        return True

    def set_role(self, node_id: int, role: str) -> bool:
        """Reassign a node P<->D mid-run (delegates to the controller)."""
        return self.controller.set_role(node_id, role)

    # -- fault tolerance ----------------------------------------------------------------
    def kill_node(self, node_id: int) -> None:
        """Simulate node death: it stops heartbeating and doing work; the
        controller's next heartbeat scan drains and re-routes its requests.

        Every paged-KV allocation on the dead node is released immediately —
        the controller's drain only frees requests still sitting in the
        scheduler queues, so without this the dead pool reports phantom
        utilization after checkpoint/restore or pool reuse."""
        self._dead.add(node_id)
        self.controller.nodes[node_id].last_heartbeat = -1e9
        engine = self.engines[node_id]
        engine.scheduler.bm.release_all()
        engine.states.clear()
        engine.spilled.clear()

    def checkpoint(self) -> dict:
        from repro.serving.checkpoint import cluster_state
        return cluster_state(self)

    def stats(self) -> Dict[str, float]:
        lat = [t.est_latency_s for t in self.transfers]
        calls = [t.num_calls for t in self.transfers]
        disp = [t.num_dispatches for t in self.transfers]
        ttfts = [t for t in (r.ttft() for r in self.finished) if t is not None]
        d_steps = sum(e.decode_steps for e in self.engines.values())
        d_disp = sum(e.decode_dispatches for e in self.engines.values())
        return {
            "finished": len(self.finished),
            "cancelled": len(self.cancelled),
            "rejected": len(self.rejected),
            "deferred": len(self.controller.deferred),
            "transfers": len(self.transfers),
            "mean_transfer_s": sum(lat) / len(lat) if lat else 0.0,
            "mean_transfer_calls": sum(calls) / len(calls) if calls else 0.0,
            "mean_transfer_dispatches": sum(disp) / len(disp) if disp else 0.0,
            "mean_ttft_cycles": sum(ttfts) / len(ttfts) if ttfts else 0.0,
            # decode data plane: dispatches per cycle is the zero-gather
            # invariant (1.0 on the paged-kernel path, O(batch) on the oracle)
            "decode_steps": d_steps,
            "decode_dispatches": d_disp,
            "mean_decode_dispatches_per_step": d_disp / d_steps if d_steps else 0.0,
            # union, not sum: same-config engines share one jitted step, so a
            # bucket two nodes both hit compiled once
            "decode_compile_variants": len(set().union(
                *(e._decode_cache_keys for e in self.engines.values()))),
            "events": len(self.controller.events),
        }
