"""Per-node host-DRAM KV tier behind the paged HBM pool.

The Mooncake/KVCache-centric move (PAPERS.md): the blocks worth keeping are
exactly the long shared prefixes that capacity pressure evicts first, so a
refcount-zero pool block whose pages back a :class:`GlobalPrefixIndex` entry
is **demoted** — copied to a host-DRAM pool in one fused descriptor-table
dispatch — instead of dying with its pages. A later hit on that prefix
**promotes** it back (one host->HBM dispatch) and the unchanged PR 5 sharing
machinery takes over.

Two classes:

* :class:`HostTier` — the DRAM pool itself: a second ``KVCacheSpec`` pool in
  its own block namespace (``dataclasses.replace(spec, num_blocks=...)`` —
  the transfer engine only requires the two specs to agree on per-block
  payload and layer count, so host and device pools may differ in size), a
  freelist allocator, and an LRU over resident host blocks so the tier
  self-evicts when full. ``with_pool=False`` is the simulator mode: full
  bookkeeping and plan/dispatch accounting with no backing array.
* :class:`TierManager` — the policy glue shared VERBATIM by ``PDCluster``
  and ``ClusterSim`` (tier decisions and span sequences match across
  runtimes by construction, not by parallel reimplementation). It hangs off
  ``BlockManager.on_evict``: inside the eviction window (pages still
  intact) it filters the victims to index-backed blocks, copies them
  host-ward as ONE fused plan, and re-points their index entries pool->host
  *before* ``on_free`` runs — so the HBM invalidation pass finds nothing to
  kill and the entries survive in the DRAM tier.

Movement is **move semantics**, not copies: a demoted block's KV lives only
in its host block, a promoted block's KV only in its (cached) pool block.
Every block of KV is thus in exactly one tier at all times — the
disjoint-and-exhaustive invariant the property suite audits.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence

from repro.core import layout as L
from repro.core.allocator import OutOfBlocksError
from repro.core.block_manager import BlockManager
from repro.core.transfer import TransferEngine, TransferPlan, TransferPlanner
from repro.serving.prefix_cache import (GlobalPrefixIndex, TIER_DRAM,
                                        TIER_HBM)


class HostTier:
    """A host-DRAM paged pool: spec + array + freelist + LRU.

    Host blocks live in their OWN id namespace (0..num_blocks-1, distinct
    from pool block ids); the prefix index tags every entry with its tier,
    so the two namespaces never mix.
    """

    def __init__(self, spec: L.KVCacheSpec, num_blocks: int,
                 with_pool: bool = True):
        self.device_spec = spec
        # spec may be None only for a disabled (num_blocks=0) tier — e.g. a
        # simulator node constructed without a KV spec.
        self.spec = (None if spec is None else
                     dataclasses.replace(spec, num_blocks=max(num_blocks, 1)))
        self.num_blocks = int(num_blocks)
        # In the real runtime this array is the DRAM staging pool (on CPU
        # backends jnp arrays are host memory already; on TPU it would be a
        # pinned host buffer). The simulator passes with_pool=False: all
        # bookkeeping, no bytes.
        self.pool = (L.alloc_cache(self.spec)
                     if with_pool and num_blocks else None)
        self._free: List[int] = list(range(self.num_blocks - 1, -1, -1))
        self._lru: "OrderedDict[int, None]" = OrderedDict()  # oldest first

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_resident(self) -> int:
        return len(self._lru)

    def allocate(self, n: int) -> List[int]:
        if n > len(self._free):
            raise OutOfBlocksError(
                f"requested {n} host blocks, only {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            self._lru[b] = None
        return out

    def free(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            b = int(b)
            if b not in self._lru:
                raise ValueError(f"host block {b} is not allocated")
            del self._lru[b]
            self._free.append(b)

    def touch(self, block: int) -> None:
        """Move a resident block to the MRU end (it is about to matter)."""
        b = int(block)
        if b in self._lru:
            self._lru.move_to_end(b)

    def evict_lru(self, n: int) -> List[int]:
        """Free the ``n`` oldest resident blocks; returns their ids.

        The caller owns index invalidation for the victims — the tier does
        not know what its blocks advertise.
        """
        n = min(n, len(self._lru))
        out = [self._lru.popitem(last=False)[0] for _ in range(n)]
        self._free.extend(out)
        return out

    def clear(self) -> List[int]:
        """Node death: every resident block dies with the node."""
        out = list(self._lru)
        self._lru.clear()
        self._free = list(range(self.num_blocks - 1, -1, -1))
        return out

    def check_invariants(self) -> None:
        free = set(self._free)
        resident = set(self._lru)
        assert len(free) == len(self._free), "duplicate free host blocks"
        assert not (free & resident), (
            f"host blocks both free and resident: {sorted(free & resident)}")
        assert len(free) + len(resident) == self.num_blocks, (
            f"host tier not tiled: free={len(free)} resident={len(resident)} "
            f"!= {self.num_blocks}")


class TierManager:
    """Demotion/promotion policy for one node, shared by both runtimes.

    Wired as ``bm.on_evict``; the owning runtime supplies ``get_tracer`` /
    ``get_clock`` thunks (read at emission time, like every other span
    producer) so :func:`repro.obs.tracing.attach_tracer` keeps working on
    already-constructed clusters.
    """

    def __init__(self, node_id: int, bm: BlockManager,
                 index: GlobalPrefixIndex, spec: L.KVCacheSpec,
                 host_blocks: int, *, kv=None, schedule: str = "flowkv",
                 get_tracer: Optional[Callable[[], object]] = None,
                 get_clock: Optional[Callable[[], float]] = None):
        self.node_id = node_id
        self.bm = bm
        self.index = index
        self.spec = spec
        self.kv = kv               # PagedKVCache, or None in the simulator
        self.schedule = schedule
        self.host = HostTier(spec, host_blocks, with_pool=kv is not None)
        self.planner = TransferPlanner(spec)
        self._demote_engine = (TransferEngine(spec, self.host.spec)
                               if kv is not None and host_blocks else None)
        self._promote_engine = (TransferEngine(self.host.spec, spec)
                                if kv is not None and host_blocks else None)
        self._get_tracer = get_tracer or (lambda: None)
        self._get_clock = get_clock or (lambda: 0.0)
        # trajectory counters
        self.demoted_blocks = 0
        self.promoted_blocks = 0
        self.demote_dispatches = 0
        self.promote_dispatches = 0
        self.host_evicted_blocks = 0
        self.last_promote_latency_s = 0.0

    @property
    def enabled(self) -> bool:
        return self.host.num_blocks > 0

    def attach(self) -> "TierManager":
        """Hook into the block manager's eviction window, and into the
        index's orphan notification (a re-insert that re-points a digest
        away from its DRAM backing must free the host block, or it squats
        resident-but-unbacked forever)."""
        self.bm.on_evict = self.on_evict
        self.index.on_host_orphan[self.node_id] = self.host.free
        return self

    # -- demotion (bm.on_evict) ---------------------------------------------------
    def on_evict(self, blocks: List[int]) -> None:
        """Cache-evicted pool blocks, pages still intact: demote the
        index-backed ones to host DRAM as one fused plan."""
        if not self.enabled:
            return
        demotable = [b for b in blocks
                     if self.index.backed_block(self.node_id, b)]
        if not demotable:
            return
        want = len(demotable)
        if self.host.num_free < want:
            victims = self.host.evict_lru(want - self.host.num_free)
            if victims:
                self.host_evicted_blocks += len(victims)
                self.index.invalidate_host_blocks(self.node_id, victims)
        take = min(want, self.host.num_free)
        if take == 0:
            return
        # the eviction list arrives LRU-oldest-first; when the host tier
        # cannot hold everything, keep the most recently used tail
        demotable = demotable[-take:]
        host_blocks = self.host.allocate(take)
        plan = self.planner.plan(self.schedule, demotable, host_blocks)
        start = self._stamp()
        if self._demote_engine is not None:
            self.host.pool = self._demote_engine.execute(
                plan, self.kv.pool, self.host.pool)
        self.demote_dispatches += 1
        for pb, hb in zip(demotable, host_blocks):
            self.index.demote_block(self.node_id, pb, hb)
        self.demoted_blocks += take
        self._emit("tier_demote", -1, start, num_blocks=take)

    # -- promotion ---------------------------------------------------------------
    def dram_match_blocks(self, tokens: Sequence[int]) -> List[int]:
        """Host blocks backing this prompt's matched chain on this node."""
        m = self.index.lookup(self.node_id, tokens)
        return [b for b, t in zip(m.block_ids, m.tiers) if t == TIER_DRAM]

    def promote_match(self, tokens: Sequence[int], trace_id: int = -1,
                      profile=None) -> int:
        """Promote every DRAM block in this prompt's matched chain back to
        (cached) pool blocks; returns the number of blocks promoted.

        Promotion destinations come from ``bm.take_for_cache`` — they belong
        to no request, so the admission path revives them exactly like any
        other cached hit and the leak audit needs no special cases. Taking
        pool blocks can itself trigger demotion (``_ensure_free`` ->
        ``on_evict``); the targets are touched to the host MRU end first so
        that cascade cannot evict what it is about to promote unless the
        tier is pathologically small — any target it does lose is dropped
        from the (chain-order) run before the copy.
        """
        if not self.enabled:
            return 0
        targets = self.dram_match_blocks(tokens)
        if not targets:
            return 0
        for hb in targets:
            self.host.touch(hb)
        n = min(len(targets), self.bm.free_capacity)
        if n == 0:
            return 0
        pool_blocks = self.bm.take_for_cache(n)
        # re-validate after the take: a demotion cascade may have evicted
        # host blocks. Keep the leading chain-order run that survived.
        alive: List[int] = []
        for hb in targets[:n]:
            if not self.index.backed_block(self.node_id, hb, tier=TIER_DRAM):
                break
            alive.append(hb)
        if len(alive) < len(pool_blocks):
            # surplus destinations go straight back: reclaim without the
            # demotion hook (they hold no KV yet, nothing to save)
            self.bm.drop_cached(pool_blocks[len(alive):])
            pool_blocks = pool_blocks[:len(alive)]
        if not alive:
            return 0
        plan = self.planner.plan(self.schedule, alive, pool_blocks)
        start = self._stamp()
        if self._promote_engine is not None:
            self.kv.import_plan(self._promote_engine, plan, self.host.pool)
        self.promote_dispatches += 1
        for hb, pb in zip(alive, pool_blocks):
            self.index.promote_entry(self.node_id, hb, pb)
        self.host.free(alive)
        self.promoted_blocks += len(alive)
        self.last_promote_latency_s = (plan.latency(profile)
                                       if profile is not None else 0.0)
        self._emit("tier_promote", trace_id, start, num_blocks=len(alive))
        return len(alive)

    # -- teardown ----------------------------------------------------------------
    def clear(self) -> None:
        """Node death: the host tier dies with the node."""
        victims = self.host.clear()
        if victims:
            self.index.invalidate_host_blocks(self.node_id, victims)

    # -- audits / stats ----------------------------------------------------------
    def check_invariants(self) -> None:
        self.host.check_invariants()
        # every resident host block backs exactly one index entry, and every
        # DRAM entry points at a resident host block (no phantom residency)
        backed = self.index._node_host_blocks.get(self.node_id, {})
        resident = set(self.host._lru)
        assert set(backed) == resident, (
            f"host tier / index drift on node {self.node_id}: "
            f"backed={sorted(backed)} resident={sorted(resident)}")

    def stats(self) -> Dict[str, int]:
        return {
            "host_blocks": self.host.num_blocks,
            "host_resident": self.host.num_resident,
            "demoted_blocks": self.demoted_blocks,
            "promoted_blocks": self.promoted_blocks,
            "demote_dispatches": self.demote_dispatches,
            "promote_dispatches": self.promote_dispatches,
            "host_evicted_blocks": self.host_evicted_blocks,
        }

    # -- span plumbing -----------------------------------------------------------
    def _stamp(self) -> float:
        return self._get_clock()

    def _emit(self, name: str, trace_id: int, start: float, **attrs) -> None:
        tracer = self._get_tracer()
        if tracer is None:
            return
        tracer.emit(trace_id, name, start_cycle=start,
                    end_cycle=self._get_clock(), node_id=self.node_id,
                    attrs=dict(attrs))


__all__ = ["HostTier", "TierManager"]
