"""FlowKV serving facade: streaming request handles over the PD cluster.

This is the front door for the disaggregated runtime. Instead of the batch
``PDCluster.run()`` loop (kept as a compatibility wrapper), callers submit
requests one at a time and get back a :class:`RequestHandle`:

.. code-block:: python

    client = FlowKVClient(cfg, params, num_prefill=1, num_decode=1)
    handle = client.submit(prompt_tokens, SamplingParams(max_new_tokens=16))
    for tok in handle.tokens():        # streams per cluster cycle
        print(tok)
    print(handle.stats())              # queue/prefill/transfer/decode split

Handles support incremental streaming (``tokens()``), blocking collection
(``result()``), mid-flight ``cancel()`` (frees KV blocks on every node the
request touched), and per-request timing stats. The client drives the
cluster clock: each ``step()`` is one cluster cycle, and iterating a handle
steps the cluster on demand, so several interleaved streams advance each
other — continuous arrival works by just calling ``submit`` between
iterations.

Node lifecycle is exposed too: ``client.set_role(node_id, "decode")`` flips
a node P<->D mid-run (see ``GlobalController.set_role``), and constructing
with ``role_flip=True`` lets the load-aware scheduler do that flip itself
under computational imbalance.

Overload: constructing with ``admission=AdmissionPolicy(...)`` arms the
controller's admission gate — under sustained overload a submit may come
back DEFERRED (parked controller-side, admitted as load drains) or
terminal REJECTED, with ``handle.rejected`` / ``handle.retry_after``
telling the client when to back off and resubmit (``examples/overload.py``,
``docs/scheduling.md``).
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Sequence, Union

from repro.models.common import ModelConfig
from repro.serving.cluster import PDCluster
from repro.serving.request import Request, RequestState, SamplingParams

# FAILED is deliberately NOT terminal: a failed request sits in the
# controller's retry queue and will be rerouted (token-exact recovery), so
# streaming handles keep driving the cluster through a failover instead of
# ending the stream mid-retry.
TERMINAL_STATES = (RequestState.FINISHED, RequestState.CANCELLED,
                   RequestState.REJECTED)


class RequestHandle:
    """One submitted request: stream, await, cancel, inspect."""

    def __init__(self, client: "FlowKVClient", req: Request):
        self._client = client
        self._req = req

    # -- identity / state ------------------------------------------------------
    @property
    def request_id(self) -> int:
        return self._req.request_id

    @property
    def request(self) -> Request:
        return self._req

    @property
    def state(self) -> RequestState:
        return self._req.state

    @property
    def done(self) -> bool:
        return self._req.state in TERMINAL_STATES

    @property
    def cancelled(self) -> bool:
        return self._req.state is RequestState.CANCELLED

    @property
    def rejected(self) -> bool:
        """True when the admission gate early-rejected this request (overload).

        Check :attr:`retry_after` for the controller's back-off hint and
        resubmit the prompt later — see ``examples/overload.py``.
        """
        return self._req.state is RequestState.REJECTED

    @property
    def retry_after(self) -> Optional[float]:
        """Back-off hint (seconds) set when deferred or rejected."""
        return self._req.retry_after

    # -- streaming -------------------------------------------------------------
    def tokens(self, max_cycles: int = 10_000) -> Iterator[int]:
        """Incremental token stream, fed per cluster cycle.

        Yields every output token exactly once, in order, stepping the
        cluster whenever no new token is buffered yet. Ends when the request
        finishes or is cancelled; raises TimeoutError after ``max_cycles``
        cluster cycles without completion (stuck cluster).
        """
        emitted = 0
        cycles = 0
        while True:
            out = self._req.output_tokens
            while emitted < len(out):
                yield out[emitted]
                emitted += 1
            if self.done:
                return
            if cycles >= max_cycles:
                raise TimeoutError(
                    f"request {self.request_id} incomplete after {max_cycles} cycles")
            self._client.step()
            cycles += 1

    def result(self, max_cycles: int = 10_000) -> List[int]:
        """Block (drive the cluster) until finished; return all output tokens."""
        for _ in self.tokens(max_cycles=max_cycles):
            pass
        return list(self._req.output_tokens)

    # -- control ----------------------------------------------------------------
    def cancel(self) -> bool:
        """Abort the request and free its KV blocks / state on every node."""
        return self._client.cluster.cancel(self._req)

    # -- observability ------------------------------------------------------------
    def stats(self) -> Dict[str, Optional[float]]:
        """Per-request timing breakdown in cluster cycles:
        queue -> prefill -> transfer -> decode, plus ttft/e2e and the
        data-plane counters — transfer ``num_calls`` (transport calls
        priced) and ``num_dispatches`` (fused kernel dispatches; 1 per
        plan), and decode ``decode_steps`` / ``decode_dispatches`` (device
        dispatches issued by the decode cycles this request rode in; equal
        on the zero-gather path, O(batch) apart on the dense oracle)."""
        d = self._req.timing_breakdown()
        d.update({
            "state": self._req.state.value,
            "num_output_tokens": self._req.num_output,
            "prefill_node": self._req.prefill_node,
            "decode_node": self._req.decode_node,
            "decode_steps": self._req.decode_steps,
            "decode_dispatches": self._req.decode_dispatches,
            # prefix reuse: prompt tokens the engine did NOT recompute, and
            # the fused dispatches a remote prefix fetch cost (0 = local hit
            # or cold prefill)
            "num_cached_prefix_tokens": self._req.num_cached_prefix_tokens,
            "prefix_fetch_dispatches": self._req.prefix_fetch_dispatches,
            "retries": self._req.retries,
            "retry_after_s": self._req.retry_after,
            "reject_reason": self._req.reject_reason,
            # fault tolerance: did this request survive a failover, how many
            # transfer attempts were retried, how many already-emitted tokens
            # the recovery re-prefilled, and what the failover cost — on the
            # driving clock (recovery_s) and in real seconds (wall).
            "recovered": self._req.recoveries > 0,
            "recoveries": self._req.recoveries,
            "transfer_retries": self._req.transfer_retries,
            "replayed_tokens": self._req.replayed_tokens,
            "recovery_s": self._req.recovery_s,
            "recovery_wall_s": self._req.recovery_wall_s,
        })
        # mesh-parallel topology of the nodes this request ran on: the TP/EP
        # degrees explain the transfer dispatch count (one fused dispatch per
        # overlapping shard pair on a cross-degree P->D hop) and the
        # shard_dispatches the destination pool landed for this request's
        # pages. Degrees default to 1 when a node id is unassigned/unknown.
        engines = self._client.cluster.engines
        for side, nid in (("prefill", self._req.prefill_node),
                          ("decode", self._req.decode_node)):
            eng = engines.get(nid) if nid is not None else None
            d[f"{side}_tp_degree"] = getattr(eng, "tp_degree", 1)
            d[f"{side}_ep_degree"] = getattr(eng, "ep_degree", 1)
        d["shard_dispatches"] = (
            self._req.transfer_dispatches
            if d["prefill_tp_degree"] > 1 or d["decode_tp_degree"] > 1 else 0)
        return d


class FlowKVClient:
    """Front-end facade over a :class:`PDCluster`.

    Either construct a cluster in place (``FlowKVClient(cfg, params, ...)``,
    extra kwargs forwarded to :class:`PDCluster`) or wrap an existing one
    with :meth:`from_cluster`.
    """

    def __init__(self, cfg: Optional[ModelConfig] = None, params=None, *,
                 cluster: Optional[PDCluster] = None, **cluster_kwargs):
        if cluster is None:
            if cfg is None or params is None:
                raise ValueError("need (cfg, params) or an existing cluster=")
            cluster = PDCluster(cfg, params, **cluster_kwargs)
        elif cluster_kwargs or cfg is not None or params is not None:
            raise ValueError(
                "cluster= is mutually exclusive with cfg/params/cluster kwargs")
        self.cluster = cluster
        self.handles: Dict[int, RequestHandle] = {}

    @classmethod
    def from_cluster(cls, cluster: PDCluster) -> "FlowKVClient":
        return cls(cluster=cluster)

    # -- request entry -----------------------------------------------------------
    def submit(self, prompt: Union[Sequence[int], Request],
               sampling: Optional[SamplingParams] = None) -> RequestHandle:
        """Submit a prompt (token ids) or a pre-built Request; route it now.

        Arrival is stamped at submission (the cluster clock), so per-request
        queue/ttft/e2e stats measure from when the system first saw it.
        """
        if isinstance(prompt, Request):
            req = prompt
            req.arrival_time = self.cluster.clock
        else:
            req = Request(prompt_tokens=list(prompt),
                          sampling=sampling or SamplingParams(),
                          arrival_time=self.cluster.clock)
        self.cluster.submit(req)
        handle = RequestHandle(self, req)
        self._prune()   # long-lived clients: drop terminal handles we track
        self.handles[req.request_id] = handle
        return handle

    # -- clock ----------------------------------------------------------------------
    def step(self) -> None:
        """Advance the cluster one cycle (all nodes + controller + transfers)."""
        self.cluster.step()

    def drain(self, max_cycles: int = 10_000) -> List[RequestHandle]:
        """Step until every tracked request reaches a terminal state."""
        tracked = list(self.handles.values())
        pending = [h for h in tracked if not h.done]
        for _ in range(max_cycles):
            if not pending:
                break
            self.step()
            pending = [h for h in pending if not h.done]
        self._prune()
        return tracked

    def _prune(self) -> None:
        """Stop tracking terminal requests (callers keep their own handles)."""
        done = [rid for rid, h in self.handles.items() if h.done]
        for rid in done:
            del self.handles[rid]

    # -- node lifecycle ---------------------------------------------------------------
    def set_role(self, node_id: int, role: str) -> bool:
        """Flip a node prefill<->decode mid-run."""
        return self.cluster.set_role(node_id, role)

    # -- observability -----------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        return self.cluster.stats()

    @property
    def controller(self):
        return self.cluster.controller
