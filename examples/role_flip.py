"""P<->D role flip under imbalanced load (the Load-Aware Scheduler's
headline capability), on the REAL engine with token-correctness checks.

A prefill-heavy burst hits a cluster provisioned decode-heavy (1P + 3D).
With ``role_flip=True`` the controller detects the computational imbalance
and REASSIGNS idle decode nodes to the prefill role (``set_role``) — not
just a bounded priority lease — then flips them back once the burst drains.
Every request still decodes token-identically to monolithic generation,
because a NodeEngine serves either role from one block pool.

    PYTHONPATH=src python examples/role_flip.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.models.api import get_model
from repro.serving.api import FlowKVClient
from repro.serving.request import SamplingParams


def main():
    cfg = get_smoke_config("qwen3-1.7b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # decode-heavy cluster, flip policy armed
    client = FlowKVClient(cfg, params, num_prefill=1, num_decode=3,
                          num_blocks=256, max_batch_tokens=256,
                          role_flip=True)

    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, cfg.vocab_size, size=rng.randint(120, 200)).tolist()
               for _ in range(16)]

    print("roles before burst:",
          {n.node_id: n.role for n in client.controller.nodes.values()})
    handles = [client.submit(p, SamplingParams(max_new_tokens=4))
               for p in prompts]
    client.drain(max_cycles=400)

    flips = [e for e in client.controller.events if e.kind == "set_role"]
    print(f"\n{len(flips)} role reassignments under the burst:")
    for e in flips:
        print(f"  [cycle {e.cycle}] {e.detail}")

    # idle out the cluster: the policy returns borrowed nodes to their home
    # role once the imbalance clears (sustained-normal + residency hysteresis)
    for _ in range(30):
        client.step()
    print("roles after the burst clears:",
          {n.node_id: n.role for n in client.controller.nodes.values()})

    # correctness: every streamed output == monolithic generation
    for h in handles:
        ref = T.greedy_generate(
            params, cfg, jnp.asarray([h.request.prompt_tokens], jnp.int32), 4)
        assert h.request.output_tokens == [int(x) for x in ref[0]], \
            f"req {h.request_id} diverged after role flip!"
    print(f"\nall {len(handles)} requests token-identical to monolithic "
          f"generation across the flips: OK")
    s = client.stats()
    print(f"mean TTFT {s['mean_ttft_cycles']:.1f} cycles, "
          f"{s['transfers']} transfers, "
          f"{s['mean_transfer_calls']:.1f} calls/transfer")


if __name__ == "__main__":
    main()
