"""Fig. 5 walk-through: why layout + segment allocation + alignment turn
O(n) transfer calls into O(1).

    PYTHONPATH=src python examples/transfer_demo.py
"""
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.alignment import align
from repro.core.allocator import BlockAllocator, SegmentAllocator
from repro.core.costmodel import IPC, NCCL_INTRA, TPU_DCN, TPU_ICI
from repro.core.layout import KVCacheSpec
from repro.core.transfer import TransferPlanner


def main():
    cfg = get_config("llama31-8b")
    spec = KVCacheSpec(num_layers=cfg.num_layers, num_blocks=512,
                       block_size=cfg.block_size, num_kv_heads=cfg.num_kv_heads,
                       head_dim=cfg.head_dim, dtype=jnp.bfloat16)
    planner = TransferPlanner(spec)
    tokens = 4000
    n = spec.blocks_for_tokens(tokens)
    print(f"model={cfg.name}  ctx={tokens} tokens -> {n} blocks of {spec.block_size}")
    print(f"bytes/block (all {cfg.num_layers} layers, K+V): {spec.bytes_per_block:,}")

    # --- step 1: the layout factor -------------------------------------------
    vllm = spec.with_layout(spec.layout.__class__.VLLM)
    print(f"\n[Eq. 5] calls per block: vLLM layout = {vllm.transfer_calls_per_block()}"
          f" (L x 2), FlowKV layout = {spec.transfer_calls_per_block()}")

    # --- step 2: allocator contiguity ----------------------------------------
    for name, cls in (("freelist", BlockAllocator), ("segment", SegmentAllocator)):
        a = cls(512)
        churn = [a.allocate(13) for _ in range(8)]
        for c in churn[::2]:
            a.free(c)
        req = a.allocate(n)
        from repro.core.segments import blocks_to_segments
        print(f"  {name:9s} allocator after churn -> request in "
              f"{len(blocks_to_segments(req))} run(s)")

    # --- step 3: bidirectional alignment --------------------------------------
    src = list(range(10, 10 + n))
    dst_aligned = list(range(200, 200 + n))
    dst_hostile = list(range(200, 200 + n))[::-1]
    print(f"\n[Fig. 5] aligned dst:  {align(src, dst_aligned).num_calls} call(s)")
    print(f"         hostile dst:  {align(src, dst_hostile).num_calls} call(s)")

    # --- step 4: priced plans ---------------------------------------------------
    ids = list(range(n))
    for sched, prof in (("layerwise", NCCL_INTRA), ("flowkv", IPC)):
        plan = planner.plan(sched, ids, ids)
        print(f"  {sched:10s}: {plan.num_calls:6d} calls  "
              f"GPU={plan.latency(prof)*1e3:9.2f} ms  "
              f"TPU-ICI={plan.latency(TPU_ICI)*1e3:7.2f} ms  "
              f"TPU-DCN={plan.latency(TPU_DCN)*1e3:7.2f} ms")


if __name__ == "__main__":
    main()
