"""Overload admission control: client-side handling of REJECTED/retry-after.

A deliberately undersized cluster (1P+1D, tiny token budget) receives a
burst it cannot absorb. With an ``AdmissionPolicy`` armed, the controller
admits what fits, DEFERS what looks transient (parked controller-side and
admitted as load drains), and early-REJECTS the rest with a ``retry_after``
back-off hint — instead of letting every request silently miss its SLO.

The client-side pattern: check ``handle.rejected``, back off by
``handle.retry_after``, resubmit the same prompt.

    PYTHONPATH=src python examples/overload.py
"""
import jax
import numpy as np

from repro.core.scheduler import AdmissionPolicy
from repro.configs import get_smoke_config
from repro.models.api import get_model
from repro.serving.api import FlowKVClient
from repro.serving.request import SamplingParams


def main():
    cfg = get_smoke_config("qwen3-1.7b")
    params = get_model(cfg).init(jax.random.PRNGKey(0))

    # Undersized on purpose: one P node with an 8-token prefill budget per
    # cycle, and an admission gate that tolerates a 2-deep queue at most.
    policy = AdmissionPolicy(max_queue_depth=2, max_defer_cycles=3,
                             retry_after_floor_s=4.0)
    client = FlowKVClient(cfg, params, num_prefill=1, num_decode=1,
                          num_blocks=128, max_batch_tokens=8,
                          admission=policy)

    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=24).tolist()
               for _ in range(8)]

    print(f"burst: {len(prompts)} requests at an undersized 1P1D cluster "
          f"(queue depth limit {policy.max_queue_depth})")
    handles = [client.submit(p, SamplingParams(max_new_tokens=4))
               for p in prompts]

    # Drive the cluster until the burst resolves: every handle is either
    # FINISHED or REJECTED (deferred ones get admitted or rejected en route).
    client.drain(max_cycles=500)
    served = [h for h in handles if not h.rejected]
    rejected = [h for h in handles if h.rejected]
    print(f"served {len(served)}, rejected {len(rejected)}")
    for h in rejected:
        s = h.stats()
        print(f"  request {h.request_id}: REJECTED ({s['reject_reason']}), "
              f"retry_after={h.retry_after:.1f}s")
    assert rejected, "expected the admission gate to fire on this burst"

    # Client-side back-off: wait out retry_after (here: cluster cycles),
    # then resubmit the same prompts. The drained cluster admits them.
    backoff = max(int(h.retry_after or 1.0) for h in rejected)
    print(f"backing off {backoff} cycles, then resubmitting "
          f"{len(rejected)} rejected prompts...")
    for _ in range(backoff):
        client.step()
    retries = [client.submit(h.request.prompt_tokens,
                             SamplingParams(max_new_tokens=4))
               for h in rejected]
    client.drain(max_cycles=500)
    assert all(not h.rejected for h in retries), "retry after back-off failed"
    print(f"all {len(retries)} retries admitted and finished; "
          f"total served {len(served) + len(retries)}/{len(prompts)} prompts")
    print("cluster stats:", {k: v for k, v in client.stats().items()
                             if k in ("finished", "rejected", "deferred")})


if __name__ == "__main__":
    main()
