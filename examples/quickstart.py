"""Quickstart: FlowKV end-to-end in ~40 lines.

Builds a small model, serves a batch of requests through the disaggregated
cluster (prefill node -> FlowKV page transfer -> decode node), and verifies
the output is token-identical to monolithic generation.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.models.api import get_model
from repro.serving.cluster import PDCluster
from repro.serving.request import Request, SamplingParams


def main():
    cfg = get_smoke_config("qwen3-1.7b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).tolist()
               for n in (12, 25, 33)]

    # 1P + 1D cluster with FlowKV transfer
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=1,
                        num_blocks=128, transfer_schedule="flowkv")
    reqs = [Request(prompt_tokens=p, sampling=SamplingParams(max_new_tokens=8))
            for p in prompts]
    done = cluster.run(reqs, max_cycles=100)

    # verify against monolithic generation
    for r in done:
        ref = T.greedy_generate(params, cfg,
                                jnp.asarray([r.prompt_tokens], jnp.int32), 8)
        assert r.output_tokens == [int(x) for x in ref[0]], "token mismatch!"
        print(f"req {r.request_id}: P->D transfer ok, tokens {r.output_tokens}")

    s = cluster.stats()
    print(f"\nFlowKV transfers: {s['transfers']} "
          f"(avg {s['mean_transfer_calls']:.1f} call(s)/request, "
          f"est {s['mean_transfer_s']*1e3:.2f} ms on TPU ICI)")
    print("disaggregated output == monolithic output: OK")


if __name__ == "__main__":
    main()
