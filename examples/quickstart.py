"""Quickstart: FlowKV end-to-end in ~40 lines.

Builds a small model, streams requests through the disaggregated cluster
(prefill node -> FlowKV page transfer -> decode node) with the
``FlowKVClient`` handle API, and verifies the streamed output is
token-identical to monolithic generation.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.models.api import get_model
from repro.serving.api import FlowKVClient
from repro.serving.request import SamplingParams


def main():
    cfg = get_smoke_config("qwen3-1.7b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).tolist()
               for n in (12, 25, 33)]

    # 1P + 1D cluster with FlowKV transfer, fronted by the streaming client
    client = FlowKVClient(cfg, params, num_prefill=1, num_decode=1,
                          num_blocks=128, transfer_schedule="flowkv")
    handles = [client.submit(p, SamplingParams(max_new_tokens=8))
               for p in prompts]

    # stream: tokens arrive per cluster cycle, before the request finishes
    for h in handles:
        streamed = list(h.tokens())
        ref = T.greedy_generate(params, cfg,
                                jnp.asarray([h.request.prompt_tokens], jnp.int32), 8)
        assert streamed == [int(x) for x in ref[0]], "token mismatch!"
        t = h.stats()
        print(f"req {h.request_id}: streamed {streamed}")
        print(f"   queue={t['queue_s']:.0f} prefill={t['prefill_s']:.0f} "
              f"transfer={t['transfer_s']:.3f} decode={t['decode_s']:.2f} "
              f"(cluster cycles), ttft={t['ttft_s']:.0f}")

    s = client.stats()
    print(f"\nFlowKV transfers: {s['transfers']} "
          f"(avg {s['mean_transfer_calls']:.1f} call(s)/request, "
          f"est {s['mean_transfer_s']*1e3:.2f} ms on TPU ICI)")
    print("streamed disaggregated output == monolithic output: OK")


if __name__ == "__main__":
    main()
