"""Disaggregated serving with fault injection + checkpoint/restore.

Demonstrates the production-runtime features:
  * 2 prefill + 2 decode nodes with load-aware routing + prefix-cache hits
  * a node failure mid-flight -> heartbeat failover requeues its requests
  * cluster checkpoint + restore

    PYTHONPATH=src python examples/serve_disaggregated.py
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.api import get_model
from repro.serving.checkpoint import save_cluster
from repro.serving.cluster import PDCluster
from repro.serving.request import Request, SamplingParams


def main():
    cfg = get_smoke_config("minitron-8b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cluster = PDCluster(cfg, params, num_prefill=2, num_decode=2,
                        num_blocks=128, hosts={0: 0, 1: 0, 2: 1, 3: 1})
    cluster.controller.heartbeat_timeout = 2.0

    rng = np.random.RandomState(1)
    shared_prefix = rng.randint(0, cfg.vocab_size, size=64).tolist()
    reqs = []
    for i in range(8):
        # half the requests share a 64-token prefix -> prefix-cache routing
        prompt = (shared_prefix + rng.randint(0, cfg.vocab_size, size=8).tolist()
                  if i % 2 == 0 else
                  rng.randint(0, cfg.vocab_size, size=24).tolist())
        reqs.append(Request(prompt_tokens=prompt,
                            sampling=SamplingParams(max_new_tokens=6)))

    for r in reqs[:5]:
        cluster.submit(r)
    for _ in range(4):
        cluster.step()

    print(">>> killing prefill node 0 mid-flight")
    cluster.kill_node(0)
    for r in reqs[5:]:
        cluster.submit(r)
    for _ in range(120):
        cluster.step()
        if len(cluster.finished) == len(reqs):
            break

    print(f"finished {len(cluster.finished)}/{len(reqs)} requests "
          f"despite the failure")
    for e in cluster.controller.events:
        print(f"  [cycle {e.cycle}] {e.kind}: {e.detail}")
    print("prefix cache:", cluster.controller.prefix_index.stats())

    save_cluster(cluster, "/tmp/flowkv_ckpt")
    print("cluster checkpointed to /tmp/flowkv_ckpt")
    stats = cluster.stats()
    print(f"transfers={stats['transfers']} "
          f"mean_calls={stats['mean_transfer_calls']:.1f}")


if __name__ == "__main__":
    main()
