"""The Load-Aware Scheduler's three regimes (paper Alg. 1 + App. B.1),
demonstrated at cluster scale with the discrete-event simulator.

  normal     — balanced routing, prefix-aware TTFT-min / transfer-min
  imbalanced — idle decode nodes switch roles to absorb a prefill burst
  extreme    — sustained overload triggers elastic scale-up

    PYTHONPATH=src python examples/load_aware_scheduling.py
"""
from repro.configs import get_config
from repro.sim.cluster_sim import ClusterSim
from repro.sim.workload import SIMULATED, WorkloadSpec, generate


def show(title, sim, stats):
    print(f"\n=== {title} ===")
    print(f"finished={stats['finished']} thr={stats['throughput_tok_s']:.1f} tok/s "
          f"e2e={stats['mean_e2e_s']:.2f}s tpot={stats['mean_tpot_s']*1e3:.1f}ms")
    kinds = {}
    for e in sim.controller.events:
        kinds.setdefault(e.kind, []).append(e)
    for kind, evts in kinds.items():
        print(f"  {kind}: x{len(evts)} (e.g. {evts[0].detail})")


def main():
    cfg = get_config("llama31-8b")

    # normal load
    sim = ClusterSim(cfg, "flowkv", num_prefill=2, num_decode=2)
    stats = sim.run(generate(SIMULATED["1k"], rps=0.5, seed=0), t_max=20_000)
    show("normal load (1k ctx, 0.5 rps, 2P2D)", sim, stats)

    # imbalanced: prefill-heavy burst against a decode-heavy cluster
    sim = ClusterSim(cfg, "flowkv", num_prefill=1, num_decode=3)
    burst = WorkloadSpec("burst-10k", 10240, 64, num_requests=120)
    stats = sim.run(generate(burst, rps=3.0, seed=0), t_max=20_000)
    show("imbalanced (10k prefill burst, 1P3D -> role switches)", sim, stats)

    # extreme: sustained overload on a tiny cluster with a scale-up factory
    from repro.core.block_manager import BlockManager
    from repro.core.scheduler import HybridScheduler, NodeHandle
    from repro.sim.hardware import A100

    sim = ClusterSim(cfg, "flowkv", num_prefill=1, num_decode=1)

    def factory(role):
        nid = 100 + len([e for e in sim.controller.events if e.kind == "scale_up"])
        from repro.sim.cluster_sim import SimNode
        node = SimNode(nid, role, A100, sim.spec, sim.kv_spec, sim.cost, 8192)
        sim.nodes[nid] = node
        sim._poll_scheduled[nid] = False
        return NodeHandle(node_id=nid, role=role, host_id=9, hardware=A100,
                          scheduler=node.scheduler)

    sim.controller.node_factory = factory
    heavy = WorkloadSpec("overload-5k", 5120, 256, num_requests=150)
    stats = sim.run(generate(heavy, rps=4.0, seed=0), t_max=20_000)
    show("extreme (5k ctx @ 4 rps on 1P1D -> elastic scale-up)", sim, stats)


if __name__ == "__main__":
    main()
