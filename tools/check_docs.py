#!/usr/bin/env python
"""Docs CI gate: every intra-repo link in README.md / docs/*.md must
resolve, and every code symbol the docs cite must exist in the tree.

Checked, per file:

* markdown links ``[text](target)`` whose target is not http(s)/mailto/#
  must point at an existing file (anchors stripped);
* backticked repo paths (`` `foo/bar.py` ``, `` `docs/x.md` ``,
  `` `.github/workflows/ci.yml` ``) must exist — tried relative to the
  repo root, then ``src/``, then ``src/repro/`` (docs often refer to
  ``kernels/...`` the way the code does);
* backticked dotted symbols (`` `repro.x.y.z` ``) must import/resolve:
  the longest importable module prefix is imported and the remaining
  attributes are getattr-walked (classes, functions, methods, dataclass
  attributes all resolve). This is what keeps docs/scheduling.md's
  Alg. 1 -> code mapping honest.

Exit 0 when clean; prints every violation and exits 1 otherwise.
Run from anywhere: ``PYTHONPATH=src python tools/check_docs.py``.
"""
from __future__ import annotations

import importlib
import pathlib
import re
import sys
from typing import List

ROOT = pathlib.Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SYMBOL_RE = re.compile(r"`(repro(?:\.\w+)+)`")
PATH_RE = re.compile(r"`((?:[\w.-]+/)*[\w.-]+\.(?:py|md|yml|yaml|txt))`")

PATH_PREFIXES = ("", "src", "src/repro")


def doc_files() -> List[pathlib.Path]:
    docs = sorted((ROOT / "docs").glob("*.md")) if (ROOT / "docs").is_dir() else []
    return [ROOT / "README.md", *docs]


def check_links(md: pathlib.Path, text: str) -> List[str]:
    errs = []
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not (md.parent / rel).resolve().exists():
            errs.append(f"{md.relative_to(ROOT)}: broken link -> {target}")
    return errs


def check_paths(md: pathlib.Path, text: str) -> List[str]:
    errs = []
    for m in PATH_RE.finditer(text):
        rel = m.group(1)
        if not any((ROOT / pre / rel).exists() for pre in PATH_PREFIXES):
            errs.append(f"{md.relative_to(ROOT)}: missing path `{rel}`")
    return errs


def resolve_symbol(dotted: str) -> bool:
    parts = dotted.split(".")
    for i in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:i]))
        except ImportError:
            continue
        for attr in parts[i:]:
            # private members are real symbols too (_handle_imbalance …)
            if not hasattr(obj, attr):
                return False
            obj = getattr(obj, attr)
        return True
    return False


def check_symbols(md: pathlib.Path, text: str) -> List[str]:
    errs = []
    for dotted in sorted(set(SYMBOL_RE.findall(text))):
        if not resolve_symbol(dotted):
            errs.append(f"{md.relative_to(ROOT)}: unresolvable symbol `{dotted}`")
    return errs


def main() -> int:
    sys.path.insert(0, str(ROOT / "src"))
    errs: List[str] = []
    for md in doc_files():
        text = md.read_text()
        errs += check_links(md, text)
        errs += check_paths(md, text)
        errs += check_symbols(md, text)
    if errs:
        for e in errs:
            print(f"FAIL {e}")
        return 1
    print(f"docs OK: {len(doc_files())} files checked")
    return 0


if __name__ == "__main__":
    sys.exit(main())
