#!/usr/bin/env python
"""Perf-trajectory CI gate over the committed BENCH_<area>.json files.

Thin CLI over :mod:`repro.obs.history` (which holds the schema, the
per-metric gating modes, and the record/check logic — importable from
tests and benchmarks alike):

* ``--check``     — fail (exit 1) when any area's newest entry regresses
  against its committed baseline; this is what the CI perf-trajectory job
  runs after the gated benchmarks append their entries.
* ``--list``      — print each area's baseline, entry count and newest
  metrics (the human view of the trajectory).
* ``--area a,b``  — restrict either mode to a subset of areas.

Run from anywhere: ``PYTHONPATH=src python tools/bench_history.py --check``.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.obs import history  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--check", action="store_true",
                    help="gate newest entries against committed baselines")
    ap.add_argument("--list", action="store_true",
                    help="print the recorded trajectory per area")
    ap.add_argument("--area", default="",
                    help=f"comma-separated subset of {sorted(history.AREAS)}")
    args = ap.parse_args()
    areas = [a for a in args.area.split(",") if a] or sorted(history.AREAS)
    for a in areas:
        if a not in history.AREAS:
            ap.error(f"unknown area {a!r}; have {sorted(history.AREAS)}")

    if args.list:
        for area in areas:
            data = history.load(area)
            if data is None:
                print(f"{area}: no history recorded")
                continue
            newest = data["entries"][-1] if data["entries"] else None
            print(f"{area}: {len(data['entries'])} entries")
            print(f"  baseline: {json.dumps(data['baseline'], sort_keys=True)}")
            if newest:
                print(f"  newest ({newest['ts']}): "
                      f"{json.dumps(newest['metrics'], sort_keys=True)}")
        if not args.check:
            return 0

    failures = history.check_all(areas)
    bad = {a: f for a, f in failures.items() if f}
    for area, msgs in sorted(bad.items()):
        for msg in msgs:
            print(f"REGRESSION {msg}")
    checked = [a for a in areas if history.load(a) is not None]
    print(f"checked {len(checked)} area(s) with history "
          f"({', '.join(checked) or 'none'}): "
          f"{'FAIL' if bad else 'ok'}")
    return 1 if (args.check and bad) else 0


if __name__ == "__main__":
    raise SystemExit(main())
