"""Scheduling scenario suite — load-aware vs naive routing across the
paper's stress regimes (§3.3–§3.4: normal / imbalanced / overload, plus the
heterogeneous fleet).

Every scenario in ``repro.sim.scenarios`` runs under three routing
policies over the SAME request trace (fixed seed, deterministic
discrete-event simulation — wall-clock independent, CI-safe):

* ``load_aware``  — the full FlowKV control plane: smoothed capability-
  normalized scores, regime actions (role flip under imbalance) and the
  overload admission gate.
* ``round_robin`` — blind rotation, passive controller.
* ``static_pd``   — fixed roles, round-robin P, least-loaded D, passive
  controller (the classic disaggregated baseline).

Reported per (scenario, policy): goodput (fraction of OFFERED requests —
rejections included — finishing within the scenario's TTFT SLO), p95 TTFT,
rejections, starved nodes, throughput.

CLI: ``python -m benchmarks.scenarios [--json] [--check] [--only a,b]``

``--check`` is the CI gate for the paper's scheduling claim:

* imbalance & overload: load-aware >= both baselines on goodput AND
  <= both baselines on p95 TTFT;
* overload: the admission gate actually fired (rejections > 0);
* heterogeneous: every offered request completes (finished + rejected ==
  offered) with ZERO starved nodes;
* normal: load-aware completes everything (no regression where there is
  nothing to exploit).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional, Sequence

from repro.sim.cluster_sim import ROUTING_POLICIES
from repro.sim.scenarios import SCENARIOS, get_scenario

GATED = ("imbalance", "overload")


def bench(names: Optional[Sequence[str]] = None
          ) -> Dict[str, Dict[str, Dict[str, float]]]:
    """{scenario: {policy: stats}} for the selected scenarios."""
    out: Dict[str, Dict[str, Dict[str, float]]] = {}
    for name in (names or list(SCENARIOS)):
        sc = get_scenario(name)
        out[name] = {}
        for pol in ROUTING_POLICIES:
            t0 = time.perf_counter()
            stats = sc.run(pol)
            stats["wall_us"] = (time.perf_counter() - t0) * 1e6
            out[name][pol] = stats
    return out


def rows(stats=None) -> List[str]:
    stats = stats or bench()
    out = []
    for name, by_policy in stats.items():
        for pol, s in by_policy.items():
            out.append(
                f"scenario/{name}/{pol},{s['wall_us']:.0f},"
                f"goodput={s['goodput']:.3f};p95_ttft_s={s['p95_ttft_s']:.2f}"
                f";finished={s['finished']};rejected={s['rejected']}"
                f";starved={s['starved_nodes']}"
                f";thr={s['throughput_tok_s']:.1f}")
    return out


def check(stats: Dict[str, Dict[str, Dict[str, float]]]) -> None:
    """CI gate: the load-aware control plane must EARN its complexity."""
    for name, by_policy in stats.items():
        la = by_policy["load_aware"]
        if name in GATED:
            for base in ("round_robin", "static_pd"):
                b = by_policy[base]
                assert la["goodput"] >= b["goodput"], (
                    f"{name}: load_aware goodput {la['goodput']:.3f} < "
                    f"{base} {b['goodput']:.3f}")
                assert la["p95_ttft_s"] <= b["p95_ttft_s"], (
                    f"{name}: load_aware p95 TTFT {la['p95_ttft_s']:.2f}s > "
                    f"{base} {b['p95_ttft_s']:.2f}s")
        if name == "overload":
            assert la["rejected"] > 0, \
                "overload: the admission gate never fired"
        if name == "heterogeneous":
            assert la["starved_nodes"] == 0, \
                f"heterogeneous: {la['starved_nodes']} starved node(s)"
            assert la["finished"] + la["rejected"] == la["offered"], (
                f"heterogeneous: {la['finished']}+{la['rejected']} of "
                f"{la['offered']} accounted for")
        if name == "normal":
            assert la["finished"] == la["offered"], \
                f"normal: only {la['finished']}/{la['offered']} finished"
        if name == "multiturn":
            assert la["prefix_tokens_reused"] > 0, \
                "multiturn: conversation history was never reused"
            assert la["tier_promoted_blocks"] > 0, \
                "multiturn: the host tier never promoted anything"
            assert la["leaked_blocks"] == 0, \
                f"multiturn: {la['leaked_blocks']} leaked blocks"


def history_metrics(stats: Dict[str, Dict[str, Dict[str, float]]]
                    ) -> Dict[str, float]:
    """Per-scenario load-aware headlines for BENCH_scenarios.json."""
    out: Dict[str, float] = {}
    for name, by_policy in stats.items():
        la = by_policy["load_aware"]
        out[f"{name}_load_aware_goodput"] = la["goodput"]
        out[f"{name}_load_aware_p95_ttft_s"] = la["p95_ttft_s"]
    if "overload" in stats:
        out["overload_rejected"] = stats["overload"]["load_aware"]["rejected"]
    if "heterogeneous" in stats:
        out["heterogeneous_starved_nodes"] = \
            stats["heterogeneous"]["load_aware"]["starved_nodes"]
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="print {scenario: {policy: stats}} as JSON")
    ap.add_argument("--check", action="store_true",
                    help="assert the load-aware-wins gates (CI smoke)")
    ap.add_argument("--history", action="store_true",
                    help="append to BENCH_scenarios.json (repro.obs.history)")
    ap.add_argument("--only", default="",
                    help=f"comma-separated subset of {sorted(SCENARIOS)}")
    args = ap.parse_args()
    names = [n for n in args.only.split(",") if n] or None
    stats = bench(names)
    if args.check:
        check(stats)
    if args.history:
        from repro.obs import history
        history.record("scenarios", history_metrics(stats))
    if args.json:
        print(json.dumps(stats, indent=2, sort_keys=True))
        return
    for r in rows(stats):
        print(r)


if __name__ == "__main__":
    main()
