"""Tables 1-2 — system throughput vs RPS, simulated-data workloads.

Drives the REAL scheduler/allocator control plane through the discrete-event
simulator for every (workload x rps x system) cell. ``--full`` runs the
paper's complete RPS grid; default is an abbreviated grid for CI.
"""
from __future__ import annotations

import time
from typing import List, Optional

from repro.configs import get_config
from repro.sim.cluster_sim import SYSTEMS, ClusterSim
from repro.sim.workload import SIMULATED, generate

FULL_RPS = (0.1, 0.2, 0.4, 0.6, 0.8, 1.0, 1.5, 2.0)
QUICK_RPS = (0.2, 1.0, 2.0)

# paper reference points for validation (Table 1, selected cells)
PAPER_8B = {
    ("1k", 2.0, "flowkv"): 507.36, ("1k", 2.0, "vllm_disagg"): 394.05,
    ("5k", 1.0, "flowkv"): 264.22, ("5k", 1.0, "vllm_disagg"): 202.87,
    ("10k", 1.0, "flowkv"): 251.55, ("10k", 1.0, "vllm_disagg"): 171.11,
    ("10k", 2.0, "flowkv"): 285.14, ("10k", 2.0, "vllm_disagg"): 185.47,
}


def rows(model: str = "llama31-8b", full: bool = False,
         systems: Optional[List[str]] = None, tp: int = 1) -> List[str]:
    cfg = get_config(model)
    rps_grid = FULL_RPS if full else QUICK_RPS
    out = []
    for wl_name, wl in SIMULATED.items():
        for rps in rps_grid:
            for kind in (systems or SYSTEMS):
                t0 = time.perf_counter()
                sim = ClusterSim(cfg, kind, tp=tp)
                stats = sim.run(generate(wl, rps=rps, seed=0), t_max=50_000)
                wall_us = (time.perf_counter() - t0) * 1e6
                ref = PAPER_8B.get((wl_name, rps, kind))
                extra = f",paper={ref}" if (ref and model == "llama31-8b") else ""
                out.append(
                    f"table1/{model}/{wl_name}/rps{rps}/{kind},{wall_us:.0f},"
                    f"throughput_tok_s={stats['throughput_tok_s']:.2f}"
                    f";e2e_s={stats['mean_e2e_s']:.2f}"
                    f";xfer_ms={stats['mean_transfer_s']*1e3:.2f}"
                    f";fin={stats['finished']}{extra}")
    return out


def rows_70b(full: bool = False) -> List[str]:
    """Table 2: llama31-70b, two nodes of intra-node TP=4."""
    return [r.replace("table1/", "table2/")
            for r in rows("llama31-70b", full=full, tp=4)]


if __name__ == "__main__":
    for r in rows():
        print(r)
