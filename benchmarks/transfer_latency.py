"""Table 3 — KV-cache transfer latency vs context length.

Exact call counts come from the real ``TransferPlanner`` over the real
allocators; latency from the Table-3-calibrated transport profiles.
Also reports the TPU-target (ICI/DCN) columns — the port's predicted
transfer latencies — and wall-clock µs/call of the planner itself.
"""
from __future__ import annotations

import time
from typing import List

from repro.configs import get_config
from repro.core.costmodel import (IPC, MOONCAKE_RDMA, NCCL_ENI, NCCL_INTRA,
                                  TPU_DCN, TPU_ICI, VLLM_MERGE_ENI,
                                  VLLM_MERGE_INTRA)
from repro.core.layout import KVCacheSpec
from repro.core.transfer import TransferPlanner

PAPER_SINGLE = {  # input_tokens -> (mooncake, vllm_disagg, flowkv_layerwise, flowkv)
    500: (0.3010, 0.1179, 0.0678, 0.0044),
    1000: (0.5416, 0.2314, 0.1309, 0.0075),
    2000: (1.0335, 0.3435, 0.2565, 0.0126),
    4000: (1.3473, 0.6670, 0.5338, 0.0236),
    8000: (2.0289, 1.3382, 1.1173, 0.0447),
    10000: (None, 1.7373, 1.4121, 0.0555),
    12000: (None, 2.1894, 1.7218, 0.0681),
}
PAPER_MULTI = {
    500: (0.3418, 0.1197, 0.1176, 0.0080),
    1000: (0.5820, 0.1914, 0.3262, 0.0136),
    2000: (0.8180, 0.3444, 0.4324, 0.0260),
    4000: (1.4342, 0.6681, 0.8668, 0.0519),
    8000: (2.1250, 1.3462, 1.6711, 0.0993),
    10000: (None, 1.7425, 2.0719, 0.1500),
    12000: (None, 2.1974, 2.4965, 0.1759),
}


def rows(arch: str = "llama31-8b") -> List[str]:
    cfg = get_config(arch)
    spec = KVCacheSpec(num_layers=cfg.num_layers, num_blocks=8192,
                       block_size=cfg.block_size, num_kv_heads=cfg.num_kv_heads,
                       head_dim=cfg.head_dim, dtype=cfg.dtype)
    planner = TransferPlanner(spec)
    out = []
    for setup, paper in (("single", PAPER_SINGLE), ("multi", PAPER_MULTI)):
        for tokens, ref in paper.items():
            n = spec.blocks_for_tokens(tokens)
            ids = list(range(n))
            t0 = time.perf_counter()
            plan_fk = planner.plan_flowkv(ids, ids)
            plan_us = (time.perf_counter() - t0) * 1e6
            plan_lw = planner.plan_layerwise(ids, ids)
            plan_bw = planner.plan_blockwise(ids, ids)
            if setup == "single":
                lat_fk = plan_fk.latency(IPC)
                lat_lw = plan_lw.latency(NCCL_INTRA)
                lat_bw = plan_bw.latency(VLLM_MERGE_INTRA)
                lat_mc = plan_bw.latency(MOONCAKE_RDMA)
                lat_tpu = plan_fk.latency(TPU_ICI)
            else:
                lat_fk = plan_fk.latency(NCCL_ENI)
                lat_lw = plan_lw.latency(NCCL_ENI)
                lat_bw = plan_bw.latency(VLLM_MERGE_ENI)
                lat_mc = plan_bw.latency(MOONCAKE_RDMA)
                lat_tpu = plan_fk.latency(TPU_DCN)
            speedup = lat_lw / lat_fk
            pref = f"table3/{setup}/{tokens}"
            out.append(f"{pref}/flowkv,{lat_fk*1e6:.1f},paper={ref[3]}")
            out.append(f"{pref}/flowkv_layerwise,{lat_lw*1e6:.1f},paper={ref[2]}")
            out.append(f"{pref}/vllm_disagg,{lat_bw*1e6:.1f},paper={ref[1]}")
            out.append(f"{pref}/mooncake,{lat_mc*1e6:.1f},paper={ref[0]}")
            out.append(f"{pref}/flowkv_tpu,{lat_tpu*1e6:.1f},speedup_vs_layerwise={speedup:.1f}x")
            out.append(f"{pref}/planner_wallclock,{plan_us:.1f},calls={plan_fk.num_calls}")
    # headline: calls per request at ~11.7k ctx (paper: 23,469 -> 1)
    n = spec.blocks_for_tokens(11700)
    ids = list(range(n))
    lw = planner.plan_layerwise(ids, ids)
    fk = planner.plan_flowkv(ids, ids)
    out.append(f"table3/calls_per_request/layerwise,{lw.num_calls},paper=23469")
    out.append(f"table3/calls_per_request/flowkv,{fk.num_calls},paper=1")
    return out


if __name__ == "__main__":
    for r in rows():
        print(r)
