"""Table 3 — KV-cache transfer latency vs context length.

Exact call counts come from the real ``TransferPlanner`` over the real
allocators; latency from the Table-3-calibrated transport profiles.
Also reports the TPU-target (ICI/DCN) columns — the port's predicted
transfer latencies — and wall-clock µs/call of the planner itself.

The dispatch section executes the REAL fused data plane (one Pallas
descriptor-table dispatch per plan) on a small pool and reports, per
schedule, the planner's transport-call count next to the executor's
dispatch count and wall-clock — the paper's call-count collapse made
observable: layerwise/blockwise/flowkv differ in ``num_calls`` only,
every one of them runs as a single dispatch.

CLI: ``python -m benchmarks.transfer_latency [--json] [--check]``
(``--check`` asserts flowkv <= blockwise <= layerwise on calls and
dispatches; used by CI as the smoke gate).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.costmodel import (IPC, MOONCAKE_RDMA, NCCL_ENI, NCCL_INTRA,
                                  TPU_DCN, TPU_ICI, VLLM_MERGE_ENI,
                                  VLLM_MERGE_INTRA)
from repro.core.layout import KVCacheSpec, alloc_cache
from repro.core.transfer import TransferEngine, TransferPlanner

SCHEDULES = ("layerwise", "blockwise", "flowkv")

PAPER_SINGLE = {  # input_tokens -> (mooncake, vllm_disagg, flowkv_layerwise, flowkv)
    500: (0.3010, 0.1179, 0.0678, 0.0044),
    1000: (0.5416, 0.2314, 0.1309, 0.0075),
    2000: (1.0335, 0.3435, 0.2565, 0.0126),
    4000: (1.3473, 0.6670, 0.5338, 0.0236),
    8000: (2.0289, 1.3382, 1.1173, 0.0447),
    10000: (None, 1.7373, 1.4121, 0.0555),
    12000: (None, 2.1894, 1.7218, 0.0681),
}
PAPER_MULTI = {
    500: (0.3418, 0.1197, 0.1176, 0.0080),
    1000: (0.5820, 0.1914, 0.3262, 0.0136),
    2000: (0.8180, 0.3444, 0.4324, 0.0260),
    4000: (1.4342, 0.6681, 0.8668, 0.0519),
    8000: (2.1250, 1.3462, 1.6711, 0.0993),
    10000: (None, 1.7425, 2.0719, 0.1500),
    12000: (None, 2.1974, 2.4965, 0.1759),
}


def rows(arch: str = "llama31-8b") -> List[str]:
    cfg = get_config(arch)
    spec = KVCacheSpec(num_layers=cfg.num_layers, num_blocks=8192,
                       block_size=cfg.block_size, num_kv_heads=cfg.num_kv_heads,
                       head_dim=cfg.head_dim, dtype=cfg.dtype)
    planner = TransferPlanner(spec)
    out = []
    for setup, paper in (("single", PAPER_SINGLE), ("multi", PAPER_MULTI)):
        for tokens, ref in paper.items():
            n = spec.blocks_for_tokens(tokens)
            ids = list(range(n))
            t0 = time.perf_counter()
            plan_fk = planner.plan_flowkv(ids, ids)
            plan_us = (time.perf_counter() - t0) * 1e6
            plan_lw = planner.plan_layerwise(ids, ids)
            plan_bw = planner.plan_blockwise(ids, ids)
            if setup == "single":
                lat_fk = plan_fk.latency(IPC)
                lat_lw = plan_lw.latency(NCCL_INTRA)
                lat_bw = plan_bw.latency(VLLM_MERGE_INTRA)
                lat_mc = plan_bw.latency(MOONCAKE_RDMA)
                lat_tpu = plan_fk.latency(TPU_ICI)
            else:
                lat_fk = plan_fk.latency(NCCL_ENI)
                lat_lw = plan_lw.latency(NCCL_ENI)
                lat_bw = plan_bw.latency(VLLM_MERGE_ENI)
                lat_mc = plan_bw.latency(MOONCAKE_RDMA)
                lat_tpu = plan_fk.latency(TPU_DCN)
            speedup = lat_lw / lat_fk
            pref = f"table3/{setup}/{tokens}"
            out.append(f"{pref}/flowkv,{lat_fk*1e6:.1f},paper={ref[3]}")
            out.append(f"{pref}/flowkv_layerwise,{lat_lw*1e6:.1f},paper={ref[2]}")
            out.append(f"{pref}/vllm_disagg,{lat_bw*1e6:.1f},paper={ref[1]}")
            out.append(f"{pref}/mooncake,{lat_mc*1e6:.1f},paper={ref[0]}")
            out.append(f"{pref}/flowkv_tpu,{lat_tpu*1e6:.1f},speedup_vs_layerwise={speedup:.1f}x")
            out.append(f"{pref}/planner_wallclock,{plan_us:.1f},calls={plan_fk.num_calls}")
    # headline: calls per request at ~11.7k ctx (paper: 23,469 -> 1)
    n = spec.blocks_for_tokens(11700)
    ids = list(range(n))
    lw = planner.plan_layerwise(ids, ids)
    fk = planner.plan_flowkv(ids, ids)
    out.append(f"table3/calls_per_request/layerwise,{lw.num_calls},paper=23469")
    out.append(f"table3/calls_per_request/flowkv,{fk.num_calls},paper=1")
    return out


def dispatch_stats() -> Dict[str, Dict[str, float]]:
    """Execute the fused data plane per schedule; report calls vs dispatches.

    Runs on a small pool (interpret-mode Pallas on CPU) so the wall-clock
    measures the one-dispatch-per-plan execution path itself, not staging an
    8k-block pool through the interpreter.
    """
    spec = KVCacheSpec(num_layers=4, num_blocks=96, block_size=4,
                       num_kv_heads=2, head_dim=8, dtype=jnp.float32)
    src_pool = jnp.arange(
        int(jnp.prod(jnp.asarray(spec.shape))), dtype=jnp.float32
    ).reshape(spec.shape)
    n = 12
    src_ids = list(range(2, 2 + n))
    dst_ids = list(range(30, 30 + n))      # aligned placement: flowkv -> 1 call
    stats: Dict[str, Dict[str, float]] = {}
    for schedule in SCHEDULES:
        engine = TransferEngine(spec)
        plan = engine.planner.plan(schedule, src_ids, dst_ids)
        engine.execute(plan, src_pool, alloc_cache(spec))   # warm the jit cache
        dst_pool = jax.block_until_ready(alloc_cache(spec))
        t0 = time.perf_counter()
        out_pool = engine.execute(plan, src_pool, dst_pool)
        jax.block_until_ready(out_pool)
        wall_s = time.perf_counter() - t0
        stats[schedule] = {
            "num_calls": plan.num_calls,
            "num_dispatches": plan.num_dispatches,
            "num_descriptors": len(plan.to_descriptors()),
            "wall_s": wall_s,
        }
    return stats


def dispatch_rows() -> List[str]:
    out = []
    for schedule, s in dispatch_stats().items():
        out.append(
            f"table3/dispatch/{schedule},{s['wall_s']*1e6:.1f},"
            f"calls={s['num_calls']} dispatches={s['num_dispatches']} "
            f"descriptors={s['num_descriptors']}")
    return out


def check(stats: Dict[str, Dict[str, float]]) -> None:
    """CI smoke gate: the paper's call-count ordering must hold, and every
    schedule must execute as a single dispatch."""
    calls = {s: stats[s]["num_calls"] for s in SCHEDULES}
    disp = {s: stats[s]["num_dispatches"] for s in SCHEDULES}
    assert disp["flowkv"] <= disp["blockwise"] <= disp["layerwise"], disp
    assert calls["flowkv"] <= calls["blockwise"] <= calls["layerwise"], calls
    assert all(d == 1 for d in disp.values()), disp


def history_metrics(stats: Dict[str, Dict[str, float]]) -> Dict[str, float]:
    """Flatten dispatch stats for BENCH_transfer.json (repro.obs.history)."""
    out: Dict[str, float] = {}
    for schedule, s in stats.items():
        out[f"{schedule}_calls"] = s["num_calls"]
        out[f"{schedule}_dispatches"] = s["num_dispatches"]
    out["flowkv_wall_s"] = stats["flowkv"]["wall_s"]
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="print per-schedule dispatch stats as JSON")
    ap.add_argument("--check", action="store_true",
                    help="assert flowkv <= blockwise <= layerwise ordering")
    ap.add_argument("--history", action="store_true",
                    help="append to BENCH_transfer.json (repro.obs.history)")
    args = ap.parse_args()
    stats = dispatch_stats()
    if args.check:
        check(stats)
    if args.history:
        from repro.obs import history
        history.record("transfer", history_metrics(stats))
    if args.json:
        print(json.dumps(stats, indent=2, sort_keys=True))
        return
    for r in rows():
        print(r)
    for r in dispatch_rows():
        print(r)


if __name__ == "__main__":
    main()
