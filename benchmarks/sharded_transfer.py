"""Sharded serving — mesh-parallel engines + per-shard-pair fused transfer.

Two sections:

* **engine** — a real ``PDCluster`` (smoke model, real JAX compute) runs the
  SAME prompts under three shard topologies (TP=2 -> TP=1, TP=1 -> TP=2,
  TP=2 -> TP=2) plus the unsharded TP=1 -> TP=1 reference. Gates:

  - every topology's output tokens are BIT-IDENTICAL to the single-device
    greedy reference (``token_mismatches == 0``);
  - each cross-degree transfer costs exactly one fused dispatch per
    overlapping shard pair — ``tp_src + tp_dst - gcd(tp_src, tp_dst)``,
    which for the 1->N / N->1 shapes equals ``tp_src * tp_dst`` literally;
  - transfer BYTES are conserved: a sharded hop moves exactly the bytes the
    unsharded reference transfer moves (``transfer_byte_mismatches == 0``).

* **sim** — the ``sharded_heterogeneous`` scenario (TP=4 70B-class prefill
  node feeding TP=1 decode nodes on the deterministic discrete-event sim):
  every transfer prices the 4-pair dispatch structure, nothing starves,
  nothing leaks.

CLI: ``python -m benchmarks.sharded_transfer [--json] [--check] [--history]``
(``--check`` is the CI ``sharded-smoke`` gate; ``--history`` appends the
headline metrics to ``BENCH_sharded.json`` via ``repro.obs.history``.)
"""
from __future__ import annotations

import argparse
import json
import math
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.costmodel import sharded_transfer_calls
from repro.models import transformer as T
from repro.models.api import get_model
from repro.serving.cluster import PDCluster
from repro.serving.request import Request, SamplingParams
from repro.sim.scenarios import get_scenario

ARCH = "qwen3-1.7b"
NUM_PROMPTS = 3
NEW_TOKENS = 4
TOPOLOGIES = (("tp2_to_tp1", 2, 1), ("tp1_to_tp2", 1, 2), ("tp2_to_tp2", 2, 2))


# ---------------------------------------------------------------------------
# engine: real cluster across shard topologies, gated on identity + structure
# ---------------------------------------------------------------------------
def _prompts(cfg) -> List[List[int]]:
    rng = np.random.RandomState(0)
    return [rng.randint(0, cfg.vocab_size, size=int(n)).tolist()
            for n in rng.randint(8, 24, size=NUM_PROMPTS)]


def _run(cfg, params, prompts, tp_src: int, tp_dst: int) -> Dict[str, object]:
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=1,
                        num_blocks=128,
                        tp_degrees={0: tp_src, 1: tp_dst})
    reqs = [Request(prompt_tokens=list(p),
                    sampling=SamplingParams(max_new_tokens=NEW_TOKENS))
            for p in prompts]
    done = cluster.run(reqs, max_cycles=120)
    assert len(done) == len(prompts), (tp_src, tp_dst, len(done))
    outputs = {tuple(r.prompt_tokens): [int(t) for t in r.output_tokens]
               for r in done}
    xfers = [t for t in cluster.transfers if t.kind == "kv"]
    return {
        "outputs": outputs,
        "dispatches_per_transfer": sorted({t.num_dispatches for t in xfers}),
        "transfer_bytes": sorted(t.num_bytes for t in xfers),
        "shard_dispatches": cluster.stats()["shard_dispatches"],
        "leaked_blocks": cluster.stats()["leaked_blocks"],
    }


def _bench_engine() -> Dict[str, object]:
    cfg = get_smoke_config(ARCH)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    prompts = _prompts(cfg)
    refs = {tuple(p): [int(x) for x in
                       T.greedy_generate(params, cfg,
                                         jnp.asarray([p], jnp.int32),
                                         NEW_TOKENS)[0]]
            for p in prompts}
    t0 = time.perf_counter()
    baseline = _run(cfg, params, prompts, 1, 1)
    out: Dict[str, object] = {"leaked_blocks": baseline["leaked_blocks"]}
    token_mismatches = sum(
        1 for p in prompts if baseline["outputs"][tuple(p)] != refs[tuple(p)])
    byte_mismatches = 0
    for label, tp_src, tp_dst in TOPOLOGIES:
        r = _run(cfg, params, prompts, tp_src, tp_dst)
        token_mismatches += sum(
            1 for p in prompts if r["outputs"][tuple(p)] != refs[tuple(p)])
        # bytes conserved: the shard-pair lowering partitions the reference
        # transfer's bytes exactly, so the per-request totals must match
        byte_mismatches += int(
            r["transfer_bytes"] != baseline["transfer_bytes"])
        expected = sharded_transfer_calls(tp_src, tp_dst)
        out[label] = {
            "tp_src": tp_src, "tp_dst": tp_dst,
            "dispatches_per_transfer": r["dispatches_per_transfer"],
            "expected_dispatches": expected,
            # for 1->N / N->1 shapes the pair count is literally the product
            "product_rule_holds": (
                min(tp_src, tp_dst) > 1
                or expected == tp_src * tp_dst),
            "shard_dispatches": r["shard_dispatches"],
        }
        out["leaked_blocks"] += r["leaked_blocks"]
    out["token_mismatches"] = token_mismatches
    out["transfer_byte_mismatches"] = byte_mismatches
    out["wall_s"] = time.perf_counter() - t0
    return out


# ---------------------------------------------------------------------------
# sim: sharded_heterogeneous scenario (TP=4 prefill -> TP=1 decode)
# ---------------------------------------------------------------------------
def _bench_sim() -> Dict[str, float]:
    sc = get_scenario("sharded_heterogeneous")
    stats = sc.run("load_aware")
    stats["expected_dispatches"] = sharded_transfer_calls(4, 1)
    return stats


def bench() -> Dict[str, object]:
    return {"engine": _bench_engine(), "sim": _bench_sim()}


def rows(stats=None) -> List[str]:
    stats = stats or bench()
    e = stats["engine"]
    out = []
    for label, _, _ in TOPOLOGIES:
        t = e[label]
        out.append(
            f"sharded/engine/{label},{e['wall_s'] * 1e6:.0f},"
            f"dispatches={t['dispatches_per_transfer']}"
            f";expected={t['expected_dispatches']}"
            f";shard_dispatches={t['shard_dispatches']}")
    out.append(
        f"sharded/engine/gates,{e['wall_s'] * 1e6:.0f},"
        f"token_mismatches={e['token_mismatches']}"
        f";byte_mismatches={e['transfer_byte_mismatches']}"
        f";leaked={e['leaked_blocks']}")
    s = stats["sim"]
    out.append(
        f"sharded/sim/heterogeneous,0,"
        f"mean_dispatches={s['mean_transfer_dispatches']:.1f}"
        f";goodput={s['goodput']:.3f};starved={s['starved_nodes']}"
        f";max_tp={s['max_tp_degree']}")
    return out


def check(stats: Dict[str, object]) -> None:
    """CI gate: identity, dispatch structure and byte conservation."""
    e = stats["engine"]
    assert e["token_mismatches"] == 0, (
        f"{e['token_mismatches']} sharded outputs diverged from the "
        f"single-device greedy reference")
    assert e["transfer_byte_mismatches"] == 0, (
        "sharded transfers moved different byte totals than the unsharded "
        "reference")
    assert e["leaked_blocks"] == 0, e["leaked_blocks"]
    for label, tp_src, tp_dst in TOPOLOGIES:
        t = e[label]
        expected = tp_src + tp_dst - math.gcd(tp_src, tp_dst)
        assert t["dispatches_per_transfer"] == [expected], (
            f"{label}: per-transfer dispatches {t['dispatches_per_transfer']} "
            f"!= one per shard pair ({expected})")
        assert t["product_rule_holds"], label
        # the cluster counter tallies lands on SHARDED destination pools, so
        # it is legitimately 0 when the decode side is unsharded (tp_dst=1)
        if tp_dst > 1:
            assert t["shard_dispatches"] > 0, label
    s = stats["sim"]
    assert s["mean_transfer_dispatches"] == s["expected_dispatches"], (
        s["mean_transfer_dispatches"], s["expected_dispatches"])
    assert s["finished"] == s["offered"], (s["finished"], s["offered"])
    assert s["starved_nodes"] == 0, s["starved_nodes"]
    assert s["leaked_blocks"] == 0, s["leaked_blocks"]


def history_metrics(stats: Dict[str, object]) -> Dict[str, float]:
    """Sharded-plane headlines for BENCH_sharded.json (repro.obs.history)."""
    e = stats["engine"]
    return {
        "dispatches_tp2_to_tp1": float(
            e["tp2_to_tp1"]["dispatches_per_transfer"][0]),
        "dispatches_tp1_to_tp2": float(
            e["tp1_to_tp2"]["dispatches_per_transfer"][0]),
        "dispatches_tp2_to_tp2": float(
            e["tp2_to_tp2"]["dispatches_per_transfer"][0]),
        "token_mismatches": float(e["token_mismatches"]),
        "transfer_byte_mismatches": float(e["transfer_byte_mismatches"]),
        "sim_mean_transfer_dispatches": float(
            stats["sim"]["mean_transfer_dispatches"]),
        "sharded_decode_wall_s": float(e["wall_s"]),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="print section stats as JSON")
    ap.add_argument("--check", action="store_true",
                    help="assert the identity/dispatch/byte gates (CI smoke)")
    ap.add_argument("--history", action="store_true",
                    help="append to BENCH_sharded.json (repro.obs.history)")
    args = ap.parse_args()
    stats = bench()
    if args.check:
        check(stats)
    if args.history:
        from repro.obs import history
        history.record("sharded", history_metrics(stats))
    if args.json:
        print(json.dumps(stats, indent=2, sort_keys=True, default=str))
        return
    for r in rows(stats):
        print(r)


if __name__ == "__main__":
    main()
