"""Chunked prefill + layerwise transfer/compute overlap — the long-prompt
mix A/B (Sarathi-style chunking vs cycle-lockstep, ± layer-window KV
streaming).

One heavy-tailed request stream (mostly short prompts, a thin tail of
~9k-token prompts — the regime where head-of-line blocking lives) runs
through the SAME deterministic FlowKV simulator under three engine
configurations:

* ``lockstep``  — chunked prefill OFF: a long prompt monopolizes its
  prefill node end-to-end and decode batches re-form only at cycle
  boundaries (the distserve-style failure mode, on FlowKV's own transfer
  plane so ONLY scheduling differs).
* ``chunked``   — Sarathi chunking ON (`prefill_chunk_tokens`): long
  prompts execute as interleaved suffix chunks, short prompts and decode
  steps schedule between them (continuous batching).
* ``overlap``   — chunked + ``layer_window``: each P->D transfer streams
  as per-layer-window sub-plans while later layers still prefill; only the
  spill past the end of prefill is exposed latency.

CLI: ``python -m benchmarks.chunked_prefill [--json] [--check] [--history]``

``--check`` is the CI gate for this PR's claim:

* chunked beats lockstep on p95 TTFT, strictly;
* chunked+overlap beats lockstep on p95 TTFT, strictly;
* overlap hides >= MIN_HIDDEN_FRAC of total transfer wall time;
* every offered request finishes under every configuration (no goodput
  cheat: the TTFT win must not come from dropping work).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Optional, Sequence

from repro.configs import get_config
from repro.sim.cluster_sim import ClusterSim
from repro.sim.workload import WorkloadSpec, generate_mixture

# Heavy-tailed prompt mix: 85% short chat-style, 11% mid documents, 4%
# long-context tail. The tail share is deliberately SMALL: chunking slows
# the long prompts themselves (more cycles, per-cycle overhead — the
# Sarathi trade-off), so its p95 win only exists when the tail latency is
# requests BLOCKED BEHIND a long prefill, not the long prefill itself.
# With >~5% long prompts p95 lands on the longs and lockstep wins; that
# regime is documented in docs/chunked_prefill.md, not gated here.
MIX = (
    WorkloadSpec("short", 256, 128, input_std=64, output_std=32),
    WorkloadSpec("mid", 2048, 256, input_std=512, output_std=64),
    WorkloadSpec("long", 9216, 256, input_std=1024, output_std=64),
)
WEIGHTS = (0.85, 0.11, 0.04)
NUM_REQUESTS = 80
RPS = 20.0              # contended but stable: queues form, nothing drops
SEED = 11

CHUNK_TOKENS = 512      # Sarathi chunk cap (tokens per prompt per cycle)
LAYER_WINDOW = 8        # layers per transfer sub-plan (llama31-8b: L=32)

# The documented floor on the share of transfer wall time layer-window
# streaming must hide behind prefill compute (docs/chunked_prefill.md).
MIN_HIDDEN_FRAC = 0.4

MODES = ("lockstep", "chunked", "overlap")


def _sim(mode: str) -> ClusterSim:
    cfg = get_config("llama31-8b")
    kw = dict(num_prefill=2, num_decode=2, same_host=False,
              max_batch_tokens=8192)
    if mode == "lockstep":
        return ClusterSim(cfg, "flowkv", chunked_prefill=False, **kw)
    if mode == "chunked":
        return ClusterSim(cfg, "flowkv", chunked_prefill=True,
                          prefill_chunk_tokens=CHUNK_TOKENS, **kw)
    if mode == "overlap":
        return ClusterSim(cfg, "flowkv", chunked_prefill=True,
                          prefill_chunk_tokens=CHUNK_TOKENS,
                          layer_window=LAYER_WINDOW, **kw)
    raise ValueError(f"unknown mode {mode!r}")


def bench(modes: Optional[Sequence[str]] = None) -> Dict[str, Dict[str, float]]:
    """{mode: sim stats} over the SAME long-prompt-mix trace."""
    out: Dict[str, Dict[str, float]] = {}
    for mode in (modes or MODES):
        requests = generate_mixture(MIX, WEIGHTS, rps=RPS,
                                    num_requests=NUM_REQUESTS, seed=SEED)
        sim = _sim(mode)
        t0 = time.perf_counter()
        stats = sim.run(requests, t_max=100_000.0)
        stats["wall_us"] = (time.perf_counter() - t0) * 1e6
        stats["windows_per_transfer"] = (
            -(-sim.kv_spec.num_layers // LAYER_WINDOW)
            if mode == "overlap" else 1)
        out[mode] = stats
    return out


def rows(stats=None):
    stats = stats or bench()
    out = []
    for mode, s in stats.items():
        out.append(
            f"chunked/{mode},{s['wall_us']:.0f},"
            f"p95_ttft_s={s['p95_ttft_s']:.2f}"
            f";finished={s['finished']}"
            f";mean_transfer_s={s['mean_transfer_s']:.4f}"
            f";hidden_frac={s['transfer_hidden_frac']:.3f}"
            f";thr={s['throughput_tok_s']:.1f}")
    return out


def check(stats: Dict[str, Dict[str, float]]) -> None:
    """CI gate: chunking + overlap must EARN their complexity."""
    lock, chk, ovl = (stats[m] for m in MODES)
    for mode, s in stats.items():
        assert s["finished"] == s["offered"], (
            f"{mode}: only {s['finished']}/{s['offered']} finished — "
            f"a p95 win over dropped work proves nothing")
    assert chk["p95_ttft_s"] < lock["p95_ttft_s"], (
        f"chunked p95 TTFT {chk['p95_ttft_s']:.2f}s not better than "
        f"lockstep {lock['p95_ttft_s']:.2f}s")
    assert ovl["p95_ttft_s"] < lock["p95_ttft_s"], (
        f"chunked+overlap p95 TTFT {ovl['p95_ttft_s']:.2f}s not better "
        f"than lockstep {lock['p95_ttft_s']:.2f}s")
    assert ovl["transfer_hidden_frac"] >= MIN_HIDDEN_FRAC, (
        f"overlap hides {ovl['transfer_hidden_frac']:.1%} of transfer wall "
        f"time < documented floor {MIN_HIDDEN_FRAC:.0%}")
    # overlap must not *cost* exposed-transfer time vs no-overlap chunked
    assert ovl["mean_transfer_s"] <= chk["mean_transfer_s"], (
        f"overlap exposed transfer {ovl['mean_transfer_s']:.4f}s > "
        f"unoverlapped {chk['mean_transfer_s']:.4f}s")


def history_metrics(stats: Dict[str, Dict[str, float]]) -> Dict[str, float]:
    """Headlines for BENCH_chunked.json (repro.obs.history area 'chunked')."""
    lock, ovl = stats["lockstep"], stats["overlap"]
    return {
        "lockstep_p95_ttft_s": lock["p95_ttft_s"],
        "chunked_p95_ttft_s": stats["chunked"]["p95_ttft_s"],
        "overlap_p95_ttft_s": ovl["p95_ttft_s"],
        "overlap_p95_speedup": lock["p95_ttft_s"] / max(ovl["p95_ttft_s"],
                                                        1e-9),
        "overlap_hidden_frac": ovl["transfer_hidden_frac"],
        "overlap_windows_per_transfer": ovl["windows_per_transfer"],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="print {mode: stats} as JSON")
    ap.add_argument("--check", action="store_true",
                    help="assert the chunked/overlap-wins gates (CI smoke)")
    ap.add_argument("--history", action="store_true",
                    help="append to BENCH_chunked.json (repro.obs.history)")
    ap.add_argument("--only", default="",
                    help=f"comma-separated subset of {MODES}")
    args = ap.parse_args()
    modes = [m for m in args.only.split(",") if m] or None
    stats = bench(modes)
    if args.check:
        check(stats)
    if args.history:
        from repro.obs import history
        history.record("chunked", history_metrics(stats))
    if args.json:
        print(json.dumps(stats, indent=2, sort_keys=True))
        return
    for r in rows(stats):
        print(r)


if __name__ == "__main__":
    main()
