"""Gated chaos benchmark: fault tolerance of the serving plane.

Two parts, both deterministic:

1. **Sim A/B** — the ``failure`` scenario (node crash + flaky/corrupting
   transfers + degraded bandwidth, ``sim/scenarios.py``) against its
   fault-free twin under ``load_aware`` routing. The gate is Mooncake-style
   goodput under chaos staying a bounded fraction of fault-free goodput,
   with every offered request terminating and zero leaked KV blocks.

2. **Real-cluster chaos** — a smoke-sized model on :class:`PDCluster` with
   a decode node killed mid-generation plus one corrupted transfer. Every
   request must finish with tokens bit-identical to a monolithic greedy
   reference (token-exact recovery: the emitted prefix is teacher-forced
   through the replacement node's prefill), each streaming handle must see
   every token exactly once, and the block audit must come back clean.

CLI (CI contract, same as the other gated benchmarks)::

    PYTHONPATH=src python -m benchmarks.fault_tolerance --json --check
    PYTHONPATH=src python -m benchmarks.fault_tolerance --history
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.faults import FaultSpec
from repro.models import transformer as T
from repro.models.api import get_model
from repro.obs import history
from repro.serving.api import FlowKVClient
from repro.serving.cluster import PDCluster
from repro.serving.request import SamplingParams
from repro.sim.scenarios import get_scenario

MODES = ("sim", "cluster")
ROUTING = "load_aware"

# real-cluster chaos shape (see tests/test_fault_tolerance.py for the
# per-fault unit variants; this is the combined smoke)
NUM_REQUESTS = 4
NEW_TOKENS = 10
CRASH_AT = 4.0          # mid-decode for this workload (~9 fault-free cycles)
CRASH_NODE = 1          # a decode node (1 prefill + 2 decode below)
HEARTBEAT_TIMEOUT = 2.0


def _prompts(cfg, n: int = NUM_REQUESTS, seed: int = 5) -> List[List[int]]:
    rng = np.random.RandomState(seed)
    return [list(rng.randint(0, cfg.vocab_size, size=rng.randint(5, 30)))
            for _ in range(n)]


def bench_sim() -> Dict[str, float]:
    """Failure scenario vs its fault-free twin: goodput ratio + audits."""
    sc = get_scenario("failure")
    chaos = sc.run(ROUTING)
    clean = dataclasses.replace(sc, faults=()).run(ROUTING)
    unfinished = (chaos["offered"] - chaos["finished"] - chaos["rejected"])
    return {
        "goodput_faulty": chaos["goodput"],
        "goodput_clean": clean["goodput"],
        "goodput_ratio": chaos["goodput"] / max(1e-9, clean["goodput"]),
        "unfinished": float(unfinished),
        "leaked_blocks": chaos["leaked_blocks"],
        "fault_kills": chaos["fault_kills"],
        "transfer_retries": chaos["transfer_retries"],
        "degraded_to_recompute": chaos["degraded_to_recompute"],
        "recoveries": chaos["recoveries"],
        "p95_ttft_s_faulty": chaos["p95_ttft_s"],
        "p95_ttft_s_clean": clean["p95_ttft_s"],
    }


def bench_cluster() -> Dict[str, float]:
    """Kill a decode node mid-generation on the real engine; the recovered
    tokens must be bit-identical to a monolithic greedy reference and each
    streaming handle must observe every token exactly once."""
    cfg = get_smoke_config("qwen3-1.7b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(cfg)
    refs = {tuple(p): [int(x) for x in T.greedy_generate(
        params, cfg, jnp.asarray([p], jnp.int32), NEW_TOKENS)[0]]
        for p in prompts}

    faults = [FaultSpec("node_crash", at=CRASH_AT, node_id=CRASH_NODE),
              FaultSpec("transfer_corrupt", at=0.0, count=1)]
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=2,
                        num_blocks=128, faults=faults,
                        heartbeat_timeout_cycles=HEARTBEAT_TIMEOUT)
    client = FlowKVClient.from_cluster(cluster)
    handles = [client.submit(list(p),
                             SamplingParams(max_new_tokens=NEW_TOKENS))
               for p in prompts]

    # drive every stream round-robin so the exactly-once property is
    # exercised ACROSS the crash, not observed after the fact
    streams: Dict[int, List[int]] = {h.request_id: [] for h in handles}
    gens = {h.request_id: h.tokens(max_cycles=400) for h in handles}
    done: set = set()
    while len(done) < len(handles):
        for h in handles:
            if h.request_id in done:
                continue
            try:
                streams[h.request_id].append(next(gens[h.request_id]))
            except StopIteration:
                done.add(h.request_id)

    divergence = 0
    stream_mismatch = 0
    for h in handles:
        req = h.request
        key = tuple(req.prompt_tokens[:req.client_prompt_len]
                    if req.client_prompt_len else req.prompt_tokens)
        if req.output_tokens != refs[key]:
            divergence += 1
        if streams[h.request_id] != req.output_tokens:
            stream_mismatch += 1

    s = cluster.stats()
    cluster.assert_no_leaks()
    return {
        "token_divergence": float(divergence),
        "stream_mismatch": float(stream_mismatch),
        "finished": s["finished"],
        "fault_kills": s["fault_kills"],
        "transfer_retries": s["transfer_retries"],
        "recoveries": s["recoveries"],
        "replayed_tokens": float(sum(h.stats()["replayed_tokens"]
                                     for h in handles)),
        "leaked_blocks": s["leaked_blocks"],
        "cycles": cluster.clock,
    }


def bench(modes=MODES) -> Dict[str, Dict[str, float]]:
    out: Dict[str, Dict[str, float]] = {}
    for mode in modes:
        t0 = time.perf_counter()
        out[mode] = bench_sim() if mode == "sim" else bench_cluster()
        out[mode]["wall_s"] = time.perf_counter() - t0
    return out


def rows(stats: Optional[Dict[str, Dict[str, float]]] = None) -> List[str]:
    stats = stats or bench()
    lines = []
    if "sim" in stats:
        s = stats["sim"]
        lines.append(
            f"faults/sim_ab,{s['wall_s'] * 1e6:.0f},"
            f"goodput_ratio={s['goodput_ratio']:.3f}"
            f";goodput={s['goodput_faulty']:.3f}"
            f";clean={s['goodput_clean']:.3f}"
            f";kills={s['fault_kills']:.0f}"
            f";retries={s['transfer_retries']:.0f}"
            f";recoveries={s['recoveries']:.0f}"
            f";degraded={s['degraded_to_recompute']:.0f}"
            f";unfinished={s['unfinished']:.0f}"
            f";leaked={s['leaked_blocks']:.0f}")
    if "cluster" in stats:
        c = stats["cluster"]
        lines.append(
            f"faults/cluster_chaos,{c['wall_s'] * 1e6:.0f},"
            f"token_divergence={c['token_divergence']:.0f}"
            f";stream_mismatch={c['stream_mismatch']:.0f}"
            f";recoveries={c['recoveries']:.0f}"
            f";replayed={c['replayed_tokens']:.0f}"
            f";retries={c['transfer_retries']:.0f}"
            f";leaked={c['leaked_blocks']:.0f}"
            f";cycles={c['cycles']:.0f}")
    return lines


def check(stats: Dict[str, Dict[str, float]]) -> None:
    """The chaos gate (ISSUE 8 acceptance)."""
    if "sim" in stats:
        s = stats["sim"]
        assert s["goodput_ratio"] >= 0.7, (
            f"goodput under faults collapsed: ratio {s['goodput_ratio']:.3f}"
            f" < 0.7")
        assert s["unfinished"] == 0, (
            f"{s['unfinished']:.0f} offered requests never terminated")
        assert s["leaked_blocks"] == 0, (
            f"{s['leaked_blocks']:.0f} KV blocks leaked under chaos")
        assert s["fault_kills"] >= 1 and s["transfer_retries"] >= 1, (
            "failure scenario did not actually exercise faults")
    if "cluster" in stats:
        c = stats["cluster"]
        assert c["token_divergence"] == 0, (
            f"{c['token_divergence']:.0f} requests diverged from the "
            f"fault-free reference after recovery")
        assert c["stream_mismatch"] == 0, (
            f"{c['stream_mismatch']:.0f} streaming handles violated "
            f"exactly-once delivery")
        assert c["finished"] == NUM_REQUESTS
        assert c["leaked_blocks"] == 0
        assert c["recoveries"] >= 1, "the crash never forced a recovery"
        assert c["transfer_retries"] >= 1, "the corruption was never caught"


def history_metrics(stats: Dict[str, Dict[str, float]]) -> Dict[str, float]:
    s, c = stats["sim"], stats["cluster"]
    return {
        "goodput_ratio": s["goodput_ratio"],
        "token_divergence": c["token_divergence"],
        "leaked_blocks": s["leaked_blocks"] + c["leaked_blocks"],
        "unfinished": s["unfinished"],
        "fault_kills": s["fault_kills"] + c["fault_kills"],
        "recoveries": s["recoveries"] + c["recoveries"],
        "transfer_retries": s["transfer_retries"] + c["transfer_retries"],
        "degraded_to_recompute": s["degraded_to_recompute"],
    }


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="fail on chaos-gate violations (CI)")
    ap.add_argument("--history", action="store_true",
                    help="append headline metrics to BENCH_faults.json")
    ap.add_argument("--only", choices=MODES, default=None)
    args = ap.parse_args(argv)

    modes = (args.only,) if args.only else MODES
    stats = bench(modes)

    if args.json:
        print(json.dumps(stats, indent=2, sort_keys=True))
    else:
        for line in rows(stats):
            print(line)

    if args.check:
        check(stats)
        print("fault-tolerance gates passed", file=sys.stderr)
    if args.history:
        if args.only:
            raise SystemExit("--history needs both modes (no --only)")
        history.record("faults", history_metrics(stats))
        print(f"recorded to {history.bench_path('faults')}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
