"""Fig. 5 / §3.3 microbenchmarks — allocator contiguity + alignment quality
under allocation churn, plus wall-clock of the control-plane hot paths.

Shows WHY the segment allocator matters: after heavy alloc/free churn the
freelist allocator scatters requests across the pool (transfer calls ~= n
blocks even after alignment), while the segment allocator keeps merge
ratios near-ideal.
"""
from __future__ import annotations

import random
import time
from typing import List

from repro.core.alignment import align
from repro.core.allocator import BlockAllocator, SegmentAllocator
from repro.core.segments import blocks_to_segments


def churn(alloc, rng: random.Random, rounds: int = 300, pool: int = 4096):
    """Random alloc/free churn; returns the final live allocations."""
    live = {}
    rid = 0
    for _ in range(rounds):
        if live and (rng.random() < 0.45 or alloc.num_free < pool // 8):
            victim = rng.choice(list(live))
            alloc.free(live.pop(victim))
        else:
            n = rng.randint(4, 64)
            if alloc.num_free >= n:
                live[rid] = alloc.allocate(n)
                rid += 1
    return live


def rows(seed: int = 7) -> List[str]:
    out = []
    for name, cls in (("freelist", BlockAllocator), ("segment", SegmentAllocator)):
        rng = random.Random(seed)
        alloc = cls(4096)
        live = churn(alloc, rng)
        runs = [len(blocks_to_segments(b)) for b in live.values()]
        mean_runs = sum(runs) / len(runs)
        # simulate a transfer: both sides under same churn profile
        rng2 = random.Random(seed + 1)
        alloc2 = cls(4096)
        live2 = churn(alloc2, rng2)
        merge = []
        t0 = time.perf_counter()
        for (rid, src), (_, dst) in zip(sorted(live.items()), sorted(live2.items())):
            m = min(len(src), len(dst))
            if m:
                merge.append(align(src[:m], dst[:m]).num_calls / m)
        align_us = (time.perf_counter() - t0) * 1e6 / max(1, len(merge))
        calls_per_block = sum(merge) / len(merge)
        out.append(f"fig5/{name}/runs_per_request,{align_us:.1f},"
                   f"mean_runs={mean_runs:.2f};aligned_calls_per_block={calls_per_block:.3f}")
        # alloc/free wall-clock
        t0 = time.perf_counter()
        a = cls(4096)
        ids = [a.allocate(32) for _ in range(64)]
        for b in ids:
            a.free(b)
        us = (time.perf_counter() - t0) * 1e6 / 128
        out.append(f"fig5/{name}/alloc_free,{us:.2f},pool=4096")
    return out


if __name__ == "__main__":
    for r in rows():
        print(r)
