"""Fig. 1 — single-request time breakdown (13k in / 100 out).

The paper's motivating figure: with block-wise NCCL transfer the KV move is
~25% of request latency; FlowKV makes it negligible.
"""
from __future__ import annotations

from typing import List

from repro.configs import get_config
from repro.core.costmodel import IPC, NCCL_INTRA, VLLM_MERGE_INTRA
from repro.core.layout import KVCacheSpec
from repro.core.transfer import TransferPlanner
from repro.core.scheduler.global_controller import ModelCost
from repro.sim.hardware import A100


def rows(model: str = "llama31-8b", in_tokens: int = 13000,
         out_tokens: int = 100) -> List[str]:
    cfg = get_config(model)
    spec = KVCacheSpec(num_layers=cfg.num_layers, num_blocks=8192,
                       block_size=cfg.block_size, num_kv_heads=cfg.num_kv_heads,
                       head_dim=cfg.head_dim, dtype=cfg.dtype)
    planner = TransferPlanner(spec)
    cost = ModelCost(flops_per_token=2.0 * cfg.active_params(),
                     kv_bytes_per_token=float(cfg.kv_bytes_per_token()),
                     weight_bytes=2.0 * cfg.num_params())
    prefill = A100.prefill_time(in_tokens * cost.flops_per_token)
    decode = sum(
        A100.decode_time(cost.weight_bytes + cost.kv_bytes_per_token * (in_tokens + i))
        for i in range(out_tokens))
    ids = list(range(spec.blocks_for_tokens(in_tokens)))
    out = []
    for name, plan, prof in (
        ("vllm_blockwise", planner.plan_blockwise(ids, ids), VLLM_MERGE_INTRA),
        ("layerwise", planner.plan_layerwise(ids, ids), NCCL_INTRA),
        ("flowkv", planner.plan_flowkv(ids, ids), IPC),
    ):
        xfer = plan.latency(prof)
        total = prefill + xfer + decode
        out.append(
            f"fig1/{name},{xfer*1e6:.0f},"
            f"xfer_frac={xfer/total:.3f};prefill_s={prefill:.3f}"
            f";decode_s={decode:.3f};total_s={total:.3f}")
    return out


if __name__ == "__main__":
    for r in rows():
        print(r)
