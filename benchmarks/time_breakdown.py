"""Fig. 1 — single-request time breakdown (13k in / 100 out).

The paper's motivating figure: with block-wise NCCL transfer the KV move is
~25% of request latency; FlowKV makes it negligible.

CLI: ``python -m benchmarks.time_breakdown [--json] [--check] [--history]``
(``--check`` asserts the transfer SHARE of total request latency under the
flowkv schedule is no worse than blockwise's — the figure's claim as a CI
gate; ``--history`` appends the shares to ``BENCH_breakdown.json``, see
``repro.obs.history``).
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

from repro.configs import get_config
from repro.core.costmodel import IPC, NCCL_INTRA, VLLM_MERGE_INTRA
from repro.core.layout import KVCacheSpec
from repro.core.transfer import TransferPlanner
from repro.core.scheduler.global_controller import ModelCost
from repro.sim.hardware import A100


def bench(model: str = "llama31-8b", in_tokens: int = 13000,
          out_tokens: int = 100) -> Dict[str, Dict[str, float]]:
    """{schedule: {prefill_s, xfer_s, decode_s, total_s, xfer_frac}}."""
    cfg = get_config(model)
    spec = KVCacheSpec(num_layers=cfg.num_layers, num_blocks=8192,
                       block_size=cfg.block_size, num_kv_heads=cfg.num_kv_heads,
                       head_dim=cfg.head_dim, dtype=cfg.dtype)
    planner = TransferPlanner(spec)
    cost = ModelCost(flops_per_token=2.0 * cfg.active_params(),
                     kv_bytes_per_token=float(cfg.kv_bytes_per_token()),
                     weight_bytes=2.0 * cfg.num_params())
    prefill = A100.prefill_time(in_tokens * cost.flops_per_token)
    decode = sum(
        A100.decode_time(cost.weight_bytes + cost.kv_bytes_per_token * (in_tokens + i))
        for i in range(out_tokens))
    ids = list(range(spec.blocks_for_tokens(in_tokens)))
    stats: Dict[str, Dict[str, float]] = {}
    for name, plan, prof in (
        ("vllm_blockwise", planner.plan_blockwise(ids, ids), VLLM_MERGE_INTRA),
        ("layerwise", planner.plan_layerwise(ids, ids), NCCL_INTRA),
        ("flowkv", planner.plan_flowkv(ids, ids), IPC),
    ):
        xfer = plan.latency(prof)
        total = prefill + xfer + decode
        stats[name] = {
            "prefill_s": prefill, "xfer_s": xfer, "decode_s": decode,
            "total_s": total, "xfer_frac": xfer / total,
            "num_calls": plan.num_calls,
        }
    return stats


def rows(stats=None) -> List[str]:
    stats = stats or bench()
    out = []
    for name, s in stats.items():
        out.append(
            f"fig1/{name},{s['xfer_s']*1e6:.0f},"
            f"xfer_frac={s['xfer_frac']:.3f};prefill_s={s['prefill_s']:.3f}"
            f";decode_s={s['decode_s']:.3f};total_s={s['total_s']:.3f}")
    return out


def check(stats: Dict[str, Dict[str, float]]) -> None:
    """CI gate: FlowKV's transfer share of request latency must not exceed
    the blockwise baseline's — the figure's entire point."""
    fk, bw = stats["flowkv"], stats["vllm_blockwise"]
    assert fk["xfer_frac"] <= bw["xfer_frac"], (
        f"flowkv xfer share {fk['xfer_frac']:.4f} > "
        f"blockwise {bw['xfer_frac']:.4f}")
    # and it must actually be negligible, not merely better (paper: <1%
    # vs ~25%); 5% leaves room for cost-model recalibration
    assert fk["xfer_frac"] < 0.05, \
        f"flowkv xfer share {fk['xfer_frac']:.4f} is not negligible"


def history_metrics(stats: Dict[str, Dict[str, float]]) -> Dict[str, float]:
    fk, bw = stats["flowkv"], stats["vllm_blockwise"]
    return {
        "flowkv_xfer_frac": fk["xfer_frac"],
        "blockwise_xfer_frac": bw["xfer_frac"],
        "flowkv_over_blockwise_xfer": fk["xfer_s"] / bw["xfer_s"],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="print per-schedule breakdown as JSON")
    ap.add_argument("--check", action="store_true",
                    help="assert flowkv's transfer share <= blockwise's")
    ap.add_argument("--history", action="store_true",
                    help="append to BENCH_breakdown.json (repro.obs.history)")
    args = ap.parse_args()
    stats = bench()
    if args.check:
        check(stats)
    if args.history:
        from repro.obs import history
        history.record("breakdown", history_metrics(stats))
    if args.json:
        print(json.dumps(stats, indent=2, sort_keys=True))
        return
    for r in rows(stats):
        print(r)


if __name__ == "__main__":
    main()
