"""Prefix-cache reuse — hit rate vs prefill compute actually executed.

Two sections:

* **engine** — a real ``PDCluster`` (smoke model, real JAX compute) runs a
  repeated-prefix trace at several share fractions. The counters are the
  ground truth: ``prefill_tokens_computed`` is incremented by the engine for
  every prompt token it actually forwards, so
  ``total - computed == prefix_tokens_reused`` holds EXACTLY or the data
  plane is lying. A 1P+1D row exercises the remote-fetch path (the donor's
  prefix re-homes to the decode node; followers pull it back as ONE fused
  descriptor-table dispatch).
* **sim** — the same trace through ``ClusterSim``: hits shrink the prefill
  chunks the duration model prices, so simulated savings match the engine's
  counter identity.

CLI: ``python -m benchmarks.prefix_reuse [--json] [--check]``
(``--check`` is the CI smoke gate: on the repeated-prefix trace, prefill
compute drops by at least one full hit length; computed == total - reused on
every row; every remote prefix fetch is exactly one fused dispatch; outputs
with reuse ON are token-identical to reuse OFF.)
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Dict, List

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.api import get_model
from repro.serving.cluster import PDCluster
from repro.serving.request import Request, SamplingParams
from repro.sim.hardware import A100, TPU_V5E

ARCH = "qwen3-1.7b"
PREFIX_LEN = 64            # 2 full 32-token blocks
N_FOLLOWERS = 4
NEW_TOKENS = 4
SHARE_FRACTIONS = (0.0, 0.5, 1.0)
# the smoke model's recompute is so cheap the honest cost model would always
# recompute; a weak profile makes reuse the rational plan, which is the data
# plane this benchmark measures (the 8B-scale break-even favors reuse)
WEAK = dataclasses.replace(TPU_V5E, peak_flops=1e6)


def _trace(cfg, share_fraction: float, seed: int = 0):
    rng = np.random.RandomState(seed)
    prefix = rng.randint(0, cfg.vocab_size, size=PREFIX_LEN).tolist()
    donor = prefix + rng.randint(0, cfg.vocab_size, size=8).tolist()
    followers = []
    n_shared = round(N_FOLLOWERS * share_fraction)
    for i in range(N_FOLLOWERS):
        tail = rng.randint(0, cfg.vocab_size, size=6 + i).tolist()
        head = prefix if i < n_shared else \
            rng.randint(0, cfg.vocab_size, size=PREFIX_LEN).tolist()
        followers.append(head + tail)
    return donor, followers


def _run_cluster(cfg, params, donor, followers, **kw) -> Dict[str, object]:
    cluster = PDCluster(cfg, params, num_blocks=256, max_batch_tokens=4096, **kw)
    # the donor decodes long enough to stay RESIDENT while followers route —
    # residency is honest now: a finished request's blocks free and its
    # index entries die with them, so a too-short donor yields zero hits
    reqs = [Request(prompt_tokens=list(p),
                    sampling=SamplingParams(
                        max_new_tokens=24 if not i else NEW_TOKENS))
            for i, p in enumerate([donor] + followers)]
    cluster.submit(reqs[0])
    for _ in range(8):
        cluster.step()
        if reqs[0].transfer_end is not None:
            break
    for r in reqs[1:]:
        cluster.submit(r)
    for _ in range(200):
        cluster.step()
        if len(cluster.finished) == len(reqs):
            break
    for e in cluster.engines.values():
        e.scheduler.bm.check_invariants()
    s = cluster.stats()
    total = sum(r.prompt_len for r in reqs)
    fetches = [t for t in cluster.transfers if t.kind == "prefix_fetch"]
    return {
        "finished": len(cluster.finished),
        "total_prompt_tokens": total,
        "prefill_tokens_computed": s["prefill_tokens_computed"],
        "prefill_tokens_saved": total - s["prefill_tokens_computed"],
        "prefix_hits": s["prefix_hits"],
        "prefix_tokens_reused": s["prefix_tokens_reused"],
        "prefix_fetches": s["prefix_fetches"],
        "fetch_dispatches": [t.num_dispatches for t in fetches],
        "outputs": {tuple(r.prompt_tokens): list(r.output_tokens)
                    for r in cluster.finished},
    }


def bench() -> Dict[str, List[Dict[str, object]]]:
    cfg = get_smoke_config(ARCH)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    out: Dict[str, List[Dict[str, object]]] = {"engine": [], "sim": []}
    for frac in SHARE_FRACTIONS:
        donor, followers = _trace(cfg, frac)
        # hybrid node: local-hit plane
        row = _run_cluster(cfg, params, donor, followers,
                           num_prefill=1, num_decode=0)
        row.update(topology="1xhybrid", share_fraction=frac, reuse=True)
        cold = _run_cluster(cfg, params, donor, followers,
                            num_prefill=1, num_decode=0, prefix_reuse=False)
        row["token_identical_vs_off"] = row["outputs"] == cold["outputs"]
        row["computed_off"] = cold["prefill_tokens_computed"]
        out["engine"].append(row)
    # remote-fetch plane: 1P + 1D, fully-shared trace
    donor, followers = _trace(cfg, 1.0)
    row = _run_cluster(cfg, params, donor, followers,
                       num_prefill=1, num_decode=1, hardware=WEAK)
    cold = _run_cluster(cfg, params, donor, followers,
                        num_prefill=1, num_decode=1, hardware=WEAK,
                        prefix_reuse=False)
    row.update(topology="1P1D", share_fraction=1.0, reuse=True,
               token_identical_vs_off=row["outputs"] == cold["outputs"],
               computed_off=cold["prefill_tokens_computed"])
    out["engine"].append(row)
    out["sim"] = _bench_sim()
    for rows_ in out.values():            # outputs are for checking, not JSON
        for r in rows_:
            r.pop("outputs", None)
    return out


def _bench_sim() -> List[Dict[str, object]]:
    from repro.sim.cluster_sim import ClusterSim

    cfg = get_smoke_config(ARCH)
    weak_p = dataclasses.replace(A100, peak_flops=1e7)
    weak_d = dataclasses.replace(A100, hbm_bandwidth=1e5)
    rows_ = []
    for frac in SHARE_FRACTIONS:
        rng = np.random.RandomState(1)
        prefix = rng.randint(0, cfg.vocab_size, size=2048).tolist()
        n_shared = round(4 * frac)
        reqs = []
        for i in range(5):
            head = prefix if (i == 0 or i <= n_shared) else \
                rng.randint(0, cfg.vocab_size, size=2048).tolist()
            reqs.append(Request(
                prompt_tokens=head + rng.randint(0, cfg.vocab_size, 128).tolist(),
                sampling=SamplingParams(max_new_tokens=64),
                arrival_time=0.0 if i == 0 else 66.0 + 0.5 * i))
        total = sum(r.prompt_len for r in reqs)
        sim = ClusterSim(cfg, "flowkv", num_prefill=1, num_decode=1,
                         routing="load_aware", hw_prefill=weak_p,
                         hw_decode=weak_d)
        s = sim.run(list(reqs), t_max=500000)
        rows_.append({
            "share_fraction": frac,
            "finished": s["finished"],
            "total_prompt_tokens": total,
            "prefill_tokens_computed": s["prefill_tokens_computed"],
            "prefill_tokens_saved": total - s["prefill_tokens_computed"],
            "prefix_hits": s["prefix_hits"],
            "prefix_tokens_reused": s["prefix_tokens_reused"],
            "prefix_fetches": s["prefix_fetches"],
            "mean_prefix_fetch_dispatches": s["mean_prefix_fetch_dispatches"],
        })
    return rows_


def rows(stats=None) -> List[str]:
    stats = stats or bench()
    out = []
    for r in stats["engine"]:
        name = f"prefix/{r['topology']}/share{r['share_fraction']:.1f}"
        out.append(f"{name},0.0,"
                   f"computed={r['prefill_tokens_computed']}/{r['total_prompt_tokens']} "
                   f"saved={r['prefill_tokens_saved']} hits={r['prefix_hits']} "
                   f"fetches={r['prefix_fetches']} "
                   f"identical={r['token_identical_vs_off']}")
    for r in stats["sim"]:
        name = f"prefix/sim/share{r['share_fraction']:.1f}"
        out.append(f"{name},0.0,"
                   f"computed={r['prefill_tokens_computed']}/{r['total_prompt_tokens']} "
                   f"saved={r['prefill_tokens_saved']} hits={r['prefix_hits']} "
                   f"fetches={r['prefix_fetches']}")
    return out


def check(stats: Dict[str, List[Dict[str, object]]]) -> None:
    """CI smoke gate for the reuse data plane (see module docstring)."""
    for r in stats["engine"]:
        assert r["finished"] == 1 + N_FOLLOWERS, r
        # counter identity: every skipped token is a reused token
        assert r["total_prompt_tokens"] - r["prefill_tokens_computed"] \
            == r["prefix_tokens_reused"], r
        # reuse on vs off changes no tokens
        assert r["token_identical_vs_off"], r
        # reuse off == cold everywhere
        assert r["computed_off"] == r["total_prompt_tokens"], r
        # every remote fetch is ONE fused descriptor-table dispatch
        assert all(d == 1 for d in r["fetch_dispatches"]), r
        if r["share_fraction"] == 0.0:
            assert r["prefix_tokens_reused"] == 0, r
        if r["share_fraction"] == 1.0:
            # compute drops by >= one full hit length on the repeated trace
            assert r["prefill_tokens_saved"] >= PREFIX_LEN, r
    fetch_rows = [r for r in stats["engine"] if r["topology"] == "1P1D"]
    assert fetch_rows and all(r["prefix_fetches"] >= 1 for r in fetch_rows)
    for r in stats["sim"]:
        assert r["total_prompt_tokens"] - r["prefill_tokens_computed"] \
            == r["prefix_tokens_reused"], r
        if r["share_fraction"] == 1.0:
            assert r["prefill_tokens_saved"] >= 2048, r
            assert r["mean_prefix_fetch_dispatches"] == 1.0, r
        if r["share_fraction"] == 0.0:
            assert r["prefix_tokens_reused"] == 0, r


def history_metrics(stats: Dict[str, List[Dict[str, object]]]
                    ) -> Dict[str, float]:
    """Reuse-plane headlines for BENCH_prefix.json (repro.obs.history)."""
    share1 = [r for r in stats["sim"] if r["share_fraction"] == 1.0]
    return {
        "engine_tokens_saved_total": sum(
            r["prefill_tokens_saved"] for r in stats["engine"]),
        "engine_max_fetch_dispatches": max(
            (max(r["fetch_dispatches"], default=0) for r in stats["engine"]),
            default=0),
        "sim_tokens_saved_share1": sum(
            r["prefill_tokens_saved"] for r in share1),
        "sim_mean_fetch_dispatches_share1": max(
            (r["mean_prefix_fetch_dispatches"] for r in share1), default=0.0),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="print per-row stats as JSON")
    ap.add_argument("--check", action="store_true",
                    help="assert the reuse-saves-compute invariants")
    ap.add_argument("--history", action="store_true",
                    help="append to BENCH_prefix.json (repro.obs.history)")
    args = ap.parse_args()
    stats = bench()
    if args.check:
        check(stats)
    if args.history:
        from repro.obs import history
        history.record("prefix", history_metrics(stats))
    if args.json:
        print(json.dumps(stats, indent=2, sort_keys=True))
        return
    for r in rows(stats):
        print(r)


if __name__ == "__main__":
    main()
