"""Tiered KV store — host-DRAM tier vs HBM-only on multi-turn chat.

Two sections:

* **sim** — the ``multiturn`` scenario (deterministic discrete-event sim,
  small HBM pools, 4-turn conversations) runs twice over the SAME trace:
  with the host tier armed and with ``host_tier_blocks=0``. Between turns
  capacity pressure demotes the cold conversation history to host DRAM;
  the tiered store wins by promoting it back (one fused dispatch) instead
  of recomputing, so it must beat HBM-only on p95 TTFT AND prefix-hit
  volume with exact-zero leaked blocks on either tier.
* **engine** — a real ``PDCluster`` (smoke model, real JAX compute) plays
  one conversation round-trip: turn 1 finishes and parks its prefix, a
  churn request forces the pool to evict it to the host tier, and turn 2
  (history + new user tokens) promotes it back. The gate is the hard one:
  outputs with the tier in the loop are TOKEN-IDENTICAL to a reuse-off
  cluster, i.e. demote -> promote is bit-preserving end to end.

CLI: ``python -m benchmarks.tiered_kv [--json] [--check] [--history]``
(``--check`` is the CI ``tiered-smoke`` gate; ``--history`` appends the
headline metrics to ``BENCH_tiered.json`` via ``repro.obs.history``.)
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.api import get_model
from repro.serving.cluster import PDCluster
from repro.serving.request import Request, SamplingParams
from repro.sim.hardware import TPU_V5E
from repro.sim.scenarios import get_scenario

ARCH = "qwen3-1.7b"
TURN1_TOKENS = 256         # 8 full 32-token blocks of conversation history
CHURN_TOKENS = 320         # big enough to force eviction on a 16-block pool
USER_TOKENS = 32           # fresh user message appended for turn 2
NEW_TOKENS = 8
POOL_BLOCKS = 16
HOST_BLOCKS = 64
# the smoke model's recompute is so cheap the honest cost model would always
# recompute; a weak profile makes promotion the rational plan, which is the
# data plane this benchmark measures (at 8B scale DRAM fetch genuinely wins)
WEAK = dataclasses.replace(TPU_V5E, peak_flops=1e6)


# ---------------------------------------------------------------------------
# sim: multiturn scenario A/B — tiered vs HBM-only over the same trace
# ---------------------------------------------------------------------------
def _bench_sim() -> Dict[str, Dict[str, float]]:
    sc = get_scenario("multiturn")
    total_prompt = sum(r.prompt_len for r in sc.requests())
    out: Dict[str, Dict[str, float]] = {}
    for label, s in (("tiered", sc),
                     ("hbm_only",
                      dataclasses.replace(sc, host_tier_blocks=0))):
        t0 = time.perf_counter()
        stats = s.run("load_aware")
        stats["wall_us"] = (time.perf_counter() - t0) * 1e6
        stats["total_prompt_tokens"] = total_prompt
        stats["hit_rate"] = stats["prefix_tokens_reused"] / total_prompt
        out[label] = stats
    return out


# ---------------------------------------------------------------------------
# engine: demote -> promote round-trip on real compute, token-identical
# ---------------------------------------------------------------------------
def _drain(cluster: PDCluster, want_finished: int, max_steps: int = 400):
    for _ in range(max_steps):
        cluster.step()
        if len(cluster.finished) >= want_finished:
            return
    raise AssertionError(
        f"engine stalled: {len(cluster.finished)}/{want_finished} finished")


def _play(cfg, params, prompts: List[List[int]], **kw) -> Dict[str, object]:
    """Submit prompts strictly one after another (a conversation, not a
    batch) so turn 1's history is cold again by the time turn 2 arrives."""
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=0,
                        num_blocks=POOL_BLOCKS, hardware=WEAK,
                        max_batch_tokens=4096, **kw)
    reqs = []
    for p in prompts:
        r = Request(prompt_tokens=list(p),
                    sampling=SamplingParams(max_new_tokens=NEW_TOKENS))
        cluster.submit(r)
        reqs.append(r)
        _drain(cluster, len(reqs))
    for e in cluster.engines.values():
        e.scheduler.bm.check_invariants()
    for tm in cluster.tiers.values():
        tm.check_invariants()
    s = cluster.stats()
    return {
        "finished": len(cluster.finished),
        "prefix_tokens_reused": s["prefix_tokens_reused"],
        "tier_demoted_blocks": s.get("tier_demoted_blocks", 0),
        "tier_promoted_blocks": s.get("tier_promoted_blocks", 0),
        "leaked_blocks": s["leaked_blocks"],
        "outputs": [list(r.output_tokens) for r in reqs],
    }


def _bench_engine() -> Dict[str, object]:
    cfg = get_smoke_config(ARCH)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    turn1 = rng.randint(0, cfg.vocab_size, size=TURN1_TOKENS).tolist()
    churn = rng.randint(0, cfg.vocab_size, size=CHURN_TOKENS).tolist()
    user = rng.randint(0, cfg.vocab_size, size=USER_TOKENS).tolist()

    t0 = time.perf_counter()
    # pass 1: tiered — turn 2's prompt embeds turn 1's REAL output tokens
    warm = _play(cfg, params, [turn1], host_tier_blocks=HOST_BLOCKS)
    turn2 = turn1 + warm["outputs"][0] + user
    tiered = _play(cfg, params, [turn1, churn, turn2],
                   host_tier_blocks=HOST_BLOCKS)
    # pass 2: reuse off — same prompts, cold compute everywhere
    cold = _play(cfg, params, [turn1, churn, turn2], prefix_reuse=False)
    wall_s = time.perf_counter() - t0
    return {
        "finished": tiered["finished"],
        "prefix_tokens_reused": tiered["prefix_tokens_reused"],
        "tier_demoted_blocks": tiered["tier_demoted_blocks"],
        "tier_promoted_blocks": tiered["tier_promoted_blocks"],
        "leaked_blocks": tiered["leaked_blocks"] + cold["leaked_blocks"],
        "token_identical_vs_off": tiered["outputs"] == cold["outputs"],
        "wall_s": wall_s,
    }


def bench() -> Dict[str, object]:
    return {"sim": _bench_sim(), "engine": _bench_engine()}


def rows(stats=None) -> List[str]:
    stats = stats or bench()
    out = []
    for label, s in stats["sim"].items():
        out.append(
            f"tiered/sim/{label},{s['wall_us']:.0f},"
            f"p95_ttft_s={s['p95_ttft_s']:.4f};goodput={s['goodput']:.3f}"
            f";hit_rate={s['hit_rate']:.3f}"
            f";reused={s['prefix_tokens_reused']}"
            f";demoted={s['tier_demoted_blocks']}"
            f";promoted={s['tier_promoted_blocks']}"
            f";leaked={s['leaked_blocks']}")
    e = stats["engine"]
    out.append(
        f"tiered/engine/roundtrip,{e['wall_s'] * 1e6:.0f},"
        f"reused={e['prefix_tokens_reused']}"
        f";demoted={e['tier_demoted_blocks']}"
        f";promoted={e['tier_promoted_blocks']}"
        f";identical={e['token_identical_vs_off']}"
        f";leaked={e['leaked_blocks']}")
    return out


def check(stats: Dict[str, object]) -> None:
    """CI gate: the tier must EARN its complexity on multi-turn traffic."""
    ti, hb = stats["sim"]["tiered"], stats["sim"]["hbm_only"]
    # the paper claim: tiered >= HBM-only on p95 TTFT and prefix-hit volume
    assert ti["p95_ttft_s"] <= hb["p95_ttft_s"], (
        f"tiered p95 TTFT {ti['p95_ttft_s']:.4f}s worse than HBM-only "
        f"{hb['p95_ttft_s']:.4f}s")
    assert ti["hit_rate"] >= hb["hit_rate"], (
        f"tiered hit rate {ti['hit_rate']:.3f} < HBM-only {hb['hit_rate']:.3f}")
    assert ti["prefix_hits"] >= hb["prefix_hits"], (ti["prefix_hits"],
                                                    hb["prefix_hits"])
    # the tier actually worked for its win
    assert ti["tier_demoted_blocks"] > 0, "nothing ever demoted"
    assert ti["tier_promoted_blocks"] > 0, "nothing ever promoted"
    assert hb["tier_demoted_blocks"] == hb["tier_promoted_blocks"] == 0
    # structural zeros, both arms
    for label, s in (("tiered", ti), ("hbm_only", hb)):
        assert s["leaked_blocks"] == 0, f"{label}: leaked {s['leaked_blocks']}"
        assert s["finished"] == s["offered"], (
            f"{label}: {s['finished']}/{s['offered']} finished")
    # engine: demote -> promote is bit-preserving on real compute
    e = stats["engine"]
    assert e["finished"] == 3, e
    assert e["tier_demoted_blocks"] > 0, "engine: nothing demoted"
    assert e["tier_promoted_blocks"] > 0, "engine: nothing promoted"
    assert e["prefix_tokens_reused"] > 0, "engine: promoted prefix unused"
    assert e["token_identical_vs_off"], \
        "engine: outputs diverge from reuse-off (tier corrupted the KV)"
    assert e["leaked_blocks"] == 0, e


def history_metrics(stats: Dict[str, object]) -> Dict[str, float]:
    """Tier-plane headlines for BENCH_tiered.json (repro.obs.history)."""
    ti, hb = stats["sim"]["tiered"], stats["sim"]["hbm_only"]
    e = stats["engine"]
    return {
        "p95_ttft_speedup": hb["p95_ttft_s"] / ti["p95_ttft_s"],
        "tiered_hit_rate": ti["hit_rate"],
        "hbm_hit_rate": hb["hit_rate"],
        "tiered_p95_ttft_s": ti["p95_ttft_s"],
        "leaked_blocks": ti["leaked_blocks"] + hb["leaked_blocks"]
        + e["leaked_blocks"],
        "demoted_blocks": ti["tier_demoted_blocks"],
        "promoted_blocks": ti["tier_promoted_blocks"],
        "engine_promoted_blocks": e["tier_promoted_blocks"],
        "engine_wall_s": e["wall_s"],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="print section stats as JSON")
    ap.add_argument("--check", action="store_true",
                    help="assert the tiered-beats-HBM-only gates (CI smoke)")
    ap.add_argument("--history", action="store_true",
                    help="append to BENCH_tiered.json (repro.obs.history)")
    args = ap.parse_args()
    stats = bench()
    if args.check:
        check(stats)
    if args.history:
        from repro.obs import history
        history.record("tiered", history_metrics(stats))
    if args.json:
        print(json.dumps(stats, indent=2, sort_keys=True))
        return
    for r in rows(stats):
        print(r)


if __name__ == "__main__":
    main()
