"""§Roofline — render the per-(arch x shape x mesh) roofline table from the
cached dry-run artifacts (results/dryrun/*.json)."""
from __future__ import annotations

import json
import pathlib
from typing import List

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def rows() -> List[str]:
    out = []
    for p in sorted(RESULTS.glob("*.json")):
        r = json.loads(p.read_text())
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r["status"] == "skipped":
            out.append(f"{name},0,skipped")
            continue
        if r["status"] != "ok":
            out.append(f"{name},0,FAILED")
            continue
        if r.get("kind") == "transfer":
            cb = r["collective_bytes"]["collective-permute"]
            out.append(f"{name},0,permute_bytes={cb}")
            continue
        rf = r["roofline"]
        dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: rf[k])
        total = rf["compute_s"] + rf["memory_s"] + rf["collective_s"]
        frac = rf[dom] / total if total else 0.0
        useful = rf.get("useful_ratio")
        out.append(
            f"{name},{rf[dom]*1e6:.0f},"
            f"bottleneck={rf['bottleneck']};compute_s={rf['compute_s']:.4f}"
            f";memory_s={rf['memory_s']:.4f};collective_s={rf['collective_s']:.4f}"
            f";useful_ratio={useful if useful is None else round(useful, 3)}"
            f";resident_GB={r['resident_bytes_per_device']/2**30:.2f}")
    return out


if __name__ == "__main__":
    for r in rows():
        print(r)
