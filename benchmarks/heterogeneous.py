"""Fig. 4 — heterogeneous deployment E2E on LongBench summarization proxies.

Compares 4P4D (P-L20 / D-H20) against the inverted placement and the
colocated baseline: decode wants bandwidth/memory (H20), prefill wants
compute (L20 is the cheaper card) — the paper's placement claim.
"""
from __future__ import annotations

import time
from typing import List

from repro.configs import get_config
from repro.sim.cluster_sim import ClusterSim
from repro.sim.hardware import H20, L20
from repro.sim.workload import LONGBENCH, generate

PAPER_E2E_GAIN = {"gov_report": 0.3467, "multi_news": 0.401, "qmsum": 0.088}


def rows(model: str = "llama31-8b", rps: float = 0.5) -> List[str]:
    cfg = get_config(model)
    out = []
    for task, wl in LONGBENCH.items():
        results = {}
        for name, (hw_p, hw_d) in (
            ("P-L20_D-H20", (L20, H20)),
            ("P-H20_D-L20", (H20, L20)),
        ):
            t0 = time.perf_counter()
            sim = ClusterSim(cfg, "flowkv", num_prefill=4, num_decode=4,
                             hw_prefill=hw_p, hw_decode=hw_d, same_host=False)
            stats = sim.run(generate(wl, rps=rps, seed=1), t_max=50_000)
            wall_us = (time.perf_counter() - t0) * 1e6
            results[name] = stats
            out.append(
                f"fig4/{task}/{name},{wall_us:.0f},"
                f"e2e_s={stats['mean_e2e_s']:.2f};tpot_ms={stats['mean_tpot_s']*1e3:.2f}"
                f";fin={stats['finished']}")
        # colocated baseline on the same 8 GPUs (L20 fleet)
        sim = ClusterSim(cfg, "vllm_colocated", num_prefill=4, num_decode=4,
                         hw_prefill=L20, same_host=False)
        stats = sim.run(generate(wl, rps=rps, seed=1), t_max=50_000)
        out.append(f"fig4/{task}/colocated-L20,0,"
                   f"e2e_s={stats['mean_e2e_s']:.2f};tpot_ms={stats['mean_tpot_s']*1e3:.2f}")
        good = results["P-L20_D-H20"]["mean_e2e_s"]
        bad = results["P-H20_D-L20"]["mean_e2e_s"]
        gain = (bad - good) / bad if bad else 0.0
        out.append(f"fig4/{task}/placement_gain,0,"
                   f"e2e_gain={gain:.3f};paper={PAPER_E2E_GAIN[task]}")
    return out


if __name__ == "__main__":
    for r in rows():
        print(r)
