# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper table/figure:

    table3  benchmarks/transfer_latency.py   KV transfer latency + call counts
    table1  benchmarks/throughput.py         8B throughput grid (sim)
    table2  benchmarks/throughput.py         70B throughput grid (sim, TP=4)
    fig4    benchmarks/heterogeneous.py      L20/H20 placement E2E
    fig1    benchmarks/time_breakdown.py     single-request time split
    fig5    benchmarks/allocator_bench.py    allocator contiguity/alignment
    decode  benchmarks/decode_throughput.py  zero-gather decode dispatches/step
    prefix  benchmarks/prefix_reuse.py       prefix-cache hit rate vs prefill compute
    scen    benchmarks/scenarios.py          scheduling scenarios (load-aware vs baselines)
    chunk   benchmarks/chunked_prefill.py    chunked prefill + layerwise overlap A/B
    faults  benchmarks/fault_tolerance.py    chaos A/B + token-exact crash recovery
    roof    benchmarks/roofline.py           dry-run roofline table

``python -m benchmarks.run [--full] [--only table3,fig4,...]``

Environment notes:

* deps: ``pip install -r requirements.txt`` (jax, numpy, msgpack, pytest;
  ``hypothesis`` optional — property tests skip without it).
* before benchmarking, verify the build with the fast tier-1 selection
  (skips the multi-device dry-run)::

      PYTHONPATH=src python -m pytest -q -m "not slow"

* run benchmarks from the repo root so ``benchmarks`` and ``src/repro``
  both resolve: ``PYTHONPATH=src python -m benchmarks.run``.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full RPS grids (paper-complete, slower)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset: table1,table2,table3,fig1,fig4,fig5,decode,prefix,scen,chunk,faults,roof")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(tag: str) -> bool:
        return only is None or tag in only

    print("name,us_per_call,derived")
    t_start = time.time()

    if want("table3"):
        from benchmarks import transfer_latency
        for r in transfer_latency.rows():
            print(r)
        # fused data plane: transport calls vs kernel dispatches per schedule
        for r in transfer_latency.dispatch_rows():
            print(r)
    if want("fig1"):
        from benchmarks import time_breakdown
        for r in time_breakdown.rows():
            print(r)
    if want("fig5"):
        from benchmarks import allocator_bench
        for r in allocator_bench.rows():
            print(r)
    if want("table1"):
        from benchmarks import throughput
        for r in throughput.rows(full=args.full):
            print(r)
    if want("table2"):
        from benchmarks import throughput
        for r in throughput.rows_70b(full=args.full):
            print(r)
    if want("fig4"):
        from benchmarks import heterogeneous
        for r in heterogeneous.rows():
            print(r)
    if want("decode"):
        from benchmarks import decode_throughput
        for r in decode_throughput.rows():
            print(r)
    if want("prefix"):
        from benchmarks import prefix_reuse
        for r in prefix_reuse.rows():
            print(r)
    if want("scen"):
        from benchmarks import scenarios
        for r in scenarios.rows():
            print(r)
    if want("chunk"):
        from benchmarks import chunked_prefill
        for r in chunked_prefill.rows():
            print(r)
    if want("faults"):
        from benchmarks import fault_tolerance
        for r in fault_tolerance.rows():
            print(r)
    if want("roof"):
        from benchmarks import roofline
        for r in roofline.rows():
            print(r)
    print(f"# total_wall_s={time.time()-t_start:.1f}", file=sys.stderr)


if __name__ == "__main__":
    main()
