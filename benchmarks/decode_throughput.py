"""Decode data plane — tokens/s and device dispatches per decode step.

Compares the two paged decode paths on a real ``NodeEngine``:

* ``dense``  — the gather-dense oracle: densify each request's pages, run
  the dense decode step, write each new token back per request. Dispatches
  per step grow as ``2*B + 1``.
* ``kernel`` — the zero-gather in-place path: ONE jitted step per cycle
  (paged Pallas attention over the pool + one fused descriptor-table
  append), regardless of batch size or context length.

Run on the smoke model so the interpret-mode Pallas kernel measures the
data-plane structure, not an 8B forward. Two prompt lengths demonstrate
context-length independence of the dispatch count.

CLI: ``python -m benchmarks.decode_throughput [--json] [--check]``
(``--check`` asserts the in-place path issues exactly 1 dispatch/step for
every batch size and context length — the O(1) invariant CI smokes on —
and that the oracle path's count grows as 2B+1.)

``decode_dispatches`` counts host-issued device computations by
construction (see ``NodeEngine._decode_paged_kernel``): the kernel path is
one jitted launch per cycle, the dense path is B gathers + decode + B
appends. The check therefore guards the *path structure* — it fails if the
engine regresses to per-request pool ops — not an externally-measured
launch trace.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.api import get_model
from repro.serving.engine import NodeEngine
from repro.serving.request import Request, SamplingParams

BATCH_SIZES = (1, 2, 4, 8)
PROMPT_LENS = (24, 72)
NEW_TOKENS = 5
ARCH = "qwen3-1.7b"


def _run_one(cfg, params, mode: str, batch: int, prompt_len: int
             ) -> Dict[str, float]:
    engine = NodeEngine(0, cfg, params, num_blocks=256, paged_decode=mode,
                        max_batch_tokens=8192)
    rng = np.random.RandomState(batch * 1000 + prompt_len)
    reqs = [Request(prompt_tokens=list(rng.randint(0, cfg.vocab_size, prompt_len)),
                    sampling=SamplingParams(max_new_tokens=NEW_TOKENS))
            for _ in range(batch)]
    for r in reqs:
        engine.scheduler.enqueue_prefill(r)
    pending = list(reqs)
    while pending:                       # prefill (emits the first token)
        done, _ = engine.step()
        for r in done:
            engine.scheduler.enqueue_decode(r)   # monolithic: local handoff
            pending.remove(r)
    # untimed warm-up: the first decode step pays jit tracing/compilation,
    # which would otherwise dominate tokens/s at this step count
    _, fin = engine.step()
    finished: List[Request] = list(fin)
    jax.block_until_ready(engine.kv.pool)
    tokens_before = sum(r.num_output for r in reqs)
    t0 = time.perf_counter()
    while len(finished) < batch:
        _, fin = engine.step()
        finished.extend(fin)
    jax.block_until_ready(engine.kv.pool)
    wall_s = time.perf_counter() - t0
    decode_tokens = sum(r.num_output for r in reqs) - tokens_before
    return {
        "batch": batch,
        "prompt_len": prompt_len,
        "decode_steps": engine.decode_steps,
        "decode_dispatches": engine.decode_dispatches,
        "dispatches_per_step": engine.decode_dispatches / max(1, engine.decode_steps),
        "compile_variants": engine.decode_compile_variants,
        "tokens_per_s": decode_tokens / wall_s if wall_s > 0 else 0.0,
        "wall_s": wall_s,
    }


def bench(batch_sizes=BATCH_SIZES, prompt_lens=PROMPT_LENS
          ) -> Dict[str, List[Dict[str, float]]]:
    cfg = get_smoke_config(ARCH)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    out: Dict[str, List[Dict[str, float]]] = {"dense": [], "kernel": []}
    for mode in ("dense", "kernel"):
        for plen in prompt_lens:
            for b in batch_sizes:
                out[mode].append(_run_one(cfg, params, mode, b, plen))
    return out


def rows(stats=None) -> List[str]:
    stats = stats or bench()
    out = []
    for mode, runs in stats.items():
        for r in runs:
            name = f"decode/{mode}/b{r['batch']}/ctx{r['prompt_len']}"
            out.append(f"{name},{r['wall_s']*1e6/max(1, r['decode_steps']):.1f},"
                       f"dispatches_per_step={r['dispatches_per_step']:.1f} "
                       f"tokens_per_s={r['tokens_per_s']:.1f} "
                       f"variants={r['compile_variants']}")
    return out


def check(stats: Dict[str, List[Dict[str, float]]]) -> None:
    """CI smoke gate: the in-place path is O(1) dispatches/step everywhere;
    the gather-dense oracle pays O(batch)."""
    for r in stats["kernel"]:
        assert r["dispatches_per_step"] == 1.0, r
    for r in stats["dense"]:
        assert r["dispatches_per_step"] == 2 * r["batch"] + 1, r
    # context length must not change the in-place dispatch count
    per_ctx = {}
    for r in stats["kernel"]:
        per_ctx.setdefault(r["prompt_len"], set()).add(r["dispatches_per_step"])
    assert all(v == {1.0} for v in per_ctx.values()), per_ctx


def history_metrics(stats: Dict[str, List[Dict[str, float]]]
                    ) -> Dict[str, float]:
    """Headline decode metrics for BENCH_decode.json (repro.obs.history)."""
    return {
        "kernel_max_dispatches_per_step": max(
            r["dispatches_per_step"] for r in stats["kernel"]),
        "dense_max_dispatches_per_step": max(
            r["dispatches_per_step"] for r in stats["dense"]),
        "kernel_compile_variants": max(
            r["compile_variants"] for r in stats["kernel"]),
        "kernel_min_tokens_per_s": min(
            r["tokens_per_s"] for r in stats["kernel"]),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="print per-path stats as JSON")
    ap.add_argument("--check", action="store_true",
                    help="assert the O(1)-dispatch decode invariant")
    ap.add_argument("--history", action="store_true",
                    help="append to BENCH_decode.json (repro.obs.history)")
    args = ap.parse_args()
    stats = bench()
    if args.check:
        check(stats)
    if args.history:
        from repro.obs import history
        history.record("decode", history_metrics(stats))
    if args.json:
        print(json.dumps(stats, indent=2, sort_keys=True))
        return
    for r in rows(stats):
        print(r)


if __name__ == "__main__":
    main()
