"""Load-aware scheduler: scores, regimes, role switching, elastic scaling,
failover (paper Alg. 1 + App. B), the overload admission gate, and the
capability-normalized heterogeneous scoring."""
import dataclasses

import pytest

from repro.core.block_manager import BlockManager
from repro.core.scheduler import (AdmissionPolicy, GlobalController,
                                  HybridScheduler, ModelCost, NodeHandle,
                                  ScoreWeights, Thresholds, classify_regime,
                                  node_score)
from repro.core.scheduler.load_score import DECODE_WEIGHTS, PREFILL_WEIGHTS
from repro.core.scheduler.metrics import NodeStatus, SlidingWindow, normalize
from repro.serving.request import Request, RequestState, SamplingParams
from repro.sim.hardware import A100, H20, L20


def _controller(num_p=2, num_d=2, node_factory=None, **kw):
    mc = ModelCost(flops_per_token=2 * 8e9, kv_bytes_per_token=131072.0,
                   weight_bytes=16e9)
    gc = GlobalController(mc, block_size=32, node_factory=node_factory, **kw)
    for i in range(num_p + num_d):
        role = "prefill" if i < num_p else "decode"
        sched = HybridScheduler(i, BlockManager(512, 32), max_batch_tokens=4096)
        gc.register_node(NodeHandle(i, role, host_id=i // 2, hardware=A100,
                                    scheduler=sched))
    return gc


def _req(n=100, rid=None):
    kw = {} if rid is None else {"request_id": rid}
    return Request(prompt_tokens=list(range(n)),
                   sampling=SamplingParams(max_new_tokens=8), **kw)


# ---------------------------------------------------------------------------
# metrics / scores
# ---------------------------------------------------------------------------
def test_sliding_window_smooths():
    w = SlidingWindow(window=4)
    for v in (0.0, 1.0, 1.0, 1.0):
        w.push(NodeStatus(kv_utilization=v))
    assert abs(w.smoothed().kv_utilization - 0.75) < 1e-9


def test_normalize_bounds_queues():
    s1 = NodeStatus(waiting_prefill=10)
    s2 = NodeStatus(waiting_prefill=5)
    n1, n2 = normalize([s1, s2])
    assert n1.waiting_prefill == 1.0 and n2.waiting_prefill == 0.5


def test_score_weight_presets_are_convex():
    """The shipped presets validate at import; validate() returns self."""
    assert PREFILL_WEIGHTS.validate() is PREFILL_WEIGHTS
    assert DECODE_WEIGHTS.validate() is DECODE_WEIGHTS
    assert abs(sum(dataclasses.astuple(PREFILL_WEIGHTS)) - 1.0) < 1e-9
    assert abs(sum(dataclasses.astuple(DECODE_WEIGHTS)) - 1.0) < 1e-9


def test_score_weight_validation_rejects_drift():
    bad_sum = dataclasses.replace(PREFILL_WEIGHTS, waiting=0.9)
    with pytest.raises(ValueError, match="sum to 1"):
        bad_sum.validate()
    negative = dataclasses.replace(PREFILL_WEIGHTS, waiting=-0.1,
                                   running=PREFILL_WEIGHTS.running + 0.4)
    with pytest.raises(ValueError, match="non-negative"):
        negative.validate()


def test_capability_normalization_weak_node_scores_hotter():
    """Same load vector: a half-capability card reads as more loaded, and
    a full-capability card reproduces the original (unscaled) formula."""
    load = NodeStatus(waiting_prefill=0.5, running_decode=0.5,
                      token_budget_used=0.5)
    weak_p = load.with_capability(0.5, 1.0, 1.0)      # compute-lean (L20-ish)
    weak_d = load.with_capability(1.0, 0.5, 1.0)      # bandwidth-lean
    assert node_score(weak_p, "prefill") > node_score(load, "prefill")
    assert node_score(weak_d, "decode") > node_score(load, "decode")
    # utilization fractions are NOT rescaled (already relative to own hw)
    util_only = NodeStatus(kv_utilization=0.8, compute_utilization=0.8,
                           bandwidth_utilization=0.8)
    assert node_score(util_only.with_capability(0.5, 0.5, 0.5), "prefill") == \
        pytest.approx(node_score(util_only, "prefill"))


def test_controller_stamps_fleet_relative_capability():
    gc = _controller(num_p=1, num_d=1)
    gc.nodes[0].hardware = L20        # weak prefill card
    gc.nodes[1].hardware = H20        # decode-friendly card
    caps = gc._capabilities()
    assert caps[1][0] == 1.0 and caps[0][0] == pytest.approx(119 / 148, rel=1e-3)
    assert caps[1][1] == 1.0 and caps[0][1] < 0.25          # 0.864 vs 4.0 TB/s
    status = gc._scored_status(gc.nodes[0])
    assert status.capability_compute == caps[0][0]
    assert status.capability_memory == caps[0][1]


def test_node_score_role_sensitivity():
    busy_prefill = NodeStatus(waiting_prefill=1.0, compute_utilization=1.0,
                              token_budget_used=1.0)
    busy_decode = NodeStatus(running_decode=1.0, kv_utilization=1.0,
                             bandwidth_utilization=1.0)
    assert node_score(busy_prefill, "prefill") > node_score(busy_prefill, "decode")
    assert node_score(busy_decode, "decode") > node_score(busy_decode, "prefill")
    with pytest.raises(ValueError):
        node_score(busy_decode, "bogus")


def test_classify_regimes():
    th = Thresholds()
    assert classify_regime(0.1, 0.1, th) == "normal"
    assert classify_regime(0.9, 0.1, th) == "imbalanced"
    assert classify_regime(0.1, 0.9, th) == "imbalanced"
    assert classify_regime(0.9, 0.9, th) == "extreme"


# ---------------------------------------------------------------------------
# routing (normal regime)
# ---------------------------------------------------------------------------
def test_routing_prefers_idle_prefill_node():
    gc = _controller()
    # preload node 0 with backlog
    for _ in range(5):
        gc.nodes[0].scheduler.enqueue_prefill(_req())
    r = _req()
    p, d = gc.route_request(r)
    assert p == 1                      # idle P node wins the TTFT estimate
    assert d in (2, 3)


def test_routing_prefers_same_host_decode():
    gc = _controller()                 # hosts: {0,1}->0/0? host_id=i//2: 0,0,1,1
    r = _req()
    p, d = gc.route_request(r)
    # prefill 0 or 1 (host 0); decode 2,3 on host 1 -> both equal; load tiebreak
    assert p in (0, 1) and d in (2, 3)


def test_prefix_cache_routing():
    gc = _controller()
    tokens = list(range(640))
    gc.record_prefix(1, tokens, block_ids=list(range(100, 120)))
    r = Request(prompt_tokens=tokens[:320], sampling=SamplingParams())
    p, _ = gc.route_request(r)
    assert p == 1
    # shareable reuse is FULL blocks only, capped so >= 1 suffix token runs:
    # 320-token prompt, 32-token blocks -> 9 shareable blocks = 288 tokens
    assert r.num_cached_prefix_tokens == 288
    assert r.prefix_src_node == 1
    assert r.prefix_block_ids == list(range(100, 109))


def test_prefix_routing_unbacked_entries_never_bill():
    """Entries recorded without block ids bias nothing: the router must not
    stamp reuse it cannot address (the phantom-hit regression)."""
    gc = _controller()
    tokens = list(range(640))
    gc.record_prefix(1, tokens)                  # no block ids
    r = Request(prompt_tokens=tokens[:320], sampling=SamplingParams())
    gc.route_request(r)
    assert r.num_cached_prefix_tokens == 0
    assert r.prefix_src_node is None


def test_prefix_routing_remote_fetch_plan():
    """A longer prefix resident on a non-prefill node becomes a remote-fetch
    plan when predicted TTFT (compute saved vs one fused transfer) wins."""
    gc = _controller(num_p=2, num_d=2)
    tokens = list(range(640))
    gc.record_prefix(3, tokens, block_ids=list(range(200, 220)))   # decode node
    r = Request(prompt_tokens=tokens, sampling=SamplingParams())
    p, _ = gc.route_request(r)
    # 8B-scale cost model: recomputing 608 tokens dwarfs one fused fetch
    assert r.prefix_src_node == 3
    assert p in (0, 1) and p != 3
    assert r.num_cached_prefix_tokens == (640 - 1) // 32 * 32 == 608
    assert r.prefix_block_ids == list(range(200, 219))


# ---------------------------------------------------------------------------
# imbalanced regime: role switching
# ---------------------------------------------------------------------------
def test_role_switch_on_imbalance():
    gc = _controller(num_p=1, num_d=1)
    # flood the P node, leave D idle; the engine would also report hot
    # token-budget / compute utilization, so simulate those signals
    for _ in range(40):
        gc.nodes[0].scheduler.enqueue_prefill(_req(2000))
    gc.nodes[0].scheduler.last_token_budget_used = 1.0
    gc.nodes[0].scheduler.last_compute_util = 1.0
    for _ in range(10):                # several cycles to build smoothed state
        regime = gc.step()
    assert regime in ("imbalanced", "extreme")
    d_sched = gc.nodes[1].scheduler
    assert any(e.kind == "role_switch" for e in gc.events)
    assert d_sched.priority == "prefill"     # idle D now helps prefill


def test_role_switch_lease_expires():
    bm = BlockManager(64, 32)
    s = HybridScheduler(0, bm)
    s.set_priority("decode", cycles=2)
    assert s.priority == "decode"
    s.schedule(); s.schedule()
    assert s.priority == "prefill"           # lease expired, back to default


# ---------------------------------------------------------------------------
# extreme regime: elastic scaling
# ---------------------------------------------------------------------------
def test_elastic_scale_up():
    created = []

    def factory(role):
        nid = 100 + len(created)
        h = NodeHandle(nid, role, host_id=9, hardware=A100,
                       scheduler=HybridScheduler(nid, BlockManager(512, 32)))
        created.append(h)
        return h

    gc = _controller(num_p=1, num_d=1, node_factory=factory)
    for _ in range(60):
        gc.nodes[0].scheduler.enqueue_prefill(_req(4000))
        gc.nodes[1].scheduler.enqueue_decode(_req(100, rid=None))
    gc.nodes[0].scheduler.schedule()        # fills the P running queue
    for nid, util in ((0, "compute"), (1, "bandwidth")):
        sched = gc.nodes[nid].scheduler
        sched.last_token_budget_used = 1.0
        setattr(sched, f"last_{util}_util", 1.0)
    for _ in range(10):
        gc.step()
    assert created, "extreme load should have scaled up"
    assert any(e.kind == "scale_up" for e in gc.events)


# ---------------------------------------------------------------------------
# fault tolerance: heartbeat failover
# ---------------------------------------------------------------------------
def test_failover_requeues_requests():
    gc = _controller(heartbeat_timeout=5.0)
    for nid in gc.nodes:
        gc.heartbeat(nid, 0.0)
    r = _req()
    p, d = gc.route_request(r)
    # node p dies (stops heartbeating); others stay fresh
    for nid in gc.nodes:
        if nid != p:
            gc.heartbeat(nid, 100.0)
    failed = gc.detect_failures(now=100.0)
    assert p in failed
    assert not gc.nodes[p].alive
    # drained request rerouted to a surviving node
    rerouted = gc.reroute_retries()
    assert rerouted == 0 or r.prefill_node != p
    assert r.retries >= 1 or r.prefill_node != p


# ---------------------------------------------------------------------------
# overload admission gate (Mooncake-style early rejection)
# ---------------------------------------------------------------------------
def test_admission_disabled_admits_everything():
    gc = _controller()
    d = gc.submit_request(_req())
    assert d.admitted and d.route is not None


def test_admission_rejects_on_predicted_ttft():
    """Deep overload (predicted TTFT far beyond SLO) rejects at submit."""
    pol = AdmissionPolicy(ttft_slo_s=1e-12, reject_factor=1.0,
                          retry_after_floor_s=2.5)
    gc = _controller(admission=pol)
    r = _req(1000)
    d = gc.submit_request(r)
    assert d.verdict == "rejected"
    assert r.state is RequestState.REJECTED
    assert r.retry_after is not None and r.retry_after >= 2.5
    assert "predicted_ttft" in r.reject_reason
    assert gc.take_rejected() == [r]
    assert gc.take_rejected() == []            # outbox drains once
    assert any(e.kind == "admission" for e in gc.events)


def test_admission_defers_then_rejects_when_load_persists():
    """Queue-depth denial defers; sustained pressure turns it terminal."""
    pol = AdmissionPolicy(max_queue_depth=1, max_defer_cycles=2)
    gc = _controller(num_p=1, num_d=1, admission=pol)
    gc.nodes[0].scheduler.prefill.waiting.append(_req())   # depth 1 == cap
    r = _req()
    d = gc.submit_request(r)
    assert d.verdict == "deferred"
    assert r in gc.deferred and r.state is RequestState.WAITING
    for _ in range(3):                         # defers 1, 2 -> reject
        gc.step()
    assert r.state is RequestState.REJECTED
    assert r not in gc.deferred
    assert gc.take_rejected() == [r]


def test_admission_admits_deferred_once_load_drains():
    pol = AdmissionPolicy(max_queue_depth=1, max_defer_cycles=50)
    gc = _controller(num_p=1, num_d=1, admission=pol)
    gc.nodes[0].scheduler.prefill.waiting.append(_req())
    admitted = []
    gc.on_admit = admitted.append
    r = _req()
    assert gc.submit_request(r).verdict == "deferred"
    gc.step()
    assert r in gc.deferred                    # still parked under pressure
    gc.nodes[0].scheduler.prefill.waiting.clear()   # load drains
    gc.step()
    assert r not in gc.deferred and admitted == [r]
    assert r.prefill_node == 0 and r.retry_after is None
    assert r in gc.nodes[0].scheduler.prefill.waiting


def test_admission_overload_epsilon_gate():
    """Every prefill node beyond eps_overload -> the gate stops admitting."""
    pol = AdmissionPolicy(max_queue_depth=1000, ttft_slo_s=1e9)
    gc = _controller(num_p=2, num_d=2, admission=pol,
                     thresholds=Thresholds(overload=0.05))
    for nid in (0, 1):
        sched = gc.nodes[nid].scheduler
        sched.last_token_budget_used = 1.0
        sched.last_compute_util = 1.0
        sched.sample_status()                  # fill the smoothing window
    d = gc.submit_request(_req())
    assert d.verdict == "deferred"
    assert "eps_overload" in d.reason


def test_passive_controller_takes_no_actions():
    """actions_enabled=False: classify-only (scenario baselines)."""
    gc = _controller(num_p=1, num_d=1, actions_enabled=False,
                     admission=AdmissionPolicy(ttft_slo_s=1e-12))
    assert gc.submit_request(_req(1000)).admitted   # gate off when passive
    for _ in range(40):
        gc.nodes[0].scheduler.enqueue_prefill(_req(2000))
    gc.nodes[0].scheduler.last_token_budget_used = 1.0
    gc.nodes[0].scheduler.last_compute_util = 1.0
    for _ in range(10):
        gc.step()
    kinds = {e.kind for e in gc.events}
    assert "role_switch" not in kinds and "scale_up" not in kinds
    assert "regime" in kinds                   # it still observes


# ---------------------------------------------------------------------------
# spill path: the swapped queue saves/restores KV through the hooks
# ---------------------------------------------------------------------------
def test_decode_preemption_spills_and_resumes_via_hooks():
    bm = BlockManager(4, 4)
    s = HybridScheduler(0, bm)
    spilled, resumed = [], []
    s.on_spill = lambda r: spilled.append(r.request_id)
    s.on_resume = lambda r: resumed.append(r.request_id)
    a, b = _req(7), _req(7)
    for r in (a, b):
        bm.allocate(r.request_id, r.total_len + 1)   # 2 blocks each: pool full
        s.enqueue_decode(r)
    a.output_tokens.append(0)                  # a grows past its 2 blocks
    b.output_tokens.append(0)
    d = s.schedule()
    # a (scanned first) cannot grow -> preempted WITH its KV saved first;
    # the freed blocks let b grow and keep decoding
    assert spilled == [a.request_id]
    assert a.state is RequestState.SWAPPED and a in s.decode.swapped
    assert not bm.owns(a.request_id) and a.block_ids == []
    assert d.decode_batch == [b] and d.preempted == [a]
    # b finishes -> its blocks free -> a resumes through on_resume
    s.decode_finished(b)
    d2 = s.schedule()
    assert resumed == [a.request_id]
    assert a.state is RequestState.DECODING and d2.decode_batch == [a]
    assert bm.owns(a.request_id)
    s.decode_finished(a)
    bm.check_invariants()
    assert bm.free_capacity == 4, "spill/resume leaked blocks"


def test_discard_hook_fires_on_cancel_and_drain():
    bm = BlockManager(8, 4)
    s = HybridScheduler(0, bm)
    discarded = []
    s.on_discard = lambda r: discarded.append(r.request_id)
    r1, r2 = _req(6), _req(6)
    s.enqueue_prefill(r1)
    s.enqueue_prefill(r2)
    s.remove_request(r1)                       # cancel path
    assert r1.request_id in discarded
    s.drain_for_failure()                      # failover path
    assert r2.request_id in discarded


def test_scheduler_drain_for_failure_frees_blocks():
    bm = BlockManager(64, 32)
    s = HybridScheduler(0, bm)
    r = _req(64)
    s.enqueue_prefill(r)
    d = s.schedule()
    assert d.kind == "prefill" and bm.owns(r.request_id)
    drained = s.drain_for_failure()
    assert r in drained
    assert not bm.owns(r.request_id)
    bm.check_invariants()
    assert bm.free_capacity == 64
