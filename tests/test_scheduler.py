"""Load-aware scheduler: scores, regimes, role switching, elastic scaling,
failover (paper Alg. 1 + App. B)."""
import pytest

from repro.core.block_manager import BlockManager
from repro.core.scheduler import (GlobalController, HybridScheduler, ModelCost,
                                  NodeHandle, Thresholds, classify_regime,
                                  node_score)
from repro.core.scheduler.metrics import NodeStatus, SlidingWindow, normalize
from repro.serving.request import Request, SamplingParams
from repro.sim.hardware import A100


def _controller(num_p=2, num_d=2, node_factory=None, **kw):
    mc = ModelCost(flops_per_token=2 * 8e9, kv_bytes_per_token=131072.0,
                   weight_bytes=16e9)
    gc = GlobalController(mc, block_size=32, node_factory=node_factory, **kw)
    for i in range(num_p + num_d):
        role = "prefill" if i < num_p else "decode"
        sched = HybridScheduler(i, BlockManager(512, 32), max_batch_tokens=4096)
        gc.register_node(NodeHandle(i, role, host_id=i // 2, hardware=A100,
                                    scheduler=sched))
    return gc


def _req(n=100, rid=None):
    kw = {} if rid is None else {"request_id": rid}
    return Request(prompt_tokens=list(range(n)),
                   sampling=SamplingParams(max_new_tokens=8), **kw)


# ---------------------------------------------------------------------------
# metrics / scores
# ---------------------------------------------------------------------------
def test_sliding_window_smooths():
    w = SlidingWindow(window=4)
    for v in (0.0, 1.0, 1.0, 1.0):
        w.push(NodeStatus(kv_utilization=v))
    assert abs(w.smoothed().kv_utilization - 0.75) < 1e-9


def test_normalize_bounds_queues():
    s1 = NodeStatus(waiting_prefill=10)
    s2 = NodeStatus(waiting_prefill=5)
    n1, n2 = normalize([s1, s2])
    assert n1.waiting_prefill == 1.0 and n2.waiting_prefill == 0.5


def test_node_score_role_sensitivity():
    busy_prefill = NodeStatus(waiting_prefill=1.0, compute_utilization=1.0,
                              token_budget_used=1.0)
    busy_decode = NodeStatus(running_decode=1.0, kv_utilization=1.0,
                             bandwidth_utilization=1.0)
    assert node_score(busy_prefill, "prefill") > node_score(busy_prefill, "decode")
    assert node_score(busy_decode, "decode") > node_score(busy_decode, "prefill")
    with pytest.raises(ValueError):
        node_score(busy_decode, "bogus")


def test_classify_regimes():
    th = Thresholds()
    assert classify_regime(0.1, 0.1, th) == "normal"
    assert classify_regime(0.9, 0.1, th) == "imbalanced"
    assert classify_regime(0.1, 0.9, th) == "imbalanced"
    assert classify_regime(0.9, 0.9, th) == "extreme"


# ---------------------------------------------------------------------------
# routing (normal regime)
# ---------------------------------------------------------------------------
def test_routing_prefers_idle_prefill_node():
    gc = _controller()
    # preload node 0 with backlog
    for _ in range(5):
        gc.nodes[0].scheduler.enqueue_prefill(_req())
    r = _req()
    p, d = gc.route_request(r)
    assert p == 1                      # idle P node wins the TTFT estimate
    assert d in (2, 3)


def test_routing_prefers_same_host_decode():
    gc = _controller()                 # hosts: {0,1}->0/0? host_id=i//2: 0,0,1,1
    r = _req()
    p, d = gc.route_request(r)
    # prefill 0 or 1 (host 0); decode 2,3 on host 1 -> both equal; load tiebreak
    assert p in (0, 1) and d in (2, 3)


def test_prefix_cache_routing():
    gc = _controller()
    tokens = list(range(640))
    gc.record_prefix(1, tokens)
    r = Request(prompt_tokens=tokens[:320], sampling=SamplingParams())
    p, _ = gc.route_request(r)
    assert p == 1
    assert r.num_cached_prefix_tokens == 320 - 1 or r.num_cached_prefix_tokens == 320


# ---------------------------------------------------------------------------
# imbalanced regime: role switching
# ---------------------------------------------------------------------------
def test_role_switch_on_imbalance():
    gc = _controller(num_p=1, num_d=1)
    # flood the P node, leave D idle; the engine would also report hot
    # token-budget / compute utilization, so simulate those signals
    for _ in range(40):
        gc.nodes[0].scheduler.enqueue_prefill(_req(2000))
    gc.nodes[0].scheduler.last_token_budget_used = 1.0
    gc.nodes[0].scheduler.last_compute_util = 1.0
    for _ in range(10):                # several cycles to build smoothed state
        regime = gc.step()
    assert regime in ("imbalanced", "extreme")
    d_sched = gc.nodes[1].scheduler
    assert any(e.kind == "role_switch" for e in gc.events)
    assert d_sched.priority == "prefill"     # idle D now helps prefill


def test_role_switch_lease_expires():
    bm = BlockManager(64, 32)
    s = HybridScheduler(0, bm)
    s.set_priority("decode", cycles=2)
    assert s.priority == "decode"
    s.schedule(); s.schedule()
    assert s.priority == "prefill"           # lease expired, back to default


# ---------------------------------------------------------------------------
# extreme regime: elastic scaling
# ---------------------------------------------------------------------------
def test_elastic_scale_up():
    created = []

    def factory(role):
        nid = 100 + len(created)
        h = NodeHandle(nid, role, host_id=9, hardware=A100,
                       scheduler=HybridScheduler(nid, BlockManager(512, 32)))
        created.append(h)
        return h

    gc = _controller(num_p=1, num_d=1, node_factory=factory)
    for _ in range(60):
        gc.nodes[0].scheduler.enqueue_prefill(_req(4000))
        gc.nodes[1].scheduler.enqueue_decode(_req(100, rid=None))
    gc.nodes[0].scheduler.schedule()        # fills the P running queue
    for nid, util in ((0, "compute"), (1, "bandwidth")):
        sched = gc.nodes[nid].scheduler
        sched.last_token_budget_used = 1.0
        setattr(sched, f"last_{util}_util", 1.0)
    for _ in range(10):
        gc.step()
    assert created, "extreme load should have scaled up"
    assert any(e.kind == "scale_up" for e in gc.events)


# ---------------------------------------------------------------------------
# fault tolerance: heartbeat failover
# ---------------------------------------------------------------------------
def test_failover_requeues_requests():
    gc = _controller(heartbeat_timeout=5.0)
    for nid in gc.nodes:
        gc.heartbeat(nid, 0.0)
    r = _req()
    p, d = gc.route_request(r)
    # node p dies (stops heartbeating); others stay fresh
    for nid in gc.nodes:
        if nid != p:
            gc.heartbeat(nid, 100.0)
    failed = gc.detect_failures(now=100.0)
    assert p in failed
    assert not gc.nodes[p].alive
    # drained request rerouted to a surviving node
    rerouted = gc.reroute_retries()
    assert rerouted == 0 or r.prefill_node != p
    assert r.retries >= 1 or r.prefill_node != p


def test_scheduler_drain_for_failure_frees_blocks():
    bm = BlockManager(64, 32)
    s = HybridScheduler(0, bm)
    r = _req(64)
    s.enqueue_prefill(r)
    d = s.schedule()
    assert d.kind == "prefill" and bm.owns(r.request_id)
    drained = s.drain_for_failure()
    assert r in drained
    assert not bm.owns(r.request_id)
    bm.check_invariants()
    assert bm.num_free == 64
