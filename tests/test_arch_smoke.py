"""Per-assigned-architecture smoke tests: REDUCED config of the same family,
one forward/train step on CPU, asserting output shapes + no NaNs (spec
requirement). Full configs are exercised only by the dry-run."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.models.api import get_model


def _smoke_batch(cfg, B=2, S=8):
    if cfg.family == "encdec":
        return {"frames": jnp.ones((B, S, cfg.d_model)),
                "tokens": jnp.ones((B, S - 2), jnp.int32),
                "labels": jnp.ones((B, S - 2), jnp.int32)}
    if cfg.frontend != "none":
        return {"tokens": jnp.ones((B, S), jnp.int32),
                "labels": jnp.ones((B, S + cfg.frontend_tokens), jnp.int32),
                "frontend_embeds": jnp.ones((B, cfg.frontend_tokens, cfg.d_model))}
    return {"tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32)}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(gn)), f"{arch}: non-finite grads"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 8
    if cfg.family == "encdec":
        batch = {"frames": jnp.ones((B, S, cfg.d_model)),
                 "tokens": jnp.ones((B, 4), jnp.int32)}
    elif cfg.frontend != "none":
        batch = {"tokens": jnp.ones((B, S), jnp.int32),
                 "frontend_embeds": jnp.ones((B, cfg.frontend_tokens, cfg.d_model))}
    else:
        batch = {"tokens": jnp.ones((B, S), jnp.int32)}
    logits, cache = model.prefill(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite prefill"
    if cfg.family == "encdec":
        c = model.init_cache(B, 16, enc_len=S)
        c["k"] = c["k"].at[:, :, :4].set(cache["k"])
        c["v"] = c["v"].at[:, :, :4].set(cache["v"])
        c["cross_k"], c["cross_v"] = cache["cross_k"], cache["cross_v"]
        c["length"] = jnp.full((B,), 4, jnp.int32)
        cache = c
    elif cfg.family in ("dense", "moe", "vlm", "audio"):
        n = logits.shape[0]
        total = S + (cfg.frontend_tokens if cfg.frontend != "none" else 0)
        c = model.init_cache(B, total + 4)
        c["k"] = c["k"].at[:, :, :total].set(cache["k"])
        c["v"] = c["v"].at[:, :, :total].set(cache["v"])
        c["length"] = jnp.full((B,), total, jnp.int32)
        cache = c
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits2, cache2 = model.decode(params, tok, cache)
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2))), f"{arch}: non-finite decode"
    assert int(cache2["length"][0]) == int(cache["length"][0]) + 1
