"""KV layout transform (paper Eq. 5) and page read/write."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import layout as L


def _spec(layout):
    return L.KVCacheSpec(num_layers=4, num_blocks=10, block_size=4,
                         num_kv_heads=2, head_dim=8, dtype=jnp.float32,
                         layout=layout)


def test_layout_shapes_and_counts():
    fk = _spec(L.KVLayout.FLOWKV)
    vl = _spec(L.KVLayout.VLLM)
    assert fk.shape == (10, 4, 2, 64)
    assert vl.shape == (4, 2, 10, 64)
    assert fk.transfer_calls_per_block() == 1
    assert vl.transfer_calls_per_block() == 8        # L*2, the paper's factor
    assert fk.bytes_per_block == vl.bytes_per_block


def test_transform_roundtrip():
    vl = _spec(L.KVLayout.VLLM)
    x = jnp.arange(np.prod(vl.shape), dtype=jnp.float32).reshape(vl.shape)
    y = L.vllm_to_flowkv(x)
    assert y.shape == _spec(L.KVLayout.FLOWKV).shape
    np.testing.assert_array_equal(np.asarray(L.flowkv_to_vllm(y)), np.asarray(x))
    np.testing.assert_array_equal(
        np.asarray(L.convert(x, L.KVLayout.VLLM, L.KVLayout.VLLM)), np.asarray(x))


@pytest.mark.parametrize("layout", [L.KVLayout.FLOWKV, L.KVLayout.VLLM])
def test_write_read_block(layout):
    spec = _spec(layout)
    cache = L.alloc_cache(spec)
    rng = np.random.RandomState(0)
    k = jnp.asarray(rng.randn(4, 2, 8), jnp.float32)
    v = jnp.asarray(rng.randn(4, 2, 8), jnp.float32)
    cache = L.write_block(cache, spec, 3, 2, k, v)
    k2, v2 = L.read_block(cache, spec, 3, 2)
    np.testing.assert_allclose(np.asarray(k2), np.asarray(k))
    np.testing.assert_allclose(np.asarray(v2), np.asarray(v))


@pytest.mark.parametrize("layout", [L.KVLayout.FLOWKV, L.KVLayout.VLLM])
def test_gather_scatter_blocks(layout):
    spec = _spec(layout)
    rng = np.random.RandomState(1)
    cache = jnp.asarray(rng.randn(*spec.shape), jnp.float32)
    ids = [7, 2, 5]
    payload = L.gather_blocks(cache, spec, ids)
    assert payload.shape == (3, 4, 2, 64)
    dst = L.alloc_cache(spec)
    dst = L.scatter_blocks(dst, spec, [1, 3, 9], payload)
    p2 = L.gather_blocks(dst, spec, [1, 3, 9])
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(payload))
