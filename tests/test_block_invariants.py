"""Property-based invariant suite for the block/tier plane.

Random interleavings of the full op vocabulary — allocate / share / free /
grow (ensure_capacity) / demote (cache reclaim through the tier hook) /
promote / rehome / release_all — against a BlockManager + GlobalPrefixIndex
+ TierManager stack, on BOTH allocators. After EVERY op the whole plane is
audited:

* ``BlockManager.check_invariants`` — refcounts mirror tables; free +
  tabled + cached tiles the pool; cached and refcounted sets disjoint;
* ``BlockManager.assert_no_leaks`` — no table outlives its request;
* ``TierManager.check_invariants`` — host-resident == index-DRAM-backed;
* tier disjointness/exhaustiveness — every backed index entry lives in
  EXACTLY one tier, HBM entries point at live pool blocks, DRAM entries at
  resident host blocks, and the two backmaps mirror the forward map.

``hypothesis`` is optional (guarded import, like ``test_allocator.py``):
without it a deterministic seeded-random fallback still drives >= 200
interleavings per allocator.
"""
import random

import jax.numpy as jnp
import pytest

from repro.core import layout as L
from repro.core.block_manager import BlockManager
from repro.serving.host_tier import TierManager
from repro.serving.prefix_cache import (GlobalPrefixIndex, TIER_DRAM,
                                        TIER_HBM)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

BLOCK = 4
POOL = 32
HOST = 16          # small on purpose: promotion must survive host evictions
NODE = 0
OPS = ("alloc", "share", "free", "grow", "demote", "promote", "rehome",
       "release_all")
SPEC = L.KVCacheSpec(num_layers=2, num_blocks=POOL, block_size=BLOCK,
                     num_kv_heads=2, head_dim=8, dtype=jnp.float32)


class _Plane:
    """One node's block/tier plane plus the model state the audit needs."""

    def __init__(self, allocator: str):
        self.bm = BlockManager(POOL, BLOCK, allocator=allocator)
        self.index = GlobalPrefixIndex(BLOCK)
        self.bm.on_free = \
            lambda blocks: self.index.invalidate_blocks(NODE, blocks)
        self.tm = TierManager(NODE, self.bm, self.index, SPEC, HOST,
                              kv=None).attach()
        self.live = {}          # rid -> prompt token list (indexed prefix)
        self.tokens = {}        # rid -> current table token count
        self.prompts = []       # every prompt ever inserted (promote targets)
        self.next_rid = 0
        self.next_token = 1


def _fresh_prompt(p: _Plane, ntok: int):
    out = list(range(p.next_token, p.next_token + ntok))
    p.next_token += ntok
    return out


def _admit(p: _Plane, prompt, prefix_blocks=()):
    rid, ntok = p.next_rid, len(prompt)
    p.next_rid += 1
    p.bm.allocate(rid, ntok, prefix_blocks=prefix_blocks)
    p.index.insert(NODE, prompt, p.bm.get(rid))
    p.live[rid] = prompt
    p.tokens[rid] = ntok
    p.prompts.append(prompt)
    del p.prompts[:-40]          # bounded promote-target history


def _step(p: _Plane, rng: random.Random, op: str) -> None:
    if op == "alloc":
        ntok = rng.randint(1, 6) * BLOCK
        if p.bm.can_allocate(ntok):
            _admit(p, _fresh_prompt(p, ntok))
    elif op == "share":
        if not p.live:
            return
        donor = p.live[rng.choice(list(p.live))]
        m = p.index.lookup(NODE, donor)
        lead = []                # only a leading HBM run is shareable
        for b, t in zip(m.block_ids, m.tiers):
            if t != TIER_HBM or not p.bm.block_alive(b):
                break
            lead.append(b)
        if not lead:
            return
        k = rng.randint(1, len(lead))
        extra = rng.randint(0, 2) * BLOCK
        ntok = k * BLOCK + extra
        if p.bm.can_allocate(ntok, shared_blocks=k,
                             shared_block_ids=lead[:k]):
            prompt = donor[:k * BLOCK] + _fresh_prompt(p, extra)
            _admit(p, prompt, prefix_blocks=lead[:k])
    elif op == "free":
        if p.live:
            rid = rng.choice(list(p.live))
            p.bm.free(rid)
            del p.live[rid], p.tokens[rid]
    elif op == "grow":
        if not p.live:
            return
        rid = rng.choice(list(p.live))
        extra = rng.randint(1, 2)
        if extra <= p.bm.free_capacity:
            p.tokens[rid] += extra * BLOCK
            p.bm.ensure_capacity(rid, p.tokens[rid])
    elif op == "demote":
        p.bm.reclaim_cache(rng.randint(1, POOL // 4))
    elif op == "promote":
        if p.prompts:
            p.tm.promote_match(rng.choice(p.prompts))
    elif op == "rehome":
        # a transfer landing: an old prompt re-inserts on fresh blocks,
        # re-pointing its digests (and orphaning any DRAM backing)
        if p.prompts:
            prompt = rng.choice(p.prompts)
            if p.bm.can_allocate(len(prompt)):
                _admit(p, prompt)
    elif op == "release_all":
        p.bm.release_all()
        p.live.clear()
        p.tokens.clear()
    else:                        # pragma: no cover - op vocabulary drift
        raise AssertionError(op)


def _audit(p: _Plane) -> None:
    p.bm.check_invariants()
    p.bm.assert_no_leaks(list(p.live))
    p.tm.check_invariants()
    by_hash = p.index._node_hashes.get(NODE, {})
    hbm = p.index._node_blocks.get(NODE, {})
    dram = p.index._node_host_blocks.get(NODE, {})
    # backmaps mirror the forward map, one tier per digest
    for b, h in hbm.items():
        assert by_hash.get(h) == (TIER_HBM, b), (b, h)
        assert p.bm.block_alive(b), f"index advertises dead pool block {b}"
    for b, h in dram.items():
        assert by_hash.get(h) == (TIER_DRAM, b), (b, h)
        assert b in p.tm.host._lru, f"index advertises evicted host block {b}"
    # disjoint and exhaustive: every backed digest is in exactly one tier
    backed = {h for h, e in by_hash.items() if e is not None}
    assert not set(hbm.values()) & set(dram.values()), "digest in both tiers"
    assert backed == set(hbm.values()) | set(dram.values()), (
        "backed entries not tiled by the two tier backmaps")


def _run_interleaving(allocator: str, ops, seed: int) -> None:
    p = _Plane(allocator)
    rng = random.Random(seed)
    for op in ops:
        _step(p, rng, op)
        _audit(p)
    # teardown leaves a clean pool (host tier may stay resident by design)
    p.bm.release_all()
    p.live.clear()
    _audit(p)
    assert p.bm.num_free == POOL


if HAVE_HYPOTHESIS:
    @st.composite
    def _traces(draw):
        return draw(st.lists(st.sampled_from(OPS), min_size=1, max_size=60))

    @pytest.mark.parametrize("allocator", ["flowkv", "vllm"])
    @given(ops=_traces(), seed=st.integers(0, 10_000))
    @settings(max_examples=200, deadline=None)
    def test_block_tier_invariants(allocator, ops, seed):
        _run_interleaving(allocator, ops, seed)
else:
    def test_hypothesis_property_suite():
        pytest.importorskip("hypothesis")   # records the skip reason


# -- deterministic fallback: >= 200 seeded interleavings per allocator --------
@pytest.mark.parametrize("allocator", ["flowkv", "vllm"])
def test_block_tier_invariants_deterministic(allocator):
    rng = random.Random(7)
    for trial in range(200):
        ops = [rng.choice(OPS) for _ in range(rng.randint(1, 60))]
        _run_interleaving(allocator, ops, trial)


def test_every_op_reachable():
    """The trace driver must actually exercise the whole vocabulary (a
    guard against the suite silently degenerating into alloc/free only)."""
    hit = set()
    rng = random.Random(11)
    p = _Plane("flowkv")
    for _ in range(4000):
        op = rng.choice(OPS)
        before = (p.tm.demoted_blocks, p.tm.promoted_blocks,
                  p.bm.cached_reused, len(p.live))
        _step(p, rng, op)
        after = (p.tm.demoted_blocks, p.tm.promoted_blocks,
                 p.bm.cached_reused, len(p.live))
        if before != after or op in ("free", "release_all", "demote"):
            hit.add(op)
    assert hit >= {"alloc", "share", "free", "demote", "promote",
                   "rehome", "release_all"}, hit
    assert p.tm.demoted_blocks > 0 and p.tm.promoted_blocks > 0
    assert p.tm.host_evicted_blocks > 0, "host LRU eviction never exercised"
