"""Property tests: allocators + segments.

``hypothesis`` is optional: without it the property tests skip (via
``pytest.importorskip``) and a deterministic seeded-random workload still
checks the allocator invariants.
"""
import random

import pytest

from repro.core.allocator import (BlockAllocator, OutOfBlocksError,
                                  SegmentAllocator)
from repro.core.segments import (Segment, blocks_to_segments, fragmentation,
                                 segments_to_blocks, validate_disjoint)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _apply_ops(cls, ops, seed):
    """Shared invariant harness: random alloc/free/extend trace."""
    rng = random.Random(seed)
    alloc = cls(256)
    live = {}
    rid = 0
    for kind, n in ops:
        if kind == "alloc":
            if n <= alloc.num_free:
                live[rid] = alloc.allocate(n)
                rid += 1
            else:
                with pytest.raises(OutOfBlocksError):
                    alloc.allocate(n)
        elif kind == "free" and live:
            victim = rng.choice(list(live))
            alloc.free(live.pop(victim))
        elif kind == "extend" and live and alloc.num_free >= 1:
            victim = rng.choice(list(live))
            live[victim] = live[victim] + alloc.extend(live[victim], 1)
        alloc.check_invariants()
        # no block owned twice
        seen = set()
        for blocks in live.values():
            bs = set(blocks)
            assert len(bs) == len(blocks)
            assert not (bs & seen)
            seen |= bs


# ---------------------------------------------------------------------------
# segments
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    @given(st.lists(st.integers(0, 500), max_size=200))
    @settings(max_examples=60, deadline=None)
    def test_blocks_segments_roundtrip(ids):
        assert segments_to_blocks(blocks_to_segments(ids)) == ids

    @st.composite
    def _ops(draw):
        return draw(st.lists(
            st.tuples(st.sampled_from(["alloc", "free", "extend"]),
                      st.integers(1, 40)),
            min_size=1, max_size=120))

    @pytest.mark.parametrize("cls", [BlockAllocator, SegmentAllocator])
    @given(ops=_ops(), seed=st.integers(0, 10_000))
    @settings(max_examples=40, deadline=None)
    def test_allocator_invariants(cls, ops, seed):
        _apply_ops(cls, ops, seed)
else:
    def test_hypothesis_property_suite():
        pytest.importorskip("hypothesis")   # records the skip reason


# -- deterministic fallbacks: same invariants, seeded random traces ------------
def test_blocks_segments_roundtrip_deterministic():
    rng = random.Random(1)
    for trial in range(40):
        ids = [rng.randint(0, 500) for _ in range(rng.randint(0, 200))]
        assert segments_to_blocks(blocks_to_segments(ids)) == ids


@pytest.mark.parametrize("cls", [BlockAllocator, SegmentAllocator])
def test_allocator_invariants_deterministic(cls):
    rng = random.Random(2)
    for seed in range(12):
        ops = [(rng.choice(["alloc", "free", "extend"]), rng.randint(1, 40))
               for _ in range(rng.randint(1, 120))]
        _apply_ops(cls, ops, seed)


def test_segment_basics():
    s = Segment(4, 3)
    assert s.end == 7 and s.contains(6) and not s.contains(7)
    assert s.merge(Segment(7, 2)) == Segment(4, 5)
    taken, rest = s.split(2)
    assert taken == Segment(4, 2) and rest == Segment(6, 1)
    with pytest.raises(ValueError):
        Segment(0, 0)
    with pytest.raises(ValueError):
        s.merge(Segment(9, 1))
    assert fragmentation(blocks_to_segments([1, 2, 3])) == 0.0


def test_segment_allocator_merges_on_free():
    a = SegmentAllocator(64)
    r1, r2, r3 = a.allocate(10), a.allocate(10), a.allocate(10)
    a.free(r1); a.free(r3); a.free(r2)     # out-of-order frees must coalesce
    segs = a.free_segments()
    assert segs == [Segment(0, 64)], segs


def test_segment_allocator_best_fit_prefers_single_run():
    a = SegmentAllocator(64)
    r1 = a.allocate(8)
    r2 = a.allocate(16)
    a.free(r1)
    # 8-run and 40-run free; a 6-block request should carve the 8-run
    r3 = a.allocate(6)
    assert r3 == list(range(0, 6))
    assert len(blocks_to_segments(r3)) == 1


def test_segment_extend_in_place():
    a = SegmentAllocator(64)
    r = a.allocate(4)
    ext = a.extend(r, 3)
    assert ext == [4, 5, 6]                 # tail-adjacent growth


def test_freelist_scatters_segment_keeps_contiguity():
    rng = random.Random(0)
    for cls, expect_contig in ((BlockAllocator, False), (SegmentAllocator, True)):
        a = cls(512)
        live = {}
        for i in range(200):
            if live and rng.random() < 0.45:
                a.free(live.pop(rng.choice(list(live))))
            elif a.num_free >= 16:
                live[i] = a.allocate(16)
        runs = [len(blocks_to_segments(b)) for b in live.values()]
        mean_runs = sum(runs) / len(runs)
        if expect_contig:
            assert mean_runs < 2.5, mean_runs
        else:
            assert mean_runs > 2.5, mean_runs
