import os
import sys

import pytest

# src-layout import path (tests run as `PYTHONPATH=src pytest tests/`, but be
# robust when invoked without it). NOTE: no XLA_FLAGS here — smoke tests and
# benches must see 1 device; only launch/dryrun.py forces 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """Drop compiled XLA executables after each test module.

    The suite compiles one scan variant per distinct (model, prompt length)
    pair; with everything kept alive, XLA's CPU backend eventually segfaults
    inside backend_compile once enough executables have accumulated in one
    process (the crashing test moves with total compile load, independent of
    which modules run). Per-module eviction keeps the working set bounded;
    within a module the cache still amortizes compiles across tests.
    """
    yield
    import jax

    jax.clear_caches()
