"""End-to-end PD-cluster correctness + fault tolerance + checkpointing.

THE reproduction-critical property: disaggregated serving (prefill on node P,
FlowKV page transfer, decode on node D) must produce token-identical output
to monolithic generation.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.models.api import get_model
from repro.serving.cluster import PDCluster
from repro.serving.request import Request, RequestState, SamplingParams


@pytest.fixture(scope="module")
def small_model():
    cfg = get_smoke_config("qwen3-1.7b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, n=4, seed=0):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(0, cfg.vocab_size, size=rng.randint(5, 30)))
            for _ in range(n)]


def _reference(cfg, params, prompts, steps=6):
    refs = {}
    for p in prompts:
        out = T.greedy_generate(params, cfg, jnp.asarray([p], jnp.int32), steps)
        refs[tuple(p)] = [int(x) for x in out[0]]
    return refs


@pytest.mark.parametrize("schedule", ["flowkv", "layerwise", "blockwise"])
def test_disaggregated_matches_monolithic(small_model, schedule):
    cfg, params = small_model
    prompts = _prompts(cfg)
    refs = _reference(cfg, params, prompts)
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=1,
                        num_blocks=128, transfer_schedule=schedule)
    reqs = [Request(prompt_tokens=list(p), sampling=SamplingParams(max_new_tokens=6))
            for p in prompts]
    done = cluster.run(reqs, max_cycles=80)
    assert len(done) == len(prompts)
    for r in done:
        assert r.output_tokens == refs[tuple(r.prompt_tokens)]
    if schedule == "flowkv":
        assert cluster.stats()["mean_transfer_calls"] == 1.0


def test_flowkv_allocator_vs_freelist_calls(small_model):
    """Freelist allocator scatters -> more transfer calls after alignment."""
    cfg, params = small_model
    prompts = _prompts(cfg, n=6, seed=3)
    calls = {}
    for alloc in ("flowkv", "freelist"):
        cluster = PDCluster(cfg, params, num_prefill=1, num_decode=1,
                            num_blocks=64, allocator=alloc)
        reqs = [Request(prompt_tokens=list(p), sampling=SamplingParams(max_new_tokens=4))
                for p in prompts]
        cluster.run(reqs, max_cycles=80)
        calls[alloc] = cluster.stats()["mean_transfer_calls"]
    assert calls["flowkv"] <= calls["freelist"]


def test_node_failure_requeues_and_completes(small_model):
    cfg, params = small_model
    prompts = _prompts(cfg, n=3, seed=5)
    refs = _reference(cfg, params, prompts, steps=4)
    cluster = PDCluster(cfg, params, num_prefill=2, num_decode=1, num_blocks=128)
    cluster.controller.heartbeat_timeout = 2.0
    reqs = [Request(prompt_tokens=list(p), sampling=SamplingParams(max_new_tokens=4))
            for p in prompts]
    for r in reqs:
        cluster.submit(r)
    cluster.kill_node(0)          # a prefill node dies before doing work
    done = cluster.run([], max_cycles=80)
    assert len(cluster.finished) == len(prompts)
    for r in cluster.finished:
        assert r.output_tokens == refs[tuple(r.prompt_tokens)]
    assert any(e.kind == "failover" for e in cluster.controller.events)


def test_cluster_checkpoint_roundtrip(tmp_path, small_model):
    from repro.serving.checkpoint import load_cluster, save_cluster
    cfg, params = small_model
    prompts = _prompts(cfg, n=3, seed=7)
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=1, num_blocks=64)
    reqs = [Request(prompt_tokens=list(p), sampling=SamplingParams(max_new_tokens=6))
            for p in prompts]
    for r in reqs:
        cluster.submit(r)
    for _ in range(3):            # mid-flight
        cluster.step()
    save_cluster(cluster, str(tmp_path / "ckpt"))

    # fresh cluster, restore, finish
    c2 = PDCluster(cfg, params, num_prefill=1, num_decode=1, num_blocks=64)
    load_cluster(c2, str(tmp_path / "ckpt"))
    # restored decode-running requests keep generating
    for _ in range(60):
        c2.step()
        if len(c2.finished) >= sum(1 for r in reqs if r.state != RequestState.WAITING):
            break
    # every restored request makes progress without allocator corruption
    for eng in c2.engines.values():
        eng.scheduler.bm.check_invariants()


def test_block_manager_no_leaks_after_run(small_model):
    cfg, params = small_model
    prompts = _prompts(cfg, n=5, seed=9)
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=1, num_blocks=64)
    reqs = [Request(prompt_tokens=list(p), sampling=SamplingParams(max_new_tokens=4))
            for p in prompts]
    cluster.run(reqs, max_cycles=80)
    for eng in cluster.engines.values():
        eng.scheduler.bm.check_invariants()
        assert eng.scheduler.bm.free_capacity == 64, "leaked blocks after completion"
