"""Tiered KV store: bit-identity, remote-DRAM fetch, fault paths, parity.

The reproduction-critical property of the host-DRAM tier: movement between
tiers is MOVE semantics over the same fused descriptor-table data plane as
P->D transfers, so a demote -> promote round trip must be bit-identical to
KV that never left the pool — decoding is deterministic argmax, so any
drift in the copy plans shows up as a wrong token, not a tolerance miss.

Covers the satellite contracts:

* demote -> promote round trip bit-identical at the page level (direct
  ``TierManager`` + ``PagedKVCache``) and token-identical end to end;
* remote-DRAM prefix fetch (source-side promote + fused pool->pool pull)
  matches the local-hit and recompute outputs;
* cancel-while-demoting and crash-during-promote (``repro.faults``) leave
  zero leaked blocks on EITHER tier;
* the free -> re-hit regression: refcount-zero prefixes stay cached (LRU)
  until capacity pressure, so a re-request after its last holder finished
  still hits;
* sim/real parity — ClusterSim and PDCluster make the same tier-routing
  decision and emit matching ``tier_demote``/``tier_promote`` span
  sequences on a shared workload (PR 7 parity pattern).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import layout as L
from repro.core.block_manager import BlockManager
from repro.faults import FaultSpec
from repro.models.api import get_model
from repro.obs.tracing import attach_tracer
from repro.serving.cluster import PDCluster
from repro.serving.host_tier import TierManager
from repro.serving.kv_cache import PagedKVCache
from repro.serving.prefix_cache import (GlobalPrefixIndex, TIER_DRAM,
                                        TIER_HBM)
from repro.serving.request import Request, SamplingParams
from repro.sim.cluster_sim import ClusterSim
from repro.sim.hardware import A100, TPU_V5E

WEAK = dataclasses.replace(TPU_V5E, peak_flops=1e6)


@pytest.fixture(scope="module")
def small_model():
    cfg = get_smoke_config("qwen3-1.7b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, params


def _drain(cluster, want, max_steps=400):
    for _ in range(max_steps):
        cluster.step()
        if len(cluster.finished) + len(cluster.cancelled) >= want:
            return
    raise AssertionError(
        f"stalled: {len(cluster.finished)}+{len(cluster.cancelled)}/{want}")


def _audit(cluster):
    assert cluster.audit_blocks() == 0
    cluster.assert_no_leaks()
    for tm in cluster.tiers.values():
        if tm.node_id not in cluster._dead:
            tm.check_invariants()


# ---------------------------------------------------------------------------
# page-level bit identity: demote -> promote round trip
# ---------------------------------------------------------------------------

def test_demote_promote_roundtrip_bit_identical():
    """The KV pages that come back from host DRAM are the exact pages that
    went down — even after the vacated pool blocks are overwritten."""
    spec = L.KVCacheSpec(num_layers=2, num_blocks=8, block_size=4,
                         num_kv_heads=2, head_dim=8, dtype=jnp.float32)
    kv = PagedKVCache(spec)
    bm = BlockManager(spec.num_blocks, spec.block_size)
    index = GlobalPrefixIndex(spec.block_size)
    bm.on_free = lambda blocks: index.invalidate_blocks(0, blocks)
    tm = TierManager(0, bm, index, spec, host_blocks=8, kv=kv).attach()

    prompt = list(range(12))               # 3 full blocks
    blocks = bm.allocate(1, len(prompt))
    index.insert(0, prompt, blocks)
    fill = jnp.arange(kv.pool.size, dtype=jnp.float32).reshape(kv.pool.shape)
    kv.pool = fill
    want = np.asarray(fill[jnp.asarray(blocks)])

    bm.free(1)
    bm.reclaim_cache()                     # capacity pressure -> demote
    assert tm.demoted_blocks == 3 and tm.host.num_resident == 3
    m = index.lookup(0, prompt)
    assert m.tiers == [TIER_DRAM] * 3
    # scribble over the vacated pool blocks: the KV must live in DRAM now
    kv.pool = kv.pool.at[jnp.asarray(blocks)].set(-1.0)

    assert tm.promote_match(prompt) == 3
    assert tm.host.num_resident == 0       # move semantics: DRAM side freed
    m = index.lookup(0, prompt)
    assert m.tiers == [TIER_HBM] * 3
    got = np.asarray(kv.pool[jnp.asarray(m.block_ids)])
    np.testing.assert_array_equal(got, want)
    # promoted destinations are CACHED blocks (no request owns them) and a
    # later allocate() revives them like any other hit
    assert all(bm.is_cached(b) for b in m.block_ids)
    bm.check_invariants()
    tm.check_invariants()


# ---------------------------------------------------------------------------
# end-to-end token identity on real compute
# ---------------------------------------------------------------------------

def _play(cfg, params, prompts, **kw):
    """One conversation: prompts submitted strictly one after another."""
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=0,
                        num_blocks=16, hardware=WEAK,
                        max_batch_tokens=4096, **kw)
    reqs = []
    for p in prompts:
        r = Request(prompt_tokens=list(p),
                    sampling=SamplingParams(max_new_tokens=6))
        cluster.submit(r)
        reqs.append(r)
        _drain(cluster, len(reqs))
    _audit(cluster)
    return cluster, reqs


def test_engine_roundtrip_token_identity(small_model):
    """turn1 parks its prefix; churn demotes it; turn2 promotes it back —
    and every output token matches both a never-demoted run (big pool, no
    tier) and a reuse-off run (cold compute)."""
    cfg, params = small_model
    rng = np.random.RandomState(0)
    turn1 = rng.randint(0, cfg.vocab_size, size=256).tolist()
    churn = rng.randint(0, cfg.vocab_size, size=320).tolist()
    turn2 = turn1 + rng.randint(0, cfg.vocab_size, size=48).tolist()
    convo = [turn1, churn, turn2]

    tiered, treqs = _play(cfg, params, convo, host_tier_blocks=64)
    s = tiered.stats()
    assert s["tier_demoted_blocks"] > 0, "pool pressure never demoted"
    assert s["tier_promoted_blocks"] > 0, "turn 2 never promoted"
    assert treqs[2].num_cached_prefix_tokens >= 256, \
        "turn 2 did not reuse the promoted history"

    cold, creqs = _play(cfg, params, convo, prefix_reuse=False)
    never, nreqs = _play(cfg, params, convo)   # reuse on, HBM-only, no churn
    for t, c, n in zip(treqs, creqs, nreqs):
        assert t.output_tokens == c.output_tokens, \
            "demote->promote changed tokens vs cold compute"
        assert t.output_tokens == n.output_tokens, \
            "tiered run diverged from the never-demoted run"


# ---------------------------------------------------------------------------
# remote-DRAM fetch: source-side promote + fused pool->pool pull
# ---------------------------------------------------------------------------

def test_remote_dram_fetch_matches_local_hit_and_recompute(small_model):
    """A prefix demoted on a REMOTE node still serves a hit: the source
    promotes (DRAM -> pool), the plan refreshes, and the fetch pulls the
    promoted pool blocks — token-identically to a local hit and to
    recompute."""
    cfg, params = small_model
    rng = np.random.RandomState(4)
    prefix = rng.randint(0, cfg.vocab_size, size=128).tolist()
    donor = prefix + rng.randint(0, cfg.vocab_size, size=8).tolist()
    follower = prefix + rng.randint(0, cfg.vocab_size, size=40).tolist()

    def remote(**kw):
        cluster = PDCluster(cfg, params, num_prefill=1, num_decode=1,
                            num_blocks=64, hardware=WEAK,
                            max_batch_tokens=4096,
                            host_tier_blocks=kw.pop("host", 64), **kw)
        r0 = Request(prompt_tokens=list(donor),
                     sampling=SamplingParams(max_new_tokens=8))
        cluster.submit(r0)
        _drain(cluster, 1)
        # capacity pressure on the DECODE node (where the prefix re-homed):
        # everything index-backed demotes to its host tier
        cluster.engines[1].scheduler.bm.reclaim_cache()
        r1 = Request(prompt_tokens=list(follower),
                     sampling=SamplingParams(max_new_tokens=6))
        cluster.submit(r1)
        _drain(cluster, 2)
        _audit(cluster)
        return cluster, r1

    cluster, r1 = remote()
    src_tm = cluster.tiers[1]
    assert src_tm.demoted_blocks >= 4, "reclaim never demoted the prefix"
    assert src_tm.promoted_blocks >= 4, "the fetch never promoted at source"
    assert r1.num_cached_prefix_tokens >= 128
    fetches = [t for t in cluster.transfers if t.kind == "prefix_fetch"]
    assert fetches and all(t.num_dispatches == 1 for t in fetches), \
        "remote-DRAM fetch is not one fused dispatch"

    # local hit: single hybrid node, nothing demoted, same prompts
    local = PDCluster(cfg, params, num_prefill=1, num_decode=0,
                      num_blocks=64, hardware=WEAK, max_batch_tokens=4096)
    l0 = Request(prompt_tokens=list(donor),
                 sampling=SamplingParams(max_new_tokens=8))
    local.submit(l0)
    _drain(local, 1)
    l1 = Request(prompt_tokens=list(follower),
                 sampling=SamplingParams(max_new_tokens=6))
    local.submit(l1)
    _drain(local, 2)
    assert l1.num_cached_prefix_tokens >= 128

    # recompute: reuse off entirely
    cold = PDCluster(cfg, params, num_prefill=1, num_decode=1,
                     num_blocks=64, hardware=WEAK, max_batch_tokens=4096,
                     prefix_reuse=False)
    c0 = Request(prompt_tokens=list(donor),
                 sampling=SamplingParams(max_new_tokens=8))
    cold.submit(c0)
    _drain(cold, 1)
    c1 = Request(prompt_tokens=list(follower),
                 sampling=SamplingParams(max_new_tokens=6))
    cold.submit(c1)
    _drain(cold, 2)

    assert r1.output_tokens == l1.output_tokens == c1.output_tokens, \
        "remote-DRAM fetch diverged from local hit / recompute"


# ---------------------------------------------------------------------------
# fault paths: zero leaked blocks on either tier
# ---------------------------------------------------------------------------

def test_cancel_while_demoting_no_leak(small_model):
    """Cancel a request whose prefix plan points at blocks being demoted
    that same window: nothing leaks on either tier, and the demoted prefix
    still serves the NEXT request via promotion."""
    cfg, params = small_model
    rng = np.random.RandomState(7)
    prefix = rng.randint(0, cfg.vocab_size, size=128).tolist()
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=0,
                        num_blocks=32, hardware=WEAK,
                        max_batch_tokens=4096, host_tier_blocks=64)
    donor = Request(prompt_tokens=list(prefix),
                    sampling=SamplingParams(max_new_tokens=6))
    cluster.submit(donor)
    _drain(cluster, 1)

    victim = Request(prompt_tokens=prefix + [1, 2, 3],
                     sampling=SamplingParams(max_new_tokens=6))
    cluster.submit(victim)                 # waiting, plan -> local blocks
    cluster.engines[0].scheduler.bm.reclaim_cache()   # demotes under it
    assert cluster.tiers[0].demoted_blocks >= 4
    assert cluster.cancel(victim)
    for _ in range(4):
        cluster.step()
    _audit(cluster)

    # the tier survived the cancel: a fresh request still promotes and hits
    retry = Request(prompt_tokens=prefix + [4, 5, 6],
                    sampling=SamplingParams(max_new_tokens=6))
    cluster.submit(retry)
    _drain(cluster, 2)
    assert cluster.tiers[0].promoted_blocks >= 4
    assert retry.num_cached_prefix_tokens >= 128
    # and cancelling mid-decode afterwards stays leak-free too
    late = Request(prompt_tokens=prefix + [7, 8, 9],
                   sampling=SamplingParams(max_new_tokens=32))
    cluster.submit(late)
    for _ in range(40):
        cluster.step()
        if any(late.request_id == r.request_id
               for e in cluster.engines.values()
               for r in e.scheduler.decode.running):
            break
    assert cluster.cancel(late)
    for _ in range(4):
        cluster.step()
    _audit(cluster)


def test_crash_during_promote_no_leak(small_model):
    """The source node dies in the window between routing (plan points at
    its DRAM-resident prefix) and the promote+fetch: the plan degrades to
    recompute, outputs stay correct, zero blocks leak on either tier.

    Deterministic two-run pattern (PR 8): a dry run measures the clock at
    which the follower is waiting on the remote plan; the armed run crashes
    the source exactly then via ``repro.faults``."""
    cfg, params = small_model
    rng = np.random.RandomState(9)
    prefix = rng.randint(0, cfg.vocab_size, size=128).tolist()
    donor = prefix + rng.randint(0, cfg.vocab_size, size=8).tolist()
    follower = prefix + rng.randint(0, cfg.vocab_size, size=40).tolist()

    def play(faults=None, crash_probe=False):
        cluster = PDCluster(cfg, params, num_prefill=1, num_decode=1,
                            num_blocks=64, hardware=WEAK,
                            max_batch_tokens=4096, host_tier_blocks=64,
                            faults=faults, heartbeat_timeout_cycles=2.0)
        r0 = Request(prompt_tokens=list(donor),
                     sampling=SamplingParams(max_new_tokens=8))
        cluster.submit(r0)
        _drain(cluster, 1)
        cluster.engines[1].scheduler.bm.reclaim_cache()   # prefix -> DRAM
        r1 = Request(prompt_tokens=list(follower),
                     sampling=SamplingParams(max_new_tokens=6))
        cluster.submit(r1)
        if crash_probe:
            return cluster.clock           # the fetch would run NEXT step
        _drain(cluster, 2, max_steps=600)
        return cluster, r1

    t_crash = play(crash_probe=True) + 1.0
    cluster, r1 = play(faults=(FaultSpec("node_crash", at=t_crash,
                                         node_id=1),))
    assert 1 in cluster._dead, "the armed crash never fired"
    assert cluster.tiers[1].promoted_blocks == 0, \
        "promotion ran on a dead node"
    assert cluster.tiers[1].host.num_resident == 0, \
        "dead node's host tier still resident"
    assert not cluster.controller.prefix_index._node_host_blocks.get(1), \
        "index still advertises the dead node's DRAM"
    _audit(cluster)

    # recompute fallback is token-correct: compare to a fault-free cold run
    cold = PDCluster(cfg, params, num_prefill=1, num_decode=0,
                     num_blocks=64, hardware=WEAK, max_batch_tokens=4096,
                     prefix_reuse=False)
    c1 = Request(prompt_tokens=list(follower),
                 sampling=SamplingParams(max_new_tokens=6))
    cold.submit(c1)
    _drain(cold, 1)
    assert r1.output_tokens == c1.output_tokens, \
        "crash-degraded recompute changed tokens"


# ---------------------------------------------------------------------------
# regression: refcount-zero prefixes stay cached until pressure
# ---------------------------------------------------------------------------

def test_refcount_zero_prefix_rehits_after_free(small_model):
    """The satellite fix: ``BlockManager.free`` must PARK refcount-zero
    shared-prefix blocks (LRU), not free them — a re-request arriving after
    the last holder finished still hits instead of recomputing."""
    cfg, params = small_model
    rng = np.random.RandomState(13)
    prefix = rng.randint(0, cfg.vocab_size, size=96).tolist()
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=0,
                        num_blocks=64, hardware=WEAK, max_batch_tokens=4096)
    donor = Request(prompt_tokens=list(prefix),
                    sampling=SamplingParams(max_new_tokens=4))
    cluster.submit(donor)
    _drain(cluster, 1)
    bm = cluster.engines[0].scheduler.bm
    assert not bm._table, "donor's table survived its finish"
    assert bm.num_cached >= 3, "finished donor's blocks were not parked"

    late = Request(prompt_tokens=prefix + rng.randint(
        0, cfg.vocab_size, size=16).tolist(),
        sampling=SamplingParams(max_new_tokens=4))
    cluster.submit(late)
    _drain(cluster, 2)
    assert late.num_cached_prefix_tokens >= 96, \
        "re-request after free missed the parked prefix"
    assert bm.cached_reused >= 3, "the hit did not revive cached blocks"
    s = cluster.stats()
    assert s["prefix_tokens_reused"] >= 96
    _audit(cluster)


# ---------------------------------------------------------------------------
# sim/real parity: tier-routing decisions and span sequences
# ---------------------------------------------------------------------------

def _tier_spans(rec):
    return [(s.name, s.node_id, s.attrs["num_blocks"]) for s in rec.spans
            if s.name in ("tier_demote", "tier_promote")]


def test_sim_matches_engine_tier_decisions(small_model):
    """ClusterSim and PDCluster, same config / pool shape / prompts: the
    churn-driven demotion and the follower's source-side promotion must
    produce the same tier-routing decision (fetch the promoted prefix from
    the decode node, same hit length) and the same
    ``tier_demote``/``tier_promote`` span sequence."""
    cfg, params = small_model
    rng = np.random.RandomState(21)
    donor = rng.randint(0, cfg.vocab_size, size=128).tolist()
    churn = rng.randint(0, cfg.vocab_size, size=416).tolist()
    follower = donor + rng.randint(0, cfg.vocab_size, size=64).tolist()
    new_tokens = (8, 4, 4)

    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=1,
                        num_blocks=16, hardware=WEAK, max_batch_tokens=4096,
                        host_tier_blocks=64)
    rec_real = attach_tracer(cluster)
    rreqs = []
    for p, n in zip((donor, churn, follower), new_tokens):
        r = Request(prompt_tokens=list(p),
                    sampling=SamplingParams(max_new_tokens=n))
        cluster.submit(r)
        rreqs.append(r)
        _drain(cluster, len(rreqs))
    _audit(cluster)

    weak_p = dataclasses.replace(A100, peak_flops=1e7)
    sim = ClusterSim(cfg, "flowkv", num_prefill=1, num_decode=1,
                     hw_prefill=weak_p, hw_decode=weak_p,
                     blocks_per_node=16, host_tier_blocks=64)
    rec_sim = attach_tracer(sim)
    sreqs = [Request(prompt_tokens=list(p),
                     sampling=SamplingParams(max_new_tokens=n),
                     arrival_time=t)
             for (p, n), t in zip(zip((donor, churn, follower), new_tokens),
                                  (0.0, 400.0, 800.0))]
    sstats = sim.run(list(sreqs), t_max=500_000)
    sim.audit_blocks()

    # same tier-routing decision: the follower reuses the same hit length,
    # served by a remote fetch of the decode node's promoted prefix
    assert rreqs[2].num_cached_prefix_tokens == \
        sreqs[2].num_cached_prefix_tokens > 0, (
        rreqs[2].num_cached_prefix_tokens,
        sreqs[2].num_cached_prefix_tokens)
    assert cluster.stats()["prefix_fetches"] == \
        sstats["prefix_fetches"] >= 1
    # same span sequence: (name, node, blocks), in order
    real_spans, sim_spans = _tier_spans(rec_real), _tier_spans(rec_sim)
    assert real_spans == sim_spans, (
        f"tier span streams diverge:\n real={real_spans}\n  sim={sim_spans}")
    assert any(n == "tier_demote" for n, _, _ in real_spans)
    assert any(n == "tier_promote" for n, _, _ in real_spans)
