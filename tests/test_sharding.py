"""Sharding rules: logical-axis mapping + divisibility fallbacks, and a real
1-device-mesh execution of the jitted train/serve steps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_smoke_config
from repro.distributed import sharding as SH
from repro.distributed import steps as ST
from repro.launch.mesh import make_local_mesh
from repro.models.api import get_model, input_specs
from repro.training import optimizer as OPT


@pytest.fixture(scope="module")
def mesh11():
    return make_local_mesh(data=1, model=1)


def _fake_mesh(shape, names):
    """Mesh stand-in exposing axis_names/devices.shape for spec tests."""
    class M:
        axis_names = names
        class devices:
            pass
    M.devices = np.zeros(shape)
    return M


def test_spec_for_divisible_dims():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    assert SH.spec_for((256, 4096), ("batch", "embed"), mesh) == P("data")
    assert SH.spec_for((4096, 32, 128), ("embed", "heads", "head_dim"), mesh) \
        == P(None, "model")
    # vocab not divisible -> replicated
    assert SH.spec_for((49155, 1024), ("vocab", "embed"), mesh) == P()


def test_spec_for_fallback_kv_seq():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    # kv_heads=8 can't shard over model=16 -> kv_seq takes the model axis
    spec = SH.spec_for((32, 128, 32768, 8, 128),
                       ("layers", "batch", "kv_seq", "kv_heads", "head_dim"), mesh)
    assert spec == P(None, "data", "model")
    # kv=16 divides: kv_seq grabs model first (dim order), kv replicated
    spec2 = SH.spec_for((24, 128, 32768, 16, 64),
                        ("layers", "batch", "kv_seq", "kv_heads", "head_dim"), mesh)
    assert spec2 == P(None, "data", "model")


def test_paged_pool_never_shards_pages():
    """A FLOWKV pool's page dim is pinned to replication: page ids are global
    names shared by every shard's block manager and descriptor table, so the
    kv_seq fallback must never grab the block dim — even when num_blocks
    happens to divide the model axis."""
    mesh = _fake_mesh((16, 16), ("data", "model"))
    # num_blocks=4096 divides model=16: under the kv_seq fallback this dim
    # WOULD shard — kv_pages pins it replicated
    spec = SH.spec_for((4096, 32, 2, 16384), SH.PAGED_POOL_AXES, mesh)
    assert spec == P()
    # misdeclaring the page dim as kv_seq is exactly the regression guarded
    # against: it silently splits the page address space
    bad = SH.spec_for((4096, 32, 2, 16384),
                      ("kv_seq", "layers", None, None), mesh)
    assert bad == P("model")
    # the declared "kv_pages" rule must exist and be an empty candidate list
    # (intent recorded, not merely absent)
    assert SH.DEFAULT_RULES["kv_pages"] == ()


def test_spec_for_multipod_batch():
    mesh = _fake_mesh((2, 16, 16), ("pod", "data", "model"))
    assert SH.spec_for((256, 4096), ("batch", "seq"), mesh) == P(("pod", "data"))
    # batch=1 (long_500k): replicated
    assert SH.spec_for((1, 131072), ("batch", "seq"), mesh) == P()


def test_zero1_extends_specs():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    shapes = {"w": jax.ShapeDtypeStruct((4096, 16384), jnp.float32)}
    p_spec = {"w": P(None, "model")}
    z = ST.zero1_specs(shapes, p_spec, mesh)
    assert z["w"] == P("data", "model")


def test_train_step_runs_and_learns(mesh11):
    cfg = get_smoke_config("qwen3-1.7b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = OPT.init_state(params)
    train_step, state_spec = ST.make_train_step(
        model, mesh11, jax.eval_shape(lambda: params),
        opt_cfg=OPT.AdamWConfig(lr=1e-2, warmup_steps=1))
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 16))),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 16)))}
    batch["labels"] = batch["tokens"]     # learn to copy
    step = jax.jit(train_step, donate_argnums=(0,))
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
    assert int(state["step"]) == 8


def test_decode_step_jitted_consistency(mesh11):
    cfg = get_smoke_config("minitron-8b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.ones((2, 6), jnp.int32)
    logits, pre = model.prefill(params, {"tokens": toks})
    cache = model.init_cache(2, 10)
    cache["k"] = cache["k"].at[:, :, :6].set(pre["k"])
    cache["v"] = cache["v"].at[:, :, :6].set(pre["v"])
    cache["length"] = jnp.full((2,), 6, jnp.int32)
    decode = jax.jit(ST.make_decode_step(model, mesh11))
    lg1, c1 = decode(params, jnp.zeros((2,), jnp.int32), cache)
    lg2, _ = model.decode(params, jnp.zeros((2,), jnp.int32), cache)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2), rtol=1e-5, atol=1e-5)


def test_input_specs_all_cells():
    """input_specs must produce spec/axes trees for every applicable cell."""
    from repro.configs import ASSIGNED_ARCHS, SHAPES, shape_applicable
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape_name, (kind, seq, batch) in SHAPES.items():
            ok, _ = shape_applicable(cfg, shape_name)
            if not ok:
                continue
            specs, axes = input_specs(cfg, kind, seq, batch)
            flat_s = jax.tree.leaves(specs)
            assert flat_s, (arch, shape_name)
            for leaf in flat_s:
                assert all(d > 0 for d in leaf.shape)


def test_gradient_compression_error_feedback():
    params = {"w": jnp.ones((8, 8)) * 0.3}
    grads = {"w": jnp.asarray(np.random.RandomState(0).randn(8, 8), jnp.float32)}
    ef = OPT.init_error_feedback(params)
    q, scales, ef = OPT.compress_grads(grads, ef)
    deq = OPT.decompress_grads(q, scales)
    err1 = float(jnp.abs(deq["w"] - grads["w"]).max())
    assert q["w"].dtype == jnp.int8
    assert err1 < float(jnp.abs(grads["w"]).max()) / 64     # <= quant step
    # residual carries the rounding error
    np.testing.assert_allclose(np.asarray(ef["w"]),
                               np.asarray(grads["w"] - deq["w"]), rtol=1e-5, atol=1e-6)
