"""System-level behaviour tests: flash reference paths, prefix cache, HLO
analyzers, and a miniature multi-device dry-run (subprocess, 8 host devices)."""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import attend, causal_mask
from repro.models.flash import flash_attention
from repro.serving.prefix_cache import PrefixCacheIndex

REPO = pathlib.Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# flash reference paths (the dry-run's attention lowering)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kwargs", [
    dict(q_chunk=32, kv_chunk=16),
    dict(q_chunk=32, wedge=True),
    dict(window=12, q_chunk=16),
    dict(q_chunk=37, kv_chunk=53),          # non-divisible chunking
])
def test_flash_matches_direct(kwargs):
    B, S, H, KV, HD = 2, 100, 4, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, HD))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, HD))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, HD))
    window = kwargs.get("window", 0)
    ref = attend(q, k, v, causal_mask(S, S, 0, window)[None, None, None])
    out = flash_attention(q, k, v, causal=True, **kwargs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)


def test_flash_differentiable():
    B, S, H, KV, HD = 1, 64, 2, 2, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, HD))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, HD))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, HD))
    g = jax.grad(lambda q: flash_attention(q, k, v, q_chunk=16, kv_chunk=16).sum())(q)
    assert bool(jnp.all(jnp.isfinite(g)))


# ---------------------------------------------------------------------------
# prefix cache
# ---------------------------------------------------------------------------
def test_prefix_cache_block_granularity():
    idx = PrefixCacheIndex(block_size=4)
    idx.insert(0, list(range(10)))          # 2 full blocks cached
    assert idx.match(0, list(range(10))) == 8
    assert idx.match(0, list(range(6))) == 4
    assert idx.match(0, [99] * 8) == 0
    assert idx.match(1, list(range(10))) == 0
    best = idx.best_nodes(list(range(10)))
    assert best[0] == (0, 8)
    idx.evict_node(0)
    assert idx.match(0, list(range(10))) == 0


def test_prefix_cache_divergent_suffix():
    idx = PrefixCacheIndex(block_size=4)
    idx.insert(2, [1, 2, 3, 4, 5, 6, 7, 8])
    probe = [1, 2, 3, 4, 9, 9, 9, 9]
    assert idx.match(2, probe) == 4          # first block matches, second not


# ---------------------------------------------------------------------------
# HLO analyzers
# ---------------------------------------------------------------------------
def test_hlo_flops_counts_nested_scans():
    from repro.launch.hlo_flops import analyze_hlo
    A = jnp.zeros((128, 128))

    def inner(x, _):
        return x @ A, None

    def outer(x, _):
        y, _ = jax.lax.scan(inner, x, None, length=7)
        return y, None

    def f(x):
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    compiled = jax.jit(f).lower(jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    c = analyze_hlo(compiled.as_text())
    expected = 2 * 128 ** 3 * 21
    assert abs(c.flops - expected) / expected < 0.01
    assert c.unknown_trip_counts == 0


def test_collective_parse_on_psum():
    from repro.launch.hlo_flops import analyze_hlo
    # single-device psum lowers away; just exercise the parser on real HLO
    compiled = jax.jit(lambda x: x * 2 + 1).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    c = analyze_hlo(compiled.as_text())
    assert c.collective_total == 0


# ---------------------------------------------------------------------------
# miniature dry-run: 8 forced host devices, (2, 2, 2) pod mesh, smoke arch
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_mini_multipod_dryrun():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, json
        from jax.sharding import NamedSharding
        from repro.configs import get_smoke_config
        from repro.distributed import sharding as SH, steps as ST
        from repro.models.api import get_model, input_specs
        from repro.training import optimizer as OPT

        cfg = get_smoke_config("minitron-8b")
        model = get_model(cfg)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        state = ST.abstract_train_state(model)
        train_step, state_spec = ST.make_train_step(model, mesh, state["params"])
        specs, axes = input_specs(cfg, "train", 16, 8)
        b_spec = SH.tree_specs(specs, axes, mesh)
        ns = lambda s: NamedSharding(mesh, s)
        fn = jax.jit(train_step,
                     in_shardings=(jax.tree.map(ns, state_spec), jax.tree.map(ns, b_spec)),
                     out_shardings=(jax.tree.map(ns, state_spec), None))
        compiled = fn.lower(state, specs).compile()
        # it must ACTUALLY run on the 8-device mesh too
        params = model.init(jax.random.PRNGKey(0))
        real = OPT.init_state(params)
        batch = {"tokens": jnp.zeros((8, 16), jnp.int32),
                 "labels": jnp.zeros((8, 16), jnp.int32)}
        out_state, metrics = fn(real, batch)
        print(json.dumps({"loss": float(metrics["loss"]),
                          "devices": jax.device_count()}))
    """)
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": str(REPO / "src")}, timeout=420)
    assert proc.returncode == 0, proc.stderr[-3000:]
    result = json.loads(proc.stdout.strip().splitlines()[-1])
    assert result["devices"] == 8
    assert result["loss"] > 0 and result["loss"] < 20
