"""FlowKVClient serving API: streaming handles, cancel, role lifecycle.

Correctness bar (same as test_cluster): everything the streaming path emits
must be token-identical to monolithic generation.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core.block_manager import BlockManager
from repro.core.scheduler import (AdmissionPolicy, GlobalController,
                                  HybridScheduler, ModelCost, NodeHandle)
from repro.models import transformer as T
from repro.models.api import get_model
from repro.serving.api import FlowKVClient
from repro.serving.cluster import PDCluster
from repro.serving.request import Request, RequestState, SamplingParams
from repro.sim.hardware import A100


@pytest.fixture(scope="module")
def small_model():
    cfg = get_smoke_config("qwen3-1.7b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, n=4, seed=0):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(0, cfg.vocab_size, size=rng.randint(5, 30)))
            for _ in range(n)]


def _reference(cfg, params, prompts, steps=6):
    refs = {}
    for p in prompts:
        out = T.greedy_generate(params, cfg, jnp.asarray([p], jnp.int32), steps)
        refs[tuple(p)] = [int(x) for x in out[0]]
    return refs


# ---------------------------------------------------------------------------
# streaming
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("schedule", ["flowkv", "layerwise", "blockwise"])
def test_streaming_matches_monolithic(small_model, schedule):
    """Interleaved token streams == monolithic generation, all 3 schedules."""
    cfg, params = small_model
    prompts = _prompts(cfg)
    refs = _reference(cfg, params, prompts)
    client = FlowKVClient(cfg, params, num_prefill=1, num_decode=1,
                          num_blocks=128, transfer_schedule=schedule)
    handles = [client.submit(p, SamplingParams(max_new_tokens=6))
               for p in prompts]
    streams = {h.request_id: [] for h in handles}
    iters = {h.request_id: h.tokens() for h in handles}
    saw_partial = False
    while iters:   # round-robin: one token per live stream per pass
        for rid, it in list(iters.items()):
            try:
                streams[rid].append(next(it))
            except StopIteration:
                del iters[rid]
                continue
            handle = next(h for h in handles if h.request_id == rid)
            if not handle.done:
                saw_partial = True   # token delivered BEFORE the request finished
    assert saw_partial, "streaming never yielded a token mid-flight"
    for h in handles:
        assert streams[h.request_id] == refs[tuple(h.request.prompt_tokens)]
        assert h.request.state is RequestState.FINISHED


def test_result_and_stats_breakdown(small_model):
    cfg, params = small_model
    [prompt] = _prompts(cfg, n=1, seed=11)
    ref = _reference(cfg, params, [prompt])[tuple(prompt)]
    client = FlowKVClient(cfg, params, num_prefill=1, num_decode=1, num_blocks=64)
    h = client.submit(prompt, SamplingParams(max_new_tokens=6))
    assert h.result() == ref
    s = h.stats()
    # the full queue->prefill->transfer->decode split must be populated
    for key in ("queue_s", "prefill_s", "transfer_s", "decode_s",
                "ttft_s", "e2e_s"):
        assert s[key] is not None, key
        assert s[key] >= 0.0, (key, s[key])
    # first token is emitted by PREFILL: TTFT ends at prefill_end, before decode
    req = h.request
    assert req.first_token_time == req.prefill_end
    assert s["e2e_s"] >= s["ttft_s"]
    assert client.stats()["mean_ttft_cycles"] > 0.0


def test_run_wrapper_equals_streaming(small_model):
    """PDCluster.run (compat wrapper) and the handle API agree token-for-token."""
    cfg, params = small_model
    prompts = _prompts(cfg, n=3, seed=21)
    cluster = PDCluster(cfg, params, num_prefill=1, num_decode=1, num_blocks=128)
    done = cluster.run([Request(prompt_tokens=list(p),
                                sampling=SamplingParams(max_new_tokens=5))
                        for p in prompts], max_cycles=80)
    batch = {tuple(r.prompt_tokens): r.output_tokens for r in done}
    client = FlowKVClient(cfg, params, num_prefill=1, num_decode=1, num_blocks=128)
    for p in prompts:
        h = client.submit(p, SamplingParams(max_new_tokens=5))
        assert h.result() == batch[tuple(p)]


# ---------------------------------------------------------------------------
# cancel
# ---------------------------------------------------------------------------
def test_cancel_frees_blocks_on_decode_node(small_model):
    cfg, params = small_model
    [prompt] = _prompts(cfg, n=1, seed=31)
    client = FlowKVClient(cfg, params, num_prefill=1, num_decode=1, num_blocks=64)
    h = client.submit(prompt, SamplingParams(max_new_tokens=200))
    while h.request.state is not RequestState.DECODING:
        client.step()
    dnode = client.cluster.engines[h.request.decode_node]
    assert dnode.scheduler.bm.owns(h.request_id)   # KV landed on D
    assert h.cancel()
    assert h.cancelled and h.done
    for eng in client.cluster.engines.values():
        assert not eng.scheduler.bm.owns(h.request_id)
        eng.scheduler.bm.check_invariants()
        # refcount-zero blocks PARK in the LRU cache (reusable, not leaked):
        # free_capacity is the no-leak audit, num_free alone undercounts
        assert eng.scheduler.bm.free_capacity == 64, "cancel leaked blocks"
    assert not h.cancel()                          # idempotent: already terminal
    # the stream ends cleanly instead of hanging
    assert list(h.tokens()) == h.request.output_tokens


def test_cancel_queued_request_before_prefill(small_model):
    cfg, params = small_model
    rng = np.random.RandomState(41)
    long_prompt = rng.randint(0, cfg.vocab_size, size=40).tolist()
    [other] = _prompts(cfg, n=1, seed=42)
    ref = _reference(cfg, params, [other], steps=4)[tuple(other)]
    # token budget 8: the first request's chunk exhausts it, so the second
    # sits in the prefill WAITING queue across cycles — cancellable there
    client = FlowKVClient(cfg, params, num_prefill=1, num_decode=1,
                          num_blocks=64, max_batch_tokens=8)
    h1 = client.submit(long_prompt, SamplingParams(max_new_tokens=4))
    h2 = client.submit(other, SamplingParams(max_new_tokens=4))
    client.step()
    assert h2.request.state is RequestState.WAITING
    pnode = client.cluster.engines[h2.request.prefill_node]
    assert h2.request in pnode.scheduler.prefill.waiting
    assert h2.cancel()
    assert h2.request not in pnode.scheduler.prefill.waiting
    assert list(h2.tokens()) == []                  # never produced anything
    # the cluster keeps serving the other request after the cancel
    ref1 = _reference(cfg, params, [long_prompt], steps=4)[tuple(long_prompt)]
    assert h1.result() == ref1
    for eng in client.cluster.engines.values():
        assert not eng.scheduler.bm.owns(h2.request_id)
        assert eng.scheduler.bm.free_capacity == 64
    # run() compat wrapper terminates even when some requests were cancelled
    assert client.cluster.submitted == 2
    assert len(client.cluster.finished) + len(client.cluster.cancelled) == 2


# ---------------------------------------------------------------------------
# node lifecycle: set_role
# ---------------------------------------------------------------------------
def test_set_role_flip_keeps_generation_token_correct(small_model):
    cfg, params = small_model
    prompts = _prompts(cfg, n=6, seed=51)
    refs = _reference(cfg, params, prompts, steps=5)
    client = FlowKVClient(cfg, params, num_prefill=1, num_decode=2,
                          num_blocks=128)
    first = [client.submit(p, SamplingParams(max_new_tokens=5))
             for p in prompts[:3]]
    for _ in range(2):
        client.step()                       # some work lands on the old roles
    # flip decode node 2 into a prefill node mid-run; in-flight decode on it
    # (if any) must still finish from the same pool
    assert client.set_role(2, "prefill")
    assert client.controller.nodes[2].role == "prefill"
    assert any(e.kind == "set_role" for e in client.controller.events)
    second = [client.submit(p, SamplingParams(max_new_tokens=5))
              for p in prompts[3:]]
    client.drain(max_cycles=200)
    for h in first + second:
        assert h.request.state is RequestState.FINISHED
        assert h.request.output_tokens == refs[tuple(h.request.prompt_tokens)]
    # no leaks across the flip
    for eng in client.cluster.engines.values():
        eng.scheduler.bm.check_invariants()
        assert eng.scheduler.bm.free_capacity == 128


def test_checkpoint_restores_roles_and_cancelled(tmp_path, small_model):
    from repro.serving.checkpoint import load_cluster, save_cluster
    cfg, params = small_model
    client = FlowKVClient(cfg, params, num_prefill=1, num_decode=2, num_blocks=64)
    client.set_role(2, "prefill")
    client.controller.nodes[2].home_role = "decode"
    h = client.submit(list(range(8)), SamplingParams(max_new_tokens=4))
    assert h.cancel()
    save_cluster(client.cluster, str(tmp_path / "ckpt"))

    c2 = PDCluster(cfg, params, num_prefill=1, num_decode=2, num_blocks=64)
    load_cluster(c2, str(tmp_path / "ckpt"))
    assert c2.controller.nodes[2].role == "prefill"          # flip survives
    assert c2.controller.nodes[2].home_role == "decode"      # flip-back armed
    assert c2.engines[2].scheduler.priority == "prefill"
    assert len(c2.cancelled) == 1
    assert c2.cancelled[0].state is RequestState.CANCELLED


def test_checkpoint_roundtrips_rejected_and_spilled(tmp_path, small_model):
    """A checkpoint taken mid-swap keeps the spilled KV and the rejected
    bookkeeping — restore does not silently drop either."""
    from repro.serving.checkpoint import load_cluster, save_cluster
    cfg, params = small_model
    rng = np.random.RandomState(9)
    prompts = [rng.randint(0, cfg.vocab_size, size=20).tolist()
               for _ in range(2)]
    client = FlowKVClient(cfg, params, num_prefill=1, num_decode=1,
                          num_blocks=3,
                          admission=AdmissionPolicy(ttft_slo_s=1e-12,
                                                    reject_factor=1.0))
    # admission armed with an impossible SLO -> this submit is REJECTED
    rej = client.submit(prompts[0], SamplingParams(max_new_tokens=2))
    assert rej.rejected
    # disarm the gate, then pressure the pool until a request is SWAPPED
    client.controller.admission = None
    handles = [client.submit(p, SamplingParams(max_new_tokens=20))
               for p in prompts]
    swapped = None
    for _ in range(400):
        client.step()
        swapped = next((h for h in handles
                        if h.request.state is RequestState.SWAPPED), None)
        if swapped is not None or all(h.done for h in handles):
            break
    assert swapped is not None
    dnode = client.cluster.engines[swapped.request.decode_node]
    assert swapped.request_id in dnode.spilled
    save_cluster(client.cluster, str(tmp_path / "ckpt"))

    c2 = PDCluster(cfg, params, num_prefill=1, num_decode=1, num_blocks=3)
    load_cluster(c2, str(tmp_path / "ckpt"))
    assert len(c2.rejected) == 1
    assert c2.rejected[0].state is RequestState.REJECTED
    assert c2.rejected[0].retry_after == rej.retry_after
    d2 = c2.engines[swapped.request.decode_node]
    assert swapped.request_id in d2.spilled
    k, v, length = d2.spilled[swapped.request_id]
    k0, v0, length0 = dnode.spilled[swapped.request_id]
    assert length == length0
    np.testing.assert_allclose(np.asarray(k, np.float32),
                               np.asarray(k0, np.float32))


def test_set_role_flip_back_and_validation(small_model):
    cfg, params = small_model
    client = FlowKVClient(cfg, params, num_prefill=1, num_decode=1, num_blocks=64)
    assert client.set_role(1, "prefill")
    assert not client.set_role(1, "prefill")      # no-op: already prefill
    assert client.set_role(1, "decode")
    with pytest.raises(ValueError):
        client.set_role(1, "bogus")


# ---------------------------------------------------------------------------
# load-triggered flip policy (controller-level, no model needed)
# ---------------------------------------------------------------------------
def _controller(num_p, num_d, **kw):
    mc = ModelCost(flops_per_token=2 * 8e9, kv_bytes_per_token=131072.0,
                   weight_bytes=16e9)
    gc = GlobalController(mc, block_size=32, **kw)
    for i in range(num_p + num_d):
        role = "prefill" if i < num_p else "decode"
        sched = HybridScheduler(i, BlockManager(512, 32), max_batch_tokens=4096)
        gc.register_node(NodeHandle(i, role, host_id=i // 2, hardware=A100,
                                    scheduler=sched))
    return gc


def test_role_flip_policy_reassigns_and_reverts():
    gc = _controller(1, 3, role_flip=True)
    for _ in range(40):                       # P flooded, D idle -> imbalance
        gc.nodes[0].scheduler.enqueue_prefill(
            Request(prompt_tokens=list(range(2000)),
                    sampling=SamplingParams(max_new_tokens=8)))
    gc.nodes[0].scheduler.last_token_budget_used = 1.0
    gc.nodes[0].scheduler.last_compute_util = 1.0
    for _ in range(30):
        gc.step()
        if len(gc.prefill_nodes()) > 1:
            break
    assert len(gc.prefill_nodes()) > 1, "flip policy never reassigned a decode node"
    assert any(e.kind == "set_role" for e in gc.events)
    assert len(gc.decode_nodes()) >= 1, "flip policy stranded the decode role"
    # residency: the flip must hold for the anti-thrash window even though the
    # diluted hot-role score reads "normal" right after the flip
    flipped = [n for n in gc.prefill_nodes() if n.home_role == "decode"]
    for _ in range(gc.role_switch_cycles - 1):
        gc.step()
        for n in flipped:
            assert n.role == "prefill", "flip reverted before its residency"
    # load clears -> flipped nodes return to their home role
    gc.nodes[0].scheduler.prefill.waiting.clear()
    gc.nodes[0].scheduler.last_token_budget_used = 0.0
    gc.nodes[0].scheduler.last_compute_util = 0.0
    for _ in range(40):
        gc.step()
        if len(gc.decode_nodes()) == 3:
            break
    assert len(gc.decode_nodes()) == 3, "flipped nodes never reverted"
    assert all(n.home_role is None for n in gc.nodes.values())


# ---------------------------------------------------------------------------
# overload admission: REJECTED + retry-after through the client
# ---------------------------------------------------------------------------
def test_overload_burst_rejected_with_retry_after(small_model):
    """An undersized cluster early-rejects part of a burst; rejected handles
    are terminal, carry retry-after, and resubmission after back-off works."""
    cfg, params = small_model
    prompts = _prompts(cfg, n=8, seed=71)
    client = FlowKVClient(cfg, params, num_prefill=1, num_decode=1,
                          num_blocks=128, max_batch_tokens=8,
                          admission=AdmissionPolicy(max_queue_depth=2,
                                                    max_defer_cycles=3,
                                                    retry_after_floor_s=2.0))
    handles = [client.submit(p, SamplingParams(max_new_tokens=3))
               for p in prompts]
    client.drain(max_cycles=500)
    rejected = [h for h in handles if h.rejected]
    served = [h for h in handles if not h.rejected]
    assert rejected, "the admission gate never fired on this burst"
    assert served, "the gate must not reject everything"
    for h in rejected:
        assert h.done and h.state is RequestState.REJECTED
        assert h.retry_after is not None and h.retry_after >= 2.0
        s = h.stats()
        assert s["retry_after_s"] == h.retry_after
        assert s["reject_reason"]
        assert list(h.tokens()) == []          # stream ends cleanly, empty
        assert not h.cancel()                  # already terminal
    for h in served:
        assert h.request.state is RequestState.FINISHED
    # bookkeeping: every submission accounted for, nothing leaked
    st = client.stats()
    assert st["rejected"] == len(rejected) and st["deferred"] == 0
    assert client.cluster.submitted == len(prompts)
    for eng in client.cluster.engines.values():
        eng.scheduler.bm.check_invariants()
        assert eng.scheduler.bm.free_capacity == 128
    # back-off honored -> resubmission of the same prompts is admitted
    for _ in range(3):
        client.step()
    retries = [client.submit(h.request.prompt_tokens,
                             SamplingParams(max_new_tokens=3))
               for h in rejected]
    client.drain(max_cycles=500)
    assert all(h.request.state is RequestState.FINISHED for h in retries)


def test_deferred_request_admitted_once_load_drains(small_model):
    """Transient pressure defers (not rejects); the parked request finishes
    with correct tokens once earlier work drains."""
    cfg, params = small_model
    prompts = _prompts(cfg, n=3, seed=81)
    refs = _reference(cfg, params, prompts, steps=3)
    client = FlowKVClient(cfg, params, num_prefill=1, num_decode=1,
                          num_blocks=128, max_batch_tokens=8,
                          admission=AdmissionPolicy(max_queue_depth=2,
                                                    max_defer_cycles=200))
    handles = [client.submit(p, SamplingParams(max_new_tokens=3))
               for p in prompts]
    assert handles[-1].request in client.controller.deferred
    client.drain(max_cycles=500)
    for h in handles:
        assert h.request.state is RequestState.FINISHED
        assert h.request.output_tokens == refs[tuple(h.request.prompt_tokens)]
    assert client.stats()["rejected"] == 0


# ---------------------------------------------------------------------------
# spill path: decode preemption survives with token-identical output
# ---------------------------------------------------------------------------
def test_decode_preemption_spill_resume_token_identical(small_model):
    """num_blocks=3 forces decode KV pressure: one request gets SWAPPED
    (KV spilled off-pool), resumes later, and still matches monolithic
    generation exactly — and nothing leaks."""
    cfg, params = small_model
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, cfg.vocab_size, size=20).tolist()
               for _ in range(2)]
    refs = _reference(cfg, params, prompts, steps=20)
    client = FlowKVClient(cfg, params, num_prefill=1, num_decode=1,
                          num_blocks=3)
    handles = [client.submit(p, SamplingParams(max_new_tokens=20))
               for p in prompts]
    swapped_cycles = 0
    for _ in range(400):
        client.step()
        swapped_cycles += sum(
            1 for h in handles if h.request.state is RequestState.SWAPPED)
        if all(h.done for h in handles):
            break
    assert swapped_cycles > 0, "pool was never pressured into a spill"
    for h in handles:
        assert h.request.state is RequestState.FINISHED
        assert h.request.output_tokens == refs[tuple(h.request.prompt_tokens)]
        assert h.request.retries == 0          # spill is not the fault path
    for eng in client.cluster.engines.values():
        eng.scheduler.bm.check_invariants()
        assert eng.scheduler.bm.free_capacity == 3, "spill/resume leaked blocks"
        assert not eng.spilled, "saved spill was never consumed"


def test_cancel_while_swapped_discards_spill(small_model):
    """Cancelling a SWAPPED request drops its saved KV via on_discard."""
    cfg, params = small_model
    rng = np.random.RandomState(9)
    prompts = [rng.randint(0, cfg.vocab_size, size=20).tolist()
               for _ in range(2)]
    client = FlowKVClient(cfg, params, num_prefill=1, num_decode=1,
                          num_blocks=3)
    handles = [client.submit(p, SamplingParams(max_new_tokens=20))
               for p in prompts]
    target = None
    for _ in range(400):
        client.step()
        target = next((h for h in handles
                       if h.request.state is RequestState.SWAPPED), None)
        if target is not None:
            break
        if all(h.done for h in handles):
            break
    assert target is not None, "never observed a swapped request"
    dnode = client.cluster.engines[target.request.decode_node]
    assert target.request_id in dnode.spilled
    assert target.cancel()
    assert target.request_id not in dnode.spilled
    client.drain(max_cycles=400)
    for eng in client.cluster.engines.values():
        eng.scheduler.bm.check_invariants()
        assert eng.scheduler.bm.free_capacity == 3


def test_stats_expose_transfer_dispatch_counts(small_model):
    """The serving API surfaces the metric the paper optimizes: transport
    calls AND fused-kernel dispatches (always 1 per plan) per request."""
    cfg, params = small_model
    [prompt] = _prompts(cfg, n=1, seed=61)
    client = FlowKVClient(cfg, params, num_prefill=1, num_decode=1,
                          num_blocks=64, transfer_schedule="layerwise")
    h = client.submit(prompt, SamplingParams(max_new_tokens=3))
    h.result()
    s = h.stats()
    assert s["num_dispatches"] == 1          # one fused dispatch per plan
    assert s["num_calls"] >= 2 * 2           # layerwise: 2*L per block
    assert s["num_calls"] == client.cluster.transfers[-1].num_calls
    assert client.stats()["mean_transfer_dispatches"] == 1.0
